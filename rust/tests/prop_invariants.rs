//! Property-based invariants (randomized, deterministic seeds) over the
//! core subsystems: graph hashing, substitution equivalence, the inner
//! search's d=1 optimality for additive objectives, cost-model additivity,
//! and JSON round-trips.

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::cost::CostFunction;
use eadgo::engine::ReferenceEngine;
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::{Activation, Graph, NodeId, OpKind, PortRef};
use eadgo::search::{exhaustive_search, inner_search, random_assignment, OptimizerContext};
use eadgo::subst::RuleSet;
use eadgo::tensor::Tensor;
use eadgo::util::json::{self, Json};
use eadgo::util::prop::{assert_close, check, default_cases};
use eadgo::util::rng::Rng;

/// Generate a random small valid CNN-ish graph: a chain of conv/pool/relu
/// with an occasional parallel branch + concat.
fn random_graph(rng: &mut Rng) -> Graph {
    let mut g = Graph::new();
    let res = 8 + 2 * rng.below(4); // 8..14
    let mut c = 1 + rng.below(3); // 1..3
    let x = g.add1(OpKind::Input { shape: vec![1, c, res, res] }, &[], "x");
    let mut cur = x;
    let mut cur_res = res;
    let depth = 1 + rng.below(3);
    let mut seed = 100 + rng.below(1000) as u64;
    for d in 0..depth {
        match rng.below(4) {
            0 | 1 => {
                // conv (+ optional relu)
                let k = 1 + rng.below(4);
                let ksz = *rng.choose(&[1usize, 3]);
                let pad = ksz / 2;
                seed += 1;
                let w = g.add1(OpKind::weight(vec![k, c, ksz, ksz], seed), &[], "w");
                cur = g.add1(
                    OpKind::Conv2d {
                        stride: (1, 1),
                        pad: (pad, pad),
                        act: Activation::None,
                        has_bias: false,
                        has_residual: false,
                    },
                    &[cur, w],
                    &format!("conv{d}"),
                );
                if rng.bool() {
                    cur = g.add1(OpKind::Relu, &[cur], "relu");
                }
                c = k;
            }
            2 => {
                // parallel 2-branch + concat
                let k1 = 1 + rng.below(3);
                let k2 = 1 + rng.below(3);
                seed += 2;
                let w1 = g.add1(OpKind::weight(vec![k1, c, 3, 3], seed - 1), &[], "w1");
                let w2 = g.add1(OpKind::weight(vec![k2, c, 3, 3], seed), &[], "w2");
                let conv_attrs = OpKind::Conv2d {
                    stride: (1, 1),
                    pad: (1, 1),
                    act: Activation::Relu,
                    has_bias: false,
                    has_residual: false,
                };
                let c1 = g.add1(conv_attrs.clone(), &[cur, w1], "b1");
                let c2 = g.add1(conv_attrs, &[cur, w2], "b2");
                cur = g.add1(OpKind::Concat { axis: 1 }, &[c1, c2], "cat");
                c = k1 + k2;
            }
            _ => {
                if cur_res >= 4 {
                    cur = g.add1(
                        OpKind::MaxPool { k: (2, 2), stride: (2, 2), pad: (0, 0) },
                        &[cur],
                        "pool",
                    );
                    cur_res /= 2;
                }
            }
        }
    }
    g.outputs = vec![PortRef::of(cur)];
    g.validate().expect("generator produced invalid graph");
    g
}

#[test]
fn prop_substitutions_preserve_semantics() {
    let rules = RuleSet::standard();
    let eng = ReferenceEngine::new();
    let reg = AlgorithmRegistry::new();
    check("subst_equivalence", default_cases(), |rng| {
        let g = random_graph(rng);
        let shape = match &g.node(NodeId(0)).op {
            OpKind::Input { shape } => shape.clone(),
            _ => unreachable!(),
        };
        let x = Tensor::rand(&shape, rng, -1.0, 1.0);
        let a = Assignment::default_for(&g, &reg);
        let base = eng
            .run(&g, &a, std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?
            .outputs
            .remove(0);
        for (ng, rule) in rules.neighbors(&g).map_err(|e| e.to_string())? {
            let na = Assignment::default_for(&ng, &reg);
            let out = eng
                .run(&ng, &na, std::slice::from_ref(&x))
                .map_err(|e| format!("{rule}: {e}"))?
                .outputs
                .remove(0);
            assert_close(base.data(), out.data(), 1e-3, 1e-3)
                .map_err(|e| format!("{rule}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_hash_invariant_under_dead_nodes_and_names() {
    check("hash_invariance", default_cases(), |rng| {
        let g = random_graph(rng);
        let h0 = graph_hash(&g);
        // renames don't matter
        let mut g2 = g.clone();
        for id in g2.ids().collect::<Vec<_>>() {
            g2.node_mut(id).name = format!("renamed{}", id.0);
        }
        if graph_hash(&g2) != h0 {
            return Err("rename changed hash".into());
        }
        // dead nodes don't matter after compact
        let mut g3 = g.clone();
        let d = g3.add1(OpKind::weight(vec![2, 2], 999), &[], "dead");
        let _ = g3.add1(OpKind::Relu, &[d], "dead2");
        g3.compact();
        if graph_hash(&g3) != h0 {
            return Err("dead code changed hash".into());
        }
        Ok(())
    });
}

#[test]
fn prop_inner_d1_optimal_for_additive() {
    check("inner_d1_optimal", 24, |rng| {
        let g = random_graph(rng);
        let ctx = OptimizerContext::offline_default();
        let (table, _) = ctx.table_for(&g).map_err(|e| e.to_string())?;
        let base = Assignment::default_for(&g, ctx.reg());
        let w = rng.f64();
        for cf in [CostFunction::Time, CostFunction::Energy, CostFunction::linear(w)] {
            let start = random_assignment(&table, &base, rng);
            let greedy = inner_search(&table, &cf, 1, start.clone()).map_err(|e| e.to_string())?;
            let Some(exact) = exhaustive_search(&table, &cf, &base, 200_000) else {
                return Ok(()); // space too large for ground truth; skip case
            };
            let gv = cf.eval(&greedy.cost);
            let ev = cf.eval(&exact.cost);
            if (gv - ev).abs() > 1e-9 * ev.max(1.0) {
                return Err(format!("d=1 found {gv}, exhaustive {ev} ({})", cf.describe()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_inner_d2_never_worse_than_d1() {
    check("inner_d2_dominates", 16, |rng| {
        let g = random_graph(rng);
        let ctx = OptimizerContext::offline_default();
        let (table, _) = ctx.table_for(&g).map_err(|e| e.to_string())?;
        let base = Assignment::default_for(&g, ctx.reg());
        for cf in [CostFunction::Power, CostFunction::Product { w: 0.5 }] {
            let start = random_assignment(&table, &base, rng);
            let d1 = inner_search(&table, &cf, 1, start.clone()).map_err(|e| e.to_string())?;
            let d2 = inner_search(&table, &cf, 2, start).map_err(|e| e.to_string())?;
            if cf.eval(&d2.cost) > cf.eval(&d1.cost) + 1e-9 {
                return Err(format!(
                    "d=2 ({}) worse than d=1 ({}) for {}",
                    cf.eval(&d2.cost),
                    cf.eval(&d1.cost),
                    cf.describe()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cost_table_swap_matches_full_eval() {
    // eval_swap (the O(1) incremental used by the inner search hot path)
    // must agree with a full re-evaluation — across every (algorithm,
    // frequency) option, not just the nominal slab.
    check("eval_swap_consistent", 24, |rng| {
        let g = random_graph(rng);
        let oracle = eadgo::cost::CostOracle::offline_default();
        let shapes = g.infer_shapes().map_err(|e| e.to_string())?;
        let mut freqs = vec![eadgo::energysim::FreqId::NOMINAL];
        freqs.extend_from_slice(oracle.dvfs_freqs());
        let (table, _) = oracle.table_for_freqs(&g, &shapes, &freqs);
        let base = Assignment::default_for(&g, oracle.reg());
        let a = random_assignment(&table, &base, rng);
        let full = table.eval(&a);
        for id in table.costed_ids() {
            for (f, slab) in table.freq_options(id) {
                for &(algo, _) in slab.iter() {
                    let inc = table.eval_swap(full, &a, id, algo, *f).map_err(|e| e.to_string())?;
                    let mut a2 = a.clone();
                    a2.set(id, algo);
                    a2.set_freq(id, *f);
                    let truth = table.eval(&a2);
                    if (inc.time_ms - truth.time_ms).abs() > 1e-9 * truth.time_ms.max(1.0)
                        || (inc.energy_j - truth.energy_j).abs() > 1e-9 * truth.energy_j.max(1.0)
                    {
                        return Err(format!("swap mismatch at node {}", id.0));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_additive_model_sums_node_costs() {
    // Graph cost == sum over nodes for any assignment (paper §3.2).
    check("cost_additivity", 24, |rng| {
        let g = random_graph(rng);
        let ctx = OptimizerContext::offline_default();
        let (table, _) = ctx.table_for(&g).map_err(|e| e.to_string())?;
        let base = Assignment::default_for(&g, ctx.reg());
        let a = random_assignment(&table, &base, rng);
        let gc = table.eval(&a);
        let mut t = 0.0;
        let mut e = 0.0;
        for id in table.costed_ids() {
            let algo = a.get(id).unwrap();
            let (_, c) = table
                .node_options(id)
                .iter()
                .find(|(x, _)| *x == algo)
                .copied()
                .unwrap();
            t += c.time_ms;
            e += c.energy_j();
        }
        if (gc.time_ms - t).abs() > 1e-9 * t.max(1.0) || (gc.energy_j - e).abs() > 1e-9 * e.max(1.0)
        {
            return Err("additivity violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.f64() * 2e6 - 1e6 * rng.f64()).round() / 128.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.choose(&['a', 'β', '"', '\\', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(4) {
                    o.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    check("json_roundtrip", 200, |rng| {
        let v = random_json(rng, 3);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = json::parse(&text).map_err(|e| e.to_string())?;
            if back != v {
                return Err(format!("roundtrip mismatch: {text}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_compact_preserves_semantics() {
    let eng = ReferenceEngine::new();
    let reg = AlgorithmRegistry::new();
    check("compact_preserves", 24, |rng| {
        let g = random_graph(rng);
        let shape = match &g.node(NodeId(0)).op {
            OpKind::Input { shape } => shape.clone(),
            _ => unreachable!(),
        };
        let x = Tensor::rand(&shape, rng, -1.0, 1.0);
        let a = Assignment::default_for(&g, &reg);
        let base = eng
            .run(&g, &a, std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?
            .outputs
            .remove(0);
        // add dead nodes then compact
        let mut g2 = g.clone();
        let d = g2.add1(OpKind::weight(vec![3, 3], 777), &[], "dead");
        let _ = g2.add1(OpKind::Relu, &[d], "dead_relu");
        g2.compact();
        let a2 = Assignment::default_for(&g2, &reg);
        let out = eng
            .run(&g2, &a2, std::slice::from_ref(&x))
            .map_err(|e| e.to_string())?
            .outputs
            .remove(0);
        assert_close(base.data(), out.data(), 1e-6, 1e-6)
    });
}

#[test]
fn prop_freq_monotonicity() {
    // DVFS invariant (ideal model): raising the core clock never slows a
    // node down (time non-increasing in f) and never lowers its draw
    // (power non-decreasing in f) — for random work shapes across every
    // algorithm profile.
    use eadgo::algo::Algorithm;
    use eadgo::energysim::{EnergyModel, FreqId, Work};
    let algos = [
        Algorithm::ConvIm2col,
        Algorithm::ConvDirect,
        Algorithm::ConvWinograd,
        Algorithm::Conv1x1Gemm,
        Algorithm::DwDirect,
        Algorithm::DwWinograd,
        Algorithm::GemmBlocked,
        Algorithm::GemmNaive,
        Algorithm::Passthrough,
    ];
    check("freq_monotonicity", default_cases(), |rng| {
        let m = EnergyModel::v100(7 + rng.below(1000) as u64);
        // Spread work across regimes: tiny (launch-bound) to huge
        // (compute-bound), with random arithmetic intensity.
        let flops = 10f64.powf(3.0 + 7.0 * rng.f64());
        let bytes = 10f64.powf(3.0 + 5.0 * rng.f64());
        let w = Work { flops, bytes };
        let algo = *rng.choose(&algos);
        let mut prev: Option<(f64, f64)> = None;
        for st in &m.spec.freq_states {
            let c = m.ideal_cost_at(&w, algo, FreqId(st.mhz));
            if let Some((pt, pp)) = prev {
                if c.time_ms > pt * (1.0 + 1e-12) {
                    return Err(format!("{algo:?}: time rose with clock ({pt} -> {})", c.time_ms));
                }
                if c.power_w < pp * (1.0 - 1e-12) {
                    return Err(format!("{algo:?}: power fell with clock ({pp} -> {})", c.power_w));
                }
            }
            prev = Some((c.time_ms, c.power_w));
        }
        Ok(())
    });
}

#[test]
fn prop_inner_d1_optimal_over_joint_freq_space() {
    // The paper's d=1 optimality claim survives the DVFS extension: the
    // objective stays separable per node, so greedy over the joint
    // (algorithm, frequency) option space still matches exhaustive
    // enumeration for additive objectives.
    check("inner_d1_optimal_dvfs", 10, |rng| {
        let g = random_graph(rng);
        let oracle = eadgo::cost::CostOracle::offline_default();
        let shapes = g.infer_shapes().map_err(|e| e.to_string())?;
        // Two non-nominal states keep the exhaustive space tractable.
        let freqs = vec![
            eadgo::energysim::FreqId::NOMINAL,
            oracle.dvfs_freqs()[0],
            *oracle.dvfs_freqs().last().unwrap(),
        ];
        let (table, _) = oracle.table_for_freqs(&g, &shapes, &freqs);
        let base = Assignment::default_for(&g, oracle.reg());
        let w = rng.f64();
        for cf in [CostFunction::Energy, CostFunction::linear(w)] {
            let start = random_assignment(&table, &base, rng);
            let greedy = inner_search(&table, &cf, 1, start.clone()).map_err(|e| e.to_string())?;
            let Some(exact) = exhaustive_search(&table, &cf, &base, 200_000) else {
                return Ok(()); // space too large for ground truth; skip case
            };
            let gv = cf.eval(&greedy.cost);
            let ev = cf.eval(&exact.cost);
            if (gv - ev).abs() > 1e-9 * ev.max(1.0) {
                return Err(format!("joint d=1 found {gv}, exhaustive {ev} ({})", cf.describe()));
            }
        }
        Ok(())
    });
}

#[test]
fn dvfs_off_reproduces_pre_dvfs_plans_bit_for_bit() {
    // The PR-1 regression contract: `--dvfs off` must run the exact
    // pre-DVFS search. Two independent witnesses:
    // (a) a DVFS-mode search against a device WITHOUT frequency states
    //     degenerates to the off-mode search, bit for bit;
    // (b) the off-mode plan JSON carries no frequency axis at all, so the
    //     emitted bytes are exactly what PR 1 wrote.
    use eadgo::cost::CostFunction;
    use eadgo::graph::serde::plan_to_json;
    use eadgo::models::{self, ModelConfig};
    use eadgo::search::{optimize, DvfsMode, SearchConfig};

    let mcfg = ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 };
    let g = models::squeezenet::build(mcfg);
    let run = |dvfs: DvfsMode, strip_freq_table: bool| {
        let mut provider = eadgo::profiler::SimV100Provider::new(7);
        if strip_freq_table {
            provider.model.spec.freq_states.clear();
        }
        let ctx = OptimizerContext::new(
            RuleSet::standard(),
            eadgo::cost::CostDb::new(),
            Box::new(provider),
        );
        let cfg = SearchConfig { max_dequeues: 16, dvfs, ..Default::default() };
        let r = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
        (
            plan_to_json(&r.graph, &r.assignment).to_string_compact(),
            r.cost.time_ms.to_bits(),
            r.cost.energy_j.to_bits(),
        )
    };

    let off = run(DvfsMode::Off, false);
    for dvfs in [DvfsMode::PerGraph, DvfsMode::PerNode] {
        let no_table = run(dvfs, true);
        assert_eq!(off, no_table, "DVFS machinery at nominal-only must be a bit-exact no-op");
    }
    assert!(!off.0.contains("freq_mhz"), "off-mode plan JSON must stay pre-DVFS");
}

#[test]
fn prop_table_assignment_distance_metric() {
    // distance() is a metric: d(a,a)=0, symmetric, triangle inequality.
    check("distance_metric", 32, |rng| {
        let g = random_graph(rng);
        let ctx = OptimizerContext::offline_default();
        let (table, _) = ctx.table_for(&g).map_err(|e| e.to_string())?;
        let base = Assignment::default_for(&g, ctx.reg());
        let a = random_assignment(&table, &base, rng);
        let b = random_assignment(&table, &base, rng);
        let c = random_assignment(&table, &base, rng);
        if a.distance(&a) != 0 {
            return Err("d(a,a) != 0".into());
        }
        if a.distance(&b) != b.distance(&a) {
            return Err("not symmetric".into());
        }
        if a.distance(&c) > a.distance(&b) + b.distance(&c) {
            return Err("triangle inequality violated".into());
        }
        Ok(())
    });
}
