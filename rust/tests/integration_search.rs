//! Integration: the two-level search on zoo models — the paper's headline
//! claims as assertions (shape, not absolute numbers).

use eadgo::cost::CostFunction;
use eadgo::models::{self, ModelConfig};
use eadgo::report::tables::{self, ExperimentConfig, SearchKnobs};
use eadgo::search::{
    optimize, optimize_with_time_budget, refine_frequency_to_budget, DvfsMode, OptimizerContext,
    SearchConfig,
};

fn cfg() -> ModelConfig {
    // compute-bound scale (sim provider is analytic; size is free)
    ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

fn quick_search() -> SearchConfig {
    SearchConfig { max_dequeues: 40, ..Default::default() }
}

#[test]
fn energy_objective_beats_time_objective_on_energy() {
    // The core claim: optimizing for energy yields less energy than
    // optimizing for time (Table 3's best_energy vs best_time columns).
    let g = models::squeezenet::build(cfg());
    let ctx = OptimizerContext::offline_default();
    let best_time = optimize(&g, &ctx, &CostFunction::Time, &quick_search()).unwrap();
    let best_energy = optimize(&g, &ctx, &CostFunction::Energy, &quick_search()).unwrap();
    assert!(best_energy.cost.energy_j <= best_time.cost.energy_j);
    assert!(best_time.cost.time_ms <= best_energy.cost.time_ms + 1e-9);
    // and both improve on origin
    assert!(best_energy.cost.energy_j < best_energy.original.energy_j);
    assert!(best_time.cost.time_ms < best_time.original.time_ms);
}

#[test]
fn ours_beats_metaflow_baseline_on_energy() {
    // "our optimized graph consumes 24% less energy than MetaFlow
    // optimized" — assert ours-is-better, not the exact factor.
    let g = models::squeezenet::build(cfg());
    let ctx = OptimizerContext::offline_default();
    let metaflow = optimize(
        &g,
        &ctx,
        &CostFunction::Time,
        &SearchConfig { enable_inner: false, ..quick_search() },
    )
    .unwrap();
    let ours = optimize(&g, &ctx, &CostFunction::Energy, &quick_search()).unwrap();
    assert!(
        ours.cost.energy_j < metaflow.cost.energy_j,
        "ours {} vs metaflow {}",
        ours.cost.energy_j,
        metaflow.cost.energy_j
    );
}

#[test]
fn best_power_trades_time_for_power() {
    // Table 3: best_power draws much less power but takes longer.
    let g = models::squeezenet::build(cfg());
    let ctx = OptimizerContext::offline_default();
    let best_time = optimize(&g, &ctx, &CostFunction::Time, &quick_search()).unwrap();
    let best_power = optimize(&g, &ctx, &CostFunction::Power, &quick_search()).unwrap();
    assert!(best_power.cost.power_w() < best_time.cost.power_w());
    assert!(best_power.cost.time_ms >= best_time.cost.time_ms);
}

#[test]
fn linear_sweep_is_monotone_in_shape() {
    // Table 4: as weight shifts from time to energy, time must not
    // decrease and energy must not increase (within model noise).
    let g = models::squeezenet::build(cfg());
    let mut times = Vec::new();
    let mut energies = Vec::new();
    for w_energy in [0.0, 0.5, 1.0] {
        let ctx = OptimizerContext::offline_default();
        let res = optimize(&g, &ctx, &CostFunction::linear(w_energy), &quick_search()).unwrap();
        times.push(res.cost.time_ms);
        energies.push(res.cost.energy_j);
    }
    assert!(times[0] <= times[2] + 1e-9, "time should grow with energy weight");
    assert!(energies[2] <= energies[0] + 1e-9, "energy should shrink with energy weight");
}

#[test]
fn inner_search_d1_equals_exhaustive_for_linear_costs() {
    // Paper §3.3's optimality claim on a real (small) model.
    let g = models::simple::build_cnn(ModelConfig {
        batch: 1,
        resolution: 16,
        width_div: 8,
        classes: 10,
    });
    let ctx = OptimizerContext::offline_default();
    let (table, _) = ctx.table_for(&g).unwrap();
    for cf in [CostFunction::Time, CostFunction::Energy, CostFunction::linear(0.3)] {
        let start = eadgo::algo::Assignment::default_for(&g, ctx.reg());
        let greedy = eadgo::search::inner_search(&table, &cf, 1, start.clone()).unwrap();
        let exact = eadgo::search::exhaustive_search(&table, &cf, &start, 2_000_000)
            .expect("space small enough");
        let gv = cf.eval(&greedy.cost);
        let ev = cf.eval(&exact.cost);
        assert!(
            (gv - ev).abs() <= 1e-9 * ev.max(1.0),
            "d=1 {gv} vs exhaustive {ev} for {}",
            cf.describe()
        );
    }
}

#[test]
fn table2_cost_model_order_preserving() {
    // Paper scale: at reduced scale the launch/dispatch overheads dominate
    // and inflate the estimate-vs-actual gap beyond the paper's regime.
    let ecfg = ExperimentConfig {
        seed: 7,
        model_cfg: ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 },
        search: SearchKnobs { alpha: 1.05, max_dequeues: 24 },
    };
    let (_t, data) = tables::table2(&ecfg);
    assert!(data.graphs.len() >= 3, "need several snapshots");
    // within ~12% value accuracy, like the paper's "up to 10%"
    assert!(data.time_mape < 15.0, "time MAPE {}", data.time_mape);
    assert!(data.energy_mape < 15.0, "energy MAPE {}", data.energy_mape);
    // order preservation is the headline claim
    assert!(data.energy_tau > 0.5, "energy rank correlation {}", data.energy_tau);
    // signs match the paper: actual time above estimate, actual power below
    let (est, act) = &data.graphs[0];
    assert!(act.time_ms >= est.time_ms * 0.98);
    assert!(act.power_w <= est.power_w() * 1.02);
}

#[test]
fn table4_endpoints_bound_the_sweep() {
    let ecfg = ExperimentConfig {
        seed: 7,
        model_cfg: cfg(),
        search: SearchKnobs { alpha: 1.05, max_dequeues: 24 },
    };
    let (_t, data) = tables::table4(&ecfg);
    assert_eq!(data.rows.len(), 6);
    let best_time = &data.rows[0].2;
    let best_energy = &data.rows[5].2;
    // endpoints: fastest first row, least energy last row (within noise)
    for (_, _, c) in &data.rows {
        assert!(c.time_ms >= best_time.time_ms * 0.98);
        assert!(c.energy_j() >= best_energy.energy_j() * 0.98);
    }
}

#[test]
fn dvfs_modes_dominate_in_order_on_the_origin_graph() {
    // Provable ordering with the outer level disabled (fixed graph,
    // additive objective, d=1 globally optimal): the per-node joint
    // (algorithm, frequency) optimum dominates any uniform state, which
    // dominates nominal-only — and on conv-heavy models the frequency
    // sweet spot makes per-graph *strictly* better than off.
    let g = models::squeezenet::build(cfg());
    let run = |dvfs: DvfsMode| {
        let ctx = OptimizerContext::offline_default();
        optimize(
            &g,
            &ctx,
            &CostFunction::Energy,
            &SearchConfig { enable_outer: false, dvfs, ..quick_search() },
        )
        .unwrap()
    };
    let off = run(DvfsMode::Off);
    let pg = run(DvfsMode::PerGraph);
    let pn = run(DvfsMode::PerNode);
    assert!(
        pg.cost.energy_j < off.cost.energy_j,
        "per-graph DVFS must beat nominal-only on energy: {} vs {}",
        pg.cost.energy_j,
        off.cost.energy_j
    );
    assert!(
        pn.cost.energy_j <= pg.cost.energy_j + 1e-9,
        "per-node DVFS must dominate per-graph: {} vs {}",
        pn.cost.energy_j,
        pg.cost.energy_j
    );
    // Per-graph plans carry one uniform state; the sweet spot is below max.
    let f = pg.assignment.uniform_freq();
    assert!(!f.is_nominal(), "energy objective should pick a reduced clock");
    // Off-mode plans never carry a frequency axis.
    assert!(off.assignment.uniform_freq().is_nominal());
}

#[test]
fn dvfs_per_graph_full_search_saves_energy() {
    // The ISSUE 2 acceptance claim on the full two-level search: with the
    // frequency axis the optimizer lands on strictly less energy than the
    // frequency-blind search (zoo models, energy objective).
    for model in ["squeezenet", "resnet"] {
        let g = models::by_name(model, cfg()).unwrap();
        let run = |dvfs: DvfsMode| {
            let ctx = OptimizerContext::offline_default();
            optimize(&g, &ctx, &CostFunction::Energy, &SearchConfig { dvfs, ..quick_search() })
                .unwrap()
        };
        let off = run(DvfsMode::Off);
        let pg = run(DvfsMode::PerGraph);
        // Guaranteed chain: the full per-graph search includes the origin's
        // per-graph evaluation, which includes the nominal state.
        let inner_pg = {
            let ctx = OptimizerContext::offline_default();
            optimize(
                &g,
                &ctx,
                &CostFunction::Energy,
                &SearchConfig { enable_outer: false, dvfs: DvfsMode::PerGraph, ..quick_search() },
            )
            .unwrap()
        };
        assert!(pg.cost.energy_j <= inner_pg.cost.energy_j + 1e-9, "{model}: outer must not hurt");
        assert!(
            pg.cost.energy_j < off.cost.energy_j,
            "{model}: (G,A,f) search must find lower energy than (G,A): {} vs {}",
            pg.cost.energy_j,
            off.cost.energy_j
        );
    }
}

#[test]
fn dvfs_saves_energy_at_alpha_band_latency() {
    // The acceptance criterion's latency side: against the DVFS-off
    // best-energy plan, frequency refinement inside a tight latency band
    // (0.5% — well inside the search's own α=1.05 band) still strictly
    // lowers energy: memory-bound nodes down-clock essentially for free.
    let g = models::squeezenet::build(cfg());
    let ctx = OptimizerContext::offline_default();
    let off = optimize(&g, &ctx, &CostFunction::Energy, &quick_search()).unwrap();
    let budget = off.cost.time_ms * 1.005;

    // (a) The direct lever: freeze the off-plan's algorithms, move only
    // frequencies (shares the warm oracle, so costs are comparable).
    let (ra, rc) = refine_frequency_to_budget(
        &ctx.oracle,
        &off.graph,
        &off.assignment,
        budget,
        DvfsMode::PerNode,
        &[],
    )
    .unwrap()
    .expect("device has DVFS states");
    assert!(rc.time_ms <= budget + 1e-9, "refinement must respect the budget");
    assert!(
        rc.energy_j < off.cost.energy_j,
        "per-node down-clocking within the band must save energy: {} vs {}",
        rc.energy_j,
        off.cost.energy_j
    );
    assert!(!ra.uniform_freq().is_nominal() || ra.freq_histogram().len() > 1);

    // (b) End-to-end: the constrained search with DVFS stays feasible,
    // inside the band, and never worse than its own pure-time anchor
    // (w = 0, the first probe in the trace).
    let r = optimize_with_time_budget(
        &g,
        &ctx,
        budget,
        &SearchConfig { dvfs: DvfsMode::PerNode, ..quick_search() },
        3,
    )
    .unwrap();
    assert!(r.feasible);
    assert!(r.result.cost.time_ms <= budget + 1e-9);
    let w0_energy = r.trace[0].2;
    assert!(r.result.cost.energy_j <= w0_energy + 1e-9);
}

#[test]
fn search_is_deterministic() {
    let g = models::squeezenet::build(cfg());
    let run = || {
        let ctx = OptimizerContext::offline_default();
        let r = optimize(&g, &ctx, &CostFunction::Energy, &quick_search()).unwrap();
        (r.cost.time_ms, r.cost.energy_j, r.stats.expanded, r.stats.generated)
    };
    assert_eq!(run(), run());
}

#[test]
fn alpha_widens_exploration() {
    let g = models::squeezenet::build(cfg());
    let explored = |alpha: f64| {
        let ctx = OptimizerContext::offline_default();
        let r = optimize(
            &g,
            &ctx,
            &CostFunction::Energy,
            &SearchConfig { alpha, max_dequeues: 60, ..Default::default() },
        )
        .unwrap();
        (r.stats.generated, r.cost.energy_j)
    };
    let (gen_greedy, e_greedy) = explored(1.0);
    let (gen_relaxed, e_relaxed) = explored(1.05);
    assert!(gen_relaxed >= gen_greedy, "relaxation must not shrink the space");
    assert!(e_relaxed <= e_greedy + 1e-9, "relaxation must not worsen the optimum");
}
