//! Property suite for the delta substitution engine (ISSUE 4): for every
//! rule and random model, the incremental artifacts the search consumes —
//! `DeltaView` shapes, `delta_hash`, carry-over cost tables, carried
//! default assignments — must equal a full rebuild of the materialized
//! product, bit for bit, at every DVFS frequency state. A separate test
//! asserts (via the oracle's build counters) that the search actually
//! takes the delta path instead of rebuilding full `GraphCostTable`s per
//! candidate.

use eadgo::algo::Assignment;
use eadgo::cost::{CostFunction, CostOracle, DeltaBase};
use eadgo::energysim::FreqId;
use eadgo::graph::canonical::{delta_hash, graph_hash, node_hashes};
use eadgo::graph::{Activation, DeltaView, Graph, NodeId, OpKind, PortRef};
use eadgo::search::{
    inner_search, inner_search_incremental, optimize, OptimizerContext, SearchConfig,
};
use eadgo::subst::{MatchContext, RuleSet};
use eadgo::util::prop::check;
use eadgo::util::rng::Rng;

/// Generate a random small valid CNN-ish graph: a chain of conv/pool/relu
/// with an occasional parallel branch + concat (and a BN/add tail often
/// enough to reach the fold/residual rules).
fn random_graph(rng: &mut Rng) -> Graph {
    let mut g = Graph::new();
    let res = 8 + 2 * rng.below(4); // 8..14
    let mut c = 1 + rng.below(3); // 1..3
    let x = g.add1(OpKind::Input { shape: vec![1, c, res, res] }, &[], "x");
    let mut cur = x;
    let mut cur_res = res;
    let depth = 1 + rng.below(3);
    let mut seed = 100 + rng.below(1000) as u64;
    for d in 0..depth {
        match rng.below(5) {
            0 | 1 => {
                // conv (+ optional relu or batchnorm)
                let k = 1 + rng.below(4);
                let ksz = *rng.choose(&[1usize, 3]);
                let pad = ksz / 2;
                seed += 1;
                let w = g.add1(OpKind::weight(vec![k, c, ksz, ksz], seed), &[], "w");
                cur = g.add1(
                    OpKind::Conv2d {
                        stride: (1, 1),
                        pad: (pad, pad),
                        act: Activation::None,
                        has_bias: false,
                        has_residual: false,
                    },
                    &[cur, w],
                    &format!("conv{d}"),
                );
                c = k;
                match rng.below(3) {
                    0 => cur = g.add1(OpKind::Relu, &[cur], "relu"),
                    1 => {
                        use eadgo::graph::op::eps_bits;
                        use eadgo::graph::op::WeightKind;
                        seed += 4;
                        let gamma =
                            g.add1(OpKind::weight_kind(vec![c], seed, WeightKind::Gamma), &[], "g");
                        let beta = g
                            .add1(OpKind::weight_kind(vec![c], seed + 1, WeightKind::Beta), &[], "b");
                        let mean = g
                            .add1(OpKind::weight_kind(vec![c], seed + 2, WeightKind::Mean), &[], "m");
                        let var =
                            g.add1(OpKind::weight_kind(vec![c], seed + 3, WeightKind::Var), &[], "v");
                        cur = g.add1(
                            OpKind::BatchNorm { eps: eps_bits(1e-5) },
                            &[cur, gamma, beta, mean, var],
                            "bn",
                        );
                    }
                    _ => {}
                }
            }
            2 => {
                // parallel 2-branch + concat
                let k1 = 1 + rng.below(3);
                let k2 = 1 + rng.below(3);
                seed += 2;
                let w1 = g.add1(OpKind::weight(vec![k1, c, 3, 3], seed - 1), &[], "w1");
                let w2 = g.add1(OpKind::weight(vec![k2, c, 3, 3], seed), &[], "w2");
                let conv_attrs = OpKind::Conv2d {
                    stride: (1, 1),
                    pad: (1, 1),
                    act: Activation::Relu,
                    has_bias: false,
                    has_residual: false,
                };
                let c1 = g.add1(conv_attrs.clone(), &[cur, w1], "b1");
                let c2 = g.add1(conv_attrs, &[cur, w2], "b2");
                cur = g.add1(OpKind::Concat { axis: 1 }, &[c1, c2], "cat");
                c = k1 + k2;
            }
            3 => {
                // residual: conv with same channel count + add (+ relu)
                seed += 1;
                let w = g.add1(OpKind::weight(vec![c, c, 3, 3], seed), &[], "wres");
                let cv = g.add1(
                    OpKind::Conv2d {
                        stride: (1, 1),
                        pad: (1, 1),
                        act: Activation::None,
                        has_bias: false,
                        has_residual: false,
                    },
                    &[cur, w],
                    &format!("resconv{d}"),
                );
                let add = g.add1(OpKind::Add, &[cv, cur], "add");
                cur = if rng.bool() { g.add1(OpKind::Relu, &[add], "addrelu") } else { add };
            }
            _ => {
                if cur_res >= 4 {
                    cur = g.add1(
                        OpKind::MaxPool { k: (2, 2), stride: (2, 2), pad: (0, 0) },
                        &[cur],
                        "pool",
                    );
                    cur_res /= 2;
                }
            }
        }
    }
    g.outputs = vec![PortRef::of(cur)];
    g.validate().expect("generator produced invalid graph");
    g
}

fn bits(c: &eadgo::cost::GraphCost) -> (u64, u64) {
    (c.time_ms.to_bits(), c.energy_j.to_bits())
}

#[test]
fn prop_delta_artifacts_match_full_rebuild() {
    check("delta_matches_full", 24, |rng| {
        let g = random_graph(rng);
        let shapes = g.infer_shapes().map_err(|e| e.to_string())?;
        let hashes = node_hashes(&g).ok_or("base graph cyclic?")?;
        let consumers = g.consumers();
        let cx = MatchContext::with_shapes(&g, &shapes);
        let oracle = CostOracle::offline_default();
        let mut freqs = vec![FreqId::NOMINAL];
        freqs.extend_from_slice(oracle.dvfs_freqs());
        let (base_table, _) = oracle.table_for_freqs(&g, &shapes, &freqs);
        let base_a = Assignment::default_for(&g, oracle.reg());

        for site in RuleSet::standard().sites(&g, &cx) {
            let rule = site.rule_name();
            let delta = site.delta(&g);
            let view = DeltaView::new(&g, &shapes, delta, Some(&consumers))
                .map_err(|e| format!("{rule}: view failed: {e}"))?;

            // --- node-set / edge equality vs the materialized product ---
            let mut full = g.apply_delta(view.delta());
            full.compact();
            full.validate().map_err(|e| format!("{rule}: invalid product: {e}"))?;
            if full.len() != view.live_count() {
                return Err(format!(
                    "{rule}: live count {} vs materialized {}",
                    view.live_count(),
                    full.len()
                ));
            }
            for (j, &i) in view.compact_order().iter().enumerate() {
                let node = full.node(NodeId(j));
                if &node.op != view.op(i) {
                    return Err(format!("{rule}: op mismatch at node {j}"));
                }
                let mapped: Vec<PortRef> = view
                    .inputs(i)
                    .iter()
                    .map(|p| PortRef {
                        node: view.compact_id(p.node.0).expect("live input"),
                        port: p.port,
                    })
                    .collect();
                if node.inputs != mapped {
                    return Err(format!("{rule}: edge mismatch at node {j}"));
                }
            }

            // --- canonical hash: incremental == full ---
            if delta_hash(&view, &hashes) != graph_hash(&full) {
                return Err(format!("{rule}: delta_hash diverged from graph_hash"));
            }

            // --- shapes: incremental == full inference ---
            let fshapes = full.infer_shapes().map_err(|e| e.to_string())?;
            for (j, &i) in view.compact_order().iter().enumerate() {
                if fshapes[j][..] != *view.out_shapes(i) {
                    return Err(format!("{rule}: shape mismatch at node {j}"));
                }
            }

            // --- cost: carry-over table == fresh full table, every state ---
            let base_conv = inner_search(&base_table, &CostFunction::Energy, 1, base_a.clone())
                .map_err(|e| e.to_string())?;
            let base = DeltaBase {
                graph: &g,
                shapes: &shapes,
                table: &base_table,
                assignment: &base_a,
                converged: Some(&base_conv.assignment),
            };
            let cand = oracle.delta_table_for_freqs(&base, &view, &freqs);
            let (dt, da) = (&cand.table, &cand.assignment);
            // The oracle's dirty cone must be exactly the view's live
            // sig-dirty set (minus constant-space nodes), in compacted
            // ids — pinning the two dirty-cone definitions together.
            let expect_dirty: Vec<NodeId> = view
                .sig_dirty_live()
                .filter(|&i| !view.op(i).is_constant_space())
                .map(|i| view.compact_id(i).expect("live node compacts"))
                .collect();
            if cand.dirty != expect_dirty {
                return Err(format!(
                    "{rule}: oracle dirty cone {:?} != view sig-dirty {:?}",
                    cand.dirty, expect_dirty
                ));
            }
            let (ft, _) = oracle.table_for_freqs(&full, &fshapes, &freqs);
            let fa = Assignment::default_for_with(&full, &fshapes, oracle.reg());
            if *da != fa {
                return Err(format!("{rule}: carried default assignment diverged"));
            }
            let d_ids: Vec<NodeId> = dt.costed_ids().collect();
            let f_ids: Vec<NodeId> = ft.costed_ids().collect();
            if d_ids != f_ids {
                return Err(format!("{rule}: costed node sets diverged"));
            }
            for id in f_ids {
                let ds = dt.freq_options(id);
                let fs = ft.freq_options(id);
                if ds.len() != fs.len() {
                    return Err(format!("{rule}: slab count mismatch at node {}", id.0));
                }
                for ((df, dopts), (ff, fopts)) in ds.iter().zip(fs.iter()) {
                    if df != ff || dopts.len() != fopts.len() {
                        return Err(format!("{rule}: slab mismatch at node {}", id.0));
                    }
                    for ((dal, dc), (fal, fc)) in dopts.iter().zip(fopts.iter()) {
                        if dal != fal
                            || dc.time_ms.to_bits() != fc.time_ms.to_bits()
                            || dc.power_w.to_bits() != fc.power_w.to_bits()
                        {
                            return Err(format!("{rule}: row bits differ at node {}", id.0));
                        }
                    }
                }
            }
            // delta_cost == full re-costing at every DVFS frequency state
            if bits(&dt.eval(da)) != bits(&ft.eval(&fa)) {
                return Err(format!("{rule}: default-assignment cost bits differ"));
            }
            for &f in &freqs {
                let mut u = fa.clone();
                u.set_uniform_freq(f);
                if bits(&dt.eval(&u)) != bits(&ft.eval(&u)) {
                    return Err(format!("{rule}: cost bits differ at {}", f.describe()));
                }
            }
            // ...and the inner search walks identical numbers.
            let di = inner_search(dt, &CostFunction::Energy, 1, da.clone())
                .map_err(|e| e.to_string())?;
            let fi = inner_search(&ft, &CostFunction::Energy, 1, fa.clone())
                .map_err(|e| e.to_string())?;
            if di.assignment != fi.assignment || bits(&di.cost) != bits(&fi.cost) {
                return Err(format!("{rule}: inner search diverged on delta table"));
            }
            // Warm start: the parent's converged plan remapped across
            // compaction, re-optimizing only the dirty cone, must land on
            // the exact same plan and cost bits as the cold re-derivation.
            let warm = cand.warm.as_ref().expect("converged plan supplied");
            let wi = inner_search_incremental(
                dt,
                &CostFunction::Energy,
                warm.clone(),
                Some(&cand.dirty),
                None,
            )
            .map_err(|e| e.to_string())?;
            if wi.assignment != di.assignment || bits(&wi.cost) != bits(&di.cost) {
                return Err(format!("{rule}: warm dirty-only inner search diverged"));
            }
            if wi.swept > cand.dirty.len() as u64 {
                return Err(format!(
                    "{rule}: warm search swept {} nodes, dirty cone is {}",
                    wi.swept,
                    cand.dirty.len()
                ));
            }
        }
        Ok(())
    });
}

fn model_cfg() -> eadgo::models::ModelConfig {
    eadgo::models::ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

#[test]
fn search_candidates_use_delta_tables_not_full_rebuilds() {
    // The acceptance criterion's instrumentation assert: per-wave
    // candidate evaluation must go through delta (carry-over) tables —
    // full table builds happen only for the baseline and once per
    // expanded wave entry, never per candidate.
    let g = eadgo::models::squeezenet::build(model_cfg());
    let ctx = OptimizerContext::offline_default();
    let cfg = SearchConfig { max_dequeues: 12, ..Default::default() };
    let res = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
    let st = ctx.oracle.table_build_stats();
    assert!(res.stats.evaluated > 0, "search evaluated no candidates");
    assert_eq!(
        st.delta_tables as usize, res.stats.evaluated,
        "every evaluated candidate must use exactly one delta table build"
    );
    assert!(
        st.full_tables as usize <= 1 + res.stats.expanded,
        "full rebuilds ({}) must be bounded by baseline + expanded entries ({})",
        st.full_tables,
        1 + res.stats.expanded
    );
    assert!(
        st.carried_rows > st.resolved_rows,
        "carry-over must dominate re-resolves ({} vs {})",
        st.carried_rows,
        st.resolved_rows
    );
    // Per-rule statistics are populated and consistent.
    let sites: usize = res.stats.rule_stats.iter().map(|r| r.sites).sum();
    assert_eq!(sites, res.stats.generated);
    assert!(res.stats.rule_stats.iter().all(|r| r.enqueued <= r.sites));
}

#[test]
fn legacy_engine_counts_zero_delta_builds() {
    let g = eadgo::models::squeezenet::build(model_cfg());
    let ctx = OptimizerContext::offline_default();
    let cfg = SearchConfig { max_dequeues: 12, delta_eval: false, ..Default::default() };
    let res = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
    let st = ctx.oracle.table_build_stats();
    assert_eq!(st.delta_tables, 0);
    assert!(
        st.full_tables as usize >= res.stats.evaluated,
        "legacy path rebuilds a full table per candidate"
    );
}
