//! Integration: PJRT runtime plumbing + the python<->rust signature
//! contract (golden strings pinned on both sides).

use eadgo::graph::{Activation, OpKind};
use eadgo::runtime::{literal_to_tensor, tensor_to_literal, Manifest, Runtime};
use eadgo::tensor::Tensor;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping PJRT test");
        None
    }
}

/// Golden signature strings — python/tests/test_aot.py pins the identical
/// strings from the python mirror (compile/opset.py). If either side
/// changes, both tests break together.
#[test]
fn signature_contract() {
    let conv = OpKind::Conv2d {
        stride: (1, 1),
        pad: (1, 1),
        act: Activation::None,
        has_bias: true,
        has_residual: false,
    };
    let sig = conv.signature(&[vec![1, 3, 32, 32], vec![8, 3, 3, 3], vec![8]]);
    assert_eq!(sig, "conv2d;st=1,1;pad=1,1;act=none;b=1;res=0;1x3x32x32;8x3x3x3;8");

    assert_eq!(OpKind::Relu.signature(&[vec![1, 8, 32, 32]]), "relu;1x8x32x32");
    assert_eq!(
        OpKind::matmul().signature(&[vec![1, 16], vec![16, 10]]),
        "matmul;1x16;16x10"
    );
    let pool = OpKind::MaxPool { k: (2, 2), stride: (2, 2), pad: (0, 0) };
    assert_eq!(pool.signature(&[vec![1, 16, 32, 32]]), "maxpool;k=2,2;st=2,2;pad=0,0;1x16x32x32");
    let cat = OpKind::Concat { axis: 1 };
    assert_eq!(
        cat.signature(&[vec![1, 8, 32, 32], vec![1, 8, 32, 32]]),
        "concat;ax=1;1x8x32x32;1x8x32x32"
    );
}

#[test]
fn manifest_parses_real_file() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    assert!(m.entries.len() >= 20);
    for e in &m.entries {
        assert!(!e.key.is_empty());
        assert!(dir.join(&e.file).exists(), "artifact file {} missing", e.file);
        assert!(!e.input_shapes.is_empty());
        assert_eq!(e.output_shapes.len(), 1, "all our artifacts are single-output");
    }
    // keys unique
    let mut keys: Vec<_> = m.entries.iter().map(|e| &e.key).collect();
    keys.sort();
    let n = keys.len();
    keys.dedup();
    assert_eq!(keys.len(), n);
}

#[test]
fn runtime_rejects_wrong_shapes_and_unknown_keys() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let bad = Tensor::zeros(&[2, 2]);
    assert!(rt.execute("no_such_key", &[&bad]).is_err());
    let key = "relu;1x8x32x32::std";
    assert!(rt.has(key));
    assert!(rt.execute(key, &[&bad]).is_err(), "shape mismatch must be rejected");
    assert!(rt.execute(key, &[]).is_err(), "arity mismatch must be rejected");
}

#[test]
fn relu_artifact_computes_relu() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let mut x = Tensor::zeros(&[1, 8, 32, 32]);
    x.data_mut()[0] = -5.0;
    x.data_mut()[1] = 3.0;
    let y = rt.execute("relu;1x8x32x32::std", &[&x]).unwrap();
    assert_eq!(y[0].data()[0], 0.0);
    assert_eq!(y[0].data()[1], 3.0);
}

#[test]
fn matmul_artifacts_agree_with_each_other() {
    // gemm_blocked (pallas) and gemm_naive (jnp) artifacts are equivalent.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let mut rng = eadgo::util::rng::Rng::seed_from(3);
    let a = Tensor::rand(&[1, 16], &mut rng, -1.0, 1.0);
    let b = Tensor::rand(&[16, 10], &mut rng, -1.0, 1.0);
    let y1 = rt.execute("matmul;1x16;16x10::gemm_blocked", &[&a, &b]).unwrap();
    let y2 = rt.execute("matmul;1x16;16x10::gemm_naive", &[&a, &b]).unwrap();
    eadgo::util::prop::assert_close(y1[0].data(), y2[0].data(), 1e-4, 1e-4).unwrap();
}

#[test]
fn literal_conversions_roundtrip_shapes() {
    for shape in [vec![1usize], vec![2, 3], vec![1, 3, 4, 4]] {
        let n: usize = shape.iter().product();
        let t = Tensor::new(shape.clone(), (0..n).map(|i| i as f32).collect());
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &shape).unwrap();
        assert_eq!(back, t);
    }
}
