//! End-to-end CLI tests: drive the `eadgo` binary the way a user would
//! (optimize → save plan → serve; profile → warm cache; reproduce tables).

use std::path::PathBuf;
use std::process::Command;

fn eadgo() -> Command {
    // target/release or target/debug depending on how tests were built
    let mut path = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    path.push("target");
    path.push(if cfg!(debug_assertions) { "debug" } else { "release" });
    path.push("eadgo");
    if !path.exists() {
        // fall back to the release binary (built by `make build`)
        path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/release/eadgo");
    }
    Command::new(path)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("eadgo_cli_{name}"))
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("binary not found — run `cargo build --release` first");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let stderr = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(out.status.success(), "command failed:\nstdout: {stdout}\nstderr: {stderr}");
    stdout
}

#[test]
fn zoo_lists_models() {
    let out = run_ok(eadgo().arg("zoo"));
    for m in ["squeezenet", "inception", "resnet", "mobilenet", "vgg"] {
        assert!(out.contains(m), "missing {m} in: {out}");
    }
}

#[test]
fn show_dumps_graph() {
    let out = run_ok(eadgo().args(["show", "--model", "simple"]));
    assert!(out.contains("conv2d"));
    assert!(out.contains("outputs:"));
}

#[test]
fn optimize_save_plan_then_serve() {
    let dir = tmp("pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let plan = dir.join("plan.json");
    let db = dir.join("db.json");
    let out = run_ok(eadgo().args([
        "optimize",
        "--model",
        "simple",
        "--objective",
        "energy",
        "--max-dequeues",
        "20",
        "--save-plan",
        plan.to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(out.contains("optimized:"), "{out}");
    assert!(plan.exists());
    assert!(db.exists());

    // Serving from the saved plan (reference engine; point artifacts at a
    // nonexistent dir so the test does not depend on `make artifacts`).
    let out = run_ok(eadgo().args([
        "serve",
        "--plan",
        plan.to_str().unwrap(),
        "--requests",
        "8",
        "--batch-max",
        "2",
        "--artifacts",
        dir.join("no_artifacts").to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(out.contains("served 8 requests"), "{out}");
    assert!(out.contains("throughput"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn incremental_inner_ab_plans_are_byte_identical() {
    // The CLI face of the ISSUE-5 A/B contract: --incremental-inner off
    // must emit byte-identical plan JSON (it also prints the economy
    // table either way).
    let dir = tmp("inner_ab");
    std::fs::create_dir_all(&dir).unwrap();
    let run = |mode: &str, tag: &str| -> (String, PathBuf) {
        let plan = dir.join(format!("plan_{tag}.json"));
        let out = run_ok(eadgo().args([
            "optimize",
            "--model",
            "simple",
            "--max-dequeues",
            "16",
            "--incremental-inner",
            mode,
            "--save-plan",
            plan.to_str().unwrap(),
            "--db",
            dir.join(format!("db_{tag}.json")).to_str().unwrap(),
        ]));
        (out, plan)
    };
    let (out_on, plan_on) = run("on", "on");
    let (out_off, plan_off) = run("off", "off");
    assert!(out_on.contains("Inner-search economy"), "{out_on}");
    assert!(out_on.contains("warm starts"), "{out_on}");
    assert!(out_off.contains("Inner-search economy"), "{out_off}");
    let on = std::fs::read(&plan_on).unwrap();
    let off = std::fs::read(&plan_off).unwrap();
    assert_eq!(on, off, "plan JSON diverged between inner engines");

    // Mistyped value: strict flag policy.
    let bad = eadgo()
        .args(["optimize", "--model", "simple", "--incremental-inner", "warp9"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("--incremental-inner expects on|off"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn profile_warm_cache_second_run() {
    let dir = tmp("profile");
    std::fs::create_dir_all(&dir).unwrap();
    let db = dir.join("db.json");
    let first = run_ok(eadgo().args([
        "profile",
        "--model",
        "simple",
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(first.contains("new measurements"), "{first}");
    // paper §4.1: "After the first run, each later run finishes [fast]
    // since most profile results have already been cached"
    let second = run_ok(eadgo().args([
        "profile",
        "--model",
        "simple",
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(second.contains("0 new measurements"), "{second}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reproduce_table1_prints_rows() {
    let out = run_ok(eadgo().args(["reproduce", "--table", "1", "--quick"]));
    assert!(out.contains("Table 1"));
    assert!(out.contains("winograd"));
    assert!(out.contains("conv3"));
}

#[test]
fn constrain_reports_trace() {
    let dir = tmp("constrain");
    std::fs::create_dir_all(&dir).unwrap();
    let out = run_ok(eadgo().args([
        "constrain",
        "--model",
        "simple",
        "--time-budget",
        "1000000",
        "--probes",
        "2",
        "--max-dequeues",
        "10",
        "--db",
        dir.join("db.json").to_str().unwrap(),
    ]));
    assert!(out.contains("feasible"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_subcommand_errors() {
    let out = eadgo().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn mistyped_flag_prints_usage_not_backtrace() {
    let out = eadgo().args(["optimize", "--modell", "simple"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown option `--modell`"), "{err}");
    assert!(err.contains("did you mean `--model`"), "{err}");
    assert!(err.contains("USAGE"), "usage text missing: {err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn optimize_with_dvfs_reports_plan_frequency() {
    let dir = tmp("dvfs");
    std::fs::create_dir_all(&dir).unwrap();
    let out = run_ok(eadgo().args([
        "optimize",
        "--model",
        "simple",
        "--objective",
        "energy",
        "--dvfs",
        "per-graph",
        "--max-dequeues",
        "10",
        "--db",
        dir.join("db.json").to_str().unwrap(),
    ]));
    assert!(out.contains("dvfs=per-graph"), "{out}");
    assert!(out.contains("plan frequency:"), "{out}");
    let bad = eadgo().args(["optimize", "--model", "simple", "--dvfs", "warp9"]).output().unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("unknown dvfs mode"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_model_errors() {
    let out = eadgo().args(["show", "--model", "alexnet9000"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn optimize_frontier_then_serve_adaptive() {
    let dir = tmp("frontier");
    std::fs::create_dir_all(&dir).unwrap();
    let plans = dir.join("plans.json");
    let db = dir.join("db.json");
    let out = run_ok(eadgo().args([
        "optimize",
        "--model",
        "simple",
        "--frontier",
        "3",
        "--max-dequeues",
        "20",
        "--save-frontier",
        plans.to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(out.contains("Pareto operating-point frontier"), "{out}");
    assert!(out.contains("frontier ("), "{out}");
    assert!(plans.exists());

    let out = run_ok(eadgo().args([
        "serve",
        "--frontier",
        plans.to_str().unwrap(),
        "--adaptive",
        "--requests",
        "8",
        "--batch-max",
        "2",
        "--artifacts",
        dir.join("no_artifacts").to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(out.contains("served 8 requests"), "{out}");
    // Single- or multi-point frontier alike, the loaded count is reported.
    assert!(out.contains("-point frontier"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_adaptive_without_frontier_errors() {
    let out = eadgo().args(["serve", "--model", "simple", "--adaptive"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--adaptive needs a frontier"), "{err}");
}

#[test]
fn devices_gpu_plans_are_byte_identical_to_flag_omitted() {
    // `--devices gpu` must be a no-op in the strictest sense: the saved
    // plan and frontier files are byte-for-byte what the flag-free run
    // writes (the placement axis leaves single-device surfaces untouched).
    let dir = tmp("devices_ab");
    std::fs::create_dir_all(&dir).unwrap();
    let run = |tag: &str, devices: Option<&str>| -> (PathBuf, PathBuf) {
        let plan = dir.join(format!("plan_{tag}.json"));
        let plans = dir.join(format!("frontier_{tag}.json"));
        let mut args = vec![
            "optimize".to_string(),
            "--model".into(),
            "simple".into(),
            "--objective".into(),
            "energy".into(),
            "--max-dequeues".into(),
            "16".into(),
            "--frontier".into(),
            "3".into(),
            "--save-plan".into(),
            plan.to_str().unwrap().into(),
            "--save-frontier".into(),
            plans.to_str().unwrap().into(),
            "--db".into(),
            dir.join(format!("db_{tag}.json")).to_str().unwrap().into(),
        ];
        if let Some(d) = devices {
            args.push("--devices".into());
            args.push(d.into());
        }
        run_ok(eadgo().args(&args));
        (plan, plans)
    };
    let (plan_a, frontier_a) = run("bare", None);
    let (plan_b, frontier_b) = run("gpu", Some("gpu"));
    assert_eq!(
        std::fs::read(&plan_a).unwrap(),
        std::fs::read(&plan_b).unwrap(),
        "--devices gpu changed the plan file"
    );
    assert_eq!(
        std::fs::read(&frontier_a).unwrap(),
        std::fs::read(&frontier_b).unwrap(),
        "--devices gpu changed the frontier file"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn devices_flag_is_validated() {
    // Unknown device name: strict, with a did-you-mean.
    let bad = eadgo().args(["optimize", "--model", "simple", "--devices", "gpu,dal"]).output().unwrap();
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("unknown device `dal`"), "{err}");
    assert!(err.contains("did you mean `dla`"), "{err}");

    // The GPU anchors device index 0 and must come first.
    let bad = eadgo().args(["optimize", "--model", "simple", "--devices", "dla,gpu"]).output().unwrap();
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("must start with `gpu`"), "{err}");

    // Placement needs the sim provider: the cpu provider is one device.
    let bad = eadgo()
        .args(["optimize", "--model", "simple", "--devices", "gpu,dla", "--provider", "cpu"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("needs the sim provider"), "{err}");
}

#[test]
fn mixed_device_plan_requires_devices_at_serve_time() {
    // optimize --devices gpu,dla produces a plan with DLA placements; the
    // serve-side guard must reject a single-device serving context with an
    // actionable hint, and accept the full device list.
    let dir = tmp("devices_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let plan = dir.join("plan.json");
    let db = dir.join("db.json");
    let out = run_ok(eadgo().args([
        "optimize",
        "--model",
        "simple",
        "--objective",
        "energy",
        "--devices",
        "gpu,dla",
        "--max-dequeues",
        "20",
        "--save-plan",
        plan.to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(out.contains("devices=gpu+dla"), "{out}");
    let saved = std::fs::read_to_string(&plan).unwrap();
    assert!(saved.contains("\"device\""), "energy search over gpu,dla placed nothing: {saved}");

    let bare = eadgo()
        .args([
            "serve",
            "--plan",
            plan.to_str().unwrap(),
            "--requests",
            "4",
            "--artifacts",
            dir.join("no_artifacts").to_str().unwrap(),
            "--db",
            db.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!bare.status.success(), "serving a DLA plan without --devices must fail");
    let err = String::from_utf8_lossy(&bare.stderr);
    assert!(err.contains("does not provide"), "{err}");
    assert!(err.contains("--devices gpu,dla"), "hint missing: {err}");

    let out = run_ok(eadgo().args([
        "serve",
        "--plan",
        plan.to_str().unwrap(),
        "--devices",
        "gpu,dla",
        "--requests",
        "4",
        "--artifacts",
        dir.join("no_artifacts").to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
    ]));
    assert!(out.contains("served 4 requests"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}
