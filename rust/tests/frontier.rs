//! Frontier invariants (ISSUE 3): no returned point dominates another, a
//! 1-point frontier is bit-identical to the single-plan optimizer output,
//! and the frontier manifest round-trips every plan exactly.

use eadgo::cost::{CostFunction, GraphCost};
use eadgo::energysim::FreqId;
use eadgo::graph::canonical::graph_hash;
use eadgo::models::{self, ModelConfig};
use eadgo::search::{
    optimize, optimize_frontier, optimize_frontier_batched, price_plan_at_batch, OptimizerContext,
    PlanFrontier, PlanPoint, SearchConfig,
};
use eadgo::util::prop::{check, default_cases};

fn tiny() -> ModelConfig {
    ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 }
}

fn scfg() -> SearchConfig {
    SearchConfig { max_dequeues: 30, ..Default::default() }
}

/// Assert the structural frontier invariant: fastest-first, strictly
/// increasing batch latency, strictly decreasing energy per request
/// (identical to plain energy when every batch is 1), pairwise
/// non-dominated.
fn assert_frontier_invariants(f: &PlanFrontier) {
    for w in f.points().windows(2) {
        assert!(w[0].cost.time_ms < w[1].cost.time_ms, "time not strictly increasing");
        assert!(
            w[0].energy_per_request() > w[1].energy_per_request(),
            "energy/request not strictly decreasing"
        );
    }
    for (i, a) in f.points().iter().enumerate() {
        for (j, b) in f.points().iter().enumerate() {
            assert!(i == j || !a.dominates(b), "frontier point {i} dominates point {j}");
        }
    }
}

#[test]
fn frontier_points_are_mutually_nondominated() {
    let g = models::squeezenet::build(tiny());
    let ctx = OptimizerContext::offline_default();
    let res = optimize_frontier(&g, &ctx, &scfg(), 5).unwrap();
    assert!(!res.frontier.is_empty());
    assert!(res.frontier.len() <= 5);
    assert_frontier_invariants(&res.frontier);
    assert_eq!(res.probes.len(), 5);
    // The extremes come from the pure-objective probes: nothing on the
    // frontier may beat the w=1 probe on energy or the w=0 probe on time.
    let e_probe = res.probes.last().unwrap().cost.energy_j;
    let t_probe = res.probes.first().unwrap().cost.time_ms;
    assert!(res.frontier.energy_optimal().cost.energy_j <= e_probe + 1e-9);
    assert!(res.frontier.latency_optimal().cost.time_ms <= t_probe + 1e-9);
}

#[test]
fn resnet_frontier_has_at_least_two_points() {
    // The acceptance shape of `optimize --frontier 5` on resnet: a ≥2-point
    // dominance-free frontier (reduced resolution keeps the test fast; the
    // algorithm trade-offs that create the frontier are scale-independent).
    let mcfg = ModelConfig { batch: 1, resolution: 64, width_div: 4, classes: 10 };
    let g = models::by_name("resnet", mcfg).unwrap();
    let ctx = OptimizerContext::offline_default();
    let res = optimize_frontier(&g, &ctx, &scfg(), 5).unwrap();
    let n = res.frontier.len();
    assert!(n >= 2, "resnet frontier collapsed to {n} point(s)");
    assert_frontier_invariants(&res.frontier);
    // Every frontier plan must beat the origin on at least one axis.
    for p in res.frontier.points() {
        assert!(
            p.cost.time_ms <= res.original.time_ms + 1e-9
                || p.cost.energy_j <= res.original.energy_j + 1e-9
        );
    }
}

#[test]
fn one_point_frontier_bit_identical_to_single_plan_optimize() {
    let g = models::squeezenet::build(tiny());
    let fres = optimize_frontier(&g, &OptimizerContext::offline_default(), &scfg(), 1).unwrap();
    assert_eq!(fres.frontier.len(), 1);
    let point = &fres.frontier.points()[0];
    let single =
        optimize(&g, &OptimizerContext::offline_default(), &CostFunction::Energy, &scfg()).unwrap();
    assert_eq!(graph_hash(&point.graph), graph_hash(&single.graph));
    assert_eq!(point.assignment, single.assignment);
    assert_eq!(point.cost.time_ms.to_bits(), single.cost.time_ms.to_bits());
    assert_eq!(point.cost.energy_j.to_bits(), single.cost.energy_j.to_bits());
    assert_eq!(fres.original.energy_j.to_bits(), single.original.energy_j.to_bits());
}

#[test]
fn manifest_roundtrip_preserves_every_plan() {
    let g = models::squeezenet::build(tiny());
    let ctx = OptimizerContext::offline_default();
    let res = optimize_frontier(&g, &ctx, &scfg(), 4).unwrap();
    let dir = std::env::temp_dir().join("eadgo_frontier_it_test");
    let path = dir.join("plans.json");
    eadgo::runtime::manifest::save_frontier(&path, &res.frontier).unwrap();
    let reg = eadgo::algo::AlgorithmRegistry::new();
    let back = eadgo::runtime::manifest::load_frontier(&path, &reg).unwrap();
    assert_eq!(back.len(), res.frontier.len());
    for (a, b) in res.frontier.points().iter().zip(back.points()) {
        assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph), "graph changed");
        assert_eq!(a.assignment.distance(&b.assignment), 0, "assignment changed");
        assert_eq!(a.cost.time_ms.to_bits(), b.cost.time_ms.to_bits(), "time changed");
        assert_eq!(a.cost.energy_j.to_bits(), b.cost.energy_j.to_bits(), "energy changed");
        assert_eq!(a.cost.freq, b.cost.freq, "frequency changed");
        assert_eq!(a.weight.to_bits(), b.weight.to_bits(), "weight changed");
    }
    assert_frontier_invariants(&back);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn legacy_single_plan_file_loads_as_one_point_frontier() {
    let reg = eadgo::algo::AlgorithmRegistry::new();
    let g = models::simple::build_cnn(tiny());
    let a = eadgo::algo::Assignment::default_for(&g, &reg);
    let dir = std::env::temp_dir().join("eadgo_frontier_legacy_test");
    let path = dir.join("plan.json");
    eadgo::graph::serde::save_plan(&path, &g, &a).unwrap();
    let f = eadgo::runtime::manifest::load_frontier(&path, &reg).unwrap();
    assert_eq!(f.len(), 1);
    assert_eq!(graph_hash(&f.points()[0].graph), graph_hash(&g));
    assert_eq!(f.points()[0].assignment.distance(&a), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_sweep_with_unit_batches_is_byte_identical_to_plain() {
    // `optimize_frontier_batched(.., &[1])` IS `optimize_frontier`: same
    // points bit-for-bit, and the saved manifests match byte-for-byte
    // (still version 2, no "batch" keys anywhere).
    let g = models::squeezenet::build(tiny());
    let plain = optimize_frontier(&g, &OptimizerContext::offline_default(), &scfg(), 3).unwrap();
    let batched =
        optimize_frontier_batched(&g, &OptimizerContext::offline_default(), &scfg(), 3, &[1])
            .unwrap();
    assert_eq!(plain.frontier.len(), batched.frontier.len());
    for (a, b) in plain.frontier.points().iter().zip(batched.frontier.points()) {
        assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph));
        assert_eq!(a.assignment.distance(&b.assignment), 0);
        assert_eq!(a.cost.time_ms.to_bits(), b.cost.time_ms.to_bits());
        assert_eq!(a.cost.energy_j.to_bits(), b.cost.energy_j.to_bits());
        assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        assert_eq!(b.batch, 1);
    }
    let dir = std::env::temp_dir().join("eadgo_frontier_batch1_test");
    let pa = dir.join("plain.json");
    let pb = dir.join("batched.json");
    eadgo::runtime::manifest::save_frontier(&pa, &plain.frontier).unwrap();
    eadgo::runtime::manifest::save_frontier(&pb, &batched.frontier).unwrap();
    let sa = std::fs::read_to_string(&pa).unwrap();
    let sb = std::fs::read_to_string(&pb).unwrap();
    assert_eq!(sa, sb, "batch-1 manifests must be byte-identical");
    assert!(!sa.contains("\"batch\""), "batch-1 manifest must not grow batch keys");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_sweep_produces_amortized_operating_points() {
    let g = models::squeezenet::build(tiny());
    let ctx = OptimizerContext::offline_default();
    let res = optimize_frontier_batched(&g, &ctx, &scfg(), 2, &[1, 8]).unwrap();
    assert_frontier_invariants(&res.frontier);
    assert!(res.frontier.points().iter().all(|p| p.batch == 1 || p.batch == 8));
    // Batching amortizes weight traffic and launch overhead: the
    // energy-optimal end of the surface must be a batch-8 point, and the
    // latency-optimal end a batch-1 point.
    assert_eq!(res.frontier.energy_optimal().batch, 8, "batch-8 must win energy/request");
    assert_eq!(res.frontier.latency_optimal().batch, 1, "batch-1 must win batch latency");
    // Probes carry their batch annotation (n per batch value).
    assert_eq!(res.probes.len(), 4);
    assert_eq!(res.probes.iter().filter(|p| p.batch == 8).count(), 2);
    // The manifest for a batched surface is v3 with per-plan batch.
    let dir = std::env::temp_dir().join("eadgo_frontier_batched_test");
    let path = dir.join("surface.json");
    eadgo::runtime::manifest::save_frontier(&path, &res.frontier).unwrap();
    let reg = eadgo::algo::AlgorithmRegistry::new();
    let back = eadgo::runtime::manifest::load_frontier(&path, &reg).unwrap();
    assert_eq!(back.len(), res.frontier.len());
    for (a, b) in res.frontier.points().iter().zip(back.points()) {
        assert_eq!(a.batch, b.batch, "batch lost in manifest roundtrip");
        assert_eq!(a.cost.energy_j.to_bits(), b.cost.energy_j.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn price_plan_at_batch_is_identity_at_one_and_amortizes_above() {
    let g = models::squeezenet::build(tiny());
    let ctx = OptimizerContext::offline_default();
    let res = optimize_frontier(&g, &ctx, &scfg(), 2).unwrap();
    for p in res.frontier.points() {
        let c1 = price_plan_at_batch(&ctx.oracle, &p.graph, &p.assignment, 1).unwrap();
        assert_eq!(c1.time_ms.to_bits(), p.cost.time_ms.to_bits(), "batch-1 time drifted");
        assert_eq!(c1.energy_j.to_bits(), p.cost.energy_j.to_bits(), "batch-1 energy drifted");
        let c8 = price_plan_at_batch(&ctx.oracle, &p.graph, &p.assignment, 8).unwrap();
        assert!(c8.time_ms > c1.time_ms, "a batch takes longer than a single request");
        assert!(
            c8.energy_j / 8.0 < c1.energy_j,
            "batch-8 energy/request {} must beat batch-1 {}",
            c8.energy_j / 8.0,
            c1.energy_j
        );
    }
}

#[test]
fn batched_sweep_rejects_bad_batch_lists() {
    let g = models::simple::build_cnn(tiny());
    let ctx = OptimizerContext::offline_default();
    assert!(optimize_frontier_batched(&g, &ctx, &scfg(), 2, &[]).is_err());
    assert!(optimize_frontier_batched(&g, &ctx, &scfg(), 2, &[0, 1]).is_err());
    assert!(optimize_frontier_batched(&g, &ctx, &scfg(), 2, &[1, 4, 4]).is_err());
    assert!(optimize_frontier_batched(&g, &ctx, &scfg(), 2, &[4, 1]).is_err());
}

#[test]
fn prop_pruning_is_sound_and_complete() {
    // For random candidate clouds: every kept point is non-dominated, and
    // every dropped point is dominated by (or cost-identical to) a kept one.
    let g = models::simple::build_cnn(tiny());
    let reg = eadgo::algo::AlgorithmRegistry::new();
    let a = eadgo::algo::Assignment::default_for(&g, &reg);
    check("frontier_pruning", default_cases(), |rng| {
        let n = 2 + rng.below(20);
        let cloud: Vec<PlanPoint> = (0..n)
            .map(|_| PlanPoint {
                graph: g.clone(),
                assignment: a.clone(),
                cost: GraphCost {
                    time_ms: 1.0 + rng.f64() * 9.0,
                    energy_j: 10.0 + rng.f64() * 90.0,
                    freq: FreqId::NOMINAL,
                },
                weight: rng.f64(),
                batch: 1,
            })
            .collect();
        let f = PlanFrontier::from_points(cloud.clone());
        if f.is_empty() {
            return Err("pruned a non-empty cloud to nothing".to_string());
        }
        assert_frontier_invariants(&f);
        for (i, p) in cloud.iter().enumerate() {
            let covered = f.points().iter().any(|k| {
                k.dominates(p)
                    || (k.cost.time_ms == p.cost.time_ms && k.cost.energy_j == p.cost.energy_j)
            });
            if !covered {
                return Err(format!(
                    "candidate {i} ({}, {}) neither kept nor dominated",
                    p.cost.time_ms, p.cost.energy_j
                ));
            }
        }
        Ok(())
    });
}
