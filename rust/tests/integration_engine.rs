//! Integration: engine backends. The PJRT-hybrid engine (AOT JAX/Pallas
//! artifacts) must agree numerically with the pure-rust reference engine —
//! the cross-language, cross-layer correctness seal of the architecture.
//!
//! Artifact-dependent tests are skipped (with a note) when
//! `artifacts/manifest.json` has not been built yet (`make artifacts`).

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::engine::pjrt::PjrtEngine;
use eadgo::engine::ReferenceEngine;
use eadgo::models::{self, ModelConfig};
use eadgo::runtime::Runtime;
use eadgo::tensor::Tensor;
use eadgo::util::prop::assert_close;
use eadgo::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping PJRT test");
        None
    }
}

/// The artifact suite is built for the quickstart CNN at resolution 32.
fn quickstart_cfg() -> ModelConfig {
    ModelConfig { batch: 1, resolution: 32, width_div: 4, classes: 10 }
}

#[test]
fn runtime_loads_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    let n = rt.load_dir(&dir).unwrap();
    assert!(n >= 20, "expected a full artifact suite, got {n}");
    assert!(rt.keys().any(|k| k.starts_with("model_fwd::")));
}

#[test]
fn pjrt_artifact_matches_reference_per_node() {
    // Execute one conv artifact directly and compare against the rust
    // reference implementation of the same algorithm.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let key = "conv2d;st=1,1;pad=1,1;act=none;b=1;res=0;1x3x32x32;8x3x3x3;8::direct";
    assert!(rt.has(key), "missing artifact {key}");
    let mut rng = Rng::seed_from(11);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let w = Tensor::rand(&[8, 3, 3, 3], &mut rng, -0.5, 0.5);
    let b = Tensor::rand(&[8], &mut rng, -0.1, 0.1);
    let got = rt.execute(key, &[&x, &w, &b]).unwrap();
    let want = eadgo::tensor::conv::conv2d_direct(&x, &w, Some(&b), (1, 1), (1, 1));
    assert_eq!(got[0].shape(), want.shape());
    assert_close(got[0].data(), want.data(), 1e-3, 1e-3).unwrap();
}

#[test]
fn hybrid_engine_matches_reference_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let g = models::simple::build_cnn(quickstart_cfg());
    let reg = AlgorithmRegistry::new();
    let a = Assignment::default_for(&g, &reg);
    let mut rng = Rng::seed_from(12);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);

    let ref_out = ReferenceEngine::new()
        .run(&g, &a, std::slice::from_ref(&x))
        .unwrap()
        .outputs
        .remove(0);

    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let engine = PjrtEngine::new(&rt);
    let (out, stats) = engine.run(&g, &a, std::slice::from_ref(&x)).unwrap();
    assert!(
        stats.pjrt_nodes >= 10,
        "expected most nodes on PJRT, got {} pjrt / {} ref",
        stats.pjrt_nodes,
        stats.reference_nodes
    );
    assert_close(ref_out.data(), out.outputs[0].data(), 1e-3, 1e-3).unwrap();
}

#[test]
fn hybrid_engine_respects_algorithm_assignment() {
    // Switch convs to winograd where applicable: hybrid must still match.
    let Some(dir) = artifacts_dir() else { return };
    let g = models::simple::build_cnn(quickstart_cfg());
    let reg = AlgorithmRegistry::new();
    let mut a = Assignment::default_for(&g, &reg);
    let shapes = g.infer_shapes().unwrap();
    for id in a.tunable_ids(&g, &reg) {
        let node = g.node(id);
        let in_shapes: Vec<_> = node
            .inputs
            .iter()
            .map(|p| shapes[p.node.0][p.port].clone())
            .collect();
        let algos = reg.applicable(&node.op, &in_shapes);
        if algos.contains(&eadgo::algo::Algorithm::ConvWinograd) {
            a.set(id, eadgo::algo::Algorithm::ConvWinograd);
        }
    }
    let mut rng = Rng::seed_from(13);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let ref_out = ReferenceEngine::new()
        .run(&g, &a, std::slice::from_ref(&x))
        .unwrap()
        .outputs
        .remove(0);
    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let (out, _) = PjrtEngine::new(&rt).run(&g, &a, std::slice::from_ref(&x)).unwrap();
    assert_close(ref_out.data(), out.outputs[0].data(), 1e-3, 1e-3).unwrap();
}

#[test]
fn whole_model_artifact_matches_reference() {
    // The L2 whole-model artifact (model_fwd::im2col) fed with the rust
    // engine's realized weights must match the reference engine.
    let Some(dir) = artifacts_dir() else { return };
    let g = models::simple::build_cnn(quickstart_cfg());
    let reg = AlgorithmRegistry::new();
    let a = Assignment::default_for(&g, &reg);
    let eng = ReferenceEngine::new();
    let plan = eng.plan(&g, &a).unwrap();

    // Gather weights in the python WEIGHT_SPECS order: stem_w, stem_b,
    // b1_w, b1_b, b3_w, b3_b, c2_w, c2_b, fc_w — i.e. graph weight nodes
    // in creation order.
    let mut weights: Vec<Tensor> = Vec::new();
    for (id, node) in g.nodes() {
        if matches!(node.op, eadgo::graph::OpKind::Weight { .. }) {
            weights.push(plan.constant(id.0, 0).unwrap().clone());
        }
    }
    assert_eq!(weights.len(), 9, "quickstart CNN has 9 weight tensors");

    let mut rng = Rng::seed_from(14);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let ref_out = eng.run(&g, &a, std::slice::from_ref(&x)).unwrap().outputs.remove(0);

    let mut rt = Runtime::cpu().unwrap();
    rt.load_dir(&dir).unwrap();
    let mut inputs: Vec<&Tensor> = vec![&x];
    inputs.extend(weights.iter());
    let got = rt.execute("model_fwd::im2col", &inputs).unwrap();
    assert_eq!(got[0].shape(), &[1, 10]);
    assert_close(ref_out.data(), got[0].data(), 1e-3, 1e-3).unwrap();
}

#[test]
fn reference_engine_batched_inputs() {
    let cfg = ModelConfig { batch: 4, resolution: 16, width_div: 8, classes: 10 };
    let g = models::simple::build_cnn(cfg);
    let reg = AlgorithmRegistry::new();
    let a = Assignment::default_for(&g, &reg);
    let mut rng = Rng::seed_from(15);
    let x = Tensor::rand(&[4, 3, 16, 16], &mut rng, -1.0, 1.0);
    let out = ReferenceEngine::new().run(&g, &a, &[x]).unwrap().outputs.remove(0);
    assert_eq!(out.shape(), &[4, 10]);
}
