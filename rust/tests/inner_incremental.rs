//! Property suite for the incremental warm-start inner search (ISSUE 5):
//! plans produced with `SearchConfig::incremental_inner = true` (warm
//! starts from the parent's converged plan, dirty-cone-only sweeps,
//! per-row argmin memoization) must be **bit-identical** to the cold
//! reference (`incremental_inner = false`) across the model zoo, every
//! DVFS mode, and every frontier weight — while the economy counters
//! prove the warm path actually swept only the dirty cone.

use eadgo::algo::Assignment;
use eadgo::cost::{CostFunction, CostOracle, DeltaBase};
use eadgo::energysim::FreqId;
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::serde::plan_to_json;
use eadgo::graph::DeltaView;
use eadgo::models::{self, ModelConfig};
use eadgo::search::{
    inner_search, inner_search_incremental, optimize, optimize_frontier, DvfsMode,
    OptimizerContext, SearchConfig,
};
use eadgo::subst::{MatchContext, RuleSet};

fn model_cfg() -> ModelConfig {
    ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

fn search_cfg(dvfs: DvfsMode, incremental_inner: bool) -> SearchConfig {
    SearchConfig { max_dequeues: 12, dvfs, incremental_inner, ..Default::default() }
}

/// One optimization with a fresh context; the full bit-identity witness
/// (graph bytes via hash, plan JSON, cost bit patterns).
fn run(
    model: &str,
    objective: &CostFunction,
    dvfs: DvfsMode,
    incremental_inner: bool,
) -> (u64, String, u64, u64) {
    let g = models::by_name(model, model_cfg()).unwrap_or_else(|| panic!("no model {model}"));
    let ctx = OptimizerContext::offline_default();
    let r = optimize(&g, &ctx, objective, &search_cfg(dvfs, incremental_inner)).unwrap();
    let plan_json = plan_to_json(&r.graph, &r.assignment).to_string_compact();
    (graph_hash(&r.graph), plan_json, r.cost.time_ms.to_bits(), r.cost.energy_j.to_bits())
}

#[test]
fn incremental_inner_bit_identical_across_zoo() {
    for model in models::zoo_names() {
        let warm = run(model, &CostFunction::Energy, DvfsMode::Off, true);
        let cold = run(model, &CostFunction::Energy, DvfsMode::Off, false);
        assert_eq!(warm, cold, "{model}: incremental inner search diverged from cold reference");
    }
}

#[test]
fn incremental_inner_bit_identical_across_dvfs_modes() {
    for dvfs in [DvfsMode::PerGraph, DvfsMode::PerNode] {
        for model in ["squeezenet", "resnet"] {
            let warm = run(model, &CostFunction::Energy, dvfs, true);
            let cold = run(model, &CostFunction::Energy, dvfs, false);
            assert_eq!(
                warm,
                cold,
                "{model}/dvfs={}: incremental inner search diverged",
                dvfs.describe()
            );
        }
    }
}

#[test]
fn incremental_inner_bit_identical_across_frontier_weights() {
    // Several weights: the linear objective at each frontier probe has
    // its own argmin memo key, and probes 2..N warm-start from the
    // previous probe's origin plan — none of which may move a bit.
    let run = |incremental_inner: bool| -> Vec<(String, u64, u64)> {
        let g = models::squeezenet::build(model_cfg());
        let ctx = OptimizerContext::offline_default();
        let cfg = search_cfg(DvfsMode::Off, incremental_inner);
        let r = optimize_frontier(&g, &ctx, &cfg, 4).unwrap();
        r.frontier
            .points()
            .iter()
            .map(|p| {
                (
                    plan_to_json(&p.graph, &p.assignment).to_string_compact(),
                    p.cost.time_ms.to_bits(),
                    p.cost.energy_j.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(run(true), run(false), "frontier points diverged between inner engines");
}

#[test]
fn batched_frontier_bit_identical_across_inner_engines() {
    // The batch axis multiplies the sweep (one weight sweep per batch
    // size, warm hints chained within each); every (plan, freq, batch)
    // operating point must still be bit-identical between the warm
    // incremental inner search and the cold reference — and at batches
    // [1] the surface must be exactly the plain frontier.
    use eadgo::search::optimize_frontier_batched;
    let run = |incremental_inner: bool, batches: &[usize]| -> Vec<(String, usize, u64, u64)> {
        let g = models::squeezenet::build(model_cfg());
        let ctx = OptimizerContext::offline_default();
        let cfg = search_cfg(DvfsMode::Off, incremental_inner);
        let r = optimize_frontier_batched(&g, &ctx, &cfg, 2, batches).unwrap();
        r.frontier
            .points()
            .iter()
            .map(|p| {
                (
                    plan_to_json(&p.graph, &p.assignment).to_string_compact(),
                    p.batch,
                    p.cost.time_ms.to_bits(),
                    p.cost.energy_j.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(
        run(true, &[1, 2, 4]),
        run(false, &[1, 2, 4]),
        "batched surface diverged between inner engines"
    );

    let plain = {
        let g = models::squeezenet::build(model_cfg());
        let ctx = OptimizerContext::offline_default();
        let r = optimize_frontier(&g, &ctx, &search_cfg(DvfsMode::Off, true), 2).unwrap();
        r.frontier
            .points()
            .iter()
            .map(|p| {
                (
                    plan_to_json(&p.graph, &p.assignment).to_string_compact(),
                    p.batch,
                    p.cost.time_ms.to_bits(),
                    p.cost.energy_j.to_bits(),
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(true, &[1]), plain, "batches=[1] must reproduce the plain frontier");
}

#[test]
fn mixed_objective_bit_identical() {
    let obj = CostFunction::linear(0.5);
    let warm = run("inception", &obj, DvfsMode::Off, true);
    let cold = run("inception", &obj, DvfsMode::Off, false);
    assert_eq!(warm, cold);
}

#[test]
fn warm_starts_sweep_only_dirty_nodes() {
    // The acceptance instrumentation: under an additive objective every
    // evaluated candidate warm-starts from its parent's converged plan
    // and re-derives only the delta's dirty cone.
    let g = models::squeezenet::build(model_cfg());
    let ctx = OptimizerContext::offline_default();
    let res = optimize(&g, &ctx, &CostFunction::Energy, &search_cfg(DvfsMode::Off, true)).unwrap();
    assert!(res.stats.evaluated > 0, "search evaluated no candidates");
    assert_eq!(
        res.stats.inner_warm as usize, res.stats.evaluated,
        "every candidate inner search must be warm-started"
    );
    assert_eq!(res.stats.inner_cold, 1, "only the origin runs cold");
    assert!(
        res.stats.inner_swept * 2 < res.stats.inner_nodes,
        "dirty-cone sweeps must stay far below total decisions ({} vs {})",
        res.stats.inner_swept,
        res.stats.inner_nodes
    );
    let lookups = res.stats.argmin_hits + res.stats.argmin_misses;
    assert!(lookups > 0, "incremental mode must consult the argmin memo");

    // The cold reference records no warm starts and no memo traffic, and
    // re-derives every visible node.
    let ctx2 = OptimizerContext::offline_default();
    let cold =
        optimize(&g, &ctx2, &CostFunction::Energy, &search_cfg(DvfsMode::Off, false)).unwrap();
    assert_eq!(cold.stats.inner_warm, 0);
    assert_eq!(cold.stats.argmin_hits + cold.stats.argmin_misses, 0);
    assert_eq!(cold.stats.inner_swept, cold.stats.inner_nodes);
}

#[test]
fn per_node_dvfs_candidates_warm_start() {
    let g = models::squeezenet::build(model_cfg());
    let ctx = OptimizerContext::offline_default();
    let res =
        optimize(&g, &ctx, &CostFunction::Energy, &search_cfg(DvfsMode::PerNode, true)).unwrap();
    assert!(res.stats.evaluated > 0);
    assert_eq!(res.stats.inner_warm as usize, res.stats.evaluated);
    assert!(res.stats.inner_swept * 2 < res.stats.inner_nodes);
}

#[test]
fn argmin_memo_is_exact_and_warms_across_runs() {
    // A second optimization through the same oracle answers its argmin
    // lookups almost entirely from the memo — and lands on the identical
    // plan.
    let g = models::resnet::build(model_cfg());
    let ctx = OptimizerContext::offline_default();
    let cfg = search_cfg(DvfsMode::Off, true);
    let a = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
    let b = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
    assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph));
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.cost.energy_j.to_bits(), b.cost.energy_j.to_bits());
    assert_eq!(
        b.stats.argmin_misses, 0,
        "second run over carried rows must be scan-free ({} misses)",
        b.stats.argmin_misses
    );
    assert!(b.stats.argmin_hit_rate() > 0.99);
}

#[test]
fn site_level_warm_inner_matches_cold_bit_for_bit() {
    // Unit-level core property (model-zoo-independent): for every rewrite
    // site of SqueezeNet, the candidate's warm dirty-scoped inner search
    // equals the cold full re-derivation — with and without the memo —
    // and sweeps at most the dirty cone.
    let g = models::squeezenet::build(model_cfg());
    let shapes = g.infer_shapes().unwrap();
    let consumers = g.consumers();
    let cx = MatchContext::with_shapes_and_consumers(&g, &shapes, &consumers);
    let oracle = CostOracle::offline_default();
    let mut freqs = vec![FreqId::NOMINAL];
    freqs.extend_from_slice(oracle.dvfs_freqs());
    let (base_table, _) = oracle.table_for_freqs(&g, &shapes, &freqs);
    let base_a = Assignment::default_for(&g, oracle.reg());
    let cf = CostFunction::Energy;
    let conv = inner_search(&base_table, &cf, 1, base_a.clone()).unwrap();

    let mut checked = 0usize;
    for site in RuleSet::standard().sites(&g, &cx) {
        let delta = site.delta(&g);
        let Ok(view) = DeltaView::new(&g, &shapes, delta, Some(&consumers)) else { continue };
        let base = DeltaBase {
            graph: &g,
            shapes: &shapes,
            table: &base_table,
            assignment: &base_a,
            converged: Some(&conv.assignment),
        };
        let cand = oracle.delta_table_for_freqs(&base, &view, &freqs);
        let warm = cand.warm.clone().expect("converged supplied");
        let cold = inner_search_incremental(&cand.table, &cf, cand.assignment.clone(), None, None)
            .unwrap();
        for memo in [None, Some(&oracle)] {
            let wi = inner_search_incremental(
                &cand.table,
                &cf,
                warm.clone(),
                Some(&cand.dirty),
                memo,
            )
            .unwrap();
            assert_eq!(wi.assignment, cold.assignment, "{}: warm plan diverged", site.rule_name());
            assert_eq!(wi.cost.energy_j.to_bits(), cold.cost.energy_j.to_bits());
            assert_eq!(wi.cost.time_ms.to_bits(), cold.cost.time_ms.to_bits());
            assert!(wi.swept <= cand.dirty.len() as u64);
            assert!(wi.swept <= wi.nodes);
        }
        checked += 1;
    }
    assert!(checked > 0, "squeezenet must expose rewrite sites");
}
