//! Determinism contract of the optimizer (ISSUE 1 acceptance criteria):
//!
//! 1. Same model + seed + config → byte-identical `--save-plan` JSON
//!    across repeated runs (fresh contexts each time).
//! 2. Parallel candidate evaluation (`threads: 8`) returns a bit-identical
//!    `(graph, assignment, cost)` to the sequential path (`threads: 1`)
//!    on every zoo model.
//!
//! The batched-wave outer search guarantees this by popping the α-band
//! frontier before evaluation and merging results in candidate sequence
//! order, so thread scheduling can never reorder best/enqueue decisions.

use eadgo::cost::CostFunction;
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::serde::plan_to_json;
use eadgo::models::{self, ModelConfig};
use eadgo::search::{optimize, OptimizerContext, SearchConfig};

fn model_cfg() -> ModelConfig {
    // compute-bound scale (the sim provider is analytic; size is free),
    // small search budget to keep the full zoo sweep fast.
    ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

fn search_cfg(threads: usize) -> SearchConfig {
    SearchConfig { max_dequeues: 16, threads, ..Default::default() }
}

/// One full optimization with a fresh context; returns everything the
/// determinism contract covers, with costs as exact bit patterns.
fn run(model: &str, objective: &CostFunction, threads: usize) -> (u64, String, u64, u64) {
    let g = models::by_name(model, model_cfg()).unwrap_or_else(|| panic!("no model {model}"));
    let ctx = OptimizerContext::offline_default();
    let r = optimize(&g, &ctx, objective, &search_cfg(threads)).unwrap();
    let plan_json = plan_to_json(&r.graph, &r.assignment).to_string_compact();
    (graph_hash(&r.graph), plan_json, r.cost.time_ms.to_bits(), r.cost.energy_j.to_bits())
}

#[test]
fn repeated_runs_produce_identical_plan_json() {
    for objective in [CostFunction::Energy, CostFunction::linear(0.5)] {
        let a = run("squeezenet", &objective, 1);
        let b = run("squeezenet", &objective, 1);
        assert_eq!(a, b, "sequential reruns diverged for {}", objective.describe());
    }
}

#[test]
fn parallel_equals_sequential_on_every_zoo_model() {
    for model in models::zoo_names() {
        let seq = run(model, &CostFunction::Energy, 1);
        let par = run(model, &CostFunction::Energy, 8);
        assert_eq!(
            seq, par,
            "{model}: threads=8 diverged from threads=1 (graph hash / plan JSON / cost bits)"
        );
    }
}

#[test]
fn parallel_is_deterministic_across_repeats() {
    // Not just equal to sequential: two threads=8 runs must also agree
    // with each other (no dependence on thread scheduling).
    let a = run("resnet", &CostFunction::Energy, 8);
    let b = run("resnet", &CostFunction::Energy, 8);
    assert_eq!(a, b);
}

#[test]
fn auto_threads_matches_sequential() {
    // threads: 0 resolves to available parallelism; same contract.
    let seq = run("inception", &CostFunction::Energy, 1);
    let auto = run("inception", &CostFunction::Energy, 0);
    assert_eq!(seq, auto);
}

#[test]
fn search_stats_structure_is_thread_invariant() {
    // Expansion/generation/dedup counts describe the search trajectory,
    // which must not depend on the worker count.
    let g = models::squeezenet::build(model_cfg());
    let stats = |threads: usize| {
        let ctx = OptimizerContext::offline_default();
        let r = optimize(&g, &ctx, &CostFunction::Energy, &search_cfg(threads)).unwrap();
        (r.stats.expanded, r.stats.generated, r.stats.deduped, r.stats.waves, r.stats.profiled)
    };
    assert_eq!(stats(1), stats(8));
}
