//! Determinism contract of the optimizer (ISSUE 1 acceptance criteria,
//! extended with the ISSUE 2 DVFS axis):
//!
//! 1. Same model + seed + config → byte-identical `--save-plan` JSON
//!    across repeated runs (fresh contexts each time).
//! 2. Parallel candidate evaluation returns a bit-identical
//!    `(graph, assignment, cost)` to the sequential path (`threads: 1`)
//!    on every zoo model — with and without the DVFS frequency axis.
//!
//! The batched-wave outer search guarantees this by popping the α-band
//! frontier before evaluation and merging results in candidate sequence
//! order, so thread scheduling can never reorder best/enqueue decisions.
//!
//! CI runs this suite as a matrix over `EADGO_TEST_THREADS` (1/4/8) to
//! catch merge-order regressions that one fixed worker count can miss;
//! unset, the parallel runs use 8 workers.
//!
//! ISSUE 4 extends the contract to the delta substitution engine:
//! candidate evaluation through `RewriteSite` deltas (`delta_eval: true`,
//! the default) must reproduce the legacy full-rebuild path
//! (`delta_eval: false`) bit for bit — for `optimize` across the zoo and
//! DVFS modes, and for every point of an `optimize --frontier` Pareto
//! set.

use eadgo::cost::CostFunction;
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::serde::plan_to_json;
use eadgo::models::{self, ModelConfig};
use eadgo::search::{optimize, DvfsMode, OptimizerContext, SearchConfig};

fn model_cfg() -> ModelConfig {
    // compute-bound scale (the sim provider is analytic; size is free),
    // small search budget to keep the full zoo sweep fast.
    ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

/// Worker count of the "parallel" runs — the CI determinism matrix sets
/// EADGO_TEST_THREADS to 1, 4, and 8.
fn par_threads() -> usize {
    std::env::var("EADGO_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8)
}

fn search_cfg(threads: usize, dvfs: DvfsMode) -> SearchConfig {
    SearchConfig { max_dequeues: 16, threads, dvfs, ..Default::default() }
}

/// One full optimization with a fresh context; returns everything the
/// determinism contract covers, with costs as exact bit patterns. The
/// plan JSON includes the per-node frequency states when DVFS is on.
fn run(
    model: &str,
    objective: &CostFunction,
    threads: usize,
    dvfs: DvfsMode,
) -> (u64, String, u64, u64) {
    run_with_engine(model, objective, threads, dvfs, true)
}

/// As [`run`], selecting the candidate-evaluation engine: `delta_eval =
/// true` is the incremental delta path, `false` the legacy full-rebuild
/// path kept as the reference implementation.
fn run_with_engine(
    model: &str,
    objective: &CostFunction,
    threads: usize,
    dvfs: DvfsMode,
    delta_eval: bool,
) -> (u64, String, u64, u64) {
    let g = models::by_name(model, model_cfg()).unwrap_or_else(|| panic!("no model {model}"));
    let ctx = OptimizerContext::offline_default();
    let cfg = SearchConfig { delta_eval, ..search_cfg(threads, dvfs) };
    let r = optimize(&g, &ctx, objective, &cfg).unwrap();
    let plan_json = plan_to_json(&r.graph, &r.assignment).to_string_compact();
    (graph_hash(&r.graph), plan_json, r.cost.time_ms.to_bits(), r.cost.energy_j.to_bits())
}

#[test]
fn repeated_runs_produce_identical_plan_json() {
    for objective in [CostFunction::Energy, CostFunction::linear(0.5)] {
        let a = run("squeezenet", &objective, 1, DvfsMode::Off);
        let b = run("squeezenet", &objective, 1, DvfsMode::Off);
        assert_eq!(a, b, "sequential reruns diverged for {}", objective.describe());
    }
}

#[test]
fn parallel_equals_sequential_on_every_zoo_model() {
    for model in models::zoo_names() {
        let seq = run(model, &CostFunction::Energy, 1, DvfsMode::Off);
        let par = run(model, &CostFunction::Energy, par_threads(), DvfsMode::Off);
        assert_eq!(
            seq, par,
            "{model}: threads={} diverged from threads=1 (graph hash / plan JSON / cost bits)",
            par_threads()
        );
    }
}

#[test]
fn parallel_is_deterministic_across_repeats() {
    // Not just equal to sequential: two parallel runs must also agree
    // with each other (no dependence on thread scheduling).
    let a = run("resnet", &CostFunction::Energy, par_threads(), DvfsMode::Off);
    let b = run("resnet", &CostFunction::Energy, par_threads(), DvfsMode::Off);
    assert_eq!(a, b);
}

#[test]
fn auto_threads_matches_sequential() {
    // threads: 0 resolves to available parallelism; same contract.
    let seq = run("inception", &CostFunction::Energy, 1, DvfsMode::Off);
    let auto = run("inception", &CostFunction::Energy, 0, DvfsMode::Off);
    assert_eq!(seq, auto);
}

#[test]
fn dvfs_plans_bit_identical_across_thread_counts() {
    // The new search axis must not leak thread scheduling into the plan:
    // per-graph and per-node frequency choices are made inside candidate
    // evaluation and merged in sequence order like everything else.
    for dvfs in [DvfsMode::PerGraph, DvfsMode::PerNode] {
        for model in ["squeezenet", "resnet"] {
            let seq = run(model, &CostFunction::Energy, 1, dvfs);
            let par = run(model, &CostFunction::Energy, par_threads(), dvfs);
            assert_eq!(
                seq,
                par,
                "{model}/dvfs={}: threads={} diverged from threads=1",
                dvfs.describe(),
                par_threads()
            );
        }
    }
}

#[test]
fn dvfs_linear_objective_deterministic() {
    // Frequency ties under a mixed objective must resolve identically
    // regardless of worker count (NOMINAL-first tie-break).
    let obj = CostFunction::linear(0.5);
    let seq = run("inception", &obj, 1, DvfsMode::PerGraph);
    let par = run("inception", &obj, par_threads(), DvfsMode::PerGraph);
    assert_eq!(seq, par);
}

#[test]
fn delta_engine_reproduces_full_rebuild_plans_bit_for_bit() {
    // The substitution-engine refactor contract: candidate evaluation
    // through RewriteSite deltas (incremental hash, carry-over cost
    // tables, lazy materialization) must choose the exact plan the legacy
    // full-rebuild path chooses — same graph bytes, same assignment, same
    // cost bits — on every zoo model.
    for model in models::zoo_names() {
        let delta = run_with_engine(model, &CostFunction::Energy, 1, DvfsMode::Off, true);
        let full = run_with_engine(model, &CostFunction::Energy, 1, DvfsMode::Off, false);
        assert_eq!(delta, full, "{model}: delta engine diverged from full rebuild");
    }
    // And across the DVFS modes (per-state restriction + joint tables).
    for dvfs in [DvfsMode::PerGraph, DvfsMode::PerNode] {
        for model in ["squeezenet", "resnet"] {
            let delta = run_with_engine(model, &CostFunction::Energy, 1, dvfs, true);
            let full = run_with_engine(model, &CostFunction::Energy, 1, dvfs, false);
            assert_eq!(
                delta,
                full,
                "{model}/dvfs={}: delta engine diverged from full rebuild",
                dvfs.describe()
            );
        }
    }
    // Mixed objective (normalized linear) exercises the α-band with
    // non-trivial tie structure.
    let delta = run_with_engine("inception", &CostFunction::linear(0.5), 1, DvfsMode::Off, true);
    let full = run_with_engine("inception", &CostFunction::linear(0.5), 1, DvfsMode::Off, false);
    assert_eq!(delta, full);
}

#[test]
fn frontier_plans_identical_across_engines() {
    // `optimize --frontier` must also be engine-invariant: every point of
    // the Pareto set byte-identical between delta and full evaluation.
    use eadgo::search::optimize_frontier;
    let run = |delta_eval: bool| -> Vec<(String, u64, u64)> {
        let g = models::squeezenet::build(model_cfg());
        let ctx = OptimizerContext::offline_default();
        let cfg = SearchConfig { max_dequeues: 16, delta_eval, ..Default::default() };
        let r = optimize_frontier(&g, &ctx, &cfg, 3).unwrap();
        r.frontier
            .points()
            .iter()
            .map(|p| {
                (
                    plan_to_json(&p.graph, &p.assignment).to_string_compact(),
                    p.cost.time_ms.to_bits(),
                    p.cost.energy_j.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(run(true), run(false), "frontier points diverged between engines");
}

#[test]
fn batch1_frontier_manifests_byte_identical_across_engine_matrix() {
    // ISSUE 6 bit-identity guard: with the batch axis present but unused
    // (batches = [1]), every delta_eval × incremental_inner engine
    // combination must produce byte-identical frontier manifests — still
    // version 2 with no "batch" keys, so plan files saved before the
    // batch axis stay reproducible byte-for-byte.
    use eadgo::search::optimize_frontier_batched;
    let manifest = |delta_eval: bool, incremental_inner: bool| -> String {
        let g = models::squeezenet::build(model_cfg());
        let ctx = OptimizerContext::offline_default();
        let cfg = SearchConfig {
            max_dequeues: 16,
            delta_eval,
            incremental_inner,
            ..Default::default()
        };
        let r = optimize_frontier_batched(&g, &ctx, &cfg, 3, &[1]).unwrap();
        eadgo::runtime::manifest::frontier_to_json(&r.frontier).to_string_compact()
    };
    let reference = manifest(true, true);
    assert!(reference.contains("\"version\":2"), "batch-1 manifest must stay v2");
    assert!(!reference.contains("\"batch\""), "batch-1 manifest must not grow batch keys");
    for (d, i) in [(true, false), (false, true), (false, false)] {
        assert_eq!(
            reference,
            manifest(d, i),
            "engine matrix (delta_eval={d}, incremental_inner={i}) diverged at batch 1"
        );
    }
}

#[test]
fn batched_frontier_points_identical_across_engines() {
    // The batch axis itself must be engine-invariant: a (plan, freq,
    // batch) surface serializes identically whether candidates were
    // evaluated through RewriteSite deltas or full rebuilds.
    use eadgo::search::optimize_frontier_batched;
    let run = |delta_eval: bool| -> String {
        let g = models::squeezenet::build(model_cfg());
        let ctx = OptimizerContext::offline_default();
        let cfg = SearchConfig { max_dequeues: 16, delta_eval, ..Default::default() };
        let r = optimize_frontier_batched(&g, &ctx, &cfg, 2, &[1, 4]).unwrap();
        eadgo::runtime::manifest::frontier_to_json(&r.frontier).to_string_compact()
    };
    let delta = run(true);
    assert!(delta.contains("\"version\":3"), "a batched surface must serialize as v3");
    assert_eq!(delta, run(false), "batched frontier diverged between engines");
}

#[test]
fn search_stats_structure_is_thread_invariant() {
    // Expansion/generation/dedup counts describe the search trajectory,
    // which must not depend on the worker count — including with DVFS.
    let g = models::squeezenet::build(model_cfg());
    let stats = |threads: usize, dvfs: DvfsMode| {
        let ctx = OptimizerContext::offline_default();
        let r = optimize(&g, &ctx, &CostFunction::Energy, &search_cfg(threads, dvfs)).unwrap();
        (r.stats.expanded, r.stats.generated, r.stats.deduped, r.stats.waves, r.stats.profiled)
    };
    assert_eq!(stats(1, DvfsMode::Off), stats(par_threads(), DvfsMode::Off));
    assert_eq!(stats(1, DvfsMode::PerNode), stats(par_threads(), DvfsMode::PerNode));
}
