//! Failure injection: corrupt persistence files, poisoned tensors, invalid
//! assignments, and hostile manifests must produce errors, not wrong
//! answers or panics.

use eadgo::algo::{Algorithm, AlgorithmRegistry, Assignment};
use eadgo::cost::CostDb;
use eadgo::engine::ReferenceEngine;
use eadgo::graph::{serde as gserde, Activation, Graph, OpKind, PortRef};
use eadgo::models::{self, ModelConfig};
use eadgo::runtime::Manifest;
use eadgo::tensor::Tensor;
use eadgo::util::rng::Rng;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("eadgo_failinj_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupt_cost_db_is_error_and_load_or_default_recovers() {
    let dir = tmpdir("db");
    let path = dir.join("profiles.json");
    std::fs::write(&path, "{ not json at all").unwrap();
    assert!(CostDb::load(&path).is_err());
    // the CLI path degrades to an empty db rather than crashing
    let db = CostDb::load_or_default(&path);
    assert_eq!(db.num_entries(), 0);
    // truncated-but-valid-json with wrong schema
    std::fs::write(&path, r#"{"profiles": 42}"#).unwrap();
    assert!(CostDb::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = tmpdir("manifest");
    let path = dir.join("manifest.json");
    for bad in [
        "{",                                     // not json
        r#"{"artifacts": "nope"}"#,              // wrong type
        r#"{"artifacts": [{"key": "k"}]}"#,      // missing file
        r#"{"artifacts": [{"file": "x.hlo"}]}"#, // missing key
    ] {
        std::fs::write(&path, bad).unwrap();
        assert!(Manifest::load(&path).is_err(), "accepted: {bad}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_pointing_at_missing_files_fails_at_load() {
    let dir = tmpdir("missingfile");
    let m = Manifest {
        entries: vec![eadgo::runtime::ArtifactEntry {
            key: "ghost::std".into(),
            file: "does_not_exist.hlo.txt".into(),
            input_shapes: vec![vec![1]],
            output_shapes: vec![vec![1]],
            kernel: "jnp".into(),
        }],
    };
    m.save(&dir.join("manifest.json")).unwrap();
    let mut rt = eadgo::runtime::Runtime::cpu().unwrap();
    assert!(rt.load_dir(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn nan_input_propagates_through_linear_ops() {
    // Through a conv (no activation) a poisoned input must surface as NaN
    // in the output — all_finite() is the detection hook. (ReLU layers
    // mask NaN via f32::max — same as real frameworks — so the check is on
    // the linear path.)
    let mut g = Graph::new();
    let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
    let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
    let c = g.add1(
        OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        },
        &[x, w],
        "c",
    );
    g.outputs = vec![PortRef::of(c)];
    let reg = AlgorithmRegistry::new();
    let a = Assignment::default_for(&g, &reg);
    let mut xt = Tensor::zeros(&[1, 3, 8, 8]);
    xt.data_mut()[0] = f32::NAN;
    let out = ReferenceEngine::new().run(&g, &a, &[xt]).unwrap().outputs.remove(0);
    assert!(!out.all_finite());
}

#[test]
fn inapplicable_algorithm_assignment_is_runtime_error() {
    // Assign winograd to a 1x1 conv: engine must refuse, not miscompute.
    let mut g = Graph::new();
    let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
    let w = g.add1(OpKind::weight(vec![4, 3, 1, 1], 1), &[], "w");
    let c = g.add1(
        OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        },
        &[x, w],
        "c",
    );
    g.outputs = vec![PortRef::of(c)];
    let reg = AlgorithmRegistry::new();
    let mut a = Assignment::default_for(&g, &reg);
    a.set(c, Algorithm::ConvWinograd);
    let mut rng = Rng::seed_from(1);
    let xt = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
    assert!(ReferenceEngine::new().run(&g, &a, &[xt]).is_err());
}

#[test]
fn corrupt_plan_files_rejected() {
    let reg = AlgorithmRegistry::new();
    let dir = tmpdir("plan");
    let path = dir.join("plan.json");
    // assignment array with wrong length
    let g = models::simple::build_cnn(ModelConfig {
        batch: 1,
        resolution: 16,
        width_div: 8,
        classes: 10,
    });
    let mut j = gserde::graph_to_json(&g);
    j.set("assignment", vec![0.0f64]); // wrong length, wrong type
    eadgo::util::json::write_file(&path, &j).unwrap();
    assert!(gserde::load_plan(&path, &reg).is_err());
    // unknown algorithm name
    let mut j2 = gserde::plan_to_json(&g, &Assignment::default_for(&g, &reg));
    if let eadgo::util::json::Json::Obj(m) = &mut j2 {
        if let Some(eadgo::util::json::Json::Arr(a)) = m.get_mut("assignment") {
            // find first non-null slot and poison it
            for slot in a.iter_mut() {
                if !matches!(slot, eadgo::util::json::Json::Null) {
                    *slot = eadgo::util::json::Json::Str("quantum_annealing".into());
                    break;
                }
            }
        }
    }
    eadgo::util::json::write_file(&path, &j2).unwrap();
    assert!(gserde::load_plan(&path, &reg).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn graph_with_dangling_output_rejected_on_load() {
    let j = eadgo::util::json::parse(
        r#"{"nodes": [{"op": "input", "shape": [1, 3, 4, 4], "inputs": []}],
            "outputs": [[7, 0]]}"#,
    )
    .unwrap();
    assert!(gserde::graph_from_json(&j).is_err());
}

#[test]
fn zero_size_serving_config_rejected() {
    let bad = eadgo::serve::ServeConfig { requests: 0, ..Default::default() };
    assert!(eadgo::serve::ServeSession::new(&bad).run(|_, b| Ok(b.to_vec())).is_err());
    let bad2 = eadgo::serve::ServeConfig { batch_max: 0, ..Default::default() };
    assert!(eadgo::serve::ServeSession::new(&bad2).run(|_, b| Ok(b.to_vec())).is_err());
}

// ---------------------------------------------------------------------------
// Hostile frontier manifests (v3/v4/v5/v6): every doctored file must be a
// typed load error, never a panic or a silently-defaulted plan.
// ---------------------------------------------------------------------------

fn frontier_fixture() -> eadgo::search::PlanFrontier {
    use eadgo::cost::GraphCost;
    use eadgo::energysim::FreqId;
    let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
    let reg = AlgorithmRegistry::new();
    let g = models::simple::build_cnn(cfg);
    let fast = Assignment::default_for(&g, &reg);
    let mut slow = fast.clone();
    slow.set_uniform_freq(FreqId(900));
    eadgo::search::PlanFrontier::from_points(vec![
        eadgo::search::PlanPoint {
            graph: g.clone(),
            assignment: fast,
            cost: GraphCost { time_ms: 1.0, energy_j: 250.0, freq: FreqId::NOMINAL },
            weight: 0.0,
            batch: 1,
        },
        eadgo::search::PlanPoint {
            graph: g,
            assignment: slow,
            cost: GraphCost { time_ms: 2.5, energy_j: 125.0, freq: FreqId(900) },
            weight: 1.0,
            batch: 1,
        },
    ])
}

fn load_frontier_str(s: &str) -> anyhow::Result<eadgo::search::PlanFrontier> {
    let j = eadgo::util::json::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
    eadgo::runtime::manifest::frontier_from_json(&j, &AlgorithmRegistry::new())
}

#[test]
fn hostile_manifest_batch_below_one_rejected() {
    use eadgo::cost::GraphCost;
    use eadgo::energysim::FreqId;
    let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
    let reg = AlgorithmRegistry::new();
    let g = models::simple::build_cnn(cfg);
    let a = Assignment::default_for(&g, &reg);
    let g8 = g.rebatch(8).unwrap();
    let f = eadgo::search::PlanFrontier::from_points(vec![
        eadgo::search::PlanPoint {
            graph: g,
            assignment: a.clone(),
            cost: GraphCost { time_ms: 1.0, energy_j: 250.0, freq: FreqId::NOMINAL },
            weight: 0.0,
            batch: 1,
        },
        eadgo::search::PlanPoint {
            graph: g8,
            assignment: a,
            cost: GraphCost { time_ms: 2.5, energy_j: 800.0, freq: FreqId::NOMINAL },
            weight: 1.0,
            batch: 8,
        },
    ]);
    let s = eadgo::runtime::manifest::frontier_to_json(&f).to_string_compact();
    assert!(s.contains("\"batch\":8"), "fixture lost its batch annotation: {s}");
    let err = load_frontier_str(&s.replace("\"batch\":8", "\"batch\":0")).unwrap_err().to_string();
    assert!(err.contains("batch"), "{err}");
}

#[test]
fn hostile_manifest_unknown_device_rejected() {
    use eadgo::energysim::{DeviceId, FreqId};
    use eadgo::graph::OpKind;
    let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
    let reg = AlgorithmRegistry::new();
    let g = models::simple::build_cnn(cfg);
    let mut mixed = Assignment::default_for(&g, &reg);
    let conv = g.nodes().find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. })).unwrap().0;
    mixed.set_freq(conv, FreqId::on(DeviceId::DLA, 0));
    let f = eadgo::search::PlanFrontier::from_points(vec![eadgo::search::PlanPoint {
        graph: g,
        assignment: mixed,
        cost: eadgo::cost::GraphCost {
            time_ms: 1.0,
            energy_j: 90.0,
            freq: FreqId::NOMINAL,
        },
        weight: 1.0,
        batch: 1,
    }]);
    let s = eadgo::runtime::manifest::frontier_to_json(&f).to_string_compact();
    assert!(s.contains("\"dla\""), "fixture lost its device array: {s}");
    let err = load_frontier_str(&s.replace("\"dla\"", "\"npu\"")).unwrap_err().to_string();
    assert!(err.contains("device") || err.contains("npu"), "{err}");
}

#[test]
fn hostile_manifest_layout_on_v2_rejected() {
    use eadgo::energysim::Layout;
    use eadgo::graph::OpKind;
    // A genuine layout-mixed (v5) manifest whose version stamp is rolled
    // back to 2: the layout array is now a key the declared format cannot
    // carry — typed error, not a silently-honored layout.
    let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
    let reg = AlgorithmRegistry::new();
    let g = models::simple::build_cnn(cfg);
    let mut mixed = Assignment::default_for(&g, &reg);
    let conv = g.nodes().find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. })).unwrap().0;
    mixed.set_freq(conv, mixed.freq(conv).with_layout(Layout::NHWC));
    let f = eadgo::search::PlanFrontier::from_points(vec![eadgo::search::PlanPoint {
        graph: g,
        assignment: mixed,
        cost: eadgo::cost::GraphCost {
            time_ms: 1.0,
            energy_j: 200.0,
            freq: eadgo::energysim::FreqId::NOMINAL,
        },
        weight: 1.0,
        batch: 1,
    }]);
    let s = eadgo::runtime::manifest::frontier_to_json(&f).to_string_compact();
    assert!(s.contains("\"version\":5"), "fixture is not a v5 manifest: {s}");
    let err = load_frontier_str(&s.replace("\"version\":5", "\"version\":2"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("layout") && err.contains("version"), "{err}");
}

#[test]
fn hostile_manifest_contingency_on_v5_rejected() {
    let f = frontier_fixture();
    let fallback = eadgo::runtime::manifest::ContingencyPlan {
        graph: f.points()[0].graph.clone(),
        assignment: f.points()[0].assignment.clone(),
        cost: f.points()[0].cost,
    };
    let s = eadgo::runtime::manifest::frontier_to_json_full(&f, &[None, Some(fallback)])
        .to_string_compact();
    assert!(s.contains("\"version\":6"), "fixture is not a v6 manifest: {s}");
    let err = load_frontier_str(&s.replace("\"version\":6", "\"version\":5"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("contingency") && err.contains("version"), "{err}");
}

#[test]
fn hostile_fault_plans_rejected() {
    use eadgo::serve::FaultPlan;
    for (bad, why) in [
        (r#"{"events": "nope"}"#, "events not an array"),
        (r#"{"events": [{"kind": "device_lost", "device": "gpu"}]}"#, "missing at_s"),
        (r#"{"events": [{"at_s": 1.0, "kind": "meteor_strike"}]}"#, "unknown kind"),
        (r#"{"events": [{"at_s": 1.0, "kind": "device_lost", "device": "npu"}]}"#, "unknown device"),
        (
            r#"{"events": [{"at_s": 1.0, "kind": "thermal_cap", "device": "gpu"}]}"#,
            "missing max_mhz",
        ),
        (
            r#"{"events": [{"at_s": 1.0, "kind": "transient_error", "rate": 1.5, "duration_s": 1.0}]}"#,
            "rate out of range",
        ),
        (r#"{"events": [], "max_retries": 99}"#, "max_retries out of range"),
        (r#"{"events": [], "retry_budget_s": 0.0}"#, "retry_budget_s not positive"),
    ] {
        let j = eadgo::util::json::parse(bad).unwrap();
        assert!(FaultPlan::from_json(&j).is_err(), "accepted ({why}): {bad}");
    }
}

#[test]
fn cost_table_missing_profile_is_error() {
    // GraphCostTable::build against an empty DB must name the gap.
    let g = models::simple::build_cnn(ModelConfig {
        batch: 1,
        resolution: 16,
        width_div: 8,
        classes: 10,
    });
    let reg = AlgorithmRegistry::new();
    let db = CostDb::new();
    let err = eadgo::cost::GraphCostTable::build(&g, &reg, &db).unwrap_err();
    assert!(err.to_string().contains("run the profiler"), "{err}");
}
