//! Failure injection: corrupt persistence files, poisoned tensors, invalid
//! assignments, and hostile manifests must produce errors, not wrong
//! answers or panics.

use eadgo::algo::{Algorithm, AlgorithmRegistry, Assignment};
use eadgo::cost::CostDb;
use eadgo::engine::ReferenceEngine;
use eadgo::graph::{serde as gserde, Activation, Graph, OpKind, PortRef};
use eadgo::models::{self, ModelConfig};
use eadgo::runtime::Manifest;
use eadgo::tensor::Tensor;
use eadgo::util::rng::Rng;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("eadgo_failinj_{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupt_cost_db_is_error_and_load_or_default_recovers() {
    let dir = tmpdir("db");
    let path = dir.join("profiles.json");
    std::fs::write(&path, "{ not json at all").unwrap();
    assert!(CostDb::load(&path).is_err());
    // the CLI path degrades to an empty db rather than crashing
    let db = CostDb::load_or_default(&path);
    assert_eq!(db.num_entries(), 0);
    // truncated-but-valid-json with wrong schema
    std::fs::write(&path, r#"{"profiles": 42}"#).unwrap();
    assert!(CostDb::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = tmpdir("manifest");
    let path = dir.join("manifest.json");
    for bad in [
        "{",                                     // not json
        r#"{"artifacts": "nope"}"#,              // wrong type
        r#"{"artifacts": [{"key": "k"}]}"#,      // missing file
        r#"{"artifacts": [{"file": "x.hlo"}]}"#, // missing key
    ] {
        std::fs::write(&path, bad).unwrap();
        assert!(Manifest::load(&path).is_err(), "accepted: {bad}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_pointing_at_missing_files_fails_at_load() {
    let dir = tmpdir("missingfile");
    let m = Manifest {
        entries: vec![eadgo::runtime::ArtifactEntry {
            key: "ghost::std".into(),
            file: "does_not_exist.hlo.txt".into(),
            input_shapes: vec![vec![1]],
            output_shapes: vec![vec![1]],
            kernel: "jnp".into(),
        }],
    };
    m.save(&dir.join("manifest.json")).unwrap();
    let mut rt = eadgo::runtime::Runtime::cpu().unwrap();
    assert!(rt.load_dir(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn nan_input_propagates_through_linear_ops() {
    // Through a conv (no activation) a poisoned input must surface as NaN
    // in the output — all_finite() is the detection hook. (ReLU layers
    // mask NaN via f32::max — same as real frameworks — so the check is on
    // the linear path.)
    let mut g = Graph::new();
    let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
    let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
    let c = g.add1(
        OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        },
        &[x, w],
        "c",
    );
    g.outputs = vec![PortRef::of(c)];
    let reg = AlgorithmRegistry::new();
    let a = Assignment::default_for(&g, &reg);
    let mut xt = Tensor::zeros(&[1, 3, 8, 8]);
    xt.data_mut()[0] = f32::NAN;
    let out = ReferenceEngine::new().run(&g, &a, &[xt]).unwrap().outputs.remove(0);
    assert!(!out.all_finite());
}

#[test]
fn inapplicable_algorithm_assignment_is_runtime_error() {
    // Assign winograd to a 1x1 conv: engine must refuse, not miscompute.
    let mut g = Graph::new();
    let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
    let w = g.add1(OpKind::weight(vec![4, 3, 1, 1], 1), &[], "w");
    let c = g.add1(
        OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        },
        &[x, w],
        "c",
    );
    g.outputs = vec![PortRef::of(c)];
    let reg = AlgorithmRegistry::new();
    let mut a = Assignment::default_for(&g, &reg);
    a.set(c, Algorithm::ConvWinograd);
    let mut rng = Rng::seed_from(1);
    let xt = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
    assert!(ReferenceEngine::new().run(&g, &a, &[xt]).is_err());
}

#[test]
fn corrupt_plan_files_rejected() {
    let reg = AlgorithmRegistry::new();
    let dir = tmpdir("plan");
    let path = dir.join("plan.json");
    // assignment array with wrong length
    let g = models::simple::build_cnn(ModelConfig {
        batch: 1,
        resolution: 16,
        width_div: 8,
        classes: 10,
    });
    let mut j = gserde::graph_to_json(&g);
    j.set("assignment", vec![0.0f64]); // wrong length, wrong type
    eadgo::util::json::write_file(&path, &j).unwrap();
    assert!(gserde::load_plan(&path, &reg).is_err());
    // unknown algorithm name
    let mut j2 = gserde::plan_to_json(&g, &Assignment::default_for(&g, &reg));
    if let eadgo::util::json::Json::Obj(m) = &mut j2 {
        if let Some(eadgo::util::json::Json::Arr(a)) = m.get_mut("assignment") {
            // find first non-null slot and poison it
            for slot in a.iter_mut() {
                if !matches!(slot, eadgo::util::json::Json::Null) {
                    *slot = eadgo::util::json::Json::Str("quantum_annealing".into());
                    break;
                }
            }
        }
    }
    eadgo::util::json::write_file(&path, &j2).unwrap();
    assert!(gserde::load_plan(&path, &reg).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn graph_with_dangling_output_rejected_on_load() {
    let j = eadgo::util::json::parse(
        r#"{"nodes": [{"op": "input", "shape": [1, 3, 4, 4], "inputs": []}],
            "outputs": [[7, 0]]}"#,
    )
    .unwrap();
    assert!(gserde::graph_from_json(&j).is_err());
}

#[test]
fn zero_size_serving_config_rejected() {
    let bad = eadgo::serve::ServeConfig { requests: 0, ..Default::default() };
    assert!(eadgo::serve::ServeSession::new(&bad).run(|_, b| Ok(b.to_vec())).is_err());
    let bad2 = eadgo::serve::ServeConfig { batch_max: 0, ..Default::default() };
    assert!(eadgo::serve::ServeSession::new(&bad2).run(|_, b| Ok(b.to_vec())).is_err());
}

#[test]
fn cost_table_missing_profile_is_error() {
    // GraphCostTable::build against an empty DB must name the gap.
    let g = models::simple::build_cnn(ModelConfig {
        batch: 1,
        resolution: 16,
        width_div: 8,
        classes: 10,
    });
    let reg = AlgorithmRegistry::new();
    let db = CostDb::new();
    let err = eadgo::cost::GraphCostTable::build(&g, &reg, &db).unwrap_err();
    assert!(err.to_string().contains("run the profiler"), "{err}");
}
