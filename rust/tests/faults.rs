//! ISSUE 10 acceptance suite: fault-tolerant serving.
//!
//! Drives [`ServeSession`] with deterministic, seeded fault plans against a
//! mixed GPU+DLA surface (the `SimHeteroProvider` world of the placement
//! suite) and locks down the robustness contract from five sides:
//!
//! 1. **Device-loss contingency** — a seeded `DeviceLost{dla}` plan against
//!    a two-plan GPU+DLA surface: zero panics, zero dropped admitted
//!    requests, exactly one contingency hot-swap through the adopt
//!    callback, and post-fault true energy/request within 5% of the best
//!    GPU-only plan on the same surface.
//! 2. **Bitwise replay** — the same seed plus the same fault plan (thermal
//!    cap + transient-error window + device loss) renders byte-identical
//!    `ServeReport` JSON across runs, including retry and shed decisions.
//! 3. **Research-panic liveness** — an injected background re-search panic
//!    surfaces as a `ResearchFailed` degrade while every request is served.
//! 4. **No drift misfire** — a thermal-cap slowdown re-prices the surface
//!    and scales the service clock coherently, so the drift detector never
//!    arms on a known hardware event.
//! 5. **Byte-invisibility** — an eventless fault plan changes nothing: the
//!    report is byte-identical to a run without one.
//!
//! Everything runs under [`ServiceModel::Virtual`], so reports are a
//! deterministic function of (config, fault plan) and host-speed free.

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::cost::{CostDb, CostOracle, GraphCost};
use eadgo::energysim::{DeviceId, FreqId};
use eadgo::models::{self, ModelConfig};
use eadgo::profiler::SimHeteroProvider;
use eadgo::search::{price_plan_at_batch, synthesize_contingency, DvfsMode, PlanPoint};
use eadgo::serve::{
    AdaptiveConfig, DegradeCause, DriftKind, FaultEvent, FaultKind, FaultPlan, FeedbackConfig,
    ServeConfig, ServeReport, ServeSession, ServiceModel,
};
use eadgo::util::json;
use std::cell::RefCell;

const BMAX: usize = 2;
const TOTAL: usize = 64;

fn hetero_oracle() -> CostOracle {
    CostOracle::new(AlgorithmRegistry::new(), CostDb::new(), Box::new(SimHeteroProvider::new(7)))
}

fn model() -> eadgo::graph::Graph {
    models::by_name("simple", ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 })
        .expect("simple model builds")
}

/// The mixed GPU+DLA serving surface: plan 0 all-GPU, plan 1 with one node
/// placed on the DLA, plus the synthesized GPU-only contingency for plan 1
/// and true per-batch cost rows for all three assignments.
struct Surface {
    points: Vec<PlanPoint>,
    conts: Vec<Option<PlanPoint>>,
    /// `rows[0]` = GPU plan, `rows[1]` = mixed plan, `rows[2]` = the
    /// contingency, each priced for batches `1..=BMAX`.
    rows: Vec<Vec<GraphCost>>,
}

fn surface() -> Surface {
    let g = model();
    let oracle = hetero_oracle();
    let a_gpu = Assignment::default_for(&g, &AlgorithmRegistry::new());
    let mut a_mixed = a_gpu.clone();
    let first = a_mixed.assigned_ids().next().expect("the model has costed nodes");
    a_mixed.set_freq(first, FreqId::on(DeviceId::DLA, 0));
    assert!(a_mixed.uses_non_gpu_device());

    let (a_fb, c_fb) = synthesize_contingency(&oracle, &g, &a_mixed, DvfsMode::Off)
        .expect("contingency synthesis prices")
        .expect("a DLA-placed plan must synthesize a GPU fallback");
    assert!(!a_fb.uses_non_gpu_device(), "the contingency must avoid the DLA");

    let price = |a: &Assignment| -> Vec<GraphCost> {
        (1..=BMAX).map(|m| price_plan_at_batch(&oracle, &g, a, m).unwrap()).collect()
    };
    let rows = vec![price(&a_gpu), price(&a_mixed), price(&a_fb)];
    let point = |a: &Assignment, cost: GraphCost| PlanPoint {
        graph: g.clone(),
        assignment: a.clone(),
        cost,
        weight: 1.0,
        batch: 1,
    };
    let points = vec![point(&a_gpu, rows[0][0]), point(&a_mixed, rows[1][0])];
    let conts = vec![None, Some(point(&a_fb, c_fb))];
    Surface { points, conts, rows }
}

/// Virtual-clock serve config over the given per-plan cost rows.
fn serve_cfg(rows: &[Vec<GraphCost>], requests: usize) -> ServeConfig {
    ServeConfig {
        requests,
        batch_max: BMAX,
        arrival_rate_hz: 2_000.0,
        max_wait_s: 0.001,
        seed: 2026,
        input_shape: vec![1, 3, 32, 32],
        phases: Vec::new(),
        service: ServiceModel::Virtual {
            per_batch_ms: rows
                .iter()
                .map(|row| row.iter().map(|c| c.time_ms).collect())
                .collect(),
            scale_s_per_ms: 1e-4,
        },
    }
}

fn assert_all_served_in_order(r: &ServeReport, total: usize) {
    assert_eq!(r.records.len(), total, "every admitted request must be served");
    for (i, rec) in r.records.iter().enumerate() {
        assert_eq!(rec.id, i, "requests served in arrival order, none dropped");
    }
}

// -------------------------------------------------------------------------
// 1. the acceptance scenario: DeviceLost{dla} with a contingency
// -------------------------------------------------------------------------

#[test]
fn device_loss_hot_swaps_to_contingency_without_dropping_requests() {
    let s = surface();
    let cfg = serve_cfg(&s.rows[..2], TOTAL);
    let run = |plan: FaultPlan, adopted: &RefCell<Vec<usize>>| -> ServeReport {
        let oracle = hetero_oracle();
        ServeSession::new(&cfg)
            .oracle(&oracle)
            .plan_points(&s.points)
            .faults(plan)
            .contingencies(s.conts.clone())
            .run_with_adopt(
                |p, b| {
                    assert!(p < 2, "exec saw out-of-surface plan {p}");
                    Ok(b.to_vec())
                },
                |pts| {
                    assert!(
                        pts.iter().all(|p| !p.assignment.uses_non_gpu_device()),
                        "the degraded surface must avoid the lost device"
                    );
                    adopted.borrow_mut().push(pts.len());
                    Ok(())
                },
            )
            .expect("fault-tolerant serving must not fail")
    };

    // Calibrate the fault timestamp to land mid-run: same surface, same
    // ops-ified mode (the far-future event never fires but still shapes
    // validation), so the two runs agree on the clock until the fault.
    let lost_at = |at_s: f64| FaultPlan {
        events: vec![FaultEvent { at_s, kind: FaultKind::DeviceLost { device: DeviceId::DLA } }],
        ..FaultPlan::default()
    };
    let calm = RefCell::new(Vec::new());
    let calib = run(lost_at(1e9), &calm);
    assert_all_served_in_order(&calib, TOTAL);
    assert!(calib.faults.is_empty() && calib.degrades.is_empty());
    assert!(calm.borrow().is_empty(), "no fault fired, so nothing to adopt");
    let t_mid = calib.records[TOTAL / 2].done_s;

    let adopted = RefCell::new(Vec::new());
    let report = run(lost_at(t_mid), &adopted);

    // Zero panics (we got here), zero dropped admitted requests.
    assert_all_served_in_order(&report, TOTAL);
    assert!(report.sheds.is_empty(), "device loss must not shed requests");
    assert_eq!(report.availability(), 1.0);

    // Exactly one fault fired and exactly one contingency hot-swap: the
    // executor adopted one degraded 2-point surface (GPU survivor + the
    // activated contingency).
    assert_eq!(report.faults.len(), 1);
    assert_eq!(report.faults[0].kind, FaultKind::DeviceLost { device: DeviceId::DLA });
    assert_eq!(*adopted.borrow(), vec![2], "one adopt of the 2-point degraded surface");
    assert_eq!(report.degrades.len(), 1, "exactly one degradation: {:?}", report.degrades);
    let d = &report.degrades[0];
    assert_eq!(d.cause, DegradeCause::DeviceLost(DeviceId::DLA));
    assert!(d.at_s >= t_mid, "the fault activates at its timestamp, not before");
    assert_eq!((d.points_before, d.points_after), (2, 2));
    assert_eq!(d.contingencies_used, 1, "the mixed plan must fail over to its contingency");
    assert_eq!(d.epoch, 1, "device loss bumps the surface epoch like a hot-swap");

    // Requests straddle the swap: epoch 0 before, epoch 1 after, monotone.
    assert!(report.records.windows(2).all(|w| w[0].epoch <= w[1].epoch));
    assert_eq!(report.records.first().unwrap().epoch, 0);
    assert_eq!(report.records.last().unwrap().epoch, 1);
    let post: Vec<_> = report.records.iter().filter(|r| r.epoch == 1).collect();
    assert!(!post.is_empty(), "the fault must land mid-run");

    // The acceptance bound: post-fault true energy/request within 5% of
    // the best GPU-only plan on the same surface at the same batch sizes.
    // Post-loss plan 0 is the GPU survivor (rows[0]), plan 1 the activated
    // contingency (rows[2]).
    let per_req = |row: &[GraphCost], m: usize| row[m - 1].energy_j / m as f64;
    let actual: f64 = post
        .iter()
        .map(|r| per_req(&s.rows[if r.plan == 0 { 0 } else { 2 }], r.batch_size))
        .sum::<f64>()
        / post.len() as f64;
    let best: f64 = post
        .iter()
        .map(|r| per_req(&s.rows[0], r.batch_size).min(per_req(&s.rows[2], r.batch_size)))
        .sum::<f64>()
        / post.len() as f64;
    assert!(
        actual <= best * 1.05,
        "post-fault energy/request {actual} mJ must be within 5% of the best \
         GPU-only plan's {best} mJ"
    );
}

// -------------------------------------------------------------------------
// 2. bitwise replay determinism
// -------------------------------------------------------------------------

#[test]
fn fault_runs_replay_bitwise_identically() {
    // Thermal cap, then a hard transient-error window (rate 1.0: every
    // attempt inside it fails) with a retry budget tight enough to shed,
    // then device loss. Same seed + same plan must render byte-identical
    // reports — including retry counts, shed decisions, and event order.
    let s = surface();
    let cfg = serve_cfg(&s.rows[..2], TOTAL);
    let plan_json = r#"{"max_retries": 2, "backoff_ms": 1.0, "retry_budget_s": 0.003,
        "events": [
            {"at_s": 0.002, "kind": "thermal_cap", "device": "gpu", "max_mhz": 900},
            {"at_s": 0.008, "kind": "transient_error", "rate": 1.0, "duration_s": 0.008},
            {"at_s": 0.02, "kind": "device_lost", "device": "dla"}]}"#;
    let run = || -> ServeReport {
        let oracle = hetero_oracle();
        let plan = FaultPlan::from_json(&json::parse(plan_json).unwrap()).unwrap();
        ServeSession::new(&cfg)
            .oracle(&oracle)
            .plan_points(&s.points)
            .faults(plan)
            .contingencies(s.conts.clone())
            .run_with_adopt(|_, b| Ok(b.to_vec()), |_| Ok(()))
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact(),
        "same seed + same fault plan must replay bitwise"
    );

    // The run must actually exercise the machinery it claims to replay.
    assert_eq!(a.faults.len(), 3, "all three events fire: {:?}", a.faults);
    assert!(!a.sheds.is_empty(), "the rate-1.0 window with a tight budget must shed");
    assert!(a.sheds.iter().all(|e| e.retries <= 2), "retries bounded by max_retries");
    assert!(a.availability() < 1.0);
    assert!(
        a.degrades.iter().any(|d| matches!(d.cause, DegradeCause::ClockCap(DeviceId::GPU, _))),
        "the thermal cap must re-price the surface"
    );
    assert!(
        a.degrades.iter().any(|d| d.cause == DegradeCause::DeviceLost(DeviceId::DLA)),
        "the device loss must degrade the surface"
    );

    // Every admitted request is accounted for exactly once: served or shed.
    let mut ids: Vec<usize> =
        a.records.iter().map(|r| r.id).chain(a.sheds.iter().map(|e| e.id)).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..TOTAL).collect::<Vec<_>>(), "served + shed must cover every request");
}

// -------------------------------------------------------------------------
// 3. background-research panic containment
// -------------------------------------------------------------------------

#[test]
fn panicked_background_research_degrades_but_keeps_serving() {
    // A single-plan surface whose virtual service runs 3x the predicted
    // cost: drift arms, a background re-search launches — and panics (the
    // chaos hook). The session must contain the panic as a ResearchFailed
    // degrade and keep serving every request on the current surface.
    let g = model();
    let oracle = hetero_oracle();
    let a = Assignment::default_for(&g, &AlgorithmRegistry::new());
    let row: Vec<GraphCost> =
        (1..=BMAX).map(|m| price_plan_at_batch(&oracle, &g, &a, m).unwrap()).collect();
    let points = vec![PlanPoint {
        graph: g.clone(),
        assignment: a.clone(),
        cost: row[0],
        weight: 1.0,
        batch: 1,
    }];
    let cfg = ServeConfig {
        service: ServiceModel::Virtual {
            per_batch_ms: vec![row.iter().map(|c| c.time_ms * 3.0).collect()],
            scale_s_per_ms: 1e-4,
        },
        ..serve_cfg(&[row.clone()], 96)
    };
    let report = ServeSession::new(&cfg)
        .oracle(&oracle)
        .plan_points(&points)
        .feedback(FeedbackConfig {
            research_interval_s: 0.0,
            max_researches: 1,
            background: true,
            inject_research_panic: true,
            ..Default::default()
        })
        .run(|_, b| Ok(b.to_vec()))
        .expect("a panicked re-search must never poison the session");

    assert_all_served_in_order(&report, 96);
    assert_eq!(report.availability(), 1.0);
    assert!(
        report.drift_events.iter().any(|e| e.kind == DriftKind::Detected),
        "the 3x mis-prediction must arm drift (else the re-search never launched)"
    );
    let failed: Vec<_> =
        report.degrades.iter().filter(|d| d.cause == DegradeCause::ResearchFailed).collect();
    assert_eq!(failed.len(), 1, "the panic surfaces as exactly one degrade: {:?}", report.degrades);
    assert!(failed[0].detail.contains("panic"), "detail names the panic: {}", failed[0].detail);
    assert!(report.swaps.is_empty(), "a failed re-search must not swap the surface");
}

// -------------------------------------------------------------------------
// 4. drift must not misfire on fault-induced slowdowns
// -------------------------------------------------------------------------

#[test]
fn drift_detector_does_not_misfire_on_fault_slowdowns() {
    // A mid-run thermal cap slows real service down — but the session
    // re-prices the surface against the capped clocks and scales the
    // service model by the same ratio, and the detector is debounced
    // through the swap. Observed stays consistent with predicted, so the
    // known hardware event must never read as cost-model drift.
    let s = surface();
    let cfg = serve_cfg(&s.rows[..2], TOTAL);
    let oracle = hetero_oracle();
    let plan = FaultPlan::from_json(
        &json::parse(
            r#"{"events": [{"at_s": 0.005, "kind": "thermal_cap", "device": "gpu", "max_mhz": 900}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let report = ServeSession::new(&cfg)
        .oracle(&oracle)
        .plan_points(&s.points)
        .feedback(FeedbackConfig { max_researches: 0, ..Default::default() })
        .faults(plan)
        .run(|_, b| Ok(b.to_vec()))
        .unwrap();

    assert_all_served_in_order(&report, TOTAL);
    assert_eq!(report.faults.len(), 1);
    assert_eq!(report.degrades.len(), 1);
    assert!(
        matches!(report.degrades[0].cause, DegradeCause::ClockCap(DeviceId::GPU, _)),
        "{:?}",
        report.degrades[0].cause
    );
    assert_eq!(report.degrades[0].epoch, 1, "a clock cap bumps the epoch");
    assert!(
        report.drift_events.is_empty(),
        "a fault-induced slowdown must not arm drift: {:?}",
        report.drift_events
    );
    assert!(report.swaps.is_empty() && report.sheds.is_empty());
}

// -------------------------------------------------------------------------
// 5. fault-free byte-identity
// -------------------------------------------------------------------------

#[test]
fn an_eventless_fault_plan_is_byte_invisible() {
    // The harness promise: attaching a fault plan that injects nothing
    // changes nothing — same RNG streams, same records, same JSON bytes.
    let s = surface();
    let cfg = serve_cfg(&s.rows[..2], 48);
    let run = |faults: Option<FaultPlan>| -> ServeReport {
        let session =
            ServeSession::new(&cfg).plan_points(&s.points).adaptive(AdaptiveConfig::default());
        let session = match faults {
            Some(f) => session.faults(f),
            None => session,
        };
        session.run(|_, b| Ok(b.to_vec())).unwrap()
    };
    let base = run(None);
    let with_plan = run(Some(FaultPlan::default()));
    let render = |r: &ServeReport| r.to_json().to_string_compact();
    assert_eq!(render(&base), render(&with_plan), "an eventless plan must be byte-invisible");
    assert!(!render(&base).contains("\"faults\""), "fault-free reports carry no fault keys");

    // A rate-0 transient window logs its activation but perturbs nothing:
    // the per-request timeline is bit-identical (the fault RNG is only
    // drawn at positive rates).
    let zero = run(Some(
        FaultPlan::from_json(
            &json::parse(
                r#"{"events": [{"at_s": 0.0, "kind": "transient_error", "rate": 0.0, "duration_s": 1e9}]}"#,
            )
            .unwrap(),
        )
        .unwrap(),
    ));
    assert_eq!(zero.faults.len(), 1, "the window activation is logged");
    assert!(zero.sheds.is_empty() && zero.degrades.is_empty());
    let bits = |r: &ServeReport| {
        r.records
            .iter()
            .map(|x| {
                (x.arrival_s.to_bits(), x.start_s.to_bits(), x.done_s.to_bits(), x.plan, x.epoch)
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(bits(&base), bits(&zero), "a zero-rate window must not perturb the timeline");
}

// -------------------------------------------------------------------------
// 6. builder guards
// -------------------------------------------------------------------------

#[test]
fn device_loss_plans_demand_adopt_oracle_and_aligned_contingencies() {
    let s = surface();
    let cfg = serve_cfg(&s.rows[..2], 8);
    let lost = FaultPlan {
        events: vec![FaultEvent {
            at_s: 0.0,
            kind: FaultKind::DeviceLost { device: DeviceId::DLA },
        }],
        ..FaultPlan::default()
    };

    // run() cannot host a contingency swap: the executor may be handed
    // plans it never compiled.
    let err = ServeSession::new(&cfg)
        .plan_points(&s.points)
        .faults(lost.clone())
        .run(|_, b| Ok(b.to_vec()))
        .unwrap_err();
    assert!(err.to_string().contains("run_with_adopt"), "{err}");

    // Structural faults need an oracle to re-price the degraded surface.
    let err = ServeSession::new(&cfg)
        .plan_points(&s.points)
        .faults(lost)
        .run_with_adopt(|_, b| Ok(b.to_vec()), |_| Ok(()))
        .unwrap_err();
    assert!(err.to_string().contains("oracle"), "{err}");

    // Contingency slots must align 1:1 with the surface's plan points.
    let err = ServeSession::new(&cfg)
        .plan_points(&s.points)
        .contingencies(vec![None])
        .run(|_, b| Ok(b.to_vec()))
        .unwrap_err();
    assert!(err.to_string().contains("contingency slots"), "{err}");

    // And they need a plan-point surface at all.
    let err = ServeSession::new(&cfg)
        .contingencies(vec![None])
        .run(|_, b| Ok(b.to_vec()))
        .unwrap_err();
    assert!(err.to_string().contains("plan-point surface"), "{err}");
}
