//! The self-tuning serve loop end to end: feedback-off byte-identity with
//! the legacy entry points, drift detection → telemetry writeback →
//! hot-swap recovery against a mis-scaled cost database, full re-search
//! through the adopt callback, and background re-search liveness.
//!
//! Ground truth throughout is a [`ServiceModel::Virtual`] priced off the
//! unperturbed database, so every run is deterministic and host-speed
//! independent. The drift scenario uses two one-op plans for exact
//! attribution: plan B's conv rows are halved in the serving database
//! (fake-cheap, so serving parks on it) while plan A's depthwise row is
//! synthesized at 0.72x plan B's true cost on both axes — the corrected
//! surface must swap to A.

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::cost::{CostDb, CostOracle, GraphCost, NodeCost};
use eadgo::energysim::FreqId;
use eadgo::graph::{Activation, Graph, NodeId, OpKind, PortRef};
use eadgo::profiler::{ensure_profiled, SimV100Provider};
use eadgo::search::{price_plan_at_batch, OptimizerContext, PlanPoint, SearchConfig};
use eadgo::serve::{
    AdaptiveConfig, DriftKind, FeedbackConfig, OperatingPoint, RatePhase, ResearchConfig,
    ServeConfig, ServeReport, ServeSession, ServiceModel,
};
use eadgo::subst::RuleSet;
use eadgo::tensor::Tensor;
use eadgo::util::json::Json;
use std::cell::Cell;

const BMAX: usize = 2;
const SEED: u64 = 11;

/// The single non-constant, non-input node of a one-op plan graph.
fn costed_node(g: &Graph) -> NodeId {
    g.nodes()
        .find(|(_, n)| !matches!(n.op, OpKind::Input { .. }) && !n.op.is_constant_space())
        .map(|(id, _)| id)
        .expect("graph has one costed node")
}

/// The profiling signature of that node (input shapes resolved).
fn only_costed_sig(g: &Graph) -> String {
    let shapes = g.infer_shapes().unwrap();
    let node = g.node(costed_node(g));
    let ins: Vec<Vec<usize>> =
        node.inputs.iter().map(|p| shapes[p.node.0][p.port].clone()).collect();
    node.op.signature(&ins)
}

/// Copy `db` with `time_ms` of every row under signatures starting with
/// `prefix` scaled by `scale` (power is unchanged, so energy scales too).
fn scale_sig_times(db: &CostDb, prefix: &str, scale: f64) -> CostDb {
    let mut j = db.to_json();
    if let Json::Obj(root) = &mut j {
        if let Some(Json::Obj(profiles)) = root.get_mut("profiles") {
            for (sig, algos) in profiles.iter_mut() {
                if !sig.starts_with(prefix) {
                    continue;
                }
                if let Json::Obj(algos) = algos {
                    for rec in algos.values_mut() {
                        if let Json::Obj(rec) = rec {
                            if let Some(Json::Num(t)) = rec.get_mut("time_ms") {
                                *t *= scale;
                            }
                        }
                    }
                }
            }
        }
    }
    CostDb::from_json(&j).expect("scaled db parses")
}

/// The two-plan drift scenario: plan A (one depthwise conv) and plan B
/// (one conv), a truth database, and a serving database whose conv rows
/// are halved.
struct Scenario {
    dw_g: Graph,
    dw_a: Assignment,
    conv_g: Graph,
    conv_a: Assignment,
    truth_db: CostDb,
    perturbed_db: CostDb,
}

fn scenario() -> Scenario {
    let shape = vec![1usize, 3, 16, 16];
    let conv_g = {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: shape.clone() }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
                has_residual: false,
            },
            &[x, w],
            "conv",
        );
        g.outputs = vec![PortRef::of(c)];
        g
    };
    let dw_g = {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: shape.clone() }, &[], "x");
        let w = g.add1(OpKind::weight(vec![3, 1, 3, 3], 1), &[], "w");
        let d = g.add1(
            OpKind::DwConv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
            },
            &[x, w],
            "dw",
        );
        g.outputs = vec![PortRef::of(d)];
        g
    };
    let reg = AlgorithmRegistry::new();
    let provider = SimV100Provider::new(SEED);
    let conv_a = Assignment::default_for(&conv_g, &reg);
    let dw_a = Assignment::default_for(&dw_g, &reg);
    let mut truth_db = CostDb::new();
    for m in 1..=BMAX {
        ensure_profiled(&conv_g.rebatch(m).unwrap(), &reg, &mut truth_db, &provider).unwrap();
        ensure_profiled(&dw_g.rebatch(m).unwrap(), &reg, &mut truth_db, &provider).unwrap();
    }
    // Pin plan A at exactly 0.72x plan B's true cost per batch size.
    for m in 1..=BMAX {
        let sig_c = only_costed_sig(&conv_g.rebatch(m).unwrap());
        let sig_d = only_costed_sig(&dw_g.rebatch(m).unwrap());
        let c = truth_db
            .get(&sig_c, conv_a.get(costed_node(&conv_g)).unwrap())
            .expect("conv profiled");
        truth_db.insert(
            &sig_d,
            dw_a.get(costed_node(&dw_g)).unwrap(),
            NodeCost { time_ms: 0.72 * c.time_ms, power_w: c.power_w },
            "synthetic",
        );
    }
    let perturbed_db = scale_sig_times(&truth_db, "conv2d;", 0.5);
    Scenario { dw_g, dw_a, conv_g, conv_a, truth_db, perturbed_db }
}

/// Price both plans for batches `1..=BMAX` against `db` (plan 0 = A, 1 = B).
fn grids(db: &CostDb, sc: &Scenario) -> Vec<Vec<GraphCost>> {
    let oracle =
        CostOracle::new(AlgorithmRegistry::new(), db.clone(), Box::new(SimV100Provider::new(SEED)));
    [(&sc.dw_g, &sc.dw_a), (&sc.conv_g, &sc.conv_a)]
        .iter()
        .map(|&(g, a)| {
            (1..=BMAX).map(|m| price_plan_at_batch(&oracle, g, a, m).unwrap()).collect()
        })
        .collect()
}

/// Plan points over the perturbed estimates, A first.
fn plan_points(sc: &Scenario, pert_grid: &[Vec<GraphCost>]) -> Vec<PlanPoint> {
    [(&sc.dw_g, &sc.dw_a), (&sc.conv_g, &sc.conv_a)]
        .iter()
        .enumerate()
        .map(|(i, &(g, a))| PlanPoint {
            graph: g.clone(),
            assignment: a.clone(),
            cost: pert_grid[i][0],
            weight: 0.5,
            batch: 1,
        })
        .collect()
}

/// Calm/burst/calm serving config on a virtual clock whose service times
/// come from the *truth* grid (observed reality vs perturbed predictions).
fn serve_cfg(truth_grid: &[Vec<GraphCost>], n: usize) -> ServeConfig {
    let svc_b_s = truth_grid[1][0].time_ms * 1e-3;
    ServeConfig {
        requests: 0,
        batch_max: BMAX,
        arrival_rate_hz: 0.0,
        max_wait_s: 4.0 * svc_b_s,
        seed: 2026,
        input_shape: vec![1, 3, 16, 16],
        phases: vec![
            RatePhase::new(0.2 / svc_b_s, n),
            RatePhase::new(1.2 / svc_b_s, 2 * n),
            RatePhase::new(0.2 / svc_b_s, n),
        ],
        service: ServiceModel::Virtual {
            per_batch_ms: truth_grid
                .iter()
                .map(|row| row.iter().map(|c| c.time_ms).collect())
                .collect(),
            scale_s_per_ms: 1e-3,
        },
    }
}

/// Mean true energy per request, priced off the unperturbed grid (both
/// runs map operating point `i` to plan `i`).
fn true_mj(r: &ServeReport, truth_grid: &[Vec<GraphCost>]) -> f64 {
    let sum: f64 = r
        .records
        .iter()
        .map(|x| truth_grid[x.plan][x.batch_size - 1].energy_j / x.batch_size as f64)
        .sum();
    sum / r.records.len() as f64
}

fn assert_served_in_order(r: &ServeReport, total: usize) {
    assert_eq!(r.records.len(), total, "every request must be served exactly once");
    for (i, rec) in r.records.iter().enumerate() {
        assert_eq!(rec.id, i, "requests served in arrival order, none dropped");
    }
}

/// Acceptance: with feedback off, the `ServeSession` builder renders a
/// report byte-identical to every legacy entry point, in all four modes.
#[test]
#[allow(deprecated)]
fn feedback_off_session_is_byte_identical_to_legacy_entry_points() {
    let render = |r: ServeReport| r.to_json().to_string_compact();
    let virt1 = ServiceModel::Virtual { per_batch_ms: vec![vec![2.0, 3.5]], scale_s_per_ms: 1e-3 };
    let cfg = ServeConfig {
        requests: 40,
        batch_max: 2,
        arrival_rate_hz: 900.0,
        max_wait_s: 0.004,
        seed: 9,
        input_shape: vec![1, 3, 8, 8],
        phases: Vec::new(),
        service: virt1,
    };

    // Plain single-plan serving.
    assert_eq!(
        render(ServeSession::new(&cfg).run(|_, b| Ok(b.to_vec())).unwrap()),
        render(eadgo::serve::serve(&cfg, |b: &[Tensor]| Ok(b.to_vec())).unwrap()),
    );

    // Fixed plan with a warm oracle estimate.
    let oracle = CostOracle::offline_default();
    let mut g = Graph::new();
    let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
    let r = g.add1(OpKind::Relu, &[x], "r");
    g.outputs = vec![PortRef::of(r)];
    let a = Assignment::default_for(&g, oracle.reg());
    oracle.table_for(&g).unwrap();
    let via_session = ServeSession::new(&cfg)
        .oracle(&oracle)
        .plan(&g, &a)
        .run(|_, b| Ok(b.to_vec()))
        .unwrap();
    assert!(via_session.plan_cost.is_some(), "warm oracle must price the plan");
    assert_eq!(
        render(via_session),
        render(
            eadgo::serve::serve_plan(&cfg, &oracle, &g, &a, |b: &[Tensor]| Ok(b.to_vec()))
                .unwrap()
        ),
    );

    // Adaptive frontier over bare cost estimates.
    let costs = vec![
        GraphCost { time_ms: 2.0, energy_j: 9.0, freq: FreqId::NOMINAL },
        GraphCost { time_ms: 5.0, energy_j: 4.0, freq: FreqId::NOMINAL },
    ];
    let virt2 = ServiceModel::Virtual {
        per_batch_ms: vec![vec![2.0, 3.5], vec![5.0, 8.0]],
        scale_s_per_ms: 1e-3,
    };
    let fcfg = ServeConfig { service: virt2, ..cfg };
    let policy = AdaptiveConfig::default();
    assert_eq!(
        render(
            ServeSession::new(&fcfg)
                .frontier_costs(&costs)
                .adaptive(policy.clone())
                .run(|_, b| Ok(b.to_vec()))
                .unwrap()
        ),
        render(
            eadgo::serve::serve_frontier(&fcfg, &costs, &policy, |_, b: &[Tensor]| {
                Ok(b.to_vec())
            })
            .unwrap()
        ),
    );

    // Operating points over an explicit price grid.
    let grid = vec![
        vec![
            GraphCost { time_ms: 2.0, energy_j: 9.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 3.5, energy_j: 14.0, freq: FreqId::NOMINAL },
        ],
        vec![
            GraphCost { time_ms: 5.0, energy_j: 4.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 8.0, energy_j: 6.0, freq: FreqId::NOMINAL },
        ],
    ];
    let ops = vec![OperatingPoint { plan: 0, batch: 1 }, OperatingPoint { plan: 1, batch: 2 }];
    assert_eq!(
        render(
            ServeSession::new(&fcfg)
                .operating_points(&grid, &ops)
                .adaptive(policy.clone())
                .run(|_, b| Ok(b.to_vec()))
                .unwrap()
        ),
        render(
            eadgo::serve::serve_operating_points(&fcfg, &grid, &ops, &policy, |_, b: &[Tensor]| {
                Ok(b.to_vec())
            })
            .unwrap()
        ),
    );
}

/// Acceptance: against a mis-scaled database the feedback loop detects
/// drift, writes measured rows back, re-prices the surface, hot-swaps
/// without dropping a request, and strictly beats the no-feedback
/// baseline on true energy per request.
#[test]
fn drift_detection_hot_swaps_and_strictly_improves_true_energy() {
    let sc = scenario();
    let truth_grid = grids(&sc.truth_db, &sc);
    let pert_grid = grids(&sc.perturbed_db, &sc);
    // The scenario's invariants: A truly cheaper than B, mis-scaled B
    // looks cheaper than A.
    for m in 1..=BMAX {
        let (a, b, pb) = (truth_grid[0][m - 1], truth_grid[1][m - 1], pert_grid[1][m - 1]);
        assert!(a.energy_j > 0.55 * b.energy_j && a.energy_j < 0.95 * b.energy_j);
        assert!(a.time_ms > 0.55 * b.time_ms && a.time_ms < 0.95 * b.time_ms);
        assert!(pb.energy_j < a.energy_j);
    }
    let n = 32;
    let total = 4 * n;
    let cfg = serve_cfg(&truth_grid, n);

    // Baseline: the same surface served from the mis-scaled grid with no
    // feedback — it parks on fake-cheap plan B and never leaves.
    let ops: Vec<OperatingPoint> =
        (0..pert_grid.len()).map(|i| OperatingPoint { plan: i, batch: BMAX }).collect();
    let off = ServeSession::new(&cfg)
        .operating_points(&pert_grid, &ops)
        .adaptive(AdaptiveConfig::default())
        .run(|_, b| Ok(b.to_vec()))
        .unwrap();
    assert_served_in_order(&off, total);
    assert!(off.drift_events.is_empty() && off.swaps.is_empty());
    assert_eq!(off.feedback_rows, 0);
    assert!(off.records.iter().all(|r| r.plan == 1 && r.epoch == 0));

    // Feedback on: the same plans through the self-tuning session.
    let serving = CostOracle::new(
        AlgorithmRegistry::new(),
        sc.perturbed_db.clone(),
        Box::new(SimV100Provider::new(SEED)),
    );
    let points = plan_points(&sc, &pert_grid);
    let on = ServeSession::new(&cfg)
        .oracle(&serving)
        .plan_points(&points)
        .feedback(FeedbackConfig { research_interval_s: 0.0, ..Default::default() })
        .run(|_, b| Ok(b.to_vec()))
        .unwrap();
    assert_served_in_order(&on, total);

    // Drift armed on plan B, then a re-pricing hot-swap.
    let detected: Vec<_> =
        on.drift_events.iter().filter(|e| e.kind == DriftKind::Detected).collect();
    assert!(!detected.is_empty(), "mis-scaled database must arm drift detection");
    assert_eq!(detected[0].plan, 1, "drift must be attributed to the mis-scaled plan");
    assert!(detected[0].ratio > 1.5, "plan B truly costs ~2x its prediction");
    assert_eq!(on.swaps.len(), 1, "one corrective hot-swap");
    let swap = on.swaps[0];
    assert!(!swap.researched, "without a research config the swap re-prices existing plans");
    assert!(
        swap.energy_mj_after < swap.energy_mj_before,
        "the corrected surface must expose a cheaper operating point"
    );
    assert!(on.feedback_rows > 0, "writeback must record measured rows");

    // The swap lands mid-run: earlier records on fake-cheap B at epoch 0,
    // later ones on truly-cheap A at epoch 1, epochs nondecreasing.
    assert_eq!(on.records.first().unwrap().plan, 1);
    assert_eq!(on.records.first().unwrap().epoch, 0);
    let last = on.records.last().unwrap();
    assert_eq!(last.plan, 0, "feedback run must end on the truly cheapest plan");
    assert_eq!(last.epoch, 1, "post-swap records carry the new surface epoch");
    assert!(on.records.windows(2).all(|w| w[0].epoch <= w[1].epoch));

    // The headline acceptance: strictly better true energy per request.
    let (mj_off, mj_on) = (true_mj(&off, &truth_grid), true_mj(&on, &truth_grid));
    assert!(
        mj_on < mj_off * 0.98,
        "feedback must strictly beat the no-feedback baseline: {mj_on} vs {mj_off} mJ/request"
    );
}

/// A full re-search (research config set) produces new plans, hands them
/// to the adopt callback before they serve, and hot-swaps the surface.
#[test]
fn full_research_hot_swap_adopts_new_plans() {
    let sc = scenario();
    let truth_grid = grids(&sc.truth_db, &sc);
    let pert_grid = grids(&sc.perturbed_db, &sc);
    let ctx = OptimizerContext::new(
        RuleSet::standard(),
        sc.perturbed_db.clone(),
        Box::new(SimV100Provider::new(SEED)),
    );
    let points = plan_points(&sc, &pert_grid);
    let n = 24;
    let cfg = serve_cfg(&truth_grid, n);
    let adopted = Cell::new(0usize);
    let report = ServeSession::new(&cfg)
        .oracle(&ctx.oracle)
        .plan_points(&points)
        .feedback(FeedbackConfig {
            research_interval_s: 0.0,
            max_researches: 1,
            ..Default::default()
        })
        .research(ResearchConfig {
            ctx: &ctx,
            origin: sc.conv_g.clone(),
            search: SearchConfig { max_dequeues: 20, ..Default::default() },
            points: 2,
            batches: vec![1, BMAX],
        })
        .run_with_adopt(
            |_, b| Ok(b.to_vec()),
            |pts: &[PlanPoint]| {
                adopted.set(adopted.get() + pts.len());
                Ok(())
            },
        )
        .unwrap();
    assert_served_in_order(&report, 4 * n);
    assert!(adopted.get() >= 1, "adopt must see the re-searched plans before they serve");
    assert!(report.swaps.iter().any(|s| s.researched), "a full re-search must hot-swap");
    assert!(report.records.last().unwrap().epoch > 0, "post-swap records carry the new epoch");
}

/// Background re-search must never drop or reorder requests: traffic
/// keeps flowing while the corrected surface is prepared off-thread.
#[test]
fn background_research_keeps_serving_every_request() {
    let sc = scenario();
    let truth_grid = grids(&sc.truth_db, &sc);
    let pert_grid = grids(&sc.perturbed_db, &sc);
    let serving = CostOracle::new(
        AlgorithmRegistry::new(),
        sc.perturbed_db.clone(),
        Box::new(SimV100Provider::new(SEED)),
    );
    let points = plan_points(&sc, &pert_grid);
    let n = 48;
    let cfg = serve_cfg(&truth_grid, n);
    let report = ServeSession::new(&cfg)
        .oracle(&serving)
        .plan_points(&points)
        .feedback(FeedbackConfig {
            research_interval_s: 0.0,
            background: true,
            ..Default::default()
        })
        .run(|_, b| Ok(b.to_vec()))
        .unwrap();
    assert_served_in_order(&report, 4 * n);
    assert!(
        report.drift_events.iter().any(|e| e.kind == DriftKind::Detected),
        "drift must still arm with background re-search"
    );
}
