//! ISSUE 9 conformance suite: the layout axis + the matmul-side rewrites.
//!
//! PR 9 adds per-node tensor layout (NCHW/NHWC) as a cost axis riding the
//! packed frequency state, and widens the rewrite space with
//! `fuse_matmul_epilogue` and the Merkle-powered `cse` rule. This suite
//! locks the contract down from four sides, mirroring `tests/placement.rs`:
//!
//! 1. **Single-layout bit-identity** — plans searched with the layout axis
//!    off carry no layout keys, serialize exactly as before the axis
//!    existed (frontier stays v2), and the delta_eval × incremental_inner
//!    engine matrix still agrees bit for bit (the CLI face, `--layouts
//!    nchw` vs flag omitted, is byte-diffed in CI).
//! 2. **Engine-matrix bit-identity on layout-spanning tables** — every
//!    `delta_eval` × `incremental_inner` combination must return the same
//!    plan bits when the table spans layouts, because the boundary-aware
//!    inner pass re-derives from the per-row argmin.
//! 3. **Layout + CSE invariants** — transpose cost is zero iff an edge
//!    crosses layouts; layout-uniform assignments conserve single-layout
//!    totals exactly; every `cse` product of every zoo model keeps the
//!    original output Merkle hash and never prices higher through the
//!    cost table (it computes the same function with fewer nodes).
//! 4. **The acceptance claim** — with `--layouts nchw,nhwc` on the
//!    attention and squeezenet models, the joint search strictly beats
//!    the best single-layout plan on energy at the same latency budget,
//!    and the winning plan round-trips through the v5 manifest.

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::cost::{CostDb, CostFunction, CostOracle};
use eadgo::energysim::{FreqId, Layout};
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::serde::{plan_from_json, plan_to_json};
use eadgo::models::{self, ModelConfig};
use eadgo::profiler::SimV100Provider;
use eadgo::search::{
    optimize, optimize_with_time_budget, DvfsMode, OptimizerContext, SearchConfig,
};
use eadgo::subst::RuleSet;

fn model_cfg() -> ModelConfig {
    ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

fn oracle() -> CostOracle {
    CostOracle::new(AlgorithmRegistry::new(), CostDb::new(), Box::new(SimV100Provider::new(7)))
}

/// The NHWC twin of the nominal GPU state.
fn nhwc0() -> FreqId {
    FreqId::NOMINAL.with_layout(Layout::NHWC)
}

fn both_layouts() -> Vec<Layout> {
    vec![Layout::NCHW, Layout::NHWC]
}

// -------------------------------------------------------------------------
// 1. single-layout surfaces stay layout-free
// -------------------------------------------------------------------------

#[test]
fn single_layout_plans_carry_no_layout_keys() {
    // Both an old model and the new attention model: with the axis off
    // (the default), nothing about PR 9 may leak into the plan bytes.
    for model in ["squeezenet", "attention"] {
        let g = models::by_name(model, model_cfg()).unwrap();
        let ctx = OptimizerContext::offline_default();
        let cfg = SearchConfig { max_dequeues: 16, ..Default::default() };
        let r = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
        let plan = plan_to_json(&r.graph, &r.assignment).to_string_compact();
        assert!(!plan.contains("\"layout\""), "{model}: layout-off plan grew a layout key");
        assert_eq!(r.assignment.layouts_used(), vec![Layout::NCHW]);
        assert!(!r.assignment.uses_non_default_layout());

        let fr = eadgo::search::optimize_frontier(&g, &ctx, &cfg, 3).unwrap();
        let manifest = eadgo::runtime::manifest::frontier_to_json(&fr.frontier).to_string_compact();
        assert!(manifest.contains("\"version\":2"), "{model}: single-layout frontier must stay v2");
        assert!(!manifest.contains("\"layout\""), "{model}: single-layout frontier grew layout keys");
    }
}

#[test]
fn layout_off_engine_matrix_bit_identical() {
    // The ISSUE 9 regression guard: with the layout axis off, the
    // delta_eval × incremental_inner matrix must still agree bit for bit —
    // the widened rule set (cse, fuse_matmul_epilogue) and the
    // size-mixing candidate dedup ride inside the existing engines
    // without perturbing any of them.
    let run = |model: &str, dvfs: DvfsMode, delta_eval: bool, incremental_inner: bool| {
        let g = models::by_name(model, model_cfg()).unwrap();
        let cfg = SearchConfig {
            max_dequeues: 16,
            dvfs,
            delta_eval,
            incremental_inner,
            ..Default::default()
        };
        let r = optimize(&g, &OptimizerContext::offline_default(), &CostFunction::Energy, &cfg)
            .unwrap();
        (
            graph_hash(&r.graph),
            plan_to_json(&r.graph, &r.assignment).to_string_compact(),
            r.cost.time_ms.to_bits(),
            r.cost.energy_j.to_bits(),
        )
    };
    for model in ["squeezenet", "attention"] {
        for dvfs in [DvfsMode::Off, DvfsMode::PerNode] {
            let reference = run(model, dvfs, true, true);
            for (d, i) in [(true, false), (false, true), (false, false)] {
                assert_eq!(
                    reference,
                    run(model, dvfs, d, i),
                    "{model}/dvfs={}: engine matrix (delta_eval={d}, incremental_inner={i}) \
                     diverged with the layout axis off",
                    dvfs.describe()
                );
            }
        }
    }
}

// -------------------------------------------------------------------------
// 2. engine-matrix bit-identity on layout-spanning tables
// -------------------------------------------------------------------------

#[test]
fn layout_on_engine_matrix_bit_identical() {
    // With `--layouts nchw,nhwc` the table spans layouts and carries the
    // re-tiling overlay; the boundary-aware inner pass is a
    // start-independent function of (table, objective), so every engine
    // combination must agree bit for bit.
    let run = |model: &str, dvfs: DvfsMode, delta_eval: bool, incremental_inner: bool| {
        let g = models::by_name(model, model_cfg()).unwrap();
        let cfg = SearchConfig {
            max_dequeues: 16,
            dvfs,
            delta_eval,
            incremental_inner,
            layouts: both_layouts(),
            ..Default::default()
        };
        let r = optimize(&g, &OptimizerContext::offline_default(), &CostFunction::Energy, &cfg)
            .unwrap();
        (
            graph_hash(&r.graph),
            plan_to_json(&r.graph, &r.assignment).to_string_compact(),
            r.cost.time_ms.to_bits(),
            r.cost.energy_j.to_bits(),
        )
    };
    for model in ["squeezenet", "attention"] {
        for dvfs in [DvfsMode::Off, DvfsMode::PerNode] {
            let reference = run(model, dvfs, true, true);
            for (d, i) in [(true, false), (false, true), (false, false)] {
                assert_eq!(
                    reference,
                    run(model, dvfs, d, i),
                    "{model}/dvfs={}: engine matrix (delta_eval={d}, incremental_inner={i}) \
                     diverged on a layout-spanning table",
                    dvfs.describe()
                );
            }
        }
    }
}

// -------------------------------------------------------------------------
// 3. layout + cse invariants on the cost tables
// -------------------------------------------------------------------------

/// A layout-spanning cost table for the simple model plus its default
/// (all-NCHW nominal) assignment.
fn simple_layout_table() -> (eadgo::graph::Graph, eadgo::cost::GraphCostTable, Assignment) {
    let oracle = oracle();
    let g = models::by_name("simple", model_cfg()).unwrap();
    let shapes = g.infer_shapes().unwrap();
    oracle.profile_graph(&g).unwrap();
    let (table, _) = oracle.table_for_freqs(&g, &shapes, &[FreqId::NOMINAL, nhwc0()]);
    assert!(table.has_links(), "a layout-spanning table must carry the re-tiling overlay");
    let a = Assignment::default_for(&g, &AlgorithmRegistry::new());
    (g, table, a)
}

#[test]
fn transpose_cost_zero_iff_an_edge_crosses_layouts() {
    let (_g, table, a) = simple_layout_table();
    let edges = table.links().unwrap().edges();
    assert!(!edges.is_empty(), "the simple model must have costed-to-costed edges");

    // Layout-uniform: no boundary, exact zero (both all-NCHW and all-NHWC).
    assert_eq!(table.transpose_cost(&a), (0.0, 0.0), "all-NCHW plan charged a re-tile");
    let mut uni = a.clone();
    uni.set_uniform_freq(nhwc0());
    assert_eq!(table.transpose_cost(&uni), (0.0, 0.0), "all-NHWC plan charged a re-tile");
    // A single-device table never charges transfers, whatever the layouts.
    assert_eq!(table.transfer_cost(&uni), (0.0, 0.0), "layout axis charged a device transfer");

    // Flip a growing prefix of costed nodes to NHWC: at each step the
    // transpose cost is zero iff no priced edge crosses layouts, and
    // strictly positive in both axes the moment one does.
    let mut b = a.clone();
    for id in table.costed_ids() {
        b.set_freq(id, nhwc0());
        let crossing = edges
            .iter()
            .any(|e| b.freq(e.src).layout() != b.freq(e.dst).layout());
        let (t, e) = table.transpose_cost(&b);
        if crossing {
            assert!(t > 0.0 && e > 0.0, "a layout-crossing edge must charge time and energy");
        } else {
            assert_eq!((t, e), (0.0, 0.0), "no crossing edge, yet a re-tile was charged");
        }
    }
    // The sweep ends all-NHWC: uniform again, so exactly zero.
    assert_eq!(table.transpose_cost(&b), (0.0, 0.0), "all-NHWC plan still charged a re-tile");
    // And the very first flip must have crossed at least one edge.
    let mut first = a.clone();
    first.set_freq(table.costed_ids().next().unwrap(), nhwc0());
    assert!(table.transpose_cost(&first).0 > 0.0, "single-node flip crossed no edge");
}

#[test]
fn layout_uniform_assignments_conserve_single_layout_totals() {
    // Evaluating a layout-uniform plan through the spanning table must
    // equal the single-state table bitwise: the overlay adds no terms.
    let (_g, table, a) = simple_layout_table();
    for f in [FreqId::NOMINAL, nhwc0()] {
        let mut af = a.clone();
        af.set_uniform_freq(f);
        let mixed = table.eval(&af);
        let single = table.restrict_to_freq(f);
        assert!(!single.has_links(), "restricted single-state table must drop the overlay");
        let alone = single.eval(&af);
        assert_eq!(
            (mixed.time_ms.to_bits(), mixed.energy_j.to_bits()),
            (alone.time_ms.to_bits(), alone.energy_j.to_bits()),
            "uniform {} plan not conserved through the layout-spanning table",
            f.describe()
        );
    }
}

#[test]
fn eval_swap_matches_full_eval_across_layout_boundaries() {
    // The O(degree) boundary adjustment in eval_swap must agree bitwise
    // with a from-scratch eval for every single-node layout flip.
    let (_g, table, a) = simple_layout_table();
    let base = table.eval(&a);
    for id in table.costed_ids() {
        for (f, slab) in table.freq_options(id) {
            for &(algo, _) in slab.iter() {
                let swapped = table.eval_swap(base, &a, id, algo, *f).unwrap();
                let mut af = a.clone();
                af.set(id, algo);
                af.set_freq(id, *f);
                let fresh = table.eval(&af);
                assert_eq!(
                    (swapped.time_ms.to_bits(), swapped.energy_j.to_bits()),
                    (fresh.time_ms.to_bits(), fresh.energy_j.to_bits()),
                    "eval_swap diverged flipping node {} to ({}, {})",
                    id.0,
                    algo.name(),
                    f.describe()
                );
            }
        }
    }
}

#[test]
fn cse_products_preserve_output_hash_and_never_price_higher() {
    // The cse soundness property, on every zoo model: a cse product
    // computes the same function (equal output Merkle hash — the same
    // invariant the search dedup trusts) with fewer nodes, so its
    // cost-table eval can only match or undercut the original.
    let reg = AlgorithmRegistry::new();
    let rs = RuleSet::standard();
    let cfg = ModelConfig::default();
    let mut cse_products = 0usize;
    for name in models::zoo_names() {
        let g = models::by_name(name, cfg).unwrap();
        let h0 = graph_hash(&g);
        let oracle = oracle();
        oracle.profile_graph(&g).unwrap();
        let shapes = g.infer_shapes().unwrap();
        let (table, _) = oracle.table_for_freqs(&g, &shapes, &[FreqId::NOMINAL]);
        let base = table.eval(&Assignment::default_for(&g, &reg));
        for (ng, rule) in rs.neighbors(&g).unwrap() {
            if rule != "cse" {
                continue;
            }
            cse_products += 1;
            assert_eq!(
                graph_hash(&ng),
                h0,
                "{name}: cse product changed the output Merkle hash"
            );
            assert!(
                ng.runtime_node_count() < g.runtime_node_count(),
                "{name}: cse product removed no nodes"
            );
            oracle.profile_graph(&ng).unwrap();
            let nshapes = ng.infer_shapes().unwrap();
            let (ntable, _) = oracle.table_for_freqs(&ng, &nshapes, &[FreqId::NOMINAL]);
            let nc = ntable.eval(&Assignment::default_for(&ng, &reg));
            assert!(
                nc.time_ms.is_finite() && nc.energy_j.is_finite(),
                "{name}: cse product priced non-finite"
            );
            assert!(
                nc.time_ms < base.time_ms && nc.energy_j < base.energy_j,
                "{name}: cse product must price strictly lower (dropped a costed node): \
                 {} vs {} ms, {} vs {} J",
                nc.time_ms,
                base.time_ms,
                nc.energy_j,
                base.energy_j
            );
        }
    }
    // The property must not be vacuous: the attention model's tied Q/K
    // guarantees at least one cse product in the zoo.
    assert!(cse_products >= 1, "no cse product anywhere in the zoo");
}

// -------------------------------------------------------------------------
// 4. the acceptance claim + v5 round-trip
// -------------------------------------------------------------------------

#[test]
fn budgeted_layout_search_beats_single_layout_on_attention_and_squeezenet() {
    // The ISSUE 9 acceptance criterion: at the same latency budget the
    // joint (algo, freq, layout) search finds a plan strictly cheaper in
    // energy than the best single-layout plan — where "best single-layout"
    // is the better of the NCHW-only search and its all-NHWC twin.
    for model in ["attention", "squeezenet"] {
        let g = models::by_name(model, model_cfg()).unwrap();
        let nchw_cfg =
            SearchConfig { max_dequeues: 12, dvfs: DvfsMode::PerNode, ..Default::default() };
        let joint_cfg = SearchConfig { layouts: both_layouts(), ..nchw_cfg.clone() };
        let ctx = OptimizerContext::offline_default;
        let tbest = optimize(&g, &ctx(), &CostFunction::Time, &nchw_cfg).unwrap().cost.time_ms;
        let budget = 2.0 * tbest;
        let r_nchw = optimize_with_time_budget(&g, &ctx(), budget, &nchw_cfg, 4).unwrap();
        let r_joint = optimize_with_time_budget(&g, &ctx(), budget, &joint_cfg, 4).unwrap();
        assert!(r_nchw.feasible && r_joint.feasible, "{model}: both searches must fit 2x best-time");
        assert!(
            r_joint.result.cost.time_ms <= budget * (1.0 + 1e-9),
            "{model}: layout-mixed plan over budget"
        );
        assert!(
            r_joint.result.assignment.uses_non_default_layout(),
            "{model}: budgeted joint search kept every node in NCHW"
        );

        // Best single-layout competitor: the NCHW winner, and — when it
        // still fits the budget — the same plan flipped uniformly to NHWC
        // (priced through a table spanning both twins of every state).
        let mut best_single = r_nchw.result.cost.energy_j;
        let gn = &r_nchw.result.graph;
        let oracle = oracle();
        oracle.profile_graph(gn).unwrap();
        let shapes = gn.infer_shapes().unwrap();
        let mut states = vec![FreqId::NOMINAL];
        states.extend_from_slice(oracle.dvfs_freqs());
        let nhwc_states: Vec<FreqId> =
            states.iter().map(|f| f.with_layout(Layout::NHWC)).collect();
        states.extend(nhwc_states);
        let (table, _) = oracle.table_for_freqs(gn, &shapes, &states);
        let mut a_nhwc = r_nchw.result.assignment.clone();
        for id in table.costed_ids() {
            a_nhwc.set_freq(id, a_nhwc.freq(id).with_layout(Layout::NHWC));
        }
        let c_nhwc = table.eval(&a_nhwc);
        if c_nhwc.time_ms <= budget {
            best_single = best_single.min(c_nhwc.energy_j);
        }
        assert!(
            r_joint.result.cost.energy_j < best_single,
            "{model}: layout mixing must strictly beat the best single-layout plan \
             at the same budget: {} vs {}",
            r_joint.result.cost.energy_j,
            best_single
        );
    }
}

#[test]
fn layout_mixed_plans_roundtrip_as_v5() {
    // A searched layout-mixed plan must survive plan JSON and the v5
    // frontier manifest byte-exactly.
    let g = models::attention::build(model_cfg());
    let cfg = SearchConfig {
        max_dequeues: 16,
        dvfs: DvfsMode::PerNode,
        layouts: both_layouts(),
        ..Default::default()
    };
    let r = optimize(&g, &OptimizerContext::offline_default(), &CostFunction::Energy, &cfg)
        .unwrap();
    assert!(r.assignment.uses_non_default_layout(), "need a layout-mixed plan for this test");

    let reg = AlgorithmRegistry::new();
    let j = plan_to_json(&r.graph, &r.assignment);
    assert!(j.to_string_compact().contains("\"layout\""), "mixed plan must carry layout keys");
    let (g2, a2) = plan_from_json(&j, &reg).unwrap();
    assert_eq!(graph_hash(&r.graph), graph_hash(&g2));
    assert_eq!(r.assignment, a2, "layout-mixed assignment did not round-trip");

    let frontier = eadgo::search::PlanFrontier::from_points(vec![eadgo::search::PlanPoint {
        graph: r.graph.clone(),
        assignment: r.assignment.clone(),
        cost: r.cost,
        weight: 0.0,
        batch: 1,
    }]);
    let mj = eadgo::runtime::manifest::frontier_to_json(&frontier);
    assert!(mj.to_string_compact().contains("\"version\":5"), "layout-mixed frontier must be v5");
    let back = eadgo::runtime::manifest::frontier_from_json(&mj, &reg).unwrap();
    assert_eq!(back.points()[0].assignment, r.assignment);
}
