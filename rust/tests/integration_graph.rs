//! Integration: model zoo graphs × substitution engine × reference engine.
//! Every substitution product of every zoo model must compute the same
//! function as the original graph (the paper's equivalence guarantee).

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::engine::ReferenceEngine;
use eadgo::graph::canonical::graph_hash;
use eadgo::models::{self, ModelConfig};
use eadgo::subst::RuleSet;
use eadgo::tensor::Tensor;
use eadgo::util::prop::assert_close;
use eadgo::util::rng::Rng;

fn tiny() -> ModelConfig {
    ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 }
}

fn run_model(g: &eadgo::graph::Graph, x: &Tensor) -> Tensor {
    let reg = AlgorithmRegistry::new();
    let a = Assignment::default_for(g, &reg);
    let eng = ReferenceEngine::new();
    eng.run(g, &a, std::slice::from_ref(x)).expect("run failed").outputs.remove(0)
}

#[test]
fn all_zoo_models_execute() {
    let mut rng = Rng::seed_from(1);
    for name in models::zoo_names() {
        let g = models::by_name(name, tiny()).unwrap();
        // Feed the model's own declared input shape (the CNNs are rank-4
        // [N,C,H,W]; the attention block is rank-2 [seq, dim]).
        let in_shape = g
            .nodes()
            .find_map(|(_, n)| match &n.op {
                eadgo::graph::OpKind::Input { shape } => Some(shape.clone()),
                _ => None,
            })
            .unwrap_or_else(|| panic!("{name} has no input node"));
        let x = Tensor::rand(&in_shape, &mut rng, -1.0, 1.0);
        let out = run_model(&g, &x);
        assert_eq!(*out.shape().last().unwrap(), 10, "{name}: {:?}", out.shape());
        assert!(out.all_finite(), "{name} produced non-finite output");
    }
}

#[test]
fn substitution_neighbors_preserve_semantics_quickstart() {
    let g = models::simple::build_cnn(tiny());
    let mut rng = Rng::seed_from(2);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let base = run_model(&g, &x);
    let rs = RuleSet::standard();
    let neighbors = rs.neighbors(&g).unwrap();
    assert!(neighbors.len() >= 4, "expected several rewrites, got {}", neighbors.len());
    for (ng, rule) in neighbors {
        let out = run_model(&ng, &x);
        assert_close(base.data(), out.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("rule {rule} broke quickstart: {e}"));
    }
}

#[test]
fn substitution_neighbors_preserve_semantics_squeezenet() {
    let g = models::squeezenet::build(tiny());
    let mut rng = Rng::seed_from(3);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let base = run_model(&g, &x);
    let rs = RuleSet::standard();
    for (ng, rule) in rs.neighbors(&g).unwrap() {
        let out = run_model(&ng, &x);
        assert_close(base.data(), out.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("rule {rule} broke squeezenet: {e}"));
    }
}

#[test]
fn substitution_neighbors_preserve_semantics_attention() {
    // The matmul-side rule family (cse, fuse_matmul_epilogue) on its home
    // model: every neighbor computes the same function.
    let g = models::attention::build(tiny());
    let mut rng = Rng::seed_from(7);
    let x = Tensor::rand(&[32, 32], &mut rng, -1.0, 1.0);
    let base = run_model(&g, &x);
    let rs = RuleSet::standard();
    let neighbors = rs.neighbors(&g).unwrap();
    let rules: Vec<&str> = neighbors.iter().map(|(_, r)| *r).collect();
    assert!(rules.contains(&"cse"), "no cse neighbor: {rules:?}");
    assert!(rules.contains(&"fuse_matmul_epilogue"), "no epilogue neighbor: {rules:?}");
    for (ng, rule) in neighbors {
        let out = run_model(&ng, &x);
        assert_close(base.data(), out.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("rule {rule} broke attention: {e}"));
    }
}

#[test]
fn two_step_substitution_chains_preserve_semantics() {
    // Apply two rounds of rewrites (sampled) on resnet and recheck.
    let g = models::resnet::build(tiny());
    let mut rng = Rng::seed_from(4);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let base = run_model(&g, &x);
    let rs = RuleSet::standard();
    let level1 = rs.neighbors(&g).unwrap();
    assert!(!level1.is_empty());
    // sample a few level-1 products, expand each once more
    for (g1, rule1) in level1.iter().take(3) {
        let out1 = run_model(g1, &x);
        assert_close(base.data(), out1.data(), 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("rule {rule1}: {e}"));
        for (g2, rule2) in rs.neighbors(g1).unwrap().into_iter().take(2) {
            let out2 = run_model(&g2, &x);
            assert_close(base.data(), out2.data(), 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("chain {rule1} -> {rule2}: {e}"));
        }
    }
}

#[test]
fn canonical_hash_distinguishes_zoo_models() {
    let cfg = tiny();
    let hashes: Vec<u64> = models::zoo_names()
        .iter()
        .map(|n| graph_hash(&models::by_name(n, cfg).unwrap()))
        .collect();
    let mut dedup = hashes.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), hashes.len(), "distinct models must hash differently");
}

#[test]
fn canonical_hash_stable_across_builds() {
    let cfg = tiny();
    let h1 = graph_hash(&models::squeezenet::build(cfg));
    let h2 = graph_hash(&models::squeezenet::build(cfg));
    assert_eq!(h1, h2);
}

#[test]
fn algorithm_choice_invariance_on_squeezenet() {
    // Flip every tunable node to each applicable algorithm in turn; outputs
    // must not change (algorithms are implementations, not semantics).
    let g = models::squeezenet::build(tiny());
    let reg = AlgorithmRegistry::new();
    let a0 = Assignment::default_for(&g, &reg);
    let eng = ReferenceEngine::new();
    let mut rng = Rng::seed_from(5);
    let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
    let base = eng.run(&g, &a0, std::slice::from_ref(&x)).unwrap().outputs.remove(0);
    let shapes = g.infer_shapes().unwrap();
    let mut flipped = 0;
    for id in a0.tunable_ids(&g, &reg) {
        let node = g.node(id);
        let in_shapes: Vec<_> = node
            .inputs
            .iter()
            .map(|p| shapes[p.node.0][p.port].clone())
            .collect();
        for algo in reg.applicable(&node.op, &in_shapes) {
            let mut a = a0.clone();
            a.set(id, algo);
            let out = eng.run(&g, &a, std::slice::from_ref(&x)).unwrap().outputs.remove(0);
            assert_close(base.data(), out.data(), 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("node {} algo {:?}: {e}", id.0, algo));
            flipped += 1;
        }
        if flipped > 30 {
            break; // bounded runtime; coverage is already broad
        }
    }
    assert!(flipped >= 10);
}
