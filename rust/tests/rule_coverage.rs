//! Rule coverage: every registered rule must find at least one site
//! somewhere in the model zoo — on an origin graph or within a short,
//! documented enabling chain of standard rewrites. This is the CI
//! rule-coverage job's target; it catches rules going silently dead
//! after opset or model changes (a rule that matches nothing is worse
//! than missing, because it still pays its scan on every expansion).

use std::collections::BTreeSet;

use eadgo::graph::Graph;
use eadgo::models::{self, ModelConfig};
use eadgo::subst::RuleSet;

/// Apply the first site of `rule`, compacted; `None` when it matches
/// nowhere.
fn apply_first(rs: &RuleSet, g: &Graph, rule: &str) -> Option<Graph> {
    let site = rs.find_sites(g).unwrap().into_iter().find(|s| s.rule_name() == rule)?;
    let mut out = g.apply_delta(&site.delta(g));
    out.compact();
    Some(out)
}

fn collect(rs: &RuleSet, g: &Graph, seen: &mut BTreeSet<&'static str>) {
    for s in rs.find_sites(g).unwrap() {
        seen.insert(s.rule_name());
    }
}

#[test]
fn every_registered_rule_finds_a_site_in_the_zoo() {
    let rs = RuleSet::standard();
    let all: BTreeSet<&'static str> = rs.names().into_iter().collect();
    let cfg = ModelConfig::default();
    let mut seen: BTreeSet<&'static str> = BTreeSet::new();

    // Origin graphs cover most of the catalog directly.
    for name in models::zoo_names() {
        collect(&rs, &models::by_name(name, cfg).unwrap(), &mut seen);
    }

    // Enabling chains for rules that only match rewrite products.
    // MobileNet's depthwise convs meet their ReLUs once the BatchNorm
    // between them folds away:
    if !seen.contains("fuse_dwconv_relu") {
        let g = models::by_name("mobilenet", cfg).unwrap();
        let p = apply_first(&rs, &g, "fuse_dwconv_bn")
            .expect("mobilenet must offer a dwconv+bn fold");
        collect(&rs, &p, &mut seen);
    }
    // Split→Concat cancellation needs the Split that merge_parallel_convs
    // introduces: fuse the fire-module ReLUs into their convs, enlarge
    // the 1x1 expand convs to padded 3x3, merge the now-identical
    // parallel pair — the merged conv's Split then feeds the fire
    // Concat directly, in port order.
    if !seen.contains("split_concat_elim") {
        let mut g = models::squeezenet::build(cfg);
        while let Some(p) = apply_first(&rs, &g, "fuse_conv_relu") {
            g = p;
        }
        while let Some(p) = apply_first(&rs, &g, "enlarge_conv_kernel") {
            g = p;
        }
        let p = apply_first(&rs, &g, "merge_parallel_convs")
            .expect("enlarged squeezenet must offer a parallel-conv merge");
        collect(&rs, &p, &mut seen);
    }

    let dead: Vec<&str> = all.difference(&seen).copied().collect();
    assert!(dead.is_empty(), "rules with no site anywhere in the zoo: {dead:?}");
    // And the registry really is the full 12-rule catalog — a rule
    // dropped from RuleSet::standard() must not pass silently.
    assert_eq!(all.len(), 12, "unexpected rule count: {all:?}");
}
