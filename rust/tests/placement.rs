//! ISSUE 8 conformance suite: heterogeneous (GPU+DLA) placement.
//!
//! The placement axis adds a second device class to the search — per-node
//! (device, frequency) states, transfer costs at device boundaries, and
//! migration as a constrained-search feasibility lever. This suite locks
//! the contract down from four sides:
//!
//! 1. **Single-device bit-identity** — plans searched over a GPU-only
//!    state set carry no device keys and serialize exactly as before the
//!    placement axis existed (the CLI face of this, `--devices gpu` vs
//!    flag omitted, is byte-diffed in `integration_cli.rs`).
//! 2. **Engine-matrix bit-identity on mixed tables** — every
//!    `delta_eval` × `incremental_inner` combination must return the same
//!    plan bits when the table spans devices, because the boundary-aware
//!    inner pass is a start-independent function of (table, objective).
//! 3. **Placement invariants** — transfer cost is zero iff no edge
//!    crosses devices; device-uniform assignments conserve the
//!    single-device totals exactly (no `+ 0.0` drift); `eval_swap`
//!    agrees bitwise with full re-evaluation across device boundaries;
//!    budget refinement never returns an infeasible plan while a feasible
//!    uniform assignment exists.
//! 4. **The acceptance claim** — at the same latency budget, the GPU+DLA
//!    search strictly beats the best GPU-only plan on energy on at least
//!    two zoo models, and the winning plan round-trips through the v4
//!    manifest.

use eadgo::algo::AlgorithmRegistry;
use eadgo::cost::{CostDb, CostFunction, CostOracle};
use eadgo::energysim::{DeviceId, FreqId};
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::serde::{plan_from_json, plan_to_json};
use eadgo::models::{self, ModelConfig};
use eadgo::profiler::SimHeteroProvider;
use eadgo::search::{
    optimize, optimize_with_time_budget, refine_frequency_to_budget, DvfsMode, OptimizerContext,
    SearchConfig,
};
use eadgo::subst::RuleSet;

fn model_cfg() -> ModelConfig {
    ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 }
}

/// A search context over the GPU+DLA provider (same seed as
/// `OptimizerContext::offline_default`, so GPU-side measurements are
/// bitwise the single-device ones).
fn hetero_ctx() -> OptimizerContext {
    OptimizerContext::new(RuleSet::standard(), CostDb::new(), Box::new(SimHeteroProvider::new(7)))
}

fn hetero_oracle() -> CostOracle {
    CostOracle::new(AlgorithmRegistry::new(), CostDb::new(), Box::new(SimHeteroProvider::new(7)))
}

/// The DLA's nominal state — the placement-only (no DVFS) migration target.
fn dla0() -> FreqId {
    FreqId::on(DeviceId::DLA, 0)
}

// -------------------------------------------------------------------------
// 1. single-device surfaces stay device-free
// -------------------------------------------------------------------------

#[test]
fn single_device_plans_carry_no_device_keys() {
    let g = models::squeezenet::build(model_cfg());
    let ctx = OptimizerContext::offline_default();
    let cfg = SearchConfig { max_dequeues: 16, ..Default::default() };
    let r = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
    let plan = plan_to_json(&r.graph, &r.assignment).to_string_compact();
    assert!(!plan.contains("\"device\""), "GPU-only plan grew a device key: {plan}");
    assert!(r.assignment.devices_used() == vec![DeviceId::GPU]);

    // And the frontier manifest stays version 2.
    let fr = eadgo::search::optimize_frontier(&g, &ctx, &cfg, 3).unwrap();
    let manifest = eadgo::runtime::manifest::frontier_to_json(&fr.frontier).to_string_compact();
    assert!(manifest.contains("\"version\":2"), "single-device frontier must stay v2");
    assert!(!manifest.contains("\"device\""), "single-device frontier grew device keys");
}

// -------------------------------------------------------------------------
// 2. engine-matrix bit-identity on multi-device tables
// -------------------------------------------------------------------------

#[test]
fn hetero_plans_bit_identical_across_engine_matrix() {
    // The boundary-aware inner pass ignores warm starts and dirty scoping
    // (unsound under transfer coupling) and re-derives from the per-row
    // argmin, so every engine combination must agree bit for bit even
    // though the objective is non-separable at device boundaries.
    let run = |model: &str, dvfs: DvfsMode, delta_eval: bool, incremental_inner: bool| {
        let g = models::by_name(model, model_cfg()).unwrap();
        let cfg = SearchConfig {
            max_dequeues: 16,
            dvfs,
            delta_eval,
            incremental_inner,
            ..Default::default()
        };
        let r = optimize(&g, &hetero_ctx(), &CostFunction::Energy, &cfg).unwrap();
        (
            graph_hash(&r.graph),
            plan_to_json(&r.graph, &r.assignment).to_string_compact(),
            r.cost.time_ms.to_bits(),
            r.cost.energy_j.to_bits(),
        )
    };
    for model in ["squeezenet", "mobilenet"] {
        for dvfs in [DvfsMode::Off, DvfsMode::PerNode] {
            let reference = run(model, dvfs, true, true);
            for (d, i) in [(true, false), (false, true), (false, false)] {
                assert_eq!(
                    reference,
                    run(model, dvfs, d, i),
                    "{model}/dvfs={}: engine matrix (delta_eval={d}, incremental_inner={i}) \
                     diverged on a multi-device table",
                    dvfs.describe()
                );
            }
        }
    }
}

#[test]
fn hetero_energy_search_places_nodes_on_the_dla() {
    // Unconstrained energy minimization over the joint state set must use
    // the low-power device — otherwise every placement test downstream is
    // vacuous. (--dvfs off still searches placement at nominal clocks.)
    let g = models::squeezenet::build(model_cfg());
    let cfg = SearchConfig { max_dequeues: 16, ..Default::default() };
    let r = optimize(&g, &hetero_ctx(), &CostFunction::Energy, &cfg).unwrap();
    assert!(
        r.assignment.uses_non_gpu_device(),
        "energy objective over GPU+DLA kept every node on the GPU"
    );
    // The hetero optimum can never lose to the GPU-only optimum: the GPU
    // state set is a strict subset of the joint one.
    let gpu = optimize(
        &g,
        &OptimizerContext::offline_default(),
        &CostFunction::Energy,
        &cfg,
    )
    .unwrap();
    assert!(
        r.cost.energy_j <= gpu.cost.energy_j,
        "joint search lost to its GPU-only subset: {} vs {}",
        r.cost.energy_j,
        gpu.cost.energy_j
    );
}

// -------------------------------------------------------------------------
// 3. placement invariants on the cost tables
// -------------------------------------------------------------------------

/// A mixed-device cost table for the simple model plus its default
/// (all-GPU nominal) assignment.
fn simple_table() -> (eadgo::graph::Graph, eadgo::cost::GraphCostTable, eadgo::algo::Assignment) {
    let oracle = hetero_oracle();
    let g = models::by_name("simple", model_cfg()).unwrap();
    let shapes = g.infer_shapes().unwrap();
    oracle.profile_graph(&g).unwrap();
    let (table, _) = oracle.table_for_freqs(&g, &shapes, &[FreqId::NOMINAL, dla0()]);
    assert!(table.has_links(), "a GPU+DLA table must carry the transfer overlay");
    let a = eadgo::algo::Assignment::default_for(&g, &AlgorithmRegistry::new());
    (g, table, a)
}

#[test]
fn transfer_cost_zero_iff_an_edge_crosses_devices() {
    let (_g, table, a) = simple_table();
    let edges = table.links().unwrap().edges();
    assert!(!edges.is_empty(), "the simple model must have costed-to-costed edges");

    // Device-uniform: no boundary, exact zero (both all-GPU and all-DLA).
    assert_eq!(table.transfer_cost(&a), (0.0, 0.0), "all-GPU plan charged a transfer");
    let mut uni = a.clone();
    uni.set_uniform_freq(dla0());
    assert_eq!(table.transfer_cost(&uni), (0.0, 0.0), "all-DLA plan charged a transfer");

    // Migrate a growing prefix of costed nodes: for every assignment along
    // the way, the transfer cost is zero iff no priced edge crosses
    // devices, and strictly positive in both axes the moment one does.
    let mut b = a.clone();
    for id in table.costed_ids() {
        b.set_freq(id, dla0());
        let crossing = edges
            .iter()
            .any(|e| b.freq(e.src).device() != b.freq(e.dst).device());
        let (t, e) = table.transfer_cost(&b);
        if crossing {
            assert!(t > 0.0 && e > 0.0, "a crossing edge must charge time and energy");
        } else {
            assert_eq!((t, e), (0.0, 0.0), "no crossing edge, yet a transfer was charged");
        }
    }
    // The sweep ends all-DLA: uniform again, so exactly zero.
    assert_eq!(table.transfer_cost(&b), (0.0, 0.0), "all-DLA plan still charged a transfer");
    // And the sweep must have exercised at least one mixed step.
    let mut first = a.clone();
    first.set_freq(table.costed_ids().next().unwrap(), dla0());
    assert!(table.transfer_cost(&first).0 > 0.0, "single-node migration crossed no edge");
}

#[test]
fn device_uniform_assignments_conserve_single_device_totals() {
    // Evaluating a device-uniform plan through the mixed table must equal
    // the single-device table bitwise: the overlay adds no terms at all.
    let (_g, table, a) = simple_table();
    for f in [FreqId::NOMINAL, dla0()] {
        let mut af = a.clone();
        af.set_uniform_freq(f);
        let mixed = table.eval(&af);
        let single = table.restrict_to_freq(f);
        assert!(!single.has_links(), "restricted single-state table must drop the overlay");
        let alone = single.eval(&af);
        assert_eq!(
            (mixed.time_ms.to_bits(), mixed.energy_j.to_bits()),
            (alone.time_ms.to_bits(), alone.energy_j.to_bits()),
            "uniform {} plan not conserved through the mixed table",
            f.describe()
        );
    }
}

#[test]
fn mixed_eval_is_node_sum_plus_boundary_edges_exactly() {
    let (_g, table, mut a) = simple_table();
    // Put the first costed node on the DLA: at least one boundary.
    let first = table.costed_ids().next().unwrap();
    a.set_freq(first, dla0());
    let full = table.eval(&a);
    // Replicate eval's accumulation exactly (per-node in id order, then
    // per-crossing-edge in edge order) so the comparison is bitwise.
    let mut t = 0.0;
    let mut e = 0.0;
    for id in table.costed_ids() {
        let c = table.option_cost(id, a.get(id).unwrap(), a.freq(id)).unwrap();
        t += c.time_ms;
        e += c.energy_j();
    }
    let mut crossed = 0usize;
    for edge in table.links().unwrap().edges() {
        if a.freq(edge.src).device() != a.freq(edge.dst).device() {
            t += edge.time_ms;
            e += edge.energy_mj;
            crossed += 1;
        }
    }
    assert!(crossed > 0, "expected a device boundary");
    assert_eq!(
        (full.time_ms.to_bits(), full.energy_j.to_bits()),
        (t.to_bits(), e.to_bits()),
        "eval != per-node sum + boundary transfer terms"
    );
}

#[test]
fn eval_swap_matches_full_eval_across_device_boundaries() {
    // The O(degree) boundary adjustment in eval_swap must agree bitwise
    // with a from-scratch eval for every single-node device move.
    let (_g, table, a) = simple_table();
    let base = table.eval(&a);
    for id in table.costed_ids() {
        for (f, slab) in table.freq_options(id) {
            for &(algo, _) in slab.iter() {
                let swapped = table.eval_swap(base, &a, id, algo, *f).unwrap();
                let mut af = a.clone();
                af.set(id, algo);
                af.set_freq(id, *f);
                let fresh = table.eval(&af);
                assert_eq!(
                    (swapped.time_ms.to_bits(), swapped.energy_j.to_bits()),
                    (fresh.time_ms.to_bits(), fresh.energy_j.to_bits()),
                    "eval_swap diverged moving node {} to ({}, {})",
                    id.0,
                    algo.name(),
                    f.describe()
                );
            }
        }
    }
}

#[test]
fn refine_to_budget_feasible_when_a_uniform_assignment_is() {
    // Start from an infeasible all-DLA plan with a budget the all-GPU
    // plan meets: migration back to the GPU is always available, so the
    // refinement must land inside the budget.
    let oracle = hetero_oracle();
    let g = models::by_name("simple", model_cfg()).unwrap();
    oracle.profile_graph(&g).unwrap();
    let shapes = g.infer_shapes().unwrap();
    let (table, _) = oracle.table_for_freqs(&g, &shapes, &[FreqId::NOMINAL, dla0()]);
    let reg = AlgorithmRegistry::new();
    let mut a = eadgo::algo::Assignment::default_for(&g, &reg);
    let gpu_time = table.eval(&a).time_ms;
    a.set_uniform_freq(dla0());
    let dla = table.eval(&a);
    assert!(dla.time_ms > gpu_time, "the DLA must be the slower device");

    // Budget feasible for all-GPU, infeasible where the plan starts.
    let budget = gpu_time * 1.001;
    let (ra, rc) = refine_frequency_to_budget(&oracle, &g, &a, budget, DvfsMode::Off, &[])
        .unwrap()
        .expect("a feasible all-GPU assignment exists — refinement must not give up");
    assert!(
        rc.time_ms <= budget,
        "refined plan still over budget: {} > {budget}",
        rc.time_ms
    );
    let fresh = table.eval(&ra);
    assert_eq!(rc.time_ms.to_bits(), fresh.time_ms.to_bits(), "reported cost is stale");

    // With a budget even the all-DLA plan meets, refinement must keep the
    // plan feasible AND not raise its energy (phase 2 only lowers).
    let loose = dla.time_ms * 2.0;
    let (_, rc2) = refine_frequency_to_budget(&oracle, &g, &a, loose, DvfsMode::Off, &[])
        .unwrap()
        .expect("trivially feasible budget");
    assert!(rc2.time_ms <= loose);
    assert!(
        rc2.energy_j <= dla.energy_j * (1.0 + 1e-12),
        "refinement raised energy under a slack budget: {} vs {}",
        rc2.energy_j,
        dla.energy_j
    );
}

// -------------------------------------------------------------------------
// 4. the acceptance claim + v4 round-trip
// -------------------------------------------------------------------------

#[test]
fn budgeted_hetero_search_beats_gpu_only_on_two_zoo_models() {
    // The ISSUE 8 acceptance criterion: at the same latency budget the
    // GPU+DLA search finds a mixed plan with strictly lower energy than
    // the best GPU-only plan, on at least two zoo models.
    for model in ["squeezenet", "mobilenet"] {
        let g = models::by_name(model, model_cfg()).unwrap();
        let scfg = SearchConfig { max_dequeues: 12, dvfs: DvfsMode::PerNode, ..Default::default() };
        let gpu_ctx = OptimizerContext::offline_default();
        let tbest = optimize(&g, &gpu_ctx, &CostFunction::Time, &scfg).unwrap().cost.time_ms;
        let budget = 2.0 * tbest;
        let r_gpu = optimize_with_time_budget(&g, &gpu_ctx, budget, &scfg, 4).unwrap();
        let r_het = optimize_with_time_budget(&g, &hetero_ctx(), budget, &scfg, 4).unwrap();
        assert!(r_gpu.feasible && r_het.feasible, "{model}: both searches must fit 2x best-time");
        assert!(
            r_het.result.cost.time_ms <= budget * (1.0 + 1e-9),
            "{model}: mixed plan over budget"
        );
        assert!(
            r_het.result.assignment.uses_non_gpu_device(),
            "{model}: budgeted hetero search placed nothing on the DLA"
        );
        assert!(
            r_het.result.cost.energy_j < r_gpu.result.cost.energy_j,
            "{model}: mixed placement must strictly beat GPU-only at the same budget: {} vs {}",
            r_het.result.cost.energy_j,
            r_gpu.result.cost.energy_j
        );
    }
}

#[test]
fn mixed_plans_roundtrip_and_gate_serving() {
    // A searched mixed plan must survive plan JSON and the v4 frontier
    // manifest byte-exactly, and the serve-side guard must name the DLA
    // when the serving context lacks it.
    let g = models::squeezenet::build(model_cfg());
    let cfg = SearchConfig { max_dequeues: 16, ..Default::default() };
    let r = optimize(&g, &hetero_ctx(), &CostFunction::Energy, &cfg).unwrap();
    assert!(r.assignment.uses_non_gpu_device(), "need a mixed plan for this test");

    let reg = AlgorithmRegistry::new();
    let j = plan_to_json(&r.graph, &r.assignment);
    assert!(j.to_string_compact().contains("\"device\""), "mixed plan must carry device keys");
    let (g2, a2) = plan_from_json(&j, &reg).unwrap();
    assert_eq!(graph_hash(&r.graph), graph_hash(&g2));
    assert_eq!(r.assignment, a2, "mixed plan assignment did not round-trip");

    let frontier = eadgo::search::PlanFrontier::from_points(vec![eadgo::search::PlanPoint {
        graph: r.graph.clone(),
        assignment: r.assignment.clone(),
        cost: r.cost,
        weight: 0.0,
        batch: 1,
    }]);
    let mj = eadgo::runtime::manifest::frontier_to_json(&frontier);
    assert!(mj.to_string_compact().contains("\"version\":4"), "mixed frontier must be v4");
    let back = eadgo::runtime::manifest::frontier_from_json(&mj, &reg).unwrap();
    assert_eq!(back.points()[0].assignment, r.assignment);

    // The serving guard: a gpu-only context must name the dla as missing;
    // the full device list clears it.
    let missing =
        eadgo::runtime::manifest::unsupported_devices(&frontier, &["gpu".to_string()]);
    assert_eq!(missing, vec!["dla".to_string()]);
    let ok = eadgo::runtime::manifest::unsupported_devices(
        &frontier,
        &["gpu".to_string(), "dla".to_string()],
    );
    assert!(ok.is_empty());
}
