//! Offline shim of the `anyhow` crate: the subset eadgo uses (`Result`,
//! `Error`, `anyhow!`, `bail!`, `ensure!`), API-compatible so the real
//! crate can be swapped back in by editing one line of `rust/Cargo.toml`.
//!
//! The build environment for this repo has no network access, so external
//! crates are vendored as minimal path dependencies rather than pulled
//! from crates.io.

use std::fmt;

/// A string-backed error value, optionally carrying the source error it
/// was converted from (via `?`).
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a standard error, keeping it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prefix the message with context (e.g. which file was being read).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The wrapped source error, if this came from one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps this blanket `?`-conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `Result` with the boxed string error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/at/all")?;
        Ok(())
    }

    #[test]
    fn macros_and_conversions() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("got {x} and {}", 4);
        assert_eq!(e.to_string(), "got 3 and 4");
        let e = anyhow!(String::from("from a value"));
        assert_eq!(e.to_string(), "from a value");
        let err = io_fail().unwrap_err();
        assert!(err.source().is_some());
        assert_eq!(format!("{err:#}"), err.to_string());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
