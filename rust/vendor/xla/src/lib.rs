//! Offline stub of the `xla-rs` PJRT surface used by `eadgo::runtime`.
//!
//! The real crate links `libxla_extension` (a multi-GB native bundle) that
//! is not present in this build environment, so the missing dependency is
//! stubbed per the repo policy: host-side data plumbing ([`Literal`]) is
//! fully functional, while device compilation/execution returns a clear
//! "unavailable" error. Swap `rust/Cargo.toml`'s `xla` entry back to the
//! real crate to run AOT artifacts through genuine PJRT.

use std::fmt;
use std::path::Path;

/// Stub error type; displays like the real crate's error strings.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT is unavailable: eadgo was built against the vendored xla stub (no libxla_extension)";

/// Element types a [`Literal`] can be read back as. Only f32 is used.
pub trait NativeType: Sized + Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// A host-side dense f32 array (optionally a tuple of arrays) with a shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// A rank-1 literal holding a copy of `data`.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec(), tuple: None }
    }

    /// The same data viewed under a new shape; errors on element-count
    /// mismatch, like the real crate.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if self.tuple.is_some() {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} vs {})",
                self.dims,
                dims,
                self.data.len(),
                want
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone(), tuple: None })
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Read the elements back out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("cannot to_vec a tuple literal".into()));
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple.ok_or_else(|| Error("literal is not a tuple".into()))
    }
}

/// Parsed HLO module (stub: retains the artifact text only).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. File errors are real; parsing is deferred
    /// to compile time (which the stub does not support).
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let path = path.as_ref();
        std::fs::read_to_string(path)
            .map(|text| HloModuleProto { text })
            .map_err(|e| Error(format!("{}: {e}", path.display())))
    }
}

/// An XLA computation wrapping an HLO module (stub: empty handle).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer holding an execution result (stub: never constructed,
/// since the stub cannot execute).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// A compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// The PJRT client. Construction succeeds (so offline flows that merely
/// probe for artifacts keep working); compilation reports unavailability.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn stub_reports_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        assert!(client.compile(&XlaComputation).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }

    #[test]
    fn missing_file_is_error() {
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo.txt").is_err());
    }
}
