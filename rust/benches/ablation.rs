//! Ablation benches for the design choices DESIGN.md calls out:
//!   1. α sweep — search breadth vs solution quality (paper §3.3: "as α
//!      increases, the search algorithm explores a larger part").
//!   2. inner distance d — d=1 vs d=2 for additive vs ratio objectives
//!      (paper §4.1 uses d=1 for linear, d=2 otherwise).
//!   3. rule-set leave-one-out — which substitution family pays.
//!   4. MobileNet (depthwise extension, paper §5 future work).
//!   5. parallel frontier — search wall-clock, threads=1 vs threads=8,
//!      with bit-identical plans (the CostOracle/wave-expansion payoff).
//!   6. DVFS — off vs per-graph vs per-node frequency search (the (G,A,f)
//!      extension; arXiv:1905.11012's sweet spot, PolyThrottle-style
//!      budgeted refinement).
//! Run: `cargo bench --bench ablation [-- --quick]` (or EADGO_BENCH_QUICK=1).
//! Emits `BENCH_ablation.json` (dir override: EADGO_BENCH_OUT_DIR).

use eadgo::cost::CostFunction;
use eadgo::graph::canonical::graph_hash;
use eadgo::models::{self, ModelConfig};
use eadgo::report::{describe_freqs, f3, Table};
use eadgo::search::{optimize, DvfsMode, OptimizerContext, SearchConfig};
use eadgo::subst::{rules, RuleSet};
use eadgo::util::json::Json;

fn ctx() -> OptimizerContext {
    OptimizerContext::offline_default()
}

fn main() {
    let quick = eadgo::util::bench::quick_requested();
    let cfg = ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 };
    let budget = if quick { 40 } else { 200 };
    let g = models::squeezenet::build(cfg);
    let mut payload = Json::obj();
    payload.set("bench", "ablation").set("quick", quick);

    // --- 1. alpha sweep ----------------------------------------------------
    let mut t = Table::new(
        "Ablation 1: alpha sweep (SqueezeNet, energy objective)",
        &["alpha", "energy_j/1k", "graphs generated", "search_s"],
    );
    let mut prev_energy = f64::INFINITY;
    let mut alpha_json = Json::obj();
    for alpha in [1.0, 1.01, 1.05, 1.10] {
        let c = ctx();
        let res = optimize(
            &g,
            &c,
            &CostFunction::Energy,
            &SearchConfig { alpha, max_dequeues: budget, ..Default::default() },
        )
        .unwrap();
        t.row(vec![
            format!("{alpha:.2}"),
            f3(res.cost.energy_j),
            res.stats.generated.to_string(),
            format!("{:.2}", res.stats.wall_s),
        ]);
        alpha_json.set(&format!("energy_alpha_{alpha}"), res.cost.energy_j);
        assert!(
            res.cost.energy_j <= prev_energy * 1.001,
            "larger alpha must not find worse solutions"
        );
        prev_energy = res.cost.energy_j;
    }
    payload.set("alpha_sweep", alpha_json);
    println!("{}", t.render());

    // --- 2. inner distance -------------------------------------------------
    let mut t = Table::new(
        "Ablation 2: inner-search distance (SqueezeNet)",
        &["objective", "d", "objective value", "inner evals"],
    );
    for (obj, name) in [
        (CostFunction::Energy, "energy"),
        (CostFunction::Power, "power"),
    ] {
        let mut per_d = Vec::new();
        for d in [1usize, 2] {
            let c = ctx();
            let res = optimize(
                &g,
                &c,
                &obj,
                &SearchConfig {
                    inner_distance: Some(d),
                    max_dequeues: budget / 2,
                    ..Default::default()
                },
            )
            .unwrap();
            t.row(vec![
                name.to_string(),
                d.to_string(),
                format!("{:.4}", res.objective_value),
                res.stats.inner_evals.to_string(),
            ]);
            per_d.push(res.objective_value);
        }
        // d=2 never worse; for the additive objective d=1 already optimal.
        assert!(per_d[1] <= per_d[0] + 1e-9, "{name}: d=2 worse than d=1");
        if matches!(obj, CostFunction::Energy) {
            assert!(
                (per_d[1] - per_d[0]).abs() <= 1e-6 * per_d[0].abs().max(1.0),
                "additive objective: d=2 should not improve on d=1"
            );
        }
    }
    println!("{}", t.render());

    // --- 3. rule-set leave-one-out ------------------------------------------
    let families: Vec<(&str, RuleSet)> = vec![
        ("all rules", RuleSet::standard()),
        (
            "no fusions",
            RuleSet::with_rules(vec![
                Box::new(rules::MergeParallelConvs),
                Box::new(rules::EnlargeConvKernel),
                Box::new(rules::SplitConcatElim),
                Box::new(rules::ConcatSplitElim),
            ]),
        ),
        (
            "no merges",
            RuleSet::with_rules(vec![
                Box::new(rules::FuseConvRelu),
                Box::new(rules::FuseDwConvRelu),
                Box::new(rules::FuseAddRelu),
                Box::new(rules::FuseConvBn),
                Box::new(rules::FuseDwConvBn),
                Box::new(rules::FuseConvResidual),
            ]),
        ),
        ("no rules (inner only)", RuleSet::empty()),
    ];
    let mut t = Table::new(
        "Ablation 3: rule families (SqueezeNet, energy objective)",
        &["rule set", "energy_j/1k", "vs all rules"],
    );
    let mut all_energy = None;
    for (name, rs) in families {
        let c = OptimizerContext::new(
            rs,
            eadgo::cost::CostDb::new(),
            Box::new(eadgo::profiler::SimV100Provider::new(7)),
        );
        let res = optimize(
            &g,
            &c,
            &CostFunction::Energy,
            &SearchConfig { max_dequeues: budget, ..Default::default() },
        )
        .unwrap();
        let base = *all_energy.get_or_insert(res.cost.energy_j);
        t.row(vec![
            name.to_string(),
            f3(res.cost.energy_j),
            format!("{:+.1}%", 100.0 * (res.cost.energy_j / base - 1.0)),
        ]);
        assert!(res.cost.energy_j >= base * 0.999, "subset beats full rule set?");
    }
    println!("{}", t.render());

    // --- 4. MobileNet (depthwise extension) ---------------------------------
    let gm = models::mobilenet::build(cfg);
    let c = ctx();
    let res = optimize(
        &gm,
        &c,
        &CostFunction::Energy,
        &SearchConfig { max_dequeues: budget, ..Default::default() },
    )
    .unwrap();
    println!(
        "MobileNetV1 (depthwise): origin {} J -> optimized {} J ({:+.1}% energy, {:+.1}% time)\n",
        f3(res.original.energy_j),
        f3(res.cost.energy_j),
        -100.0 * res.energy_savings(),
        -100.0 * res.time_savings()
    );
    assert!(res.cost.energy_j < res.original.energy_j);

    // --- 5. parallel frontier expansion -------------------------------------
    // The tentpole claim: threads=8 returns a bit-identical plan to
    // threads=1 while spending less wall-clock on the search (resnet and
    // inception at the paper's alpha=1.05).
    let mut t = Table::new(
        "Ablation 5: parallel frontier (energy objective, alpha=1.05)",
        &["model", "threads", "search_s", "speedup", "energy_j/1k", "plan hash"],
    );
    let mut frontier_json = Json::obj();
    for name in ["resnet", "inception"] {
        let g = models::by_name(name, cfg).unwrap();
        let run = |threads: usize| {
            let c = ctx();
            let res = optimize(
                &g,
                &c,
                &CostFunction::Energy,
                &SearchConfig { alpha: 1.05, max_dequeues: budget, threads, ..Default::default() },
            )
            .unwrap();
            (res.stats.wall_s, res.cost, graph_hash(&res.graph), res.assignment)
        };
        let (seq_s, seq_cost, seq_hash, seq_a) = run(1);
        let (par_s, par_cost, par_hash, par_a) = run(8);
        frontier_json
            .set(&format!("{name}_seq_s"), seq_s)
            .set(&format!("{name}_par_s"), par_s)
            .set(&format!("{name}_energy"), seq_cost.energy_j);
        for (threads, wall, cost, hash) in
            [(1usize, seq_s, seq_cost, seq_hash), (8usize, par_s, par_cost, par_hash)]
        {
            t.row(vec![
                name.to_string(),
                threads.to_string(),
                format!("{wall:.3}"),
                format!("{:.2}x", seq_s / wall.max(1e-9)),
                f3(cost.energy_j),
                format!("{hash:016x}"),
            ]);
        }
        assert_eq!(seq_hash, par_hash, "{name}: parallel plan graph differs");
        assert_eq!(seq_a, par_a, "{name}: parallel assignment differs");
        assert_eq!(
            seq_cost.energy_j.to_bits(),
            par_cost.energy_j.to_bits(),
            "{name}: parallel cost differs"
        );
        if par_s >= seq_s {
            eprintln!(
                "NOTE: {name}: no parallel speedup on this host ({par_s:.3}s vs {seq_s:.3}s) — \
                 expected on single-core machines"
            );
        }
    }
    payload.set("parallel_frontier", frontier_json);
    println!("{}", t.render());

    // --- 6. DVFS frequency axis ---------------------------------------------
    // The (G, A, f) extension: per-graph locks one state per plan,
    // per-node lets every node pick its own. Inner-only rows give the
    // provable ordering (the joint per-node optimum dominates any uniform
    // state, which dominates nominal-only); full-search rows show what the
    // whole two-level search does with the extra axis.
    let mut t = Table::new(
        "Ablation 6: DVFS frequency axis (SqueezeNet, energy objective)",
        &["dvfs", "search", "time_ms", "energy_j/1k", "plan freq"],
    );
    let mut dvfs_json = Json::obj();
    let mut inner_energy: Vec<f64> = Vec::new();
    for (label, dvfs) in [
        ("off", DvfsMode::Off),
        ("per-graph", DvfsMode::PerGraph),
        ("per-node", DvfsMode::PerNode),
    ] {
        for (search, outer) in [("inner-only", false), ("full", true)] {
            let c = ctx();
            let res = optimize(
                &g,
                &c,
                &CostFunction::Energy,
                &SearchConfig {
                    dvfs,
                    enable_outer: outer,
                    max_dequeues: budget / 2,
                    ..Default::default()
                },
            )
            .unwrap();
            t.row(vec![
                label.to_string(),
                search.to_string(),
                f3(res.cost.time_ms),
                f3(res.cost.energy_j),
                describe_freqs(&res.assignment),
            ]);
            dvfs_json.set(&format!("energy_{label}_{search}"), res.cost.energy_j);
            if !outer {
                inner_energy.push(res.cost.energy_j);
            }
        }
    }
    println!("{}", t.render());
    // Guaranteed ordering on the fixed origin graph: per-node ≤ per-graph
    // ≤ off (larger option spaces, additive objective, d=1 optimal).
    assert!(
        inner_energy[1] <= inner_energy[0] + 1e-9,
        "per-graph DVFS must not lose to nominal-only: {} vs {}",
        inner_energy[1],
        inner_energy[0]
    );
    assert!(
        inner_energy[2] <= inner_energy[1] + 1e-9,
        "per-node DVFS must dominate per-graph: {} vs {}",
        inner_energy[2],
        inner_energy[1]
    );
    println!(
        "DVFS inner-only energy: off {} -> per-graph {} ({:+.1}%) -> per-node {} ({:+.1}%)\n",
        f3(inner_energy[0]),
        f3(inner_energy[1]),
        100.0 * (inner_energy[1] / inner_energy[0] - 1.0),
        f3(inner_energy[2]),
        100.0 * (inner_energy[2] / inner_energy[0] - 1.0),
    );
    payload.set("dvfs", dvfs_json);

    eadgo::util::bench::emit_bench_json("ablation", &payload).expect("bench payload write");
}
