//! Ablation benches for the design choices DESIGN.md calls out:
//!   1. α sweep — search breadth vs solution quality (paper §3.3: "as α
//!      increases, the search algorithm explores a larger part").
//!   2. inner distance d — d=1 vs d=2 for additive vs ratio objectives
//!      (paper §4.1 uses d=1 for linear, d=2 otherwise).
//!   3. rule-set leave-one-out — which substitution family pays.
//!   4. MobileNet (depthwise extension, paper §5 future work).
//!   5. parallel frontier — search wall-clock, threads=1 vs threads=8,
//!      with bit-identical plans (the CostOracle/wave-expansion payoff).
//!   6. DVFS — off vs per-graph vs per-node frequency search (the (G,A,f)
//!      extension; arXiv:1905.11012's sweet spot, PolyThrottle-style
//!      budgeted refinement).
//!   7. Pareto frontier + load-adaptive serving — fixed latency-optimal
//!      plan vs the FrontierController across the frontier, at low and
//!      high request rates (energy/request and steady-state p99).
//!   8. substitution engine — candidate-evaluation throughput
//!      (candidates/sec) of the RewriteSite delta engine vs the legacy
//!      full-rebuild path, with bit-identical plans asserted.
//!   9. incremental inner search — end-to-end candidates/sec of the
//!      warm-start + argmin-memo inner engine (ISSUE 5) vs the PR-4
//!      delta-only engine vs the full-rebuild engine, with bit-identical
//!      plans and a deterministic drop in per-candidate option
//!      evaluations asserted.
//!  10. adaptive batching — deadline-aware serving over the joint
//!      (plan, freq, batch) operating-point surface vs the fixed batch-1
//!      loop on a bursty calm/burst/calm trace: requests/joule and p99
//!      (ISSUE 6).
//!  12. heterogeneous placement — GPU-only vs GPU+DLA latency-constrained
//!      search at the same time budget on two zoo models: the mixed
//!      placement must strictly cut energy/request (ISSUE 8), published
//!      as `placement.energy_ratio`.
//!  13. rewrite ablation — best plan on the origin graph (algorithms +
//!      frequencies only, no substitutions) vs the full rule set, on a
//!      conv model and the attention block: the rewrite space must
//!      strictly cut energy (ISSUE 9), published as
//!      `rewrite.cost_ratio_{conv,attention}`.
//!  14. fault tolerance — a seeded `DeviceLost{dla}` against a mixed
//!      GPU+DLA surface: zero dropped admitted requests, one contingency
//!      hot-swap, deterministic virtual-clock replay (ISSUE 10), published
//!      as `serve.availability_under_faults` and
//!      `serve.degraded_energy_ratio`.
//! Run: `cargo bench --bench ablation [-- --quick]` (or EADGO_BENCH_QUICK=1).
//! Emits `BENCH_ablation.json` (dir override: EADGO_BENCH_OUT_DIR).

use eadgo::algo::{AlgorithmRegistry, Assignment};
use eadgo::cost::{CostDb, CostFunction, CostOracle, GraphCost, NodeCost};
use eadgo::energysim::{DeviceId, FreqId};
use eadgo::graph::canonical::graph_hash;
use eadgo::graph::{Activation, Graph, OpKind, PortRef};
use eadgo::models::{self, ModelConfig};
use eadgo::profiler::{ensure_profiled, SimHeteroProvider, SimV100Provider};
use eadgo::report::tables::frontier_table;
use eadgo::report::{describe_freqs, f3, Table};
use eadgo::search::{
    optimize, optimize_frontier, optimize_frontier_batched, optimize_with_time_budget,
    price_plan_at_batch, synthesize_contingency, DvfsMode, OptimizerContext, PlanPoint,
    SearchConfig,
};
use eadgo::serve::{
    AdaptiveConfig, DriftKind, FaultEvent, FaultKind, FaultPlan, FeedbackConfig, OperatingPoint,
    RatePhase, ServeConfig, ServeReport, ServeSession, ServiceModel,
};
use eadgo::subst::{rules, RuleSet};
use eadgo::tensor::Tensor;
use eadgo::util::json::Json;
use eadgo::util::stats::percentile_sorted;

fn ctx() -> OptimizerContext {
    OptimizerContext::offline_default()
}

fn main() {
    let quick = eadgo::util::bench::quick_requested();
    let cfg = ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 };
    let budget = if quick { 40 } else { 200 };
    let g = models::squeezenet::build(cfg);
    let mut payload = Json::obj();
    payload.set("bench", "ablation").set("quick", quick);

    // --- 1. alpha sweep ----------------------------------------------------
    let mut t = Table::new(
        "Ablation 1: alpha sweep (SqueezeNet, energy objective)",
        &["alpha", "energy_j/1k", "graphs generated", "search_s"],
    );
    let mut prev_energy = f64::INFINITY;
    let mut alpha_json = Json::obj();
    for alpha in [1.0, 1.01, 1.05, 1.10] {
        let c = ctx();
        let res = optimize(
            &g,
            &c,
            &CostFunction::Energy,
            &SearchConfig { alpha, max_dequeues: budget, ..Default::default() },
        )
        .unwrap();
        t.row(vec![
            format!("{alpha:.2}"),
            f3(res.cost.energy_j),
            res.stats.generated.to_string(),
            format!("{:.2}", res.stats.wall_s),
        ]);
        alpha_json.set(&format!("energy_alpha_{alpha}"), res.cost.energy_j);
        assert!(
            res.cost.energy_j <= prev_energy * 1.001,
            "larger alpha must not find worse solutions"
        );
        prev_energy = res.cost.energy_j;
    }
    payload.set("alpha_sweep", alpha_json);
    println!("{}", t.render());

    // --- 2. inner distance -------------------------------------------------
    let mut t = Table::new(
        "Ablation 2: inner-search distance (SqueezeNet)",
        &["objective", "d", "objective value", "inner evals"],
    );
    for (obj, name) in [
        (CostFunction::Energy, "energy"),
        (CostFunction::Power, "power"),
    ] {
        let mut per_d = Vec::new();
        for d in [1usize, 2] {
            let c = ctx();
            let res = optimize(
                &g,
                &c,
                &obj,
                &SearchConfig {
                    inner_distance: Some(d),
                    max_dequeues: budget / 2,
                    ..Default::default()
                },
            )
            .unwrap();
            t.row(vec![
                name.to_string(),
                d.to_string(),
                format!("{:.4}", res.objective_value),
                res.stats.inner_evals.to_string(),
            ]);
            per_d.push(res.objective_value);
        }
        // d=2 never worse; for the additive objective d=1 already optimal.
        assert!(per_d[1] <= per_d[0] + 1e-9, "{name}: d=2 worse than d=1");
        if matches!(obj, CostFunction::Energy) {
            assert!(
                (per_d[1] - per_d[0]).abs() <= 1e-6 * per_d[0].abs().max(1.0),
                "additive objective: d=2 should not improve on d=1"
            );
        }
    }
    println!("{}", t.render());

    // --- 3. rule-set leave-one-out ------------------------------------------
    let families: Vec<(&str, RuleSet)> = vec![
        ("all rules", RuleSet::standard()),
        (
            "no fusions",
            RuleSet::with_rules(vec![
                Box::new(rules::MergeParallelConvs),
                Box::new(rules::EnlargeConvKernel),
                Box::new(rules::SplitConcatElim),
                Box::new(rules::ConcatSplitElim),
            ]),
        ),
        (
            "no merges",
            RuleSet::with_rules(vec![
                Box::new(rules::FuseConvRelu),
                Box::new(rules::FuseDwConvRelu),
                Box::new(rules::FuseAddRelu),
                Box::new(rules::FuseConvBn),
                Box::new(rules::FuseDwConvBn),
                Box::new(rules::FuseConvResidual),
            ]),
        ),
        ("no rules (inner only)", RuleSet::empty()),
    ];
    let mut t = Table::new(
        "Ablation 3: rule families (SqueezeNet, energy objective)",
        &["rule set", "energy_j/1k", "vs all rules"],
    );
    let mut all_energy = None;
    for (name, rs) in families {
        let c = OptimizerContext::new(
            rs,
            eadgo::cost::CostDb::new(),
            Box::new(eadgo::profiler::SimV100Provider::new(7)),
        );
        let res = optimize(
            &g,
            &c,
            &CostFunction::Energy,
            &SearchConfig { max_dequeues: budget, ..Default::default() },
        )
        .unwrap();
        let base = *all_energy.get_or_insert(res.cost.energy_j);
        t.row(vec![
            name.to_string(),
            f3(res.cost.energy_j),
            format!("{:+.1}%", 100.0 * (res.cost.energy_j / base - 1.0)),
        ]);
        assert!(res.cost.energy_j >= base * 0.999, "subset beats full rule set?");
    }
    println!("{}", t.render());

    // --- 4. MobileNet (depthwise extension) ---------------------------------
    let gm = models::mobilenet::build(cfg);
    let c = ctx();
    let res = optimize(
        &gm,
        &c,
        &CostFunction::Energy,
        &SearchConfig { max_dequeues: budget, ..Default::default() },
    )
    .unwrap();
    println!(
        "MobileNetV1 (depthwise): origin {} J -> optimized {} J ({:+.1}% energy, {:+.1}% time)\n",
        f3(res.original.energy_j),
        f3(res.cost.energy_j),
        -100.0 * res.energy_savings(),
        -100.0 * res.time_savings()
    );
    assert!(res.cost.energy_j < res.original.energy_j);

    // --- 5. parallel frontier expansion -------------------------------------
    // The tentpole claim: threads=8 returns a bit-identical plan to
    // threads=1 while spending less wall-clock on the search (resnet and
    // inception at the paper's alpha=1.05).
    let mut t = Table::new(
        "Ablation 5: parallel frontier (energy objective, alpha=1.05)",
        &["model", "threads", "search_s", "speedup", "energy_j/1k", "plan hash"],
    );
    let mut frontier_json = Json::obj();
    for name in ["resnet", "inception"] {
        let g = models::by_name(name, cfg).unwrap();
        let run = |threads: usize| {
            let c = ctx();
            let res = optimize(
                &g,
                &c,
                &CostFunction::Energy,
                &SearchConfig { alpha: 1.05, max_dequeues: budget, threads, ..Default::default() },
            )
            .unwrap();
            (res.stats.wall_s, res.cost, graph_hash(&res.graph), res.assignment)
        };
        let (seq_s, seq_cost, seq_hash, seq_a) = run(1);
        let (par_s, par_cost, par_hash, par_a) = run(8);
        frontier_json
            .set(&format!("{name}_seq_s"), seq_s)
            .set(&format!("{name}_par_s"), par_s)
            .set(&format!("{name}_energy"), seq_cost.energy_j);
        for (threads, wall, cost, hash) in
            [(1usize, seq_s, seq_cost, seq_hash), (8usize, par_s, par_cost, par_hash)]
        {
            t.row(vec![
                name.to_string(),
                threads.to_string(),
                format!("{wall:.3}"),
                format!("{:.2}x", seq_s / wall.max(1e-9)),
                f3(cost.energy_j),
                format!("{hash:016x}"),
            ]);
        }
        assert_eq!(seq_hash, par_hash, "{name}: parallel plan graph differs");
        assert_eq!(seq_a, par_a, "{name}: parallel assignment differs");
        assert_eq!(
            seq_cost.energy_j.to_bits(),
            par_cost.energy_j.to_bits(),
            "{name}: parallel cost differs"
        );
        if par_s >= seq_s {
            eprintln!(
                "NOTE: {name}: no parallel speedup on this host ({par_s:.3}s vs {seq_s:.3}s) — \
                 expected on single-core machines"
            );
        }
    }
    payload.set("parallel_frontier", frontier_json);
    println!("{}", t.render());

    // --- 6. DVFS frequency axis ---------------------------------------------
    // The (G, A, f) extension: per-graph locks one state per plan,
    // per-node lets every node pick its own. Inner-only rows give the
    // provable ordering (the joint per-node optimum dominates any uniform
    // state, which dominates nominal-only); full-search rows show what the
    // whole two-level search does with the extra axis.
    let mut t = Table::new(
        "Ablation 6: DVFS frequency axis (SqueezeNet, energy objective)",
        &["dvfs", "search", "time_ms", "energy_j/1k", "plan freq"],
    );
    let mut dvfs_json = Json::obj();
    let mut inner_energy: Vec<f64> = Vec::new();
    for (label, dvfs) in [
        ("off", DvfsMode::Off),
        ("per-graph", DvfsMode::PerGraph),
        ("per-node", DvfsMode::PerNode),
    ] {
        for (search, outer) in [("inner-only", false), ("full", true)] {
            let c = ctx();
            let res = optimize(
                &g,
                &c,
                &CostFunction::Energy,
                &SearchConfig {
                    dvfs,
                    enable_outer: outer,
                    max_dequeues: budget / 2,
                    ..Default::default()
                },
            )
            .unwrap();
            t.row(vec![
                label.to_string(),
                search.to_string(),
                f3(res.cost.time_ms),
                f3(res.cost.energy_j),
                describe_freqs(&res.assignment),
            ]);
            dvfs_json.set(&format!("energy_{label}_{search}"), res.cost.energy_j);
            if !outer {
                inner_energy.push(res.cost.energy_j);
            }
        }
    }
    println!("{}", t.render());
    // Guaranteed ordering on the fixed origin graph: per-node ≤ per-graph
    // ≤ off (larger option spaces, additive objective, d=1 optimal).
    assert!(
        inner_energy[1] <= inner_energy[0] + 1e-9,
        "per-graph DVFS must not lose to nominal-only: {} vs {}",
        inner_energy[1],
        inner_energy[0]
    );
    assert!(
        inner_energy[2] <= inner_energy[1] + 1e-9,
        "per-node DVFS must dominate per-graph: {} vs {}",
        inner_energy[2],
        inner_energy[1]
    );
    println!(
        "DVFS inner-only energy: off {} -> per-graph {} ({:+.1}%) -> per-node {} ({:+.1}%)\n",
        f3(inner_energy[0]),
        f3(inner_energy[1]),
        100.0 * (inner_energy[1] / inner_energy[0] - 1.0),
        f3(inner_energy[2]),
        100.0 * (inner_energy[2] / inner_energy[0] - 1.0),
    );
    payload.set("dvfs", dvfs_json);

    // --- 7. pareto frontier + load-adaptive serving --------------------------
    // Enumerate a (latency, energy) frontier for SqueezeNet, then compare
    // fixed latency-optimal serving against the adaptive FrontierController
    // at a low and a high request rate. Batch execution busy-spins 0.1 ms of
    // real time per oracle-estimated sim-millisecond, so utilization on the
    // serving loop's virtual clock is consistent with the estimates and the
    // comparison is host-speed independent to first order.
    let c = ctx();
    let fres = optimize_frontier(
        &g,
        &c,
        &SearchConfig { max_dequeues: budget / 2, ..Default::default() },
        if quick { 3 } else { 5 },
    )
    .unwrap();
    let frontier = &fres.frontier;
    assert!(frontier.len() >= 2, "squeezenet must yield a >=2-point frontier");
    for (i, a) in frontier.points().iter().enumerate() {
        for (j, b) in frontier.points().iter().enumerate() {
            assert!(i == j || !a.dominates(b), "frontier point {i} dominates {j}");
        }
    }
    print!("{}", frontier_table(frontier, Some(&fres.original)).render());
    let costs = frontier.costs();
    const SPIN_S_PER_SIM_MS: f64 = 1e-4;
    let serve_at = |plan_costs: &[GraphCost], rate_hz: f64, requests: usize| -> ServeReport {
        let scfg = ServeConfig {
            requests,
            batch_max: 4,
            arrival_rate_hz: rate_hz,
            max_wait_s: 0.002,
            seed: 2026,
            input_shape: vec![1, 3, 8, 8],
            phases: Vec::new(),
            service: ServiceModel::Wallclock,
        };
        let pc: Vec<GraphCost> = plan_costs.to_vec();
        ServeSession::new(&scfg)
            .frontier_costs(plan_costs)
            .adaptive(AdaptiveConfig::default())
            .run(move |idx, batch: &[Tensor]| {
                let target = SPIN_S_PER_SIM_MS * pc[idx].time_ms * batch.len() as f64;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < target {}
                Ok(batch.to_vec())
            })
            .unwrap()
    };
    // p99 over the steady-state tail (first half dropped): the adaptive
    // controller legitimately starts on the energy plan, escalates, then
    // drains the warmup backlog with the latency plan's spare capacity —
    // raw p99 includes that transient by design, steady-state p99 is the
    // apples-to-apples SLO comparison.
    let steady_p99 = |r: &ServeReport| -> f64 {
        let skip = r.records.len() / 2;
        let mut lat: Vec<f64> = r.records[skip..].iter().map(|x| x.latency_s()).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&lat, 99.0)
    };
    let requests = if quick { 240 } else { 480 };
    let svc_lat_s = SPIN_S_PER_SIM_MS * costs[0].time_ms;
    let svc_energy_s = SPIN_S_PER_SIM_MS * costs[costs.len() - 1].time_ms;
    let low_rate = 0.05 / svc_energy_s; // utilization 5% even on the energy plan
    // Utilization 90% on the latency plan — which makes every slower plan
    // exceed the controller's high-util threshold (0.9 · t_i/t_0 > 0.85
    // for all i > 0), so the adaptive run provably converges to plan 0.
    let high_rate = 0.9 / svc_lat_s;
    let fixed_latency = &costs[..1]; // single-point frontier = fixed plan
    let mut t = Table::new(
        "Ablation 7: fixed latency-optimal vs adaptive frontier serving (SqueezeNet)",
        &["rate", "serving", "energy mJ/req", "p99 ms", "steady p99 ms", "switches", "plans used"],
    );
    let mut serve_json = Json::obj();
    let row = |label: &str, rate: f64, r: &ServeReport, t: &mut Table, json: &mut Json| {
        let e = r.energy_mj_per_request.expect("oracle estimates present");
        t.row(vec![
            format!("{rate:.0}/s"),
            label.to_string(),
            f3(e),
            f3(r.latency_summary().p99 * 1e3),
            f3(steady_p99(r) * 1e3),
            r.switches.len().to_string(),
            r.plan_distribution(),
        ]);
        json.set(&format!("{label}_{rate:.0}_energy_mj"), e)
            .set(&format!("{label}_{rate:.0}_steady_p99_s"), steady_p99(r));
    };

    let fixed_low = serve_at(fixed_latency, low_rate, requests);
    let adapt_low = serve_at(&costs, low_rate, requests);
    let fixed_high = serve_at(fixed_latency, high_rate, requests);
    let adapt_high = serve_at(&costs, high_rate, requests);
    row("fixed-latency", low_rate, &fixed_low, &mut t, &mut serve_json);
    row("adaptive", low_rate, &adapt_low, &mut t, &mut serve_json);
    row("fixed-latency", high_rate, &fixed_high, &mut t, &mut serve_json);
    row("adaptive", high_rate, &adapt_high, &mut t, &mut serve_json);
    println!("{}", t.render());

    // Low rate: adaptive serves the energy-optimal plan and must beat the
    // fixed latency-optimal plan on energy/request.
    let e_fixed = fixed_low.energy_mj_per_request.unwrap();
    let e_adapt = adapt_low.energy_mj_per_request.unwrap();
    assert!(
        e_adapt < e_fixed * 0.999,
        "adaptive must save energy at low rate: {e_adapt} vs {e_fixed}"
    );
    // High rate: the controller must leave the energy plan, and its
    // steady-state p99 must track the fixed latency-optimal plan.
    assert!(
        adapt_high.records.last().unwrap().plan < costs.len() - 1,
        "adaptive must escalate off the energy plan under load"
    );
    let p99_fixed = steady_p99(&fixed_high);
    let p99_adapt = steady_p99(&adapt_high);
    // The p99 bound compares two wallclock-measured busy-spin runs; a
    // scheduler preemption on a noisy host inflates one run's service
    // times far past the spin targets and would fail the bound for
    // reasons unrelated to the controller. Detect that by comparing
    // measured engine-busy time against the spin budget and downgrade
    // the assert to a note (mirrors the section-5 no-speedup note).
    let spin_budget = |r: &ServeReport, pc: &[GraphCost]| -> f64 {
        r.records.iter().map(|x| SPIN_S_PER_SIM_MS * pc[x.plan].time_ms).sum()
    };
    let quiet_host = fixed_high.busy_s <= spin_budget(&fixed_high, fixed_latency) * 1.3
        && adapt_high.busy_s <= spin_budget(&adapt_high, &costs) * 1.3;
    if quiet_host {
        assert!(
            p99_adapt <= p99_fixed * 1.5 + 1e-6,
            "adaptive steady-state p99 {p99_adapt} too far above fixed {p99_fixed}"
        );
    } else {
        eprintln!(
            "NOTE: host preemption detected (busy time >130% of spin budget) — \
             skipping the steady-state p99 bound ({p99_adapt} vs {p99_fixed})"
        );
    }
    println!(
        "adaptive serving: energy/request {} -> {} mJ at low rate ({:+.1}%), steady p99 {} vs {} ms at high rate\n",
        f3(e_fixed),
        f3(e_adapt),
        100.0 * (e_adapt / e_fixed - 1.0),
        f3(p99_adapt * 1e3),
        f3(p99_fixed * 1e3),
    );
    serve_json.set("frontier_points", frontier.len());
    payload.set("adaptive_serving", serve_json);

    // --- 8. substitution engine: delta evaluation vs full rebuild -----------
    // The ISSUE-4 refactor claim: evaluating candidates through RewriteSite
    // deltas (carry-over cost tables, incremental hashing, lazy
    // materialization) raises wave throughput while choosing bit-identical
    // plans. `delta_eval: false` runs the legacy full-rebuild path.
    let run_engine = |delta_eval: bool| {
        let c = ctx();
        let res = optimize(
            &g,
            &c,
            &CostFunction::Energy,
            &SearchConfig { max_dequeues: budget, delta_eval, ..Default::default() },
        )
        .unwrap();
        let builds = c.oracle.table_build_stats();
        (res, builds)
    };
    let (full_res, full_builds) = run_engine(false);
    let (delta_res, delta_builds) = run_engine(true);
    assert_eq!(
        graph_hash(&full_res.graph),
        graph_hash(&delta_res.graph),
        "delta engine chose a different plan graph"
    );
    assert_eq!(full_res.assignment, delta_res.assignment, "delta engine assignment differs");
    assert_eq!(
        full_res.cost.energy_j.to_bits(),
        delta_res.cost.energy_j.to_bits(),
        "delta engine cost differs"
    );
    // Instrumentation: the delta run must not rebuild full tables per
    // candidate (only baseline + one per expanded wave entry), while the
    // legacy run rebuilds one per candidate.
    assert_eq!(delta_builds.delta_tables as usize, delta_res.stats.evaluated);
    assert!(delta_builds.full_tables as usize <= 1 + delta_res.stats.expanded);
    assert_eq!(full_builds.delta_tables, 0);
    assert!(full_builds.full_tables as usize >= full_res.stats.evaluated);
    let cps_full = full_res.stats.candidates_per_sec();
    let cps_delta = delta_res.stats.candidates_per_sec();
    let mut t = Table::new(
        "Ablation 8: substitution engine (SqueezeNet, energy objective)",
        &["engine", "candidates", "cand/s", "search_s", "full tables", "delta tables"],
    );
    for (label, res, builds, cps) in [
        ("full-rebuild", &full_res, &full_builds, cps_full),
        ("delta", &delta_res, &delta_builds, cps_delta),
    ] {
        t.row(vec![
            label.to_string(),
            res.stats.evaluated.to_string(),
            format!("{cps:.0}"),
            format!("{:.3}", res.stats.wall_s),
            builds.full_tables.to_string(),
            builds.delta_tables.to_string(),
        ]);
    }
    println!("{}", t.render());
    print!("{}", eadgo::report::tables::rule_stats_table(&delta_res.stats).render());
    let speedup = cps_delta / cps_full.max(1e-9);
    println!(
        "substitution engine throughput: full-rebuild {cps_full:.0} -> delta {cps_delta:.0} candidates/sec ({speedup:.2}x)\n"
    );
    if speedup < 1.0 {
        eprintln!(
            "NOTE: no delta-engine speedup on this host ({cps_delta:.0} vs {cps_full:.0} cand/s) \
             — expected under heavy host noise; plans are still bit-identical"
        );
    }
    let mut engine_json = Json::obj();
    engine_json
        .set("candidates_per_sec_full", cps_full)
        .set("candidates_per_sec_delta", cps_delta)
        .set("speedup", speedup)
        .set("candidates", delta_res.stats.evaluated as f64);
    payload.set("subst_engine", engine_json);

    // --- 9. incremental inner search: warm starts + argmin memo --------------
    // The ISSUE-5 claim: warm-starting candidate inner searches from the
    // parent's converged plan (re-optimizing only the delta's dirty cone)
    // plus per-row argmin memoization raises end-to-end candidates/sec
    // over the PR-4 delta-only engine, with bit-identical plans. The
    // per-candidate option-evaluation drop is deterministic and asserted;
    // wall-clock is reported (and noted, not asserted, under host noise).
    let run_engines = |delta_eval: bool, incremental_inner: bool| {
        let c = ctx();
        let cfg = SearchConfig {
            max_dequeues: budget,
            delta_eval,
            incremental_inner,
            ..Default::default()
        };
        optimize(&g, &c, &CostFunction::Energy, &cfg).unwrap()
    };
    let full9 = run_engines(false, false);
    let delta9 = run_engines(true, false);
    let incr9 = run_engines(true, true);
    for (label, res) in [("delta-only", &delta9), ("delta+incremental", &incr9)] {
        assert_eq!(
            graph_hash(&full9.graph),
            graph_hash(&res.graph),
            "{label}: plan graph diverged from full-rebuild reference"
        );
        assert_eq!(full9.assignment, res.assignment, "{label}: assignment diverged");
        assert_eq!(
            full9.cost.energy_j.to_bits(),
            res.cost.energy_j.to_bits(),
            "{label}: cost bits diverged"
        );
    }
    let per_cand = |res: &eadgo::search::OptimizeResult| {
        res.stats.inner_evals as f64 / (res.stats.evaluated.max(1)) as f64
    };
    let (evals_cold, evals_warm) = (per_cand(&delta9), per_cand(&incr9));
    // Deterministic economy: warm starts + memo must strictly cut the
    // option evaluations each candidate pays.
    assert!(
        evals_warm < evals_cold,
        "incremental inner search must evaluate fewer options/candidate ({evals_warm} vs {evals_cold})"
    );
    assert_eq!(
        incr9.stats.inner_warm as usize, incr9.stats.evaluated,
        "every candidate must warm-start"
    );
    assert!(
        incr9.stats.inner_swept * 2 < incr9.stats.inner_nodes,
        "warm sweeps must stay below half the node decisions"
    );
    let mut t = Table::new(
        "Ablation 9: incremental inner search (SqueezeNet, energy objective)",
        &["engine", "candidates", "cand/s", "evals/candidate", "warm starts", "argmin hit rate"],
    );
    for (label, res) in
        [("full-rebuild", &full9), ("delta-only", &delta9), ("delta+incremental", &incr9)]
    {
        t.row(vec![
            label.to_string(),
            res.stats.evaluated.to_string(),
            format!("{:.0}", res.stats.candidates_per_sec()),
            format!("{:.1}", per_cand(res)),
            res.stats.inner_warm.to_string(),
            format!("{:.1}%", 100.0 * res.stats.argmin_hit_rate()),
        ]);
    }
    println!("{}", t.render());
    print!("{}", eadgo::report::tables::inner_stats_table(&incr9.stats).render());
    let cps_delta9 = delta9.stats.candidates_per_sec();
    let cps_incr9 = incr9.stats.candidates_per_sec();
    let speedup9 = cps_incr9 / cps_delta9.max(1e-9);
    println!(
        "inner-search engine throughput: delta-only {cps_delta9:.0} -> delta+incremental {cps_incr9:.0} candidates/sec ({speedup9:.2}x); evals/candidate {evals_cold:.1} -> {evals_warm:.1}\n"
    );
    if speedup9 < 1.0 {
        eprintln!(
            "NOTE: no incremental-inner wall-clock speedup on this host ({cps_incr9:.0} vs \
             {cps_delta9:.0} cand/s) — expected under heavy host noise; the evals/candidate \
             drop above is deterministic and plans are bit-identical"
        );
    }
    let starts = (incr9.stats.inner_warm + incr9.stats.inner_cold).max(1);
    let warm_share = incr9.stats.inner_warm as f64 / starts as f64;
    let mut inner_json = Json::obj();
    inner_json
        .set("evals_per_candidate_cold", evals_cold)
        .set("evals_per_candidate_warm", evals_warm)
        .set("candidates_per_sec_full", full9.stats.candidates_per_sec())
        .set("candidates_per_sec_delta_only", cps_delta9)
        .set("candidates_per_sec_incremental", cps_incr9)
        .set("speedup_vs_delta_only", speedup9)
        .set("warm_start_share", warm_share)
        .set("carry_rate", incr9.stats.inner_carry_rate())
        .set("argmin_hit_rate", incr9.stats.argmin_hit_rate());
    payload.set("inner_search", inner_json);

    // --- 10. deadline-aware adaptive batching vs the fixed batch-1 loop ------
    // The ISSUE-6 claim: serving from the joint (plan, freq, batch)
    // operating-point surface with deadline-aware batch formation beats
    // the fixed batch-1 loop on requests/joule under bursty load without
    // giving up tail latency. A tiny model keeps per-launch overhead
    // visible, so batching amortizes real energy on the sim provider
    // (batch 8 is several times cheaper per request); the burst phase runs
    // at 1.4x the fixed loop's capacity, so the fixed loop backlogs while
    // the batched point absorbs the burst with utilization to spare.
    let bcfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
    let bg = models::squeezenet::build(bcfg);
    let c10 = ctx();
    let bres = optimize_frontier_batched(
        &bg,
        &c10,
        &SearchConfig { max_dequeues: budget / 4, ..Default::default() },
        2,
        &[1, 8],
    )
    .unwrap();
    let points = bres.frontier.points();
    assert!(points.iter().any(|p| p.batch > 1), "surface must keep a batched point");
    assert!(points.iter().any(|p| p.batch == 1), "surface must keep a batch-1 point");
    // Fixed baseline: the cheapest batch-1 point — what the pre-batch-axis
    // serve loop would pick for an energy objective — pinned as the only
    // operating point, so batch formation is capped at one request.
    let fixed_idx = points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.batch == 1)
        .min_by(|a, b| a.1.cost.energy_j.partial_cmp(&b.1.cost.energy_j).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    // Price every point's plan for all batch sizes it can form.
    let grid: Vec<Vec<GraphCost>> = points
        .iter()
        .map(|p| {
            (1..=p.batch)
                .map(|m| price_plan_at_batch(&c10.oracle, &p.graph, &p.assignment, m).unwrap())
                .collect()
        })
        .collect();
    let all_ops: Vec<OperatingPoint> =
        (0..points.len()).map(|i| OperatingPoint { plan: i, batch: points[i].batch }).collect();
    let fixed_ops = vec![OperatingPoint { plan: fixed_idx, batch: 1 }];
    let svc_fixed_s = SPIN_S_PER_SIM_MS * grid[fixed_idx][0].time_ms;
    let calm = RatePhase::new(0.2 / svc_fixed_s, if quick { 16 } else { 32 });
    let burst = RatePhase::new(1.4 / svc_fixed_s, if quick { 96 } else { 192 });
    let serve_ops = |ops: &[OperatingPoint]| -> ServeReport {
        let scfg = ServeConfig {
            requests: 0,
            batch_max: 8,
            arrival_rate_hz: 0.0,
            max_wait_s: 8.0 * svc_fixed_s,
            seed: 2026,
            input_shape: vec![1, 3, 32, 32],
            phases: vec![calm, burst, calm],
            service: ServiceModel::Wallclock,
        };
        let gc = grid.clone();
        ServeSession::new(&scfg)
            .operating_points(&grid, ops)
            .adaptive(AdaptiveConfig::default())
            .run(move |plan, batch: &[Tensor]| {
                let target = SPIN_S_PER_SIM_MS * gc[plan][batch.len() - 1].time_ms;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < target {}
                Ok(batch.to_vec())
            })
            .unwrap()
    };
    let fixed10 = serve_ops(&fixed_ops);
    let adapt10 = serve_ops(&all_ops);
    let rpj_fixed = fixed10.requests_per_joule().expect("oracle energy present");
    let rpj_adapt = adapt10.requests_per_joule().expect("oracle energy present");
    let p99_fixed10 = fixed10.latency_summary().p99;
    let p99_adapt10 = adapt10.latency_summary().p99;
    let mut t = Table::new(
        "Ablation 10: fixed batch-1 vs deadline-aware adaptive batching (bursty trace)",
        &["serving", "requests/J", "p99 ms", "mean batch", "switches"],
    );
    for (label, r, rpj, p99) in [
        ("fixed batch-1", &fixed10, rpj_fixed, p99_fixed10),
        ("adaptive ops", &adapt10, rpj_adapt, p99_adapt10),
    ] {
        t.row(vec![
            label.to_string(),
            f3(rpj),
            f3(p99 * 1e3),
            format!("{:.2}", r.mean_batch_size()),
            r.switches.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    // Energy accounting is oracle-priced per formed batch, so the
    // requests/joule win is deterministic: burst batches fill toward 8
    // within the admission deadline, and the fixed loop pays batch-1
    // energy for every request.
    assert!(
        adapt10.mean_batch_size() > 1.5,
        "adaptive loop must form real batches under burst (mean {})",
        adapt10.mean_batch_size()
    );
    assert!(
        rpj_adapt > rpj_fixed * 1.05,
        "adaptive batching must beat fixed batch-1 on requests/joule: {rpj_adapt} vs {rpj_fixed}"
    );
    // The p99 side compares two wallclock-measured busy-spin runs; as in
    // section 7, downgrade the bound to a note when host preemption
    // inflates measured busy time past the spin budget.
    let spin_budget10 = |r: &ServeReport, ops: &[OperatingPoint]| -> f64 {
        r.records
            .iter()
            .map(|x| {
                SPIN_S_PER_SIM_MS * grid[ops[x.plan].plan][x.batch_size - 1].time_ms
                    / x.batch_size as f64
            })
            .sum()
    };
    let quiet_host10 = fixed10.busy_s <= spin_budget10(&fixed10, &fixed_ops) * 1.3
        && adapt10.busy_s <= spin_budget10(&adapt10, &all_ops) * 1.3;
    if quiet_host10 {
        assert!(
            p99_adapt10 <= p99_fixed10 * 1.1 + 1e-6,
            "adaptive p99 {p99_adapt10} must stay within 1.1x of fixed {p99_fixed10}"
        );
    } else {
        eprintln!(
            "NOTE: host preemption detected (busy time >130% of spin budget) — \
             skipping the adaptive-batching p99 bound ({p99_adapt10} vs {p99_fixed10})"
        );
    }
    println!(
        "adaptive batching: {} -> {} requests/joule ({:.2}x), p99 {} vs {} ms, mean batch {:.2}\n",
        f3(rpj_fixed),
        f3(rpj_adapt),
        rpj_adapt / rpj_fixed,
        f3(p99_adapt10 * 1e3),
        f3(p99_fixed10 * 1e3),
        adapt10.mean_batch_size(),
    );
    let mut serve10_json = Json::obj();
    serve10_json
        .set("requests_per_joule_fixed", rpj_fixed)
        .set("requests_per_joule_adaptive", rpj_adapt)
        .set("p99_ms_fixed", p99_fixed10 * 1e3)
        .set("p99_ms_adaptive", p99_adapt10 * 1e3)
        .set("mean_batch_adaptive", adapt10.mean_batch_size())
        .set("operating_points", points.len());

    // --- 11. self-tuning serve: drift detection, writeback, hot-swap ---------
    // The ISSUE-7 claim: served against a mis-scaled cost database, the
    // feedback loop detects predicted-vs-observed drift, writes measured
    // rows back into the oracle, re-prices the surface, and hot-swaps the
    // controller onto the truly cheapest plan — strictly beating the same
    // run without feedback on *true* energy per request. Ground truth is a
    // virtual service model priced off the unperturbed database, so the
    // whole section is deterministic and host-independent. Two one-op
    // plans make attribution exact: plan B's conv rows are halved in the
    // serving database (fake-cheap, so serving parks on it); plan A's
    // depthwise rows are synthesized at 0.72x plan B's true cost on both
    // axes, so the corrected surface must swap to A.
    let shape11 = vec![1usize, 3, 16, 16];
    let bmax11 = 2usize;
    let conv_g = {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: shape11.clone() }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
                has_residual: false,
            },
            &[x, w],
            "conv",
        );
        g.outputs = vec![PortRef::of(c)];
        g
    };
    let dw_g = {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: shape11.clone() }, &[], "x");
        let w = g.add1(OpKind::weight(vec![3, 1, 3, 3], 1), &[], "w");
        let d = g.add1(
            OpKind::DwConv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
            },
            &[x, w],
            "dw",
        );
        g.outputs = vec![PortRef::of(d)];
        g
    };
    let reg11 = AlgorithmRegistry::new();
    let provider11 = SimV100Provider::new(11);
    let conv_a = Assignment::default_for(&conv_g, &reg11);
    let dw_a = Assignment::default_for(&dw_g, &reg11);
    let mut truth_db = CostDb::new();
    for m in 1..=bmax11 {
        ensure_profiled(&conv_g.rebatch(m).unwrap(), &reg11, &mut truth_db, &provider11).unwrap();
        ensure_profiled(&dw_g.rebatch(m).unwrap(), &reg11, &mut truth_db, &provider11).unwrap();
    }
    // Pin plan A at exactly 0.72x plan B's true cost per batch size.
    for m in 1..=bmax11 {
        let sig_c = only_costed_sig(&conv_g.rebatch(m).unwrap());
        let sig_d = only_costed_sig(&dw_g.rebatch(m).unwrap());
        let c = truth_db
            .get(&sig_c, conv_a.get(costed_node(&conv_g)).unwrap())
            .expect("conv profiled");
        truth_db.insert(
            &sig_d,
            dw_a.get(costed_node(&dw_g)).unwrap(),
            NodeCost { time_ms: 0.72 * c.time_ms, power_w: c.power_w },
            "synthetic",
        );
    }
    let perturbed_db = scale_sig_times(&truth_db, "conv2d;", 0.5);
    let truth_oracle =
        CostOracle::new(AlgorithmRegistry::new(), truth_db, Box::new(SimV100Provider::new(11)));
    let serving_oracle = CostOracle::new(
        AlgorithmRegistry::new(),
        perturbed_db,
        Box::new(SimV100Provider::new(11)),
    );
    let plans11: Vec<(&Graph, &Assignment)> = vec![(&dw_g, &dw_a), (&conv_g, &conv_a)];
    let grid_for = |oracle: &CostOracle| -> Vec<Vec<GraphCost>> {
        plans11
            .iter()
            .map(|&(g, a)| {
                (1..=bmax11).map(|m| price_plan_at_batch(oracle, g, a, m).unwrap()).collect()
            })
            .collect()
    };
    let truth_grid = grid_for(&truth_oracle);
    let pert_grid = grid_for(&serving_oracle);
    for m in 1..=bmax11 {
        let (a, b, pb) = (truth_grid[0][m - 1], truth_grid[1][m - 1], pert_grid[1][m - 1]);
        assert!(
            a.energy_j > 0.55 * b.energy_j && a.energy_j < 0.95 * b.energy_j,
            "plan A must sit between half and full of plan B's true energy at batch {m}"
        );
        assert!(
            a.time_ms > 0.55 * b.time_ms && a.time_ms < 0.95 * b.time_ms,
            "plan A must sit between half and full of plan B's true latency at batch {m}"
        );
        assert!(pb.energy_j < a.energy_j, "mis-scaled plan B must look cheaper than plan A");
    }
    let points11: Vec<PlanPoint> = plans11
        .iter()
        .enumerate()
        .map(|(i, &(g, a))| PlanPoint {
            graph: g.clone(),
            assignment: a.clone(),
            cost: pert_grid[i][0],
            weight: 0.5,
            batch: 1,
        })
        .collect();
    let svc_b_s = truth_grid[1][0].time_ms * 1e-3;
    let n11 = if quick { 24 } else { 48 };
    let scfg11 = ServeConfig {
        requests: 0,
        batch_max: bmax11,
        arrival_rate_hz: 0.0,
        max_wait_s: 4.0 * svc_b_s,
        seed: 2026,
        input_shape: shape11.clone(),
        phases: vec![
            RatePhase::new(0.2 / svc_b_s, n11),
            RatePhase::new(1.2 / svc_b_s, 2 * n11),
            RatePhase::new(0.2 / svc_b_s, n11),
        ],
        service: ServiceModel::Virtual {
            per_batch_ms: truth_grid
                .iter()
                .map(|row| row.iter().map(|c| c.time_ms).collect())
                .collect(),
            scale_s_per_ms: 1e-3,
        },
    };
    let ops11: Vec<OperatingPoint> =
        (0..pert_grid.len()).map(|i| OperatingPoint { plan: i, batch: bmax11 }).collect();
    let exec11 = |_: usize, batch: &[Tensor]| Ok(batch.to_vec());
    let off11 = ServeSession::new(&scfg11)
        .operating_points(&pert_grid, &ops11)
        .adaptive(AdaptiveConfig::default())
        .run(exec11)
        .unwrap();
    let on11 = ServeSession::new(&scfg11)
        .oracle(&serving_oracle)
        .plan_points(&points11)
        .feedback(FeedbackConfig { research_interval_s: 0.0, ..Default::default() })
        .run(exec11)
        .unwrap();
    let total11 = 4 * n11;
    for r in [&off11, &on11] {
        assert_eq!(r.records.len(), total11, "every request must be served exactly once");
        for (i, rec) in r.records.iter().enumerate() {
            assert_eq!(rec.id, i, "requests served in arrival order, none dropped");
        }
    }
    assert!(
        on11.drift_events.iter().any(|e| e.kind == DriftKind::Detected),
        "mis-scaled database must arm drift detection"
    );
    assert!(!on11.swaps.is_empty(), "sustained drift must hot-swap a corrected surface");
    assert!(on11.feedback_rows > 0, "writeback must record measured rows");
    assert!(off11.swaps.is_empty() && off11.drift_events.is_empty());
    // True energy per request, priced off the unperturbed grid (the ops
    // grids map operating point i to plan i in both runs).
    let true_mj = |r: &ServeReport| -> f64 {
        let sum: f64 = r
            .records
            .iter()
            .map(|x| truth_grid[x.plan][x.batch_size - 1].energy_j / x.batch_size as f64)
            .sum();
        sum / r.records.len() as f64
    };
    let (mj_off, mj_on) = (true_mj(&off11), true_mj(&on11));
    let recovery = mj_off / mj_on;
    assert!(
        recovery > 1.02,
        "feedback must strictly beat the no-feedback baseline on true energy: {mj_on} vs {mj_off}"
    );
    assert_eq!(off11.records.last().unwrap().plan, 1, "baseline parks on the fake-cheap plan");
    let last_on = on11.records.last().unwrap();
    assert!(last_on.epoch > 0, "post-swap requests must record the new surface epoch");
    assert_eq!(last_on.plan, 0, "feedback run must end on the truly cheapest plan");
    let mut t = Table::new(
        "Ablation 11: self-tuning serve under a mis-scaled cost db (2-plan surface)",
        &["serving", "true energy mJ/req", "drift events", "hot-swaps", "final plan"],
    );
    for (label, r, mj) in [("no feedback", &off11, mj_off), ("feedback", &on11, mj_on)] {
        t.row(vec![
            label.to_string(),
            f3(mj),
            r.drift_events.len().to_string(),
            r.swaps.len().to_string(),
            r.records.last().unwrap().plan.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "feedback serve: drift detected, surface re-priced and hot-swapped; \
         true energy/request {} -> {} mJ ({recovery:.2}x recovery)\n",
        f3(mj_off),
        f3(mj_on),
    );
    let mut feedback_json = Json::obj();
    feedback_json
        .set("drift_events", on11.drift_events.len())
        .set("hot_swaps", on11.swaps.len())
        .set("researches", on11.swaps.iter().filter(|s| s.researched).count())
        .set("energy_mj_no_feedback", mj_off)
        .set("energy_mj_feedback", mj_on);
    serve10_json.set("drift_recovery_ratio", recovery);
    payload.set("feedback", feedback_json);
    // serve10_json is published after section 14 adds the fault metrics.

    // --- 12. heterogeneous placement: GPU-only vs GPU+DLA -------------------
    // The ISSUE-8 claim: at the same latency budget, letting the
    // constrained search place nodes on the DLA (far lower power envelope,
    // slower compute/memory path, transfer cost at every device boundary)
    // strictly cuts energy/request versus the best GPU-only plan. The
    // budget is anchored at 2x the GPU's best achievable time, so the
    // GPU-only run has headroom to downclock and the comparison is
    // downclocking-vs-migration, not feasible-vs-infeasible.
    let cfg12 = ModelConfig { batch: 1, resolution: 64, width_div: 4, classes: 100 };
    let scfg12 = SearchConfig {
        max_dequeues: budget / 4,
        dvfs: DvfsMode::PerNode,
        ..SearchConfig::default()
    };
    let mut t = Table::new(
        "Ablation 12: GPU-only vs GPU+DLA at the same latency budget (per-node DVFS)",
        &["model", "budget_ms", "devices", "time_ms", "energy_j/1k", "plan freq"],
    );
    let mut placement_json = Json::obj();
    let mut ratios: Vec<f64> = Vec::new();
    for name in ["squeezenet", "mobilenet"] {
        let g12 = models::by_name(name, cfg12).unwrap();
        // GPU-only best time anchors the budget.
        let c_gpu = ctx();
        let tbest = optimize(
            &g12,
            &c_gpu,
            &CostFunction::Time,
            &SearchConfig { max_dequeues: budget / 4, ..SearchConfig::default() },
        )
        .unwrap()
        .cost
        .time_ms;
        let tb12 = 2.0 * tbest;
        let r_gpu = optimize_with_time_budget(&g12, &c_gpu, tb12, &scfg12, 6).unwrap();
        let c_het = OptimizerContext::new(
            RuleSet::standard(),
            CostDb::new(),
            Box::new(SimHeteroProvider::new(7)),
        );
        let r_het = optimize_with_time_budget(&g12, &c_het, tb12, &scfg12, 6).unwrap();
        for (devices, r) in [("gpu", &r_gpu), ("gpu+dla", &r_het)] {
            t.row(vec![
                name.to_string(),
                f3(tb12),
                devices.to_string(),
                f3(r.result.cost.time_ms),
                f3(r.result.cost.energy_j),
                describe_freqs(&r.result.assignment),
            ]);
        }
        assert!(r_gpu.feasible, "{name}: GPU-only search infeasible at 2x its own best time");
        assert!(r_het.feasible, "{name}: GPU+DLA search infeasible at a budget the GPU meets");
        assert!(
            r_het.result.cost.time_ms <= tb12 * (1.0 + 1e-9),
            "{name}: mixed plan exceeds the latency budget"
        );
        assert!(
            r_het.result.assignment.uses_non_gpu_device(),
            "{name}: the budgeted search must place at least one node on the DLA"
        );
        let (e_gpu, e_het) = (r_gpu.result.cost.energy_j, r_het.result.cost.energy_j);
        assert!(
            e_het < e_gpu,
            "{name}: mixed placement must strictly beat GPU-only on energy: {e_het} vs {e_gpu}"
        );
        let ratio = e_het / e_gpu;
        ratios.push(ratio);
        placement_json
            .set(&format!("{name}_energy_gpu"), e_gpu)
            .set(&format!("{name}_energy_hetero"), e_het)
            .set(&format!("{name}_energy_ratio"), ratio);
    }
    println!("{}", t.render());
    let energy_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
    placement_json.set("energy_ratio", energy_ratio);
    println!(
        "heterogeneous placement: GPU+DLA energy/request at {:.0}% of GPU-only ({:+.1}%)\n",
        100.0 * energy_ratio,
        100.0 * (energy_ratio - 1.0),
    );
    payload.set("placement", placement_json);

    // --- 13. rewrite ablation: origin-graph search vs full rule set ---------
    // The ISSUE-9 claim: the widened rewrite space (conv fusion family on
    // the CNN side; matmul epilogue fusion, Merkle CSE, and split/concat
    // algebra on the attention side) strictly cuts the energy of the best
    // plan versus searching algorithms and frequencies on the origin graph
    // alone. Both searches share the same provider, objective, and budget,
    // so the ratio isolates what the substitutions themselves buy.
    let cfg13 = ModelConfig { batch: 1, resolution: 64, width_div: 4, classes: 100 };
    let scfg13 = SearchConfig {
        max_dequeues: budget / 4,
        dvfs: DvfsMode::PerNode,
        ..SearchConfig::default()
    };
    let mut t = Table::new(
        "Ablation 13: rewrite contribution (origin-graph search vs full rule set)",
        &["model", "origin energy_j/1k", "rewritten energy_j/1k", "ratio", "nodes"],
    );
    let mut rewrite_json = Json::obj();
    for (key, name) in [("conv", "squeezenet"), ("attention", "attention")] {
        let g13 = models::by_name(name, cfg13).unwrap();
        let c_none = OptimizerContext::new(
            RuleSet::empty(),
            CostDb::new(),
            Box::new(SimV100Provider::new(7)),
        );
        let r_none = optimize(&g13, &c_none, &CostFunction::Energy, &scfg13).unwrap();
        let r_full = optimize(&g13, &ctx(), &CostFunction::Energy, &scfg13).unwrap();
        let ratio = r_full.cost.energy_j / r_none.cost.energy_j;
        t.row(vec![
            name.to_string(),
            f3(r_none.cost.energy_j),
            f3(r_full.cost.energy_j),
            format!("{ratio:.3}"),
            format!(
                "{} -> {}",
                r_none.graph.runtime_node_count(),
                r_full.graph.runtime_node_count()
            ),
        ]);
        assert!(
            ratio < 1.0,
            "{name}: the rewrite space must strictly cut energy: {} vs {}",
            r_full.cost.energy_j,
            r_none.cost.energy_j
        );
        rewrite_json
            .set(&format!("energy_origin_{key}"), r_none.cost.energy_j)
            .set(&format!("energy_rewritten_{key}"), r_full.cost.energy_j)
            .set(&format!("cost_ratio_{key}"), ratio);
    }
    println!("{}", t.render());
    payload.set("rewrite", rewrite_json);

    // --- 14. fault tolerance: device loss with a contingency hot-swap --------
    // The ISSUE-10 claim: a seeded DeviceLost{dla} fault against a mixed
    // GPU+DLA surface drops nothing — every admitted request is served,
    // exactly one contingency hot-swap fires, and post-fault energy/request
    // stays within 5% of the best GPU-only plan. The service model is
    // virtual, so both published metrics are deterministic replays.
    let cfg14 = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
    let g14 = models::by_name("simple", cfg14).unwrap();
    let hetero14 = || {
        CostOracle::new(
            AlgorithmRegistry::new(),
            CostDb::new(),
            Box::new(SimHeteroProvider::new(7)),
        )
    };
    let oracle14 = hetero14();
    let a_gpu14 = Assignment::default_for(&g14, &AlgorithmRegistry::new());
    let mut a_dla14 = a_gpu14.clone();
    let first14 = a_dla14.assigned_ids().next().expect("model has costed nodes");
    a_dla14.set_freq(first14, FreqId::on(DeviceId::DLA, 0));
    let (a_fb14, c_fb14) = synthesize_contingency(&oracle14, &g14, &a_dla14, DvfsMode::Off)
        .unwrap()
        .expect("a DLA-placed plan must synthesize a GPU fallback");
    let bmax14 = 2usize;
    let price14 = |a: &Assignment| -> Vec<GraphCost> {
        (1..=bmax14).map(|m| price_plan_at_batch(&oracle14, &g14, a, m).unwrap()).collect()
    };
    // rows14[0] = GPU plan, [1] = mixed plan, [2] = the contingency.
    let rows14 = vec![price14(&a_gpu14), price14(&a_dla14), price14(&a_fb14)];
    let point14 = |a: &Assignment, cost: GraphCost| PlanPoint {
        graph: g14.clone(),
        assignment: a.clone(),
        cost,
        weight: 1.0,
        batch: 1,
    };
    let points14 = vec![point14(&a_gpu14, rows14[0][0]), point14(&a_dla14, rows14[1][0])];
    let conts14 = vec![None, Some(point14(&a_fb14, c_fb14))];
    let n14 = if quick { 48 } else { 96 };
    let scfg14 = ServeConfig {
        requests: n14,
        batch_max: bmax14,
        arrival_rate_hz: 2_000.0,
        max_wait_s: 0.001,
        seed: 2026,
        input_shape: vec![1, 3, 32, 32],
        phases: Vec::new(),
        service: ServiceModel::Virtual {
            per_batch_ms: rows14[..2]
                .iter()
                .map(|row| row.iter().map(|c| c.time_ms).collect())
                .collect(),
            scale_s_per_ms: 1e-4,
        },
    };
    let run14 = |at_s: f64| -> ServeReport {
        let oracle = hetero14();
        let plan = FaultPlan {
            events: vec![FaultEvent {
                at_s,
                kind: FaultKind::DeviceLost { device: DeviceId::DLA },
            }],
            ..FaultPlan::default()
        };
        ServeSession::new(&scfg14)
            .oracle(&oracle)
            .plan_points(&points14)
            .faults(plan)
            .contingencies(conts14.clone())
            .run_with_adopt(|_, b| Ok(b.to_vec()), |_| Ok(()))
            .expect("fault-tolerant serving must not fail")
    };
    // Calibrate the fault timestamp to land mid-run (the far-future event
    // never fires but keeps both runs in the same ops-ified serving mode).
    let calib14 = run14(1e9);
    assert_eq!(calib14.records.len(), n14);
    let t_mid14 = calib14.records[n14 / 2].done_s;
    let faulted14 = run14(t_mid14);
    assert_eq!(faulted14.records.len(), n14, "device loss must not drop admitted requests");
    assert!(faulted14.sheds.is_empty(), "device loss must not shed requests");
    assert_eq!(faulted14.degrades.len(), 1, "exactly one contingency hot-swap");
    assert_eq!(faulted14.degrades[0].contingencies_used, 1);
    let availability14 = faulted14.availability();
    assert_eq!(availability14, 1.0);
    // True energy/request before vs after the loss. Post-loss plan 0 is the
    // GPU survivor (rows14[0]), plan 1 the activated contingency (rows14[2]).
    let per_req14 = |row: &[GraphCost], m: usize| row[m - 1].energy_j / m as f64;
    let mean_mj14 = |epoch: usize, map: &dyn Fn(usize) -> usize| -> f64 {
        let (mut sum, mut n) = (0.0f64, 0usize);
        for r in faulted14.records.iter().filter(|r| r.epoch == epoch) {
            sum += per_req14(&rows14[map(r.plan)], r.batch_size);
            n += 1;
        }
        sum / n.max(1) as f64
    };
    let mj_pre14 = mean_mj14(0, &|p| p);
    let mj_post14 = mean_mj14(1, &|p| if p == 0 { 0 } else { 2 });
    let degraded_ratio14 = mj_post14 / mj_pre14;
    let best_post14: f64 = {
        let post: Vec<_> = faulted14.records.iter().filter(|r| r.epoch == 1).collect();
        post.iter()
            .map(|r| {
                per_req14(&rows14[0], r.batch_size).min(per_req14(&rows14[2], r.batch_size))
            })
            .sum::<f64>()
            / post.len().max(1) as f64
    };
    assert!(
        mj_post14 <= best_post14 * 1.05,
        "post-fault energy/request {mj_post14} must be within 5% of the best \
         GPU-only plan's {best_post14}"
    );
    let mut t = Table::new(
        "Ablation 14: device-loss fault tolerance (mixed GPU+DLA surface)",
        &["phase", "requests", "energy mJ/req", "sheds", "hot-swaps"],
    );
    for (label, epoch, mj) in [("pre-fault", 0usize, mj_pre14), ("post-fault", 1, mj_post14)] {
        t.row(vec![
            label.to_string(),
            faulted14.records.iter().filter(|r| r.epoch == epoch).count().to_string(),
            f3(mj),
            faulted14.sheds.len().to_string(),
            faulted14.degrades.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "fault tolerance: availability {availability14:.3} under DeviceLost{{dla}}, \
         degraded energy/request at {:.0}% of pre-fault ({:+.1}%)\n",
        100.0 * degraded_ratio14,
        100.0 * (degraded_ratio14 - 1.0),
    );
    serve10_json
        .set("availability_under_faults", availability14)
        .set("degraded_energy_ratio", degraded_ratio14);
    payload.set("serve", serve10_json);

    eadgo::util::bench::emit_bench_json("ablation", &payload).expect("bench payload write");
}

/// The single non-constant, non-input node of a one-op plan graph.
fn costed_node(g: &Graph) -> eadgo::graph::NodeId {
    g.nodes()
        .find(|(_, n)| !matches!(n.op, OpKind::Input { .. }) && !n.op.is_constant_space())
        .map(|(id, _)| id)
        .expect("graph has one costed node")
}

/// The profiling signature of that node (input shapes resolved).
fn only_costed_sig(g: &Graph) -> String {
    let shapes = g.infer_shapes().unwrap();
    let node = g.node(costed_node(g));
    let ins: Vec<Vec<usize>> =
        node.inputs.iter().map(|p| shapes[p.node.0][p.port].clone()).collect();
    node.op.signature(&ins)
}

/// Copy `db` with `time_ms` of every row under signatures starting with
/// `prefix` scaled by `scale` (power is unchanged, so energy scales too).
fn scale_sig_times(db: &CostDb, prefix: &str, scale: f64) -> CostDb {
    let mut j = db.to_json();
    if let Json::Obj(root) = &mut j {
        if let Some(Json::Obj(profiles)) = root.get_mut("profiles") {
            for (sig, algos) in profiles.iter_mut() {
                if !sig.starts_with(prefix) {
                    continue;
                }
                if let Json::Obj(algos) = algos {
                    for rec in algos.values_mut() {
                        if let Json::Obj(rec) = rec {
                            if let Some(Json::Num(t)) = rec.get_mut("time_ms") {
                                *t *= scale;
                            }
                        }
                    }
                }
            }
        }
    }
    CostDb::from_json(&j).expect("scaled db parses")
}
