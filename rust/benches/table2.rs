//! Bench: regenerate paper Table 2 (cost-model accuracy on SqueezeNet) and
//! report the accuracy metrics (MAPE + rank correlation).
//! Run: `cargo bench --bench table2 [-- --quick]`

use eadgo::report::tables::{table2, ExperimentConfig};
use eadgo::util::bench::BenchSuite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { ExperimentConfig::quick() } else { ExperimentConfig::default() };

    let (t, data) = table2(&cfg);
    println!("{}", t.render());
    println!(
        "model accuracy: time MAPE {:.1}%  power MAPE {:.1}%  energy MAPE {:.1}%  energy Kendall-tau {:.2}",
        data.time_mape, data.power_mape, data.energy_mape, data.energy_tau
    );
    assert!(data.energy_mape < 15.0, "paper reports <=10% — ours must stay close");
    assert!(data.energy_tau > 0.5, "cost model must preserve ordering");
    println!("shape check OK: value error bounded, ordering preserved\n");

    let mut suite = BenchSuite::new("table2 generation");
    suite.banner();
    suite.run("table2_full", || table2(&cfg));
}
