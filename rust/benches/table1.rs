//! Bench: regenerate paper Table 1 (per-node algorithm costs) and measure
//! the profiling throughput that backs it.
//! Run: `cargo bench --bench table1 [-- --quick]`

use eadgo::report::tables::{table1, ExperimentConfig};
use eadgo::util::bench::BenchSuite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { ExperimentConfig::quick() } else { ExperimentConfig::default() };

    let (t, data) = table1(&cfg);
    println!("{}", t.render());

    // Shape assertions (the reproduction criterion from DESIGN.md).
    let conv3 = &data.nodes[2].1;
    let energy = |a: eadgo::algo::Algorithm| {
        conv3.iter().find(|(al, _)| *al == a).map(|(_, c)| c.energy_j()).unwrap()
    };
    assert!(energy(eadgo::algo::Algorithm::ConvWinograd) < energy(eadgo::algo::Algorithm::ConvIm2col));
    assert!(energy(eadgo::algo::Algorithm::ConvDirect) < energy(eadgo::algo::Algorithm::ConvIm2col));
    println!("shape check OK: winograd & direct beat im2col on conv3 energy\n");

    let mut suite = BenchSuite::new("table1 generation");
    suite.banner();
    suite.run("table1_full", || table1(&cfg));
}
