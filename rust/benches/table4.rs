//! Bench: regenerate paper Table 4 (time/energy tradeoff sweep on
//! SqueezeNet) and check the sweep is a smooth frontier.
//! Run: `cargo bench --bench table4 [-- --quick]`

use eadgo::report::tables::{table4, ExperimentConfig};
use eadgo::util::bench::BenchSuite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { ExperimentConfig::quick() } else { ExperimentConfig::default() };

    let (t, data) = table4(&cfg);
    println!("{}", t.render());

    // Endpoints bound the sweep (paper: "a smooth balance").
    let first = &data.rows.first().unwrap().2; // best time
    let last = &data.rows.last().unwrap().2; // best energy
    for (label, _, c) in &data.rows {
        assert!(c.time_ms >= first.time_ms * 0.98, "{label}: beats best_time?");
        assert!(c.energy_j() >= last.energy_j() * 0.98, "{label}: beats best_energy?");
    }
    println!("shape check OK: endpoints bound the frontier\n");

    let mut suite = BenchSuite::with_config(
        "table4 generation",
        eadgo::util::bench::BenchConfig { warmup_secs: 0.0, measure_secs: 0.1, min_iters: 1, max_iters: 1 },
    );
    suite.banner();
    suite.run("table4_full", || table4(&cfg));
}
