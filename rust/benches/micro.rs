//! Microbenchmarks of the optimizer hot paths (the §Perf targets):
//! subgraph matching, inner-search evaluation, canonical hashing, cost
//! table construction, and reference-engine node dispatch.
//! Run: `cargo bench --bench micro [-- --quick]`

use eadgo::algo::Assignment;
use eadgo::cost::CostFunction;
use eadgo::graph::canonical::graph_hash;
use eadgo::models::{self, ModelConfig};
use eadgo::search::{inner_search, OptimizerContext};
use eadgo::subst::RuleSet;
use eadgo::tensor::Tensor;
use eadgo::util::bench::{black_box, BenchSuite};
use eadgo::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("optimizer hot paths");
    suite.banner();

    let cfg = ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 };
    let squeezenet = models::squeezenet::build(cfg);
    let resnet = models::resnet::build(cfg);
    let rules = RuleSet::standard();

    suite.run("graph_hash/squeezenet", || black_box(graph_hash(&squeezenet)));
    suite.run("graph_hash/resnet", || black_box(graph_hash(&resnet)));
    suite.run("graph_clone_compact/resnet", || {
        let mut g = resnet.clone();
        g.compact();
        black_box(g.len())
    });
    suite.run("infer_shapes/resnet", || black_box(resnet.infer_shapes().unwrap().len()));
    suite.run("subst_neighbors/squeezenet", || {
        black_box(rules.neighbors(&squeezenet).unwrap().len())
    });
    suite.run("subst_neighbors/resnet", || black_box(rules.neighbors(&resnet).unwrap().len()));
    // Match phase alone (no materialization): the delta engine's hot path.
    suite.run("subst_find_sites/squeezenet", || {
        black_box(rules.find_sites(&squeezenet).unwrap().len())
    });
    suite.run("subst_find_sites/resnet", || black_box(rules.find_sites(&resnet).unwrap().len()));
    // Site -> delta -> incremental hash (what dedup costs per candidate).
    let sq_shapes = squeezenet.infer_shapes().unwrap();
    let sq_hashes = eadgo::graph::canonical::node_hashes(&squeezenet).unwrap();
    let sq_consumers = squeezenet.consumers();
    suite.run("delta_hash_all_sites/squeezenet", || {
        let cx = eadgo::subst::MatchContext::with_shapes(&squeezenet, &sq_shapes);
        let mut acc = 0u64;
        for site in rules.sites(&squeezenet, &cx) {
            let view = eadgo::graph::DeltaView::new(
                &squeezenet,
                &sq_shapes,
                site.delta(&squeezenet),
                Some(&sq_consumers),
            )
            .unwrap();
            acc ^= eadgo::graph::canonical::delta_hash(&view, &sq_hashes);
        }
        black_box(acc)
    });

    // Cost table + inner search (through the shared cost oracle).
    let ctx = OptimizerContext::offline_default();
    let (table, _) = ctx.table_for(&squeezenet).unwrap();
    let base = Assignment::default_for(&squeezenet, ctx.reg());
    suite.run("cost_table_build/squeezenet", || {
        black_box(ctx.table_for(&squeezenet).unwrap().0)
    });
    suite.run("cost_eval_full/squeezenet", || black_box(table.eval(&base)));
    // Indexed-slab swap lookups (the former linear `find` hot path).
    let swap_cost = table.eval(&base);
    let swap_ids: Vec<_> = table.costed_ids().filter(|id| table.option_count(*id) > 1).collect();
    suite.run("cost_eval_swap_sweep/squeezenet", || {
        let mut acc = 0.0f64;
        for &id in &swap_ids {
            for (f, slab) in table.freq_options(id) {
                for &(algo, _) in slab.iter() {
                    acc += table.eval_swap(swap_cost, &base, id, algo, *f).unwrap().energy_j;
                }
            }
        }
        black_box(acc)
    });
    suite.run("inner_search_d1_energy/squeezenet", || {
        black_box(inner_search(&table, &CostFunction::Energy, 1, base.clone()).unwrap().evals)
    });
    suite.run("inner_search_d2_power/squeezenet", || {
        black_box(inner_search(&table, &CostFunction::Power, 2, base.clone()).unwrap().evals)
    });

    // Warm vs cold incremental inner search on a real candidate delta:
    // the cold run re-derives every node; the warm run re-optimizes only
    // the delta's dirty cone from the parent's converged plan.
    let oracle: &eadgo::cost::CostOracle = &ctx.oracle;
    let conv = inner_search(&table, &CostFunction::Energy, 1, base.clone()).unwrap();
    let cx = eadgo::subst::MatchContext::with_shapes_and_consumers(
        &squeezenet,
        &sq_shapes,
        &sq_consumers,
    );
    let site = rules
        .sites(&squeezenet, &cx)
        .into_iter()
        .next()
        .expect("squeezenet exposes rewrite sites");
    let view = eadgo::graph::DeltaView::new(
        &squeezenet,
        &sq_shapes,
        site.delta(&squeezenet),
        Some(&sq_consumers),
    )
    .unwrap();
    let dbase = eadgo::cost::DeltaBase {
        graph: &squeezenet,
        shapes: &sq_shapes,
        table: &table,
        assignment: &base,
        converged: Some(&conv.assignment),
    };
    let cand = oracle.delta_table_for_freqs(&dbase, &view, &[eadgo::energysim::FreqId::NOMINAL]);
    let warm = cand.warm.clone().expect("converged supplied");
    suite.run("inner_search_cold/candidate", || {
        black_box(
            eadgo::search::inner_search_incremental(
                &cand.table,
                &CostFunction::Energy,
                cand.assignment.clone(),
                None,
                None,
            )
            .unwrap()
            .swept,
        )
    });
    suite.run("inner_search_warm_dirty/candidate", || {
        black_box(
            eadgo::search::inner_search_incremental(
                &cand.table,
                &CostFunction::Energy,
                warm.clone(),
                Some(&cand.dirty),
                Some(oracle),
            )
            .unwrap()
            .swept,
        )
    });

    // Engine execution (reference backend, small tensors).
    let small = ModelConfig { batch: 1, resolution: 16, width_div: 8, classes: 10 };
    let g = models::simple::build_cnn(small);
    let reg = eadgo::algo::AlgorithmRegistry::new();
    let a = Assignment::default_for(&g, &reg);
    let eng = eadgo::engine::ReferenceEngine::new();
    let plan = eng.plan(&g, &a).unwrap();
    let mut rng = Rng::seed_from(1);
    let x = Tensor::rand(&[1, 3, 16, 16], &mut rng, -1.0, 1.0);
    suite.run("reference_engine/quickstart16", || {
        black_box(eng.run_plan(&g, &a, &plan, std::slice::from_ref(&x)).unwrap().wall_s)
    });

    // Tensor kernels (the rust-side algorithm implementations).
    let xi = Tensor::rand(&[1, 16, 32, 32], &mut rng, -1.0, 1.0);
    let wi = Tensor::rand(&[16, 16, 3, 3], &mut rng, -0.5, 0.5);
    suite.run("conv_direct/16x32x32", || {
        black_box(eadgo::tensor::conv::conv2d_direct(&xi, &wi, None, (1, 1), (1, 1)))
    });
    suite.run("conv_im2col/16x32x32", || {
        black_box(eadgo::tensor::conv::conv2d_im2col(&xi, &wi, None, (1, 1), (1, 1)))
    });
    suite.run("conv_winograd/16x32x32", || {
        black_box(eadgo::tensor::winograd::conv2d_winograd(&xi, &wi, None, (1, 1)))
    });
}
