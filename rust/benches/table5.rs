//! Bench: regenerate paper Table 5 (inner-search ablation on SqueezeNet,
//! energy objective) and check the contribution ordering.
//! Run: `cargo bench --bench table5 [-- --quick]` (or EADGO_BENCH_QUICK=1).
//! Emits `BENCH_table5.json`.

use eadgo::report::tables::{table5, ExperimentConfig};
use eadgo::util::bench::BenchSuite;
use eadgo::util::json::Json;

fn main() {
    let quick = eadgo::util::bench::quick_requested();
    let cfg = if quick { ExperimentConfig::quick() } else { ExperimentConfig::default() };

    let (t, d) = table5(&cfg);
    println!("{}", t.render());

    assert!(d.outer_only.energy_j() < d.origin.energy_j(), "outer search must save energy");
    assert!(d.inner_only.energy_j() < d.origin.energy_j(), "inner search must save energy");
    assert!(
        d.both.energy_j() <= d.outer_only.energy_j().min(d.inner_only.energy_j()) * 1.02,
        "both levels must beat either alone"
    );
    println!(
        "shape check OK: both(-{:.0}%) <= min(outer -{:.0}%, inner -{:.0}%) vs origin\n",
        100.0 * (1.0 - d.both.energy_j() / d.origin.energy_j()),
        100.0 * (1.0 - d.outer_only.energy_j() / d.origin.energy_j()),
        100.0 * (1.0 - d.inner_only.energy_j() / d.origin.energy_j()),
    );

    let mut suite = BenchSuite::with_config(
        "table5 generation",
        eadgo::util::bench::BenchConfig { warmup_secs: 0.0, measure_secs: 0.1, min_iters: 1, max_iters: 1 },
    );
    suite.banner();
    suite.run("table5_full", || table5(&cfg));

    let mut payload = Json::obj();
    payload
        .set("bench", "table5")
        .set("quick", quick)
        .set("origin_energy", d.origin.energy_j())
        .set("outer_only_energy", d.outer_only.energy_j())
        .set("inner_only_energy", d.inner_only.energy_j())
        .set("both_energy", d.both.energy_j())
        .set("timings", eadgo::util::bench::results_to_json(suite.results()));
    eadgo::util::bench::emit_bench_json("table5", &payload).expect("bench payload write");
}
