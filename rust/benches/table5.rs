//! Bench: regenerate paper Table 5 (inner-search ablation on SqueezeNet,
//! energy objective) and check the contribution ordering.
//! Run: `cargo bench --bench table5 [-- --quick]`

use eadgo::report::tables::{table5, ExperimentConfig};
use eadgo::util::bench::BenchSuite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { ExperimentConfig::quick() } else { ExperimentConfig::default() };

    let (t, d) = table5(&cfg);
    println!("{}", t.render());

    assert!(d.outer_only.energy_j() < d.origin.energy_j(), "outer search must save energy");
    assert!(d.inner_only.energy_j() < d.origin.energy_j(), "inner search must save energy");
    assert!(
        d.both.energy_j() <= d.outer_only.energy_j().min(d.inner_only.energy_j()) * 1.02,
        "both levels must beat either alone"
    );
    println!(
        "shape check OK: both(-{:.0}%) <= min(outer -{:.0}%, inner -{:.0}%) vs origin\n",
        100.0 * (1.0 - d.both.energy_j() / d.origin.energy_j()),
        100.0 * (1.0 - d.outer_only.energy_j() / d.origin.energy_j()),
        100.0 * (1.0 - d.inner_only.energy_j() / d.origin.energy_j()),
    );

    let mut suite = BenchSuite::with_config(
        "table5 generation",
        eadgo::util::bench::BenchConfig { warmup_secs: 0.0, measure_secs: 0.1, min_iters: 1, max_iters: 1 },
    );
    suite.banner();
    suite.run("table5_full", || table5(&cfg));
}
