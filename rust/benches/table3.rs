//! Bench: regenerate paper Table 3 (objectives × the three CNNs) — the
//! headline result (24% energy savings on SqueezeNet vs MetaFlow-best-time
//! with negligible performance impact) plus the DVFS variants.
//! Run: `cargo bench --bench table3 [-- --quick]` (or EADGO_BENCH_QUICK=1).
//! Emits `BENCH_table3.json`.

use eadgo::report::tables::{table3, ExperimentConfig};
use eadgo::util::bench::BenchSuite;
use eadgo::util::json::Json;

fn main() {
    let quick = eadgo::util::bench::quick_requested();
    let cfg = if quick { ExperimentConfig::quick() } else { ExperimentConfig::default() };

    let (t, data) = table3(&cfg);
    println!("{}", t.render());

    for model in ["squeezenet", "inception", "resnet"] {
        let metaflow = data.get(model, "metaflow_best_time").unwrap().cost;
        let best_energy = data.get(model, "best_energy").unwrap().cost;
        let best_power = data.get(model, "best_power").unwrap().cost;
        let best_time = data.get(model, "best_time").unwrap().cost;
        let origin = data.get(model, "origin").unwrap().cost;
        let save = 100.0 * (1.0 - best_energy.energy_j() / metaflow.energy_j());
        println!(
            "{model}: best_energy saves {save:.0}% energy vs metaflow-best-time; \
             best_power {:.0}% less power than origin; best_time {:.0}% faster than metaflow",
            100.0 * (1.0 - best_power.power_w / origin.power_w),
            100.0 * (1.0 - best_time.time_ms / metaflow.time_ms),
        );
        assert!(best_energy.energy_j() < metaflow.energy_j(), "{model}: energy-aware must win");
        assert!(best_power.power_w < origin.power_w, "{model}: power objective must cut power");
        assert!(best_time.time_ms <= metaflow.time_ms * 1.01, "{model}: ours >= metaflow on time");
    }
    println!("shape check OK: Table 3 orderings hold on all three models\n");

    let mut suite = BenchSuite::with_config(
        "table3 generation",
        eadgo::util::bench::BenchConfig { warmup_secs: 0.0, measure_secs: 0.1, min_iters: 1, max_iters: 1 },
    );
    suite.banner();
    suite.run("table3_full", || table3(&cfg));

    let mut payload = Json::obj();
    payload.set("bench", "table3").set("quick", quick);
    for row in &data.rows {
        payload.set(&format!("{}_{}_energy", row.model, row.variant), row.cost.energy_j());
    }
    payload.set("timings", eadgo::util::bench::results_to_json(suite.results()));
    eadgo::util::bench::emit_bench_json("table3", &payload).expect("bench payload write");
}
