//! Operator kinds, attributes, shape inference, and cost-db signatures.
//!
//! Mirrors the paper's §3.1: "Each node is an operator (e.g., convolution,
//! max pooling, add) and each edge is a tensor."
//!
//! Two families of operators:
//! - **Runtime ops** executed on the request path (conv, pool, relu, ...).
//! - **Weight-space constant ops** (`Concat` on weights, [`OpKind::FoldBnWeight`],
//!   [`OpKind::PadKernel`], ...) introduced by substitutions that rewrite
//!   parameters (e.g. folding batch-norm into conv weights). They depend
//!   only on `Weight` leaves, so the engine constant-folds them at plan
//!   time; they cost nothing at inference.

use std::fmt;

/// Activation fused into a producing op (cuDNN-style epilogue fusion).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    /// No epilogue activation.
    None,
    /// Fused rectified linear unit.
    Relu,
}

impl Activation {
    /// Stable serialization tag.
    pub fn tag(&self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "relu",
        }
    }
}

/// Semantic role of a constant weight tensor — determines the deterministic
/// initialization distribution at realization time (e.g. a BN variance must
/// be positive, a BN gamma near 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightKind {
    /// Conv/matmul filter: He-uniform over fan-in.
    Filter,
    /// Additive bias: small uniform.
    Bias,
    /// BN scale: uniform near 1.
    Gamma,
    /// BN shift: small uniform.
    Beta,
    /// BN running mean: small uniform.
    Mean,
    /// BN running variance: uniform in [0.5, 1.5] (strictly positive).
    Var,
}

impl WeightKind {
    /// Stable serialization tag.
    pub fn tag(&self) -> &'static str {
        match self {
            WeightKind::Filter => "filter",
            WeightKind::Bias => "bias",
            WeightKind::Gamma => "gamma",
            WeightKind::Beta => "beta",
            WeightKind::Mean => "mean",
            WeightKind::Var => "var",
        }
    }
}

/// The operator of a node, with all static attributes.
///
/// Input tensor conventions (by input port order):
/// - `Conv2d`: `[x, w]` + optional bias `[K]` + optional residual (same
///   shape as output, added pre-activation — ResNet fusion).
/// - `BatchNorm`: `[x, gamma, beta, mean, var]`.
/// - `FoldBnWeight`: `[w, gamma, var]` → `w * gamma/sqrt(var+eps)` per
///   output channel.
/// - `FoldBnBias`: `[gamma, beta, mean, var]` (+ leading `bias` input when
///   `has_bias`) → `(bias - mean) * gamma/sqrt(var+eps) + beta`.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Graph input placeholder.
    Input {
        /// Shape of the fed tensor.
        shape: Vec<usize>,
    },
    /// Constant weight tensor; contents generated deterministically from
    /// `seed` with a `kind`-appropriate distribution.
    Weight {
        /// Shape of the constant tensor.
        shape: Vec<usize>,
        /// Deterministic realization seed.
        seed: u64,
        /// Semantic role (drives the init distribution).
        kind: WeightKind,
    },
    /// 2-D convolution with optional fused bias/activation/residual.
    Conv2d {
        /// Spatial stride (h, w).
        stride: (usize, usize),
        /// Zero padding (h, w).
        pad: (usize, usize),
        /// Fused epilogue activation.
        act: Activation,
        /// Whether a bias input follows the weight.
        has_bias: bool,
        /// Whether a residual input is added pre-activation.
        has_residual: bool,
    },
    /// Depthwise convolution (channel multiplier 1): weight `[C, 1, R, S]`,
    /// each channel convolved independently — the MobileNet building block
    /// (paper §5 future work: "more types of DNNs").
    DwConv2d {
        /// Spatial stride (h, w).
        stride: (usize, usize),
        /// Zero padding (h, w).
        pad: (usize, usize),
        /// Fused epilogue activation.
        act: Activation,
        /// Whether a bias input follows the weight.
        has_bias: bool,
    },
    /// Dense matrix multiply with optional fused bias/activation epilogue
    /// (classifier heads, attention/FFN blocks). Inputs `[a, b]` + optional
    /// bias (same shape as the output — a broadcast row bias realized as a
    /// full constant, matching the `Add` it fuses away).
    MatMul {
        /// Fused epilogue activation.
        act: Activation,
        /// Whether a bias input follows the operands.
        has_bias: bool,
    },
    /// Elementwise rectified linear unit.
    Relu,
    /// Elementwise logistic sigmoid.
    Sigmoid,
    /// Elementwise addition (residual connections).
    Add,
    /// Fused residual-add + ReLU (produced by the AddRelu fusion rule).
    AddRelu,
    /// Elementwise multiplication.
    Mul,
    /// Max pooling over `k`-sized windows.
    MaxPool {
        /// Window size (h, w).
        k: (usize, usize),
        /// Spatial stride (h, w).
        stride: (usize, usize),
        /// Zero padding (h, w).
        pad: (usize, usize),
    },
    /// Average pooling over `k`-sized windows.
    AvgPool {
        /// Window size (h, w).
        k: (usize, usize),
        /// Spatial stride (h, w).
        stride: (usize, usize),
        /// Zero padding (h, w).
        pad: (usize, usize),
    },
    /// Global spatial average pooling to `[N, C, 1, 1]`.
    GlobalAvgPool,
    /// Batch normalization (inference form, running statistics).
    BatchNorm {
        /// Stability epsilon as f32 bits (see [`eps_bits`]).
        eps: u32,
    },
    /// Concatenate along `axis` (axis 1 = channels at runtime; axis 0 used
    /// in weight space when merging parallel convolutions).
    Concat {
        /// Concatenation axis.
        axis: usize,
    },
    /// Split along `axis` into parts of the given sizes; one output port per part.
    Split {
        /// Split axis.
        axis: usize,
        /// Size of each part along the axis.
        sizes: Vec<usize>,
    },
    /// Collapse trailing dimensions to `[N, C*H*W]`.
    Flatten,
    /// Softmax over the last dimension.
    Softmax,
    // ---- weight-space constant ops ----
    /// Fold BN scale into a conv filter: `w * gamma/sqrt(var+eps)`.
    FoldBnWeight {
        /// Stability epsilon as f32 bits (see [`eps_bits`]).
        eps: u32,
    },
    /// Fold BN shift into a conv bias: `(b - mean)*gamma/sqrt(var+eps) + beta`.
    FoldBnBias {
        /// Stability epsilon as f32 bits (see [`eps_bits`]).
        eps: u32,
        /// Whether a conv bias input leads the BN parameters.
        has_bias: bool,
    },
    /// Zero-pad a conv kernel [K,C,r,s] spatially (centered) to `target`.
    PadKernel {
        /// Target spatial kernel size (r, s).
        target: (usize, usize),
    },
}

/// f32 bits <-> attribute-safe epsilon (keeps OpKind Eq/Hash-able).
pub fn eps_bits(eps: f32) -> u32 {
    eps.to_bits()
}
/// Inverse of [`eps_bits`]: recover the f32 epsilon from its stored bits.
pub fn eps_val(bits: u32) -> f32 {
    f32::from_bits(bits)
}

impl OpKind {
    /// Filter weight constructor (the overwhelmingly common case).
    pub fn weight(shape: Vec<usize>, seed: u64) -> OpKind {
        OpKind::Weight { shape, seed, kind: WeightKind::Filter }
    }

    /// Plain (unfused) matrix multiply — the pre-fusion default.
    pub fn matmul() -> OpKind {
        OpKind::MatMul { act: Activation::None, has_bias: false }
    }

    /// Weight constructor with an explicit kind.
    pub fn weight_kind(shape: Vec<usize>, seed: u64, kind: WeightKind) -> OpKind {
        OpKind::Weight { shape, seed, kind }
    }

    /// Is this op removed from the request path by constant folding?
    pub fn is_constant_space(&self) -> bool {
        matches!(
            self,
            OpKind::Weight { .. }
                | OpKind::FoldBnWeight { .. }
                | OpKind::FoldBnBias { .. }
                | OpKind::PadKernel { .. }
        )
    }

    /// Number of output ports.
    pub fn num_outputs(&self) -> usize {
        match self {
            OpKind::Split { sizes, .. } => sizes.len(),
            _ => 1,
        }
    }

    /// Short stable mnemonic used in signatures and reports.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input { .. } => "input",
            OpKind::Weight { .. } => "weight",
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::DwConv2d { .. } => "dwconv2d",
            OpKind::MatMul { .. } => "matmul",
            OpKind::Relu => "relu",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Add => "add",
            OpKind::AddRelu => "addrelu",
            OpKind::Mul => "mul",
            OpKind::MaxPool { .. } => "maxpool",
            OpKind::AvgPool { .. } => "avgpool",
            OpKind::GlobalAvgPool => "gavgpool",
            OpKind::BatchNorm { .. } => "batchnorm",
            OpKind::Concat { .. } => "concat",
            OpKind::Split { .. } => "split",
            OpKind::Flatten => "flatten",
            OpKind::Softmax => "softmax",
            OpKind::FoldBnWeight { .. } => "foldbnw",
            OpKind::FoldBnBias { .. } => "foldbnb",
            OpKind::PadKernel { .. } => "padkernel",
        }
    }

    /// Infer output shapes from input shapes. Errors describe the mismatch —
    /// they double as graph validation.
    pub fn infer_shapes(&self, inputs: &[Vec<usize>]) -> Result<Vec<Vec<usize>>, String> {
        let one = |s: Vec<usize>| Ok(vec![s]);
        match self {
            OpKind::Input { shape } => {
                if inputs.is_empty() {
                    one(shape.clone())
                } else {
                    Err("Input takes no inputs".into())
                }
            }
            OpKind::Weight { shape, .. } => {
                if inputs.is_empty() {
                    one(shape.clone())
                } else {
                    Err("Weight takes no inputs".into())
                }
            }
            OpKind::Conv2d { stride, pad, has_bias, has_residual, .. } => {
                let expect = 2 + usize::from(*has_bias) + usize::from(*has_residual);
                if inputs.len() != expect {
                    return Err(format!("Conv2d expects {expect} inputs, got {}", inputs.len()));
                }
                let x = &inputs[0];
                let w = &inputs[1];
                if x.len() != 4 || w.len() != 4 {
                    return Err(format!("Conv2d expects rank-4 x and w, got {x:?}, {w:?}"));
                }
                let (n, c, h, wid) = (x[0], x[1], x[2], x[3]);
                let (k, wc, r, s) = (w[0], w[1], w[2], w[3]);
                if c != wc {
                    return Err(format!("Conv2d channels: input {c} vs weight {wc}"));
                }
                if h + 2 * pad.0 < r || wid + 2 * pad.1 < s {
                    return Err(format!("Conv2d kernel {r}x{s} larger than padded input"));
                }
                let oh = (h + 2 * pad.0 - r) / stride.0 + 1;
                let ow = (wid + 2 * pad.1 - s) / stride.1 + 1;
                let mut idx = 2;
                if *has_bias {
                    if inputs[idx] != vec![k] {
                        return Err(format!("Conv2d bias must be [{k}], got {:?}", inputs[idx]));
                    }
                    idx += 1;
                }
                if *has_residual && inputs[idx] != vec![n, k, oh, ow] {
                    return Err(format!(
                        "Conv2d residual must be [{n},{k},{oh},{ow}], got {:?}",
                        inputs[idx]
                    ));
                }
                one(vec![n, k, oh, ow])
            }
            OpKind::DwConv2d { stride, pad, has_bias, .. } => {
                let expect = 2 + usize::from(*has_bias);
                if inputs.len() != expect {
                    return Err(format!("DwConv2d expects {expect} inputs, got {}", inputs.len()));
                }
                let x = &inputs[0];
                let w = &inputs[1];
                if x.len() != 4 || w.len() != 4 {
                    return Err(format!("DwConv2d expects rank-4 x and w, got {x:?}, {w:?}"));
                }
                let (n, c, h, wid) = (x[0], x[1], x[2], x[3]);
                let (wc, mult, r, s) = (w[0], w[1], w[2], w[3]);
                if wc != c || mult != 1 {
                    return Err(format!(
                        "DwConv2d weight must be [{c},1,R,S], got {w:?}"
                    ));
                }
                if h + 2 * pad.0 < r || wid + 2 * pad.1 < s {
                    return Err(format!("DwConv2d kernel {r}x{s} larger than padded input"));
                }
                let oh = (h + 2 * pad.0 - r) / stride.0 + 1;
                let ow = (wid + 2 * pad.1 - s) / stride.1 + 1;
                if *has_bias && inputs[2] != vec![c] {
                    return Err(format!("DwConv2d bias must be [{c}], got {:?}", inputs[2]));
                }
                one(vec![n, c, oh, ow])
            }
            OpKind::MatMul { has_bias, .. } => {
                let expect = 2 + usize::from(*has_bias);
                if inputs.len() != expect {
                    return Err(format!("MatMul expects {expect} inputs, got {}", inputs.len()));
                }
                let (a, b) = (&inputs[0], &inputs[1]);
                if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
                    return Err(format!("MatMul shapes incompatible: {a:?} @ {b:?}"));
                }
                let out = vec![a[0], b[1]];
                if *has_bias && inputs[2] != out {
                    return Err(format!(
                        "MatMul bias must be {out:?}, got {:?}",
                        inputs[2]
                    ));
                }
                one(out)
            }
            OpKind::Relu | OpKind::Sigmoid | OpKind::Flatten | OpKind::Softmax => {
                if inputs.len() != 1 {
                    return Err(format!("{} expects 1 input", self.mnemonic()));
                }
                match self {
                    OpKind::Flatten => {
                        let x = &inputs[0];
                        if x.len() < 2 {
                            return Err("Flatten expects rank >= 2".into());
                        }
                        one(vec![x[0], x[1..].iter().product()])
                    }
                    OpKind::Softmax => {
                        if inputs[0].len() != 2 {
                            return Err("Softmax expects rank-2".into());
                        }
                        one(inputs[0].clone())
                    }
                    _ => one(inputs[0].clone()),
                }
            }
            OpKind::Add | OpKind::AddRelu | OpKind::Mul => {
                if inputs.len() != 2 || inputs[0] != inputs[1] {
                    return Err(format!(
                        "{} expects 2 same-shape inputs, got {inputs:?}",
                        self.mnemonic()
                    ));
                }
                one(inputs[0].clone())
            }
            OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
                if inputs.len() != 1 || inputs[0].len() != 4 {
                    return Err("pool expects one rank-4 input".into());
                }
                let x = &inputs[0];
                if x[2] + 2 * pad.0 < k.0 || x[3] + 2 * pad.1 < k.1 {
                    return Err("pool kernel larger than padded input".into());
                }
                let oh = (x[2] + 2 * pad.0 - k.0) / stride.0 + 1;
                let ow = (x[3] + 2 * pad.1 - k.1) / stride.1 + 1;
                one(vec![x[0], x[1], oh, ow])
            }
            OpKind::GlobalAvgPool => {
                if inputs.len() != 1 || inputs[0].len() != 4 {
                    return Err("gavgpool expects one rank-4 input".into());
                }
                one(vec![inputs[0][0], inputs[0][1], 1, 1])
            }
            OpKind::BatchNorm { .. } => {
                if inputs.len() != 5 {
                    return Err("BatchNorm expects [x,gamma,beta,mean,var]".into());
                }
                let x = &inputs[0];
                if x.len() != 4 {
                    return Err("BatchNorm expects rank-4 x".into());
                }
                let c = x[1];
                for (i, p) in inputs[1..].iter().enumerate() {
                    if p != &vec![c] {
                        return Err(format!("BatchNorm param {i} must be [{c}], got {p:?}"));
                    }
                }
                one(x.clone())
            }
            OpKind::Concat { axis } => {
                if inputs.is_empty() {
                    return Err("Concat expects >= 1 input".into());
                }
                let rank = inputs[0].len();
                if *axis >= rank {
                    return Err(format!("Concat axis {axis} out of range for rank {rank}"));
                }
                let mut out = inputs[0].clone();
                for x in &inputs[1..] {
                    if x.len() != rank {
                        return Err("Concat rank mismatch".into());
                    }
                    for (d, (a, b)) in out.iter().zip(x.iter()).enumerate() {
                        if d != *axis && a != b {
                            return Err(format!("Concat non-axis dim {d} mismatch: {a} vs {b}"));
                        }
                    }
                    out[*axis] += x[*axis];
                }
                one(out)
            }
            OpKind::Split { axis, sizes } => {
                if inputs.len() != 1 {
                    return Err("Split expects 1 input".into());
                }
                let x = &inputs[0];
                if *axis >= x.len() {
                    return Err(format!("Split axis {axis} out of range"));
                }
                if sizes.iter().sum::<usize>() != x[*axis] {
                    return Err(format!(
                        "Split sizes {sizes:?} do not sum to dim {}",
                        x[*axis]
                    ));
                }
                Ok(sizes
                    .iter()
                    .map(|&sz| {
                        let mut s = x.clone();
                        s[*axis] = sz;
                        s
                    })
                    .collect())
            }
            OpKind::FoldBnWeight { .. } => {
                if inputs.len() != 3 {
                    return Err("FoldBnWeight expects [w,gamma,var]".into());
                }
                let w = &inputs[0];
                if w.len() != 4 {
                    return Err("FoldBnWeight expects rank-4 w".into());
                }
                let k = w[0];
                if inputs[1] != vec![k] || inputs[2] != vec![k] {
                    return Err("FoldBnWeight params must be [K]".into());
                }
                one(w.clone())
            }
            OpKind::FoldBnBias { has_bias, .. } => {
                let expect = 4 + usize::from(*has_bias);
                if inputs.len() != expect {
                    return Err(format!("FoldBnBias expects {expect} inputs"));
                }
                let k = inputs[0][0];
                for p in inputs {
                    if p != &vec![k] {
                        return Err("FoldBnBias inputs must all be [K]".into());
                    }
                }
                one(vec![k])
            }
            OpKind::PadKernel { target } => {
                if inputs.len() != 1 || inputs[0].len() != 4 {
                    return Err("PadKernel expects one rank-4 weight".into());
                }
                let w = &inputs[0];
                if target.0 < w[2] || target.1 < w[3] {
                    return Err("PadKernel target smaller than kernel".into());
                }
                if (target.0 - w[2]) % 2 != 0 || (target.1 - w[3]) % 2 != 0 {
                    return Err("PadKernel padding must be symmetric".into());
                }
                one(vec![w[0], w[1], target.0, target.1])
            }
        }
    }

    /// Cost-database signature: identifies a node up to everything that
    /// influences its cost (op, attributes, input shapes) — the paper's
    /// "nodes with the same parameters only need to be measured once".
    pub fn signature(&self, input_shapes: &[Vec<usize>]) -> String {
        let mut s = String::with_capacity(64);
        self.signature_into(input_shapes, &mut s);
        s
    }

    /// As [`OpKind::signature`], appending into a caller-provided buffer.
    /// The cost oracle's table builder reuses one scratch buffer per graph
    /// and interns the result, so the hot path allocates no signature
    /// strings after warmup.
    pub fn signature_into(&self, input_shapes: &[Vec<usize>], s: &mut String) {
        s.push_str(self.mnemonic());
        match self {
            OpKind::Conv2d { stride, pad, act, has_bias, has_residual } => {
                s.push_str(&format!(
                    ";st={},{};pad={},{};act={};b={};res={}",
                    stride.0, stride.1, pad.0, pad.1, act.tag(), *has_bias as u8, *has_residual as u8
                ));
            }
            OpKind::DwConv2d { stride, pad, act, has_bias } => {
                s.push_str(&format!(
                    ";st={},{};pad={},{};act={};b={}",
                    stride.0, stride.1, pad.0, pad.1, act.tag(), *has_bias as u8
                ));
            }
            OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
                s.push_str(&format!(
                    ";k={},{};st={},{};pad={},{}",
                    k.0, k.1, stride.0, stride.1, pad.0, pad.1
                ));
            }
            // Epilogue attrs appear only when non-default, so the plain
            // matmul keeps its historical signature byte-for-byte.
            OpKind::MatMul { act, has_bias } => {
                if !matches!(act, Activation::None) || *has_bias {
                    s.push_str(&format!(";act={};b={}", act.tag(), *has_bias as u8));
                }
            }
            OpKind::Concat { axis } => s.push_str(&format!(";ax={axis}")),
            OpKind::Split { axis, sizes } => {
                s.push_str(&format!(";ax={axis};sz="));
                for (i, z) in sizes.iter().enumerate() {
                    if i > 0 {
                        s.push('/');
                    }
                    s.push_str(&z.to_string());
                }
            }
            _ => {}
        }
        for shape in input_shapes {
            s.push(';');
            for (i, d) in shape.iter().enumerate() {
                if i > 0 {
                    s.push('x');
                }
                s.push_str(&d.to_string());
            }
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.mnemonic())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_inference() {
        let op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::Relu,
            has_bias: true,
            has_residual: false,
        };
        let out = op
            .infer_shapes(&[vec![2, 3, 32, 32], vec![16, 3, 3, 3], vec![16]])
            .unwrap();
        assert_eq!(out, vec![vec![2, 16, 32, 32]]);
    }

    #[test]
    fn conv_channel_mismatch_rejected() {
        let op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        assert!(op.infer_shapes(&[vec![1, 3, 8, 8], vec![4, 5, 3, 3]]).is_err());
    }

    #[test]
    fn conv_residual_shape_checked() {
        let op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::Relu,
            has_bias: false,
            has_residual: true,
        };
        assert!(op
            .infer_shapes(&[vec![1, 3, 8, 8], vec![4, 3, 3, 3], vec![1, 4, 8, 8]])
            .is_ok());
        assert!(op
            .infer_shapes(&[vec![1, 3, 8, 8], vec![4, 3, 3, 3], vec![1, 4, 4, 4]])
            .is_err());
    }

    #[test]
    fn pool_shapes() {
        let op = OpKind::MaxPool { k: (3, 3), stride: (2, 2), pad: (0, 0) };
        assert_eq!(
            op.infer_shapes(&[vec![1, 8, 15, 15]]).unwrap(),
            vec![vec![1, 8, 7, 7]]
        );
    }

    #[test]
    fn concat_split_shapes() {
        let cat = OpKind::Concat { axis: 1 };
        assert_eq!(
            cat.infer_shapes(&[vec![1, 3, 8, 8], vec![1, 5, 8, 8]]).unwrap(),
            vec![vec![1, 8, 8, 8]]
        );
        let split = OpKind::Split { axis: 1, sizes: vec![3, 5] };
        assert_eq!(
            split.infer_shapes(&[vec![1, 8, 8, 8]]).unwrap(),
            vec![vec![1, 3, 8, 8], vec![1, 5, 8, 8]]
        );
        assert!(split.infer_shapes(&[vec![1, 7, 8, 8]]).is_err());
    }

    #[test]
    fn matmul_and_flatten() {
        assert_eq!(
            OpKind::matmul().infer_shapes(&[vec![4, 8], vec![8, 3]]).unwrap(),
            vec![vec![4, 3]]
        );
        assert!(OpKind::matmul().infer_shapes(&[vec![4, 8], vec![7, 3]]).is_err());
        assert_eq!(
            OpKind::Flatten.infer_shapes(&[vec![2, 3, 4, 5]]).unwrap(),
            vec![vec![2, 60]]
        );
    }

    #[test]
    fn fused_matmul_shapes_and_signature() {
        let fused = OpKind::MatMul { act: Activation::Relu, has_bias: true };
        assert_eq!(
            fused
                .infer_shapes(&[vec![4, 8], vec![8, 3], vec![4, 3]])
                .unwrap(),
            vec![vec![4, 3]]
        );
        // Bias must match the output shape.
        assert!(fused.infer_shapes(&[vec![4, 8], vec![8, 3], vec![3]]).is_err());
        // The plain matmul keeps its historical attribute-free signature;
        // fused epilogues key distinct cost rows.
        let shapes = vec![vec![4, 8], vec![8, 3]];
        assert_eq!(OpKind::matmul().signature(&shapes), "matmul;4x8;8x3");
        let fshapes = vec![vec![4, 8], vec![8, 3], vec![4, 3]];
        assert!(fused.signature(&fshapes).starts_with("matmul;act=relu;b=1;"));
    }

    #[test]
    fn weight_space_shapes() {
        let f = OpKind::FoldBnWeight { eps: eps_bits(1e-5) };
        assert_eq!(
            f.infer_shapes(&[vec![4, 3, 3, 3], vec![4], vec![4]]).unwrap(),
            vec![vec![4, 3, 3, 3]]
        );
        let p = OpKind::PadKernel { target: (3, 3) };
        assert_eq!(
            p.infer_shapes(&[vec![4, 3, 1, 1]]).unwrap(),
            vec![vec![4, 3, 3, 3]]
        );
        assert!(p.infer_shapes(&[vec![4, 3, 2, 2]]).is_err()); // asymmetric
    }

    #[test]
    fn signatures_stable_and_distinct() {
        let op1 = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::Relu,
            has_bias: true,
            has_residual: false,
        };
        let op2 = OpKind::Conv2d {
            stride: (2, 2),
            pad: (1, 1),
            act: Activation::Relu,
            has_bias: true,
            has_residual: false,
        };
        let shapes = vec![vec![1, 3, 32, 32], vec![8, 3, 3, 3], vec![8]];
        let s1 = op1.signature(&shapes);
        let s2 = op2.signature(&shapes);
        assert_ne!(s1, s2);
        assert_eq!(s1, op1.signature(&shapes));
        assert!(s1.starts_with("conv2d;"));
    }

    #[test]
    fn eps_roundtrip() {
        let e = 1e-5f32;
        assert_eq!(eps_val(eps_bits(e)), e);
    }
}
