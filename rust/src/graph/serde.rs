//! Graph (+ assignment) JSON serialization: lets users define custom models
//! without recompiling, and persists optimizer results ("optimize once,
//! serve later" — `eadgo optimize --save-plan` / `eadgo run --plan`).

use super::op::{Activation, OpKind, WeightKind};
use super::{Graph, NodeId, PortRef};
use crate::algo::{Algorithm, Assignment};
use crate::energysim::{DeviceId, FreqId, Layout};
use crate::util::json::{self, Json};
use std::path::Path;

fn pair_to_json(p: (usize, usize)) -> Json {
    Json::Arr(vec![Json::Num(p.0 as f64), Json::Num(p.1 as f64)])
}

fn pair_from_json(v: &Json, what: &str) -> anyhow::Result<(usize, usize)> {
    let a = v
        .as_arr()
        .filter(|a| a.len() == 2)
        .ok_or_else(|| anyhow::anyhow!("{what}: expected [a, b]"))?;
    Ok((
        a[0].as_usize().ok_or_else(|| anyhow::anyhow!("{what}[0] not a number"))?,
        a[1].as_usize().ok_or_else(|| anyhow::anyhow!("{what}[1] not a number"))?,
    ))
}

fn shape_to_json(s: &[usize]) -> Json {
    Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect())
}

fn shape_from_json(v: &Json, what: &str) -> anyhow::Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what} not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("{what} dim not a number")))
        .collect()
}

fn act_from(tag: &str) -> anyhow::Result<Activation> {
    match tag {
        "none" => Ok(Activation::None),
        "relu" => Ok(Activation::Relu),
        other => anyhow::bail!("unknown activation `{other}`"),
    }
}

fn wkind_from(tag: &str) -> anyhow::Result<WeightKind> {
    Ok(match tag {
        "filter" => WeightKind::Filter,
        "bias" => WeightKind::Bias,
        "gamma" => WeightKind::Gamma,
        "beta" => WeightKind::Beta,
        "mean" => WeightKind::Mean,
        "var" => WeightKind::Var,
        other => anyhow::bail!("unknown weight kind `{other}`"),
    })
}

fn op_to_json(op: &OpKind) -> Json {
    let mut o = Json::obj();
    o.set("op", op.mnemonic());
    match op {
        OpKind::Input { shape } => {
            o.set("shape", shape_to_json(shape));
        }
        OpKind::Weight { shape, seed, kind } => {
            o.set("shape", shape_to_json(shape))
                .set("seed", *seed as f64)
                .set("kind", kind.tag());
        }
        OpKind::Conv2d { stride, pad, act, has_bias, has_residual } => {
            o.set("stride", pair_to_json(*stride))
                .set("pad", pair_to_json(*pad))
                .set("act", act.tag())
                .set("bias", *has_bias)
                .set("residual", *has_residual);
        }
        OpKind::DwConv2d { stride, pad, act, has_bias } => {
            o.set("stride", pair_to_json(*stride))
                .set("pad", pair_to_json(*pad))
                .set("act", act.tag())
                .set("bias", *has_bias);
        }
        OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
            o.set("k", pair_to_json(*k))
                .set("stride", pair_to_json(*stride))
                .set("pad", pair_to_json(*pad));
        }
        OpKind::BatchNorm { eps } | OpKind::FoldBnWeight { eps } => {
            o.set("eps_bits", *eps as f64);
        }
        OpKind::FoldBnBias { eps, has_bias } => {
            o.set("eps_bits", *eps as f64).set("bias", *has_bias);
        }
        // Epilogue attrs only when non-default: plain matmuls keep their
        // historical attribute-free JSON byte-for-byte.
        OpKind::MatMul { act, has_bias } => {
            if !matches!(act, Activation::None) || *has_bias {
                o.set("act", act.tag()).set("bias", *has_bias);
            }
        }
        OpKind::Concat { axis } => {
            o.set("axis", *axis);
        }
        OpKind::Split { axis, sizes } => {
            o.set("axis", *axis).set("sizes", shape_to_json(sizes));
        }
        OpKind::PadKernel { target } => {
            o.set("target", pair_to_json(*target));
        }
        _ => {}
    }
    o
}

fn op_from_json(v: &Json) -> anyhow::Result<OpKind> {
    let op = v.req_str("op")?;
    let pair = |key: &str| -> anyhow::Result<(usize, usize)> {
        pair_from_json(v.get(key).unwrap_or(&Json::Null), key)
    };
    let flag = |key: &str| v.get(key).and_then(Json::as_bool).unwrap_or(false);
    Ok(match op {
        "input" => OpKind::Input { shape: shape_from_json(v.get("shape").unwrap_or(&Json::Null), "shape")? },
        "weight" => OpKind::Weight {
            shape: shape_from_json(v.get("shape").unwrap_or(&Json::Null), "shape")?,
            seed: v.req_f64("seed")? as u64,
            kind: wkind_from(v.get("kind").and_then(Json::as_str).unwrap_or("filter"))?,
        },
        "conv2d" => OpKind::Conv2d {
            stride: pair("stride")?,
            pad: pair("pad")?,
            act: act_from(v.get("act").and_then(Json::as_str).unwrap_or("none"))?,
            has_bias: flag("bias"),
            has_residual: flag("residual"),
        },
        "dwconv2d" => OpKind::DwConv2d {
            stride: pair("stride")?,
            pad: pair("pad")?,
            act: act_from(v.get("act").and_then(Json::as_str).unwrap_or("none"))?,
            has_bias: flag("bias"),
        },
        "matmul" => OpKind::MatMul {
            act: act_from(v.get("act").and_then(Json::as_str).unwrap_or("none"))?,
            has_bias: flag("bias"),
        },
        "relu" => OpKind::Relu,
        "sigmoid" => OpKind::Sigmoid,
        "add" => OpKind::Add,
        "addrelu" => OpKind::AddRelu,
        "mul" => OpKind::Mul,
        "maxpool" => OpKind::MaxPool { k: pair("k")?, stride: pair("stride")?, pad: pair("pad")? },
        "avgpool" => OpKind::AvgPool { k: pair("k")?, stride: pair("stride")?, pad: pair("pad")? },
        "gavgpool" => OpKind::GlobalAvgPool,
        "batchnorm" => OpKind::BatchNorm { eps: v.req_f64("eps_bits")? as u32 },
        "concat" => OpKind::Concat {
            axis: v.get("axis").and_then(Json::as_usize).unwrap_or(1),
        },
        "split" => OpKind::Split {
            axis: v.get("axis").and_then(Json::as_usize).unwrap_or(1),
            sizes: shape_from_json(v.get("sizes").unwrap_or(&Json::Null), "sizes")?,
        },
        "flatten" => OpKind::Flatten,
        "softmax" => OpKind::Softmax,
        "foldbnw" => OpKind::FoldBnWeight { eps: v.req_f64("eps_bits")? as u32 },
        "foldbnb" => OpKind::FoldBnBias {
            eps: v.req_f64("eps_bits")? as u32,
            has_bias: flag("bias"),
        },
        "padkernel" => OpKind::PadKernel { target: pair("target")? },
        other => anyhow::bail!("unknown op `{other}`"),
    })
}

/// Serialize a graph to JSON.
pub fn graph_to_json(g: &Graph) -> Json {
    let mut root = Json::obj();
    root.set("version", 1i64);
    let nodes: Vec<Json> = g
        .nodes()
        .map(|(_, node)| {
            let mut n = op_to_json(&node.op);
            n.set("name", node.name.as_str());
            n.set(
                "inputs",
                Json::Arr(
                    node.inputs
                        .iter()
                        .map(|p| {
                            Json::Arr(vec![Json::Num(p.node.0 as f64), Json::Num(p.port as f64)])
                        })
                        .collect(),
                ),
            );
            n
        })
        .collect();
    root.set("nodes", Json::Arr(nodes));
    root.set(
        "outputs",
        Json::Arr(
            g.outputs
                .iter()
                .map(|p| Json::Arr(vec![Json::Num(p.node.0 as f64), Json::Num(p.port as f64)]))
                .collect(),
        ),
    );
    root
}

/// Deserialize + validate a graph from JSON.
pub fn graph_from_json(v: &Json) -> anyhow::Result<Graph> {
    let mut g = Graph::new();
    let nodes = v
        .get("nodes")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("graph json missing `nodes`"))?;
    for (i, n) in nodes.iter().enumerate() {
        let op = op_from_json(n).map_err(|e| anyhow::anyhow!("node {i}: {e}"))?;
        let inputs = n
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("node {i} missing `inputs`"))?
            .iter()
            .map(|p| {
                let (node, port) = pair_from_json(p, "input ref")?;
                Ok(PortRef { node: NodeId(node), port })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let name = n.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        g.add(op, inputs, &name);
    }
    let outputs = v
        .get("outputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("graph json missing `outputs`"))?;
    g.outputs = outputs
        .iter()
        .map(|p| {
            let (node, port) = pair_from_json(p, "output ref")?;
            Ok(PortRef { node: NodeId(node), port })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    g.validate().map_err(|e| anyhow::anyhow!("loaded graph invalid: {e}"))?;
    Ok(g)
}

/// Serialize an optimized plan: graph + per-node algorithm assignment +
/// (when any node runs off the nominal clock) per-node DVFS states +
/// (when any node is placed off the GPU) per-node device names. Plans
/// without a frequency axis serialize byte-identically to pre-DVFS plans,
/// and all-GPU plans byte-identically to pre-placement plans: `freq_mhz`
/// always carries the **device-local** clock (for the GPU that equals the
/// raw packed value), and the `device` key only appears for mixed plans.
/// Likewise the `layout` key only appears when some node runs NHWC, so
/// every all-NCHW plan keeps its historical bytes.
pub fn plan_to_json(g: &Graph, a: &Assignment) -> Json {
    let mut root = graph_to_json(g);
    let algos: Vec<Json> = g
        .ids()
        .map(|id| match a.get(id) {
            Some(algo) => Json::Str(algo.name().to_string()),
            None => Json::Null,
        })
        .collect();
    root.set("assignment", Json::Arr(algos));
    if g.ids().any(|id| !a.freq(id).is_nominal()) {
        let freqs: Vec<Json> = g
            .ids()
            .map(|id| Json::Num(a.freq(id).mhz() as f64))
            .collect();
        root.set("freq_mhz", Json::Arr(freqs));
    }
    if g.ids().any(|id| a.freq(id).device() != DeviceId::GPU) {
        let devices: Vec<Json> = g
            .ids()
            .map(|id| match a.get(id) {
                Some(_) => Json::Str(a.freq(id).device().name().to_string()),
                None => Json::Null,
            })
            .collect();
        root.set("device", Json::Arr(devices));
    }
    if g.ids().any(|id| a.freq(id).layout() != Layout::NCHW) {
        let layouts: Vec<Json> = g
            .ids()
            .map(|id| match a.get(id) {
                Some(_) => Json::Str(a.freq(id).layout().name().to_string()),
                None => Json::Null,
            })
            .collect();
        root.set("layout", Json::Arr(layouts));
    }
    root
}

/// Load an optimized plan (graph + assignment + optional DVFS states +
/// optional per-node device placement + optional per-node layouts).
/// Unknown device names are rejected; a `device` entry composes with the
/// node's device-local `freq_mhz` into the packed state, so a DLA node at
/// its nominal clock still lands on the DLA. A `layout` entry folds into
/// the same packed state via the layout bit.
pub fn plan_from_json(v: &Json, reg: &crate::algo::AlgorithmRegistry) -> anyhow::Result<(Graph, Assignment)> {
    let g = graph_from_json(v)?;
    let mut a = Assignment::default_for(&g, reg);
    if let Some(arr) = v.get("assignment").and_then(Json::as_arr) {
        anyhow::ensure!(arr.len() == g.len(), "assignment length != node count");
        for (i, entry) in arr.iter().enumerate() {
            if let Some(name) = entry.as_str() {
                let algo = Algorithm::from_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown algorithm `{name}`"))?;
                a.set(NodeId(i), algo);
            }
        }
    }
    let devices: Option<Vec<Option<DeviceId>>> = match v.get("device").and_then(Json::as_arr) {
        Some(arr) => {
            anyhow::ensure!(arr.len() == g.len(), "device length != node count");
            Some(
                arr.iter()
                    .enumerate()
                    .map(|(i, entry)| match entry.as_str() {
                        Some(name) => DeviceId::parse(name).map(Some).ok_or_else(|| {
                            anyhow::anyhow!(
                                "device[{i}]: unknown device `{name}` (known: {})",
                                crate::energysim::DEVICE_NAMES.join(", ")
                            )
                        }),
                        None => Ok(None),
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            )
        }
        None => None,
    };
    if let Some(arr) = v.get("freq_mhz").and_then(Json::as_arr) {
        anyhow::ensure!(arr.len() == g.len(), "freq_mhz length != node count");
        for (i, entry) in arr.iter().enumerate() {
            let mhz = entry
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("freq_mhz[{i}] not a number"))?;
            match &devices {
                Some(devs) => {
                    anyhow::ensure!(
                        mhz <= 0x0FFF,
                        "freq_mhz[{i}] out of range for a device-local clock"
                    );
                    let f = FreqId::on(devs[i].unwrap_or(DeviceId::GPU), mhz as u16);
                    if f.0 != 0 && a.get(NodeId(i)).is_some() {
                        a.set_freq(NodeId(i), f);
                    }
                }
                None => {
                    // Legacy (single-device) plans: the value IS the state.
                    anyhow::ensure!(mhz <= u16::MAX as usize, "freq_mhz[{i}] out of range");
                    if mhz > 0 && a.get(NodeId(i)).is_some() {
                        a.set_freq(NodeId(i), FreqId(mhz as u16));
                    }
                }
            }
        }
    } else if let Some(devs) = &devices {
        // All clocks nominal, but placement may still be mixed: a non-GPU
        // node must get its packed device state even at local mhz 0.
        for (i, dev) in devs.iter().enumerate() {
            if let Some(dev) = dev {
                if *dev != DeviceId::GPU && a.get(NodeId(i)).is_some() {
                    a.set_freq(NodeId(i), FreqId::on(*dev, 0));
                }
            }
        }
    }
    if let Some(arr) = v.get("layout").and_then(Json::as_arr) {
        anyhow::ensure!(arr.len() == g.len(), "layout length != node count");
        for (i, entry) in arr.iter().enumerate() {
            if let Some(name) = entry.as_str() {
                let lay = Layout::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "layout[{i}]: unknown layout `{name}` (known: {})",
                        crate::energysim::LAYOUT_NAMES.join(", ")
                    )
                })?;
                if lay != Layout::NCHW && a.get(NodeId(i)).is_some() {
                    let f = a.freq(NodeId(i));
                    a.set_freq(NodeId(i), f.with_layout(lay));
                }
            }
        }
    }
    Ok((g, a))
}

/// Serialize + write a plan file (see [`plan_to_json`]).
pub fn save_plan(path: &Path, g: &Graph, a: &Assignment) -> anyhow::Result<()> {
    json::write_file(path, &plan_to_json(g, a))
}

/// Read + parse a plan file (see [`plan_from_json`]).
pub fn load_plan(path: &Path, reg: &crate::algo::AlgorithmRegistry) -> anyhow::Result<(Graph, Assignment)> {
    plan_from_json(&json::read_file(path)?, reg)
}

/// Serialize + write a bare graph file.
pub fn save_graph(path: &Path, g: &Graph) -> anyhow::Result<()> {
    json::write_file(path, &graph_to_json(g))
}

/// Read + parse a bare graph file.
pub fn load_graph(path: &Path) -> anyhow::Result<Graph> {
    graph_from_json(&json::read_file(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::AlgorithmRegistry;
    use crate::graph::canonical::graph_hash;
    use crate::models::{self, ModelConfig};

    fn tiny() -> ModelConfig {
        ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 }
    }

    #[test]
    fn all_zoo_models_roundtrip() {
        for name in models::zoo_names() {
            let g = models::by_name(name, tiny()).unwrap();
            let j = graph_to_json(&g);
            let back = graph_from_json(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(graph_hash(&g), graph_hash(&back), "{name} hash changed");
            assert_eq!(g.len(), back.len());
        }
    }

    #[test]
    fn plan_roundtrip_preserves_assignment() {
        let g = models::simple::build_cnn(tiny());
        let reg = AlgorithmRegistry::new();
        let mut a = Assignment::default_for(&g, &reg);
        // flip one conv to a non-default algorithm
        let conv = g
            .nodes()
            .find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. }))
            .unwrap()
            .0;
        a.set(conv, Algorithm::ConvDirect);
        let j = plan_to_json(&g, &a);
        let (back_g, back_a) = plan_from_json(&j, &reg).unwrap();
        assert_eq!(graph_hash(&g), graph_hash(&back_g));
        assert_eq!(back_a.get(conv), Some(Algorithm::ConvDirect));
        assert_eq!(a.distance(&back_a), 0);
    }

    #[test]
    fn dvfs_plan_roundtrips_and_off_plans_stay_pre_dvfs() {
        use crate::energysim::FreqId;
        let g = models::simple::build_cnn(tiny());
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        // All-nominal plan: no frequency key — byte-identical to a plan
        // written before the DVFS axis existed.
        let j = plan_to_json(&g, &a);
        assert!(j.get("freq_mhz").is_none());

        // Mixed per-node plan roundtrips exactly.
        let mut a2 = a.clone();
        let conv = g
            .nodes()
            .find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. }))
            .unwrap()
            .0;
        a2.set_freq(conv, FreqId(900));
        let j2 = plan_to_json(&g, &a2);
        assert!(j2.get("freq_mhz").is_some());
        let (back_g, back_a) = plan_from_json(&j2, &reg).unwrap();
        assert_eq!(graph_hash(&g), graph_hash(&back_g));
        assert_eq!(back_a.freq(conv), FreqId(900));
        assert_eq!(a2.distance(&back_a), 0);
    }

    #[test]
    fn device_plans_roundtrip_and_gpu_plans_stay_legacy() {
        use crate::energysim::{DeviceId, FreqId};
        let g = models::simple::build_cnn(tiny());
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let conv = g
            .nodes()
            .find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. }))
            .unwrap()
            .0;

        // All-GPU plan: no `device` key, and a sub-nominal GPU clock
        // serializes as the same number it always did (device-local ==
        // packed for device 0).
        let mut gpu = a.clone();
        gpu.set_freq(conv, FreqId(900));
        let j = plan_to_json(&g, &gpu);
        assert!(j.get("device").is_none());
        let freqs = j.get("freq_mhz").unwrap().as_arr().unwrap();
        assert_eq!(freqs[conv.0].as_usize(), Some(900));

        // DLA at its nominal clock: `device` key, NO `freq_mhz` key (the
        // clock is nominal), and the loader still lands the node on the
        // DLA's packed state.
        let mut dla = a.clone();
        dla.set_freq(conv, FreqId::on(DeviceId::DLA, 0));
        let j2 = plan_to_json(&g, &dla);
        assert!(j2.get("freq_mhz").is_none());
        let devs = j2.get("device").unwrap().as_arr().unwrap();
        assert_eq!(devs[conv.0].as_str(), Some("dla"));
        let (back_g, back_a) = plan_from_json(&j2, &reg).unwrap();
        assert_eq!(graph_hash(&g), graph_hash(&back_g));
        assert_eq!(back_a.freq(conv), FreqId::on(DeviceId::DLA, 0));
        assert_eq!(dla.distance(&back_a), 0);

        // DLA at a sub-nominal clock: freq_mhz carries the device-local
        // 640, not the packed 4736, and the pair round-trips exactly.
        let mut dla_slow = a.clone();
        dla_slow.set_freq(conv, FreqId::on(DeviceId::DLA, 640));
        let j3 = plan_to_json(&g, &dla_slow);
        let freqs3 = j3.get("freq_mhz").unwrap().as_arr().unwrap();
        assert_eq!(freqs3[conv.0].as_usize(), Some(640));
        let (_, back3) = plan_from_json(&j3, &reg).unwrap();
        assert_eq!(back3.freq(conv), FreqId::on(DeviceId::DLA, 640));

        // Unknown device names are rejected with the known list.
        let mut bad = j2.clone();
        bad.set("device", Json::Arr(vec![Json::Str("tpu".to_string()); g.len()]));
        let err = plan_from_json(&bad, &reg).unwrap_err().to_string();
        assert!(err.contains("unknown device `tpu`"), "{err}");
        assert!(err.contains("gpu, dla"), "{err}");
    }

    #[test]
    fn layout_plans_roundtrip_and_nchw_plans_stay_legacy() {
        use crate::energysim::{DeviceId, FreqId, Layout};
        let g = models::simple::build_cnn(tiny());
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let conv = g
            .nodes()
            .find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. }))
            .unwrap()
            .0;

        // All-NCHW plan: no `layout` key — byte-identical to a plan
        // written before the layout axis existed.
        let j = plan_to_json(&g, &a);
        assert!(j.get("layout").is_none());

        // NHWC at the nominal clock: layout key appears, freq_mhz does
        // not (the clock IS nominal — the layout bit is not a clock).
        let mut mixed = a.clone();
        mixed.set_freq(conv, FreqId::NOMINAL.with_layout(Layout::NHWC));
        let j2 = plan_to_json(&g, &mixed);
        assert!(j2.get("freq_mhz").is_none());
        assert!(j2.get("device").is_none());
        let lays = j2.get("layout").unwrap().as_arr().unwrap();
        assert_eq!(lays[conv.0].as_str(), Some("nhwc"));
        let (back_g, back_a) = plan_from_json(&j2, &reg).unwrap();
        assert_eq!(graph_hash(&g), graph_hash(&back_g));
        assert_eq!(back_a.freq(conv).layout(), Layout::NHWC);
        assert_eq!(mixed.distance(&back_a), 0);

        // Layout composes with device + clock: a DLA node at 640 MHz in
        // NHWC round-trips to the same packed state.
        let mut full = a.clone();
        full.set_freq(conv, FreqId::on(DeviceId::DLA, 640).with_layout(Layout::NHWC));
        let j3 = plan_to_json(&g, &full);
        let freqs3 = j3.get("freq_mhz").unwrap().as_arr().unwrap();
        assert_eq!(freqs3[conv.0].as_usize(), Some(640));
        let (_, back3) = plan_from_json(&j3, &reg).unwrap();
        assert_eq!(back3.freq(conv), FreqId::on(DeviceId::DLA, 640).with_layout(Layout::NHWC));
        assert_eq!(full.distance(&back3), 0);

        // Unknown layout names are rejected with the known list.
        let mut bad = j2.clone();
        bad.set("layout", Json::Arr(vec![Json::Str("nhcw".to_string()); g.len()]));
        let err = plan_from_json(&bad, &reg).unwrap_err().to_string();
        assert!(err.contains("unknown layout `nhcw`"), "{err}");
        assert!(err.contains("nchw, nhwc"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eadgo_serde_test");
        let path = dir.join("plan.json");
        let g = models::simple::build_cnn(tiny());
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        save_plan(&path, &g, &a).unwrap();
        let (back, _) = load_plan(&path, &reg).unwrap();
        assert_eq!(graph_hash(&g), graph_hash(&back));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn invalid_graphs_rejected() {
        // missing nodes
        assert!(graph_from_json(&crate::util::json::parse("{}").unwrap()).is_err());
        // bad op
        let bad = crate::util::json::parse(
            r#"{"nodes": [{"op": "warp_drive", "inputs": []}], "outputs": [[0, 0]]}"#,
        )
        .unwrap();
        assert!(graph_from_json(&bad).is_err());
        // inconsistent shapes (conv without weight)
        let bad2 = crate::util::json::parse(
            r#"{"nodes": [
                 {"op": "input", "shape": [1, 3, 8, 8], "inputs": []},
                 {"op": "relu", "inputs": [[0, 0], [0, 0]]}
               ],
               "outputs": [[1, 0]]}"#,
        )
        .unwrap();
        assert!(graph_from_json(&bad2).is_err());
    }

    #[test]
    fn semantics_preserved_through_roundtrip() {
        use crate::engine::ReferenceEngine;
        use crate::tensor::Tensor;
        use crate::util::rng::Rng;
        let g = models::squeezenet::build(tiny());
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let back = graph_from_json(&graph_to_json(&g)).unwrap();
        let ab = Assignment::default_for(&back, &reg);
        let mut rng = Rng::seed_from(8);
        let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
        let eng = ReferenceEngine::new();
        let y1 = eng.run(&g, &a, std::slice::from_ref(&x)).unwrap().outputs.remove(0);
        let y2 = eng.run(&back, &ab, std::slice::from_ref(&x)).unwrap().outputs.remove(0);
        assert_eq!(y1, y2);
    }
}
