//! Canonical graph hashing for search-state deduplication.
//!
//! The outer search (paper Algorithm 1) enqueues every substitution product;
//! without dedup the same graph is reachable along many substitution paths
//! and the queue blows up. We hash each node from its operator signature and
//! the hashes of its inputs (a Merkle hash over the DAG), then combine the
//! output-port hashes. Isomorphic graphs — same computation, different node
//! numbering — collide (by design); distinct computations collide only with
//! ~2^-64 probability.

use super::{Graph, OpKind};

/// FNV-1a 64-bit, good enough and dependency-free.
#[derive(Clone, Copy)]
pub struct Fnv(pub u64);

impl Default for Fnv {
    fn default() -> Self {
        Fnv(0xcbf29ce484222325)
    }
}

impl Fnv {
    /// Absorb raw bytes.
    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    /// Absorb a `u64` (little-endian).
    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Absorb a `usize` (as `u64`).
    #[inline]
    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// The accumulated hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

fn op_structural_tag(op: &OpKind, h: &mut Fnv) {
    h.write(op.mnemonic().as_bytes());
    match op {
        OpKind::Input { shape } | OpKind::Weight { shape, .. } => {
            if let OpKind::Weight { seed, kind, .. } = op {
                h.write_u64(*seed);
                h.write(kind.tag().as_bytes());
            }
            for d in shape {
                h.write_usize(*d);
            }
        }
        OpKind::Conv2d { stride, pad, act, has_bias, has_residual } => {
            h.write_usize(stride.0);
            h.write_usize(stride.1);
            h.write_usize(pad.0);
            h.write_usize(pad.1);
            h.write(act.tag().as_bytes());
            h.write(&[*has_bias as u8, *has_residual as u8]);
        }
        OpKind::DwConv2d { stride, pad, act, has_bias } => {
            h.write_usize(stride.0);
            h.write_usize(stride.1);
            h.write_usize(pad.0);
            h.write_usize(pad.1);
            h.write(act.tag().as_bytes());
            h.write(&[*has_bias as u8]);
        }
        OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
            for v in [k.0, k.1, stride.0, stride.1, pad.0, pad.1] {
                h.write_usize(v);
            }
        }
        OpKind::BatchNorm { eps } | OpKind::FoldBnWeight { eps } => {
            h.write_u64(*eps as u64);
        }
        OpKind::FoldBnBias { eps, has_bias } => {
            h.write_u64(*eps as u64);
            h.write(&[*has_bias as u8]);
        }
        // Default epilogue attrs hash nothing extra, so every pre-fusion
        // matmul keeps its historical hash (and manifests keyed on it).
        OpKind::MatMul { act, has_bias } => {
            if !matches!(act, super::Activation::None) || *has_bias {
                h.write(act.tag().as_bytes());
                h.write(&[*has_bias as u8]);
            }
        }
        OpKind::Concat { axis } => h.write_usize(*axis),
        OpKind::Split { axis, sizes } => {
            h.write_usize(*axis);
            for s in sizes {
                h.write_usize(*s);
            }
        }
        OpKind::PadKernel { target } => {
            h.write_usize(target.0);
            h.write_usize(target.1);
        }
        _ => {}
    }
}

/// Per-node Merkle hashes of a graph's computation (each node hashed from
/// its operator tag, input hashes, and input ports). `None` when the graph
/// is cyclic. The outer search caches these per expanded graph so every
/// candidate delta can rehash only its changed cone ([`delta_hash`]).
pub fn node_hashes(g: &Graph) -> Option<Vec<u64>> {
    let order = g.topo_order().ok()?;
    let mut node_hash = vec![0u64; g.len()];
    for id in order {
        let node = g.node(id);
        let mut h = Fnv::default();
        op_structural_tag(&node.op, &mut h);
        for inp in &node.inputs {
            h.write_u64(node_hash[inp.node.0]);
            h.write_usize(inp.port);
        }
        node_hash[id.0] = h.finish();
    }
    Some(node_hash)
}

/// Merkle-style canonical hash of the graph's computation.
pub fn graph_hash(g: &Graph) -> u64 {
    // invalid graphs all hash to 0; callers validate separately
    let Some(node_hash) = node_hashes(g) else { return 0 };
    let mut h = Fnv::default();
    h.write(b"outputs");
    for out in &g.outputs {
        h.write_u64(node_hash[out.node.0]);
        h.write_usize(out.port);
    }
    h.finish()
}

/// Canonical hash of a candidate `base + delta` **without materializing
/// it**: nodes outside the delta's changed cone reuse `base_hashes` (the
/// base graph's [`node_hashes`]); only structurally changed nodes and
/// their transitive consumers rehash. Because the hash is a Merkle hash
/// over the DAG and dead nodes never feed the outputs, the result is
/// bit-identical to `graph_hash` of the materialized, compacted product
/// (property-tested in `rust/tests/delta_engine.rs`).
pub fn delta_hash(view: &crate::graph::DeltaView<'_>, base_hashes: &[u64]) -> u64 {
    let m = view.node_count();
    let mut hash = vec![0u64; m];
    let mut dirty = vec![false; m];
    for &i in view.topo_order() {
        let needs =
            view.is_changed(i) || view.inputs(i).iter().any(|p| dirty[p.node.0]);
        if !needs {
            continue;
        }
        let mut h = Fnv::default();
        op_structural_tag(view.op(i), &mut h);
        for p in view.inputs(i) {
            let ph = if dirty[p.node.0] { hash[p.node.0] } else { base_hashes[p.node.0] };
            h.write_u64(ph);
            h.write_usize(p.port);
        }
        hash[i] = h.finish();
        dirty[i] = true;
    }
    let mut h = Fnv::default();
    h.write(b"outputs");
    for p in view.outputs() {
        let ph = if dirty[p.node.0] { hash[p.node.0] } else { base_hashes[p.node.0] };
        h.write_u64(ph);
        h.write_usize(p.port);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Graph, OpKind, PortRef};

    fn conv_graph(order_swapped: bool) -> Graph {
        let mut g = Graph::new();
        // Build with two different node insertion orders but identical structure.
        if !order_swapped {
            let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
            let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 7), &[], "w");
            let c = g.add1(conv_op(), &[x, w], "c");
            g.outputs = vec![PortRef::of(c)];
        } else {
            let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 7), &[], "w");
            let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
            let c = g.add1(conv_op(), &[x, w], "c");
            g.outputs = vec![PortRef::of(c)];
        }
        g
    }

    fn conv_op() -> OpKind {
        OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        }
    }

    #[test]
    fn isomorphic_graphs_collide() {
        assert_eq!(graph_hash(&conv_graph(false)), graph_hash(&conv_graph(true)));
    }

    #[test]
    fn different_attrs_differ() {
        let g1 = conv_graph(false);
        let mut g2 = conv_graph(false);
        if let OpKind::Conv2d { act, .. } = &mut g2.node_mut(crate::graph::NodeId(2)).op {
            *act = Activation::Relu;
        }
        assert_ne!(graph_hash(&g1), graph_hash(&g2));
    }

    #[test]
    fn different_weights_differ() {
        let g1 = conv_graph(false);
        let mut g2 = conv_graph(false);
        if let OpKind::Weight { seed, .. } = &mut g2.node_mut(crate::graph::NodeId(1)).op {
            *seed = 8;
        }
        assert_ne!(graph_hash(&g1), graph_hash(&g2));
    }

    #[test]
    fn delta_hash_matches_full_rehash() {
        use crate::graph::{DeltaBuilder, DeltaView, NodeId};
        let g = conv_graph(false);
        let shapes = g.infer_shapes().unwrap();
        let base_hashes = node_hashes(&g).unwrap();
        // Fuse an activation into the conv and retarget the output.
        let mut b = DeltaBuilder::new(&g);
        if let OpKind::Conv2d { stride, pad, has_bias, has_residual, .. } =
            g.node(NodeId(2)).op
        {
            b.replace_op(
                NodeId(2),
                OpKind::Conv2d { stride, pad, act: Activation::Relu, has_bias, has_residual },
            );
        }
        let d = b.finish();
        let view = DeltaView::new(&g, &shapes, d.clone(), None).unwrap();
        let mut full = g.apply_delta(&d);
        full.compact();
        assert_eq!(delta_hash(&view, &base_hashes), graph_hash(&full));
        assert_ne!(delta_hash(&view, &base_hashes), graph_hash(&g));
    }

    #[test]
    fn names_do_not_affect_hash() {
        let g1 = conv_graph(false);
        let mut g2 = conv_graph(false);
        g2.node_mut(crate::graph::NodeId(2)).name = "renamed".into();
        assert_eq!(graph_hash(&g1), graph_hash(&g2));
    }
}
