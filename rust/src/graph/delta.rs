//! Graph **deltas**: the difference between a graph and one substitution
//! product, plus the incremental machinery the search layers use to
//! evaluate a candidate without materializing it.
//!
//! A substitution rule no longer clones the whole graph. Matching yields a
//! [`crate::subst::RewriteSite`]; the site expands into a [`GraphDelta`] —
//! the exact edit script the legacy rule code used to perform on a clone:
//! in-place operator replacements, appended nodes, and port redirections,
//! replayed in that fixed order. [`Graph::apply_delta`] materializes the
//! product (bit-identical to the historical clone-and-rewrite path);
//! [`DeltaView`] exposes the product *virtually* — node ops, rewired
//! inputs, liveness, compaction order, and **incrementally inferred
//! shapes** — so the cost and hashing layers can price and dedup a
//! candidate while touching only the nodes the delta actually changed.

use super::{Graph, Node, NodeId, OpKind, PortRef, TensorShape};
use std::collections::BTreeMap;

/// Post-redirect inputs of candidate node `i` (shared by the builder pass
/// and the accessors so the two can never disagree).
fn view_inputs<'a>(
    i: usize,
    n_base: usize,
    remapped: &'a [Option<Vec<PortRef>>],
    delta: &'a GraphDelta,
    base: &'a Graph,
) -> &'a [PortRef] {
    if let Some(v) = &remapped[i] {
        v
    } else if i >= n_base {
        &delta.add_nodes[i - n_base].inputs
    } else {
        &base.node(NodeId(i)).inputs
    }
}

/// The candidate's operator at node `i` (last replacement wins, matching
/// sequential replay order).
fn view_op<'a>(i: usize, n_base: usize, delta: &'a GraphDelta, base: &'a Graph) -> &'a OpKind {
    if i >= n_base {
        return &delta.add_nodes[i - n_base].op;
    }
    if let Some((_, op)) = delta.replace_ops.iter().rev().find(|(id, _)| id.0 == i) {
        return op;
    }
    &base.node(NodeId(i)).op
}

/// Output shapes of candidate node `i` given the sparse recompute table.
fn view_shapes<'a>(
    i: usize,
    shapes: &'a [Option<Vec<TensorShape>>],
    base_shapes: &'a [Vec<TensorShape>],
) -> &'a [TensorShape] {
    match &shapes[i] {
        Some(v) => v,
        None => &base_shapes[i],
    }
}

/// The edit script turning a base graph into one substitution product.
///
/// Applied in three fixed phases (replacements, additions, redirections),
/// which is exactly the order every rule historically edited its clone in,
/// so `base.apply_delta(&delta)` reproduces the legacy product verbatim —
/// node order, names, and all.
#[derive(Debug, Clone, Default)]
pub struct GraphDelta {
    /// In-place operator replacements on base nodes, applied first.
    pub replace_ops: Vec<(NodeId, OpKind)>,
    /// Nodes appended after the base graph's nodes, in order. Inputs may
    /// reference base nodes or previously added nodes.
    pub add_nodes: Vec<Node>,
    /// Port redirections `(from, to)` applied last, in order, to every
    /// node input (including added nodes) and to the graph outputs.
    pub redirects: Vec<(PortRef, PortRef)>,
}

impl GraphDelta {
    /// Whether the delta performs no edits at all.
    pub fn is_empty(&self) -> bool {
        self.replace_ops.is_empty() && self.add_nodes.is_empty() && self.redirects.is_empty()
    }

    /// Map one port through the redirection chain, in order — the
    /// pure-function equivalent of replaying [`Graph::redirect`] calls.
    pub fn map_port(&self, mut p: PortRef) -> PortRef {
        for (from, to) in &self.redirects {
            if p == *from {
                p = *to;
            }
        }
        p
    }
}

/// Incremental [`GraphDelta`] construction with the same call shape the
/// rules used against a cloned graph (`replace_op`/`add`/`redirect`).
pub struct DeltaBuilder {
    next: usize,
    delta: GraphDelta,
}

impl DeltaBuilder {
    /// Start a delta over `base` (new node ids continue after its last).
    pub fn new(base: &Graph) -> DeltaBuilder {
        DeltaBuilder { next: base.len(), delta: GraphDelta::default() }
    }

    /// Replace the operator of an existing base node.
    pub fn replace_op(&mut self, id: NodeId, op: OpKind) {
        self.delta.replace_ops.push((id, op));
    }

    /// Append a node, returning the id it will hold in the product.
    pub fn add(&mut self, op: OpKind, inputs: Vec<PortRef>, name: &str) -> NodeId {
        let id = NodeId(self.next);
        self.next += 1;
        self.delta.add_nodes.push(Node { op, inputs, name: name.to_string() });
        id
    }

    /// Rewire every consumer of `from` (and graph outputs) to read `to`.
    pub fn redirect(&mut self, from: PortRef, to: PortRef) {
        self.delta.redirects.push((from, to));
    }

    /// Finish, yielding the delta.
    pub fn finish(self) -> GraphDelta {
        self.delta
    }
}

impl Graph {
    /// Materialize a delta into a full product graph: clone, replay the
    /// three edit phases. The caller compacts (mirroring the historical
    /// `RuleSet::neighbors` flow). Bit-identical to the legacy
    /// clone-and-rewrite rule implementations.
    pub fn apply_delta(&self, d: &GraphDelta) -> Graph {
        let mut g = self.clone();
        for (id, op) in &d.replace_ops {
            g.node_mut(*id).op = op.clone();
        }
        for n in &d.add_nodes {
            g.add(n.op.clone(), n.inputs.clone(), &n.name);
        }
        for (from, to) in &d.redirects {
            g.redirect(*from, *to);
        }
        g
    }
}

/// A virtual view of `base + delta`: the candidate graph as the search
/// sees it, without materializing nodes.
///
/// Construction performs **incremental shape inference**: only nodes whose
/// operator, inputs, or upstream shapes changed are re-inferred (and
/// validated); every other node borrows the base graph's shapes. The view
/// also computes the candidate's live set and compaction order — identical
/// to what [`Graph::compact`] would produce on the materialized product —
/// so per-node results (cost tables, assignments) are indexed exactly like
/// the compacted graph the winner eventually materializes into.
pub struct DeltaView<'g> {
    base: &'g Graph,
    base_shapes: &'g [Vec<TensorShape>],
    delta: GraphDelta,
    n_base: usize,
    /// Post-redirect inputs for nodes whose inputs changed; `None` = the
    /// node's raw inputs are unchanged.
    remapped: Vec<Option<Vec<PortRef>>>,
    /// Candidate outputs (base outputs mapped through the redirects).
    outputs: Vec<PortRef>,
    /// Live (reachable-from-outputs) flags per candidate node.
    live: Vec<bool>,
    /// Live node indices ascending — the candidate's compaction order:
    /// `order[j]` is the view index of compacted node `j`.
    order: Vec<usize>,
    /// Old index -> compacted id (only meaningful for live nodes).
    compact_ids: Vec<usize>,
    /// Live node indices in topological order (producers first).
    topo: Vec<usize>,
    /// Structural change per node: op replaced, node added, or inputs
    /// rewired. Seeds both re-costing and incremental rehashing.
    changed: Vec<bool>,
    /// Whether the node's cost signature must be re-resolved (structural
    /// change or an input shape differing from the base).
    sig_dirty: Vec<bool>,
    /// Recomputed output shapes for dirty nodes; `None` = base shapes.
    shapes: Vec<Option<Vec<TensorShape>>>,
}

impl<'g> DeltaView<'g> {
    /// Build the view. `base_shapes` is the base graph's full shape table
    /// (one inference per parent, shared across all its candidate sites);
    /// `consumers` is the base graph's consumer map, likewise shared.
    /// Errors indicate an invalid delta (bad references, cycles, or shape
    /// inference failures on the touched nodes).
    pub fn new(
        base: &'g Graph,
        base_shapes: &'g [Vec<TensorShape>],
        delta: GraphDelta,
        consumers: Option<&BTreeMap<PortRef, Vec<NodeId>>>,
    ) -> anyhow::Result<DeltaView<'g>> {
        let n = base.len();
        let m = n + delta.add_nodes.len();
        anyhow::ensure!(base_shapes.len() == n, "base shape table does not match the base graph");
        for (id, _) in &delta.replace_ops {
            anyhow::ensure!(id.0 < n, "delta replaces missing node {}", id.0);
        }
        for (k, node) in delta.add_nodes.iter().enumerate() {
            for p in &node.inputs {
                anyhow::ensure!(
                    p.node.0 < n + k,
                    "added node {k} reads node {} before it exists",
                    p.node.0
                );
            }
        }
        for (from, to) in &delta.redirects {
            anyhow::ensure!(
                from.node.0 < m && to.node.0 < m,
                "delta redirect references a missing node"
            );
        }

        // Which nodes see different inputs after the redirects? Base nodes
        // come from the (shared) consumer map of each redirect source;
        // added nodes are few enough to check directly.
        let mut remapped: Vec<Option<Vec<PortRef>>> = vec![None; m];
        if !delta.redirects.is_empty() {
            let owned;
            let consumers = match consumers {
                Some(c) => c,
                None => {
                    owned = base.consumers();
                    &owned
                }
            };
            let mut affected: Vec<usize> = Vec::new();
            for (from, _) in &delta.redirects {
                if let Some(v) = consumers.get(from) {
                    affected.extend(v.iter().map(|id| id.0));
                }
            }
            for (k, node) in delta.add_nodes.iter().enumerate() {
                if node.inputs.iter().any(|p| delta.redirects.iter().any(|(f, _)| p == f)) {
                    affected.push(n + k);
                }
            }
            affected.sort_unstable();
            affected.dedup();
            for i in affected {
                let raw: &[PortRef] =
                    if i >= n { &delta.add_nodes[i - n].inputs } else { &base.node(NodeId(i)).inputs };
                let mapped: Vec<PortRef> = raw.iter().map(|&p| delta.map_port(p)).collect();
                if mapped != raw {
                    remapped[i] = Some(mapped);
                }
            }
        }
        let outputs: Vec<PortRef> = base.outputs.iter().map(|&p| delta.map_port(p)).collect();

        // Liveness: reachable backwards from the candidate outputs.
        let mut live = vec![false; m];
        let mut stack: Vec<usize> = outputs.iter().map(|p| p.node.0).collect();
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            for p in view_inputs(i, n, &remapped, &delta, base) {
                stack.push(p.node.0);
            }
        }
        let order: Vec<usize> = (0..m).filter(|&i| live[i]).collect();
        let mut compact_ids = vec![usize::MAX; m];
        for (j, &i) in order.iter().enumerate() {
            compact_ids[i] = j;
        }

        // Deterministic topological order over the live subgraph (same
        // lowest-id-first discipline as `Graph::topo_order`).
        let mut indegree = vec![0usize; m];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); m];
        for &i in &order {
            for p in view_inputs(i, n, &remapped, &delta, base) {
                indegree[i] += 1;
                adj[p.node.0].push(i);
            }
        }
        let mut queue: Vec<usize> = order.iter().copied().filter(|&i| indegree[i] == 0).collect();
        queue.sort_unstable_by(|a, b| b.cmp(a));
        let mut topo = Vec::with_capacity(order.len());
        while let Some(i) = queue.pop() {
            topo.push(i);
            for &c in &adj[i] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    let pos = queue.binary_search_by(|x| c.cmp(x)).unwrap_or_else(|p| p);
                    queue.insert(pos, c);
                }
            }
        }
        anyhow::ensure!(topo.len() == order.len(), "delta product contains a cycle");

        // Structural change seeds.
        let mut changed = vec![false; m];
        for (id, _) in &delta.replace_ops {
            changed[id.0] = true;
        }
        for c in changed.iter_mut().skip(n) {
            *c = true; // added nodes
        }
        for (c, r) in changed.iter_mut().zip(&remapped) {
            *c |= r.is_some();
        }

        // Incremental shape inference over the live subgraph: recompute a
        // node iff it changed structurally or an input shape moved; stop
        // propagating as soon as recomputed shapes match the base again
        // (for semantics-preserving rules that is immediately).
        let mut shapes: Vec<Option<Vec<TensorShape>>> = vec![None; m];
        let mut sig_dirty = vec![false; m];
        let mut out_changed = vec![false; m];
        for &i in &topo {
            let mut recompute = changed[i];
            if !recompute {
                // Unchanged node: only re-infer when a producer's shape at
                // the consumed port actually differs from the base.
                for p in view_inputs(i, n, &remapped, &delta, base) {
                    if !out_changed[p.node.0] {
                        continue;
                    }
                    let now = view_shapes(p.node.0, &shapes, base_shapes).get(p.port);
                    let before = base_shapes[p.node.0].get(p.port);
                    if now != before {
                        recompute = true;
                        break;
                    }
                }
            }
            if !recompute {
                continue;
            }
            let ports = view_inputs(i, n, &remapped, &delta, base);
            let mut in_shapes: Vec<TensorShape> = Vec::with_capacity(ports.len());
            for p in ports {
                let s = view_shapes(p.node.0, &shapes, base_shapes).get(p.port).cloned();
                let s = s.ok_or_else(|| {
                    anyhow::anyhow!(
                        "delta node {i} reads invalid port {} of node {}",
                        p.port,
                        p.node.0
                    )
                })?;
                in_shapes.push(s);
            }
            let outs = view_op(i, n, &delta, base)
                .infer_shapes(&in_shapes)
                .map_err(|e| anyhow::anyhow!("delta node {i}: {e}"))?;
            sig_dirty[i] = true;
            out_changed[i] = i >= n || outs != base_shapes[i];
            shapes[i] = Some(outs);
        }
        // Output ports must exist on their (possibly reshaped) producers.
        for p in &outputs {
            anyhow::ensure!(
                p.port < view_shapes(p.node.0, &shapes, base_shapes).len(),
                "delta output references invalid port {} of node {}",
                p.port,
                p.node.0
            );
        }

        Ok(DeltaView {
            base,
            base_shapes,
            delta,
            n_base: n,
            remapped,
            outputs,
            live,
            order,
            compact_ids,
            topo,
            changed,
            sig_dirty,
            shapes,
        })
    }

    /// The base graph the delta applies to.
    pub fn base(&self) -> &Graph {
        self.base
    }

    /// The delta itself (for materialization via [`Graph::apply_delta`]).
    pub fn delta(&self) -> &GraphDelta {
        &self.delta
    }

    /// Total candidate node count (base nodes + added, including dead).
    pub fn node_count(&self) -> usize {
        self.n_base + self.delta.add_nodes.len()
    }

    /// Number of live nodes — the materialized product's `len()` after
    /// compaction.
    pub fn live_count(&self) -> usize {
        self.order.len()
    }

    /// Whether candidate node `i` survives compaction.
    pub fn is_live(&self, i: usize) -> bool {
        self.live[i]
    }

    /// Live view indices ascending — index `j` holds the view index of
    /// compacted node `j` (the same renumbering [`Graph::compact`] does).
    pub fn compact_order(&self) -> &[usize] {
        &self.order
    }

    /// The compacted id a live view index maps to.
    pub fn compact_id(&self, i: usize) -> Option<NodeId> {
        self.live[i].then(|| NodeId(self.compact_ids[i]))
    }

    /// Live view indices in topological order (producers first).
    pub fn topo_order(&self) -> &[usize] {
        &self.topo
    }

    /// The candidate's operator at view index `i`.
    pub fn op(&self, i: usize) -> &OpKind {
        view_op(i, self.n_base, &self.delta, self.base)
    }

    /// The candidate's (post-redirect) inputs at view index `i`.
    pub fn inputs(&self, i: usize) -> &[PortRef] {
        view_inputs(i, self.n_base, &self.remapped, &self.delta, self.base)
    }

    /// The candidate's outputs (base outputs mapped through redirects).
    pub fn outputs(&self) -> &[PortRef] {
        &self.outputs
    }

    /// Whether node `i` changed structurally (op replaced, added, or
    /// inputs rewired) — the seed set for incremental rehash/recost.
    pub fn is_changed(&self, i: usize) -> bool {
        self.changed[i]
    }

    /// Whether node `i`'s cost signature must be re-resolved (structural
    /// change or input shapes moved). Everything else carries its cost
    /// rows over from the base table untouched.
    pub fn is_sig_dirty(&self, i: usize) -> bool {
        self.sig_dirty[i]
    }

    /// The delta's **dirty cone** as the cost layer sees it: live view
    /// indices (ascending — compaction order) whose cost signature must
    /// re-resolve. Everything outside this set carries its rows — and,
    /// downstream, its converged per-row argmin — over from the base
    /// unchanged, which is what lets the incremental inner search
    /// re-optimize only these nodes.
    pub fn sig_dirty_live(&self) -> impl Iterator<Item = usize> + '_ {
        self.order.iter().copied().filter(|&i| self.sig_dirty[i])
    }

    /// Output shapes of node `i` (recomputed when dirty, borrowed from
    /// the base otherwise).
    pub fn out_shapes(&self, i: usize) -> &[TensorShape] {
        view_shapes(i, &self.shapes, self.base_shapes)
    }

    /// Input shapes of node `i`, cloned (ports validated at build time).
    pub fn in_shapes(&self, i: usize) -> Vec<TensorShape> {
        self.inputs(i).iter().map(|p| self.out_shapes(p.node.0)[p.port].clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Activation;

    fn conv_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
                has_residual: false,
            },
            &[x, w],
            "conv",
        );
        let r = g.add1(OpKind::Relu, &[c], "relu");
        g.outputs = vec![PortRef::of(r)];
        g
    }

    #[test]
    fn apply_delta_replays_edits_in_order() {
        let g = conv_graph();
        let mut b = DeltaBuilder::new(&g);
        b.replace_op(
            NodeId(2),
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
        );
        b.redirect(PortRef::of(NodeId(3)), PortRef::of(NodeId(2)));
        let d = b.finish();
        let mut ng = g.apply_delta(&d);
        ng.compact();
        ng.validate().unwrap();
        assert_eq!(ng.len(), 3); // relu fused away
        assert_eq!(ng.outputs, vec![PortRef::of(NodeId(2))]);
    }

    #[test]
    fn view_tracks_liveness_and_dirty_set() {
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let mut b = DeltaBuilder::new(&g);
        b.replace_op(
            NodeId(2),
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
        );
        b.redirect(PortRef::of(NodeId(3)), PortRef::of(NodeId(2)));
        let view = DeltaView::new(&g, &shapes, b.finish(), None).unwrap();
        assert_eq!(view.node_count(), 4);
        assert_eq!(view.live_count(), 3); // relu dead
        assert!(!view.is_live(3));
        assert!(view.is_sig_dirty(2)); // conv op changed
        assert!(!view.is_sig_dirty(0));
        assert!(!view.is_sig_dirty(1));
        // The dirty cone is exactly the live sig-dirty set, ascending.
        assert_eq!(view.sig_dirty_live().collect::<Vec<_>>(), vec![2]);
        // shapes of the untouched nodes are borrowed from the base
        assert_eq!(view.out_shapes(0), &shapes[0][..]);
        // compact order is ascending live indices
        assert_eq!(view.compact_order(), &[0, 1, 2]);
        assert_eq!(view.compact_id(2), Some(NodeId(2)));
        assert_eq!(view.compact_id(3), None);
    }

    #[test]
    fn view_adds_nodes_and_maps_added_inputs() {
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let mut b = DeltaBuilder::new(&g);
        let s = b.add(OpKind::Sigmoid, vec![PortRef::of(NodeId(3))], "sig");
        b.redirect(PortRef::of(NodeId(3)), PortRef::of(s));
        // The redirect must NOT rewire the added sigmoid's own input onto
        // itself-via-chain: legacy `redirect` rewrites it too, creating a
        // self-loop — the view must report the cycle, exactly like the
        // materialized product would fail validation.
        let view = DeltaView::new(&g, &shapes, b.finish(), None);
        assert!(view.is_err(), "self-referential product must be rejected");
    }

    #[test]
    fn view_matches_materialized_product() {
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        // Append a sigmoid head AFTER the relu (no redirect of its input).
        let mut b = DeltaBuilder::new(&g);
        let s = b.add(OpKind::Sigmoid, vec![PortRef::of(NodeId(2))], "sig");
        b.redirect(PortRef::of(NodeId(3)), PortRef::of(s));
        let d = b.finish();
        // The relu consumed conv port 0; sigmoid reads the conv directly,
        // so only the output is redirected and no cycle forms.
        let view = DeltaView::new(&g, &shapes, d.clone(), None).unwrap();
        let mut full = g.apply_delta(&d);
        full.compact();
        full.validate().unwrap();
        assert_eq!(full.len(), view.live_count());
        for (j, &i) in view.compact_order().iter().enumerate() {
            assert_eq!(&full.node(NodeId(j)).op, view.op(i));
            let mapped: Vec<PortRef> = view
                .inputs(i)
                .iter()
                .map(|p| PortRef { node: view.compact_id(p.node.0).unwrap(), port: p.port })
                .collect();
            assert_eq!(full.node(NodeId(j)).inputs, mapped);
        }
        let fshapes = full.infer_shapes().unwrap();
        for (j, &i) in view.compact_order().iter().enumerate() {
            assert_eq!(&fshapes[j][..], view.out_shapes(i));
        }
    }

    #[test]
    fn bad_delta_references_rejected() {
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let d = GraphDelta {
            replace_ops: vec![(NodeId(99), OpKind::Relu)],
            add_nodes: Vec::new(),
            redirects: Vec::new(),
        };
        assert!(DeltaView::new(&g, &shapes, d, None).is_err());
    }
}
