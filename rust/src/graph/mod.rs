//! Computation-graph IR: nodes are operators, edges are tensors (paper §3.1).
//!
//! Graphs are immutable-ish DAGs over [`Node`]s identified by dense
//! [`NodeId`]s. Substitutions describe themselves as [`GraphDelta`] edit
//! scripts; winners materialize via [`Graph::apply_delta`] +
//! [`Graph::compact`], while candidate screening works on the incremental
//! [`DeltaView`]. Search-state dedup uses [`canonical::graph_hash`] (full)
//! or [`canonical::delta_hash`] (incremental).

/// Canonical graph hashing (isomorphism-robust dedup key).
pub mod canonical;
/// Graph deltas: substitution edit scripts + the incremental product view.
pub mod delta;
/// Operator kinds, attributes, signatures, and shape inference.
pub mod op;
/// Graph + plan (de)serialization to JSON.
pub mod serde;

pub use delta::{DeltaBuilder, DeltaView, GraphDelta};
pub use op::{Activation, OpKind};

use std::collections::BTreeMap;

/// Dense node index within a [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// Reference to one output port of a node (Split has several ports).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PortRef {
    /// The producing node.
    pub node: NodeId,
    /// Which of its output ports (0 for single-output ops).
    pub port: usize,
}

impl PortRef {
    /// Port 0 of `node` — the common single-output case.
    pub fn of(node: NodeId) -> PortRef {
        PortRef { node, port: 0 }
    }
}

/// A graph node: operator + input edges.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// The operator this node computes.
    pub op: OpKind,
    /// Input edges, in operator argument order.
    pub inputs: Vec<PortRef>,
    /// Human-readable label (layer name); not semantically meaningful.
    pub name: String,
}

/// A computation graph. `outputs` are the tensors the graph produces.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    nodes: Vec<Node>,
    /// The tensors the graph produces, in output order.
    pub outputs: Vec<PortRef>,
}

/// A fully-qualified tensor shape (alias for readability).
pub type TensorShape = Vec<usize>;

impl Graph {
    /// An empty graph.
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Append a node, returning its id. Shape validity is checked lazily by
    /// [`Graph::validate`] / [`Graph::infer_shapes`].
    pub fn add(&mut self, op: OpKind, inputs: Vec<PortRef>, name: &str) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node { op, inputs, name: name.to_string() });
        id
    }

    /// Convenience: add with single-port input ids.
    pub fn add1(&mut self, op: OpKind, inputs: &[NodeId], name: &str) -> NodeId {
        self.add(op, inputs.iter().map(|&n| PortRef::of(n)).collect(), name)
    }

    /// The node with the given id. Panics when out of range.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    /// Mutable access to one node (substitution rewrites).
    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0]
    }

    /// The graph re-instantiated at batch size `batch`: every
    /// [`OpKind::Input`] leading (batch) dimension is multiplied by
    /// `batch`, and the change propagates through shape inference to every
    /// activation tensor. Weights and other constant-space nodes are
    /// untouched — they are batch-invariant, which is exactly what makes
    /// batching pay: weight traffic amortizes over the batch while
    /// activation traffic and compute scale with it.
    ///
    /// Because node *signatures* embed input shapes, the rebatched graph
    /// keys the entire cost stack (energysim work, `CostDb` entries,
    /// oracle resolve cache, cost slabs) on batch automatically.
    /// `rebatch(1)` returns a plain clone, so batch=1 costing is
    /// bit-identical to the pre-batch-axis pipeline by construction.
    pub fn rebatch(&self, batch: usize) -> Result<Graph, String> {
        if batch == 0 {
            return Err("batch size must be >= 1".into());
        }
        let mut g = self.clone();
        if batch == 1 {
            return Ok(g);
        }
        let mut scaled = 0usize;
        for id in g.ids().collect::<Vec<_>>() {
            if let OpKind::Input { shape } = &mut g.node_mut(id).op {
                match shape.first_mut() {
                    Some(n) => {
                        *n *= batch;
                        scaled += 1;
                    }
                    None => return Err(format!("input node {} has a rank-0 shape", id.0)),
                }
            }
        }
        if scaled == 0 {
            return Err("graph has no Input nodes to rebatch".into());
        }
        g.validate()?;
        Ok(g)
    }

    /// Total node count (including constant-space nodes).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the graph holds no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// All node ids, ascending.
    pub fn ids(&self) -> impl Iterator<Item = NodeId> {
        (0..self.nodes.len()).map(NodeId)
    }

    /// All `(id, node)` pairs, ascending by id.
    pub fn nodes(&self) -> impl Iterator<Item = (NodeId, &Node)> {
        self.nodes.iter().enumerate().map(|(i, n)| (NodeId(i), n))
    }

    /// Topological order (inputs before consumers). Errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, String> {
        let n = self.nodes.len();
        let mut indegree = vec![0usize; n];
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for inp in &node.inputs {
                if inp.node.0 >= n {
                    return Err(format!("node {i} references missing node {}", inp.node.0));
                }
                indegree[i] += 1;
                consumers[inp.node.0].push(i);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        // Stable order: process lowest id first so topo order is deterministic.
        queue.sort_unstable_by(|a, b| b.cmp(a));
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(NodeId(i));
            for &c in &consumers[i] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    // keep deterministic ascending pop order
                    let pos = queue.binary_search_by(|x| c.cmp(x)).unwrap_or_else(|p| p);
                    queue.insert(pos, c);
                }
            }
        }
        if order.len() != n {
            return Err("graph contains a cycle".into());
        }
        Ok(order)
    }

    /// Infer the output shapes of every node. Errors indicate an invalid graph.
    pub fn infer_shapes(&self) -> Result<Vec<Vec<TensorShape>>, String> {
        let order = self.topo_order()?;
        let mut shapes: Vec<Option<Vec<TensorShape>>> = vec![None; self.nodes.len()];
        for id in order {
            let node = &self.nodes[id.0];
            let mut in_shapes = Vec::with_capacity(node.inputs.len());
            for inp in &node.inputs {
                let src = shapes[inp.node.0]
                    .as_ref()
                    .ok_or_else(|| format!("node {} input not computed", id.0))?;
                let shape = src.get(inp.port).ok_or_else(|| {
                    format!(
                        "node {} reads port {} of node {} which has {} ports",
                        id.0,
                        inp.port,
                        inp.node.0,
                        src.len()
                    )
                })?;
                in_shapes.push(shape.clone());
            }
            let out = node
                .op
                .infer_shapes(&in_shapes)
                .map_err(|e| format!("node {} ({}): {e}", id.0, node.name))?;
            shapes[id.0] = Some(out);
        }
        Ok(shapes.into_iter().map(Option::unwrap).collect())
    }

    /// Full validation: DAG, ports in range, shapes consistent, outputs valid.
    pub fn validate(&self) -> Result<(), String> {
        let shapes = self.infer_shapes()?;
        if self.outputs.is_empty() {
            return Err("graph has no outputs".into());
        }
        for out in &self.outputs {
            let ports = shapes
                .get(out.node.0)
                .ok_or_else(|| format!("output references missing node {}", out.node.0))?;
            if out.port >= ports.len() {
                return Err(format!("output references invalid port {} of node {}", out.port, out.node.0));
            }
        }
        Ok(())
    }

    /// Signature of a node (for the cost database): op + attrs + input shapes.
    pub fn node_signature(&self, id: NodeId, shapes: &[Vec<TensorShape>]) -> String {
        let node = &self.nodes[id.0];
        let in_shapes: Vec<TensorShape> = node
            .inputs
            .iter()
            .map(|p| shapes[p.node.0][p.port].clone())
            .collect();
        node.op.signature(&in_shapes)
    }

    /// Drop nodes unreachable (backwards) from the outputs and remap ids.
    /// Returns the old-id -> new-id map.
    pub fn compact(&mut self) -> BTreeMap<NodeId, NodeId> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<usize> = self.outputs.iter().map(|p| p.node.0).collect();
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            for inp in &self.nodes[i].inputs {
                stack.push(inp.node.0);
            }
        }
        let mut map = BTreeMap::new();
        let mut new_nodes = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.drain(..).enumerate() {
            if live[i] {
                map.insert(NodeId(i), NodeId(new_nodes.len()));
                new_nodes.push(node);
            }
        }
        for node in &mut new_nodes {
            for inp in &mut node.inputs {
                inp.node = map[&inp.node];
            }
        }
        for out in &mut self.outputs {
            out.node = map[&out.node];
        }
        self.nodes = new_nodes;
        map
    }

    /// Rewire every consumer of `from` (and graph outputs) to read `to`.
    pub fn redirect(&mut self, from: PortRef, to: PortRef) {
        for node in &mut self.nodes {
            for inp in &mut node.inputs {
                if *inp == from {
                    *inp = to;
                }
            }
        }
        for out in &mut self.outputs {
            if *out == from {
                *out = to;
            }
        }
    }

    /// Consumers of each node port: map from PortRef to consuming node ids.
    pub fn consumers(&self) -> BTreeMap<PortRef, Vec<NodeId>> {
        let mut map: BTreeMap<PortRef, Vec<NodeId>> = BTreeMap::new();
        for (i, node) in self.nodes.iter().enumerate() {
            for inp in &node.inputs {
                map.entry(*inp).or_default().push(NodeId(i));
            }
        }
        map
    }

    /// Count of request-path (non-constant-space) nodes — the `n` in the
    /// paper's search-complexity discussion.
    pub fn runtime_node_count(&self) -> usize {
        self.nodes.iter().filter(|n| !n.op.is_constant_space()).count()
    }

    /// Pretty one-line-per-node dump for debugging and `eadgo show`.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for (i, node) in self.nodes.iter().enumerate() {
            let ins: Vec<String> = node
                .inputs
                .iter()
                .map(|p| {
                    if p.port == 0 {
                        format!("%{}", p.node.0)
                    } else {
                        format!("%{}.{}", p.node.0, p.port)
                    }
                })
                .collect();
            s.push_str(&format!(
                "%{i} = {}({}) \"{}\"\n",
                node.op.mnemonic(),
                ins.join(", "),
                node.name
            ));
        }
        let outs: Vec<String> = self.outputs.iter().map(|p| format!("%{}.{}", p.node.0, p.port)).collect();
        s.push_str(&format!("outputs: {}\n", outs.join(", ")));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        // input -> conv(w) -> relu -> output
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
                has_residual: false,
            },
            &[x, w],
            "conv",
        );
        let r = g.add1(OpKind::Relu, &[c], "relu");
        g.outputs = vec![PortRef::of(r)];
        g
    }

    #[test]
    fn topo_and_validate() {
        let g = tiny_graph();
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 4);
        // conv (id 2) must come after both x (0) and w (1)
        let pos = |id: usize| order.iter().position(|n| n.0 == id).unwrap();
        assert!(pos(2) > pos(0) && pos(2) > pos(1));
        assert!(pos(3) > pos(2));
        g.validate().unwrap();
    }

    #[test]
    fn shapes_inferred() {
        let g = tiny_graph();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[2], vec![vec![1, 4, 8, 8]]);
        assert_eq!(shapes[3], vec![vec![1, 4, 8, 8]]);
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new();
        let a = g.add(OpKind::Relu, vec![PortRef { node: NodeId(1), port: 0 }], "a");
        let _b = g.add(OpKind::Relu, vec![PortRef::of(a)], "b");
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn missing_input_node_detected() {
        let mut g = Graph::new();
        g.add(OpKind::Relu, vec![PortRef { node: NodeId(42), port: 0 }], "a");
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn bad_port_detected() {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 2, 4, 4] }, &[], "x");
        // Relu has one output port; reading port 3 is invalid.
        let r = g.add(OpKind::Relu, vec![PortRef { node: x, port: 3 }], "r");
        g.outputs = vec![PortRef::of(r)];
        assert!(g.validate().is_err());
    }

    #[test]
    fn compact_drops_dead_nodes() {
        let mut g = tiny_graph();
        // dead branch
        let d = g.add1(OpKind::weight(vec![2, 2], 9), &[], "dead");
        let _d2 = g.add1(OpKind::Relu, &[d], "dead2");
        assert_eq!(g.len(), 6);
        g.compact();
        assert_eq!(g.len(), 4);
        g.validate().unwrap();
    }

    #[test]
    fn redirect_rewires_consumers_and_outputs() {
        let mut g = tiny_graph();
        let conv = NodeId(2);
        let relu = NodeId(3);
        // redirect relu's consumers (the graph output) to conv directly
        g.redirect(PortRef::of(relu), PortRef::of(conv));
        assert_eq!(g.outputs[0], PortRef::of(conv));
        g.compact();
        assert_eq!(g.len(), 3); // relu dropped
    }

    #[test]
    fn split_ports_validate() {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 8, 4, 4] }, &[], "x");
        let s = g.add1(OpKind::Split { axis: 1, sizes: vec![3, 5] }, &[x], "split");
        let a = g.add(OpKind::Relu, vec![PortRef { node: s, port: 0 }], "a");
        let b = g.add(OpKind::Relu, vec![PortRef { node: s, port: 1 }], "b");
        g.outputs = vec![PortRef::of(a), PortRef::of(b)];
        g.validate().unwrap();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[s.0], vec![vec![1, 3, 4, 4], vec![1, 5, 4, 4]]);
    }

    #[test]
    fn runtime_node_count_excludes_weights() {
        let g = tiny_graph();
        assert_eq!(g.runtime_node_count(), 3); // input, conv, relu
    }

    #[test]
    fn dump_contains_all_nodes() {
        let g = tiny_graph();
        let d = g.dump();
        assert!(d.contains("conv2d"));
        assert!(d.contains("outputs:"));
    }

    #[test]
    fn rebatch_scales_activations_not_weights() {
        let g = tiny_graph();
        let g4 = g.rebatch(4).unwrap();
        let shapes = g4.infer_shapes().unwrap();
        // Input and every activation lead with the new batch dim.
        for (id, node) in g4.nodes() {
            match &node.op {
                OpKind::Input { shape } => assert_eq!(shape[0], 4),
                OpKind::Weight { shape, .. } => {
                    // weights untouched — batch-invariant
                    assert_eq!(shape, match &g.node(id).op {
                        OpKind::Weight { shape, .. } => shape,
                        _ => unreachable!(),
                    });
                }
                _ => assert_eq!(shapes[id.0][0][0], 4, "node {} not batched", id.0),
            }
        }
        // Node ids and count are preserved: assignments carry over as-is.
        assert_eq!(g4.len(), g.len());
    }

    #[test]
    fn rebatch_one_is_identity_clone() {
        let g = tiny_graph();
        let g1 = g.rebatch(1).unwrap();
        assert_eq!(
            crate::graph::canonical::graph_hash(&g),
            crate::graph::canonical::graph_hash(&g1)
        );
        // Signatures (the cost-db keys) are unchanged at batch=1.
        let s0 = g.infer_shapes().unwrap();
        let s1 = g1.infer_shapes().unwrap();
        assert_eq!(s0, s1);
    }

    #[test]
    fn rebatch_changes_signatures_for_batch_gt_one() {
        // The batch axis keys the cost stack through node signatures:
        // a rebatched conv must present a different signature (different
        // db row / slab key) than its batch-1 twin.
        let g = tiny_graph();
        let g2 = g.rebatch(2).unwrap();
        let conv = NodeId(2);
        let sig1 = g.node_signature(conv, &g.infer_shapes().unwrap());
        let sig2 = g2.node_signature(conv, &g2.infer_shapes().unwrap());
        assert_ne!(sig1, sig2);
    }

    #[test]
    fn rebatch_zero_rejected() {
        assert!(tiny_graph().rebatch(0).is_err());
    }
}
