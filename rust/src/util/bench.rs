//! Micro-benchmark harness — offline substitute for `criterion`.
//!
//! Provides warmup, adaptive iteration count, and summary statistics, plus a
//! `BenchSuite` used by the `benches/tableN.rs` binaries (`cargo bench` runs
//! them with `harness = false`).

use crate::util::stats::Summary;
use std::time::Instant;

/// Configuration for one benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Seconds spent warming up (JIT-free rust, but caches/allocator warm).
    pub warmup_secs: f64,
    /// Target seconds of measurement.
    pub measure_secs: f64,
    /// Minimum number of measured iterations regardless of duration.
    pub min_iters: usize,
    /// Hard cap on iterations (protects very fast bodies).
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_secs: 0.2, measure_secs: 1.0, min_iters: 5, max_iters: 100_000 }
    }
}

impl BenchConfig {
    /// A fast profile for CI / `--quick` runs.
    pub fn quick() -> BenchConfig {
        BenchConfig { warmup_secs: 0.02, measure_secs: 0.1, min_iters: 3, max_iters: 10_000 }
    }
}

/// Result of a benchmark: per-iteration wallclock summary (seconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration wallclock summary, seconds.
    pub summary: Summary,
    /// Iterations measured.
    pub total_iters: usize,
}

impl BenchResult {
    /// Mean iteration time in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }
}

/// Measure `body` under `cfg`. The body's return value is black-boxed to
/// keep the optimizer from deleting the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut body: impl FnMut() -> T) -> BenchResult {
    // Warmup phase.
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < cfg.warmup_secs {
        black_box(body());
    }
    // Estimate cost to pick an iteration count.
    let t1 = Instant::now();
    black_box(body());
    let est = t1.elapsed().as_secs_f64().max(1e-9);
    let iters = ((cfg.measure_secs / est) as usize).clamp(cfg.min_iters, cfg.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let it = Instant::now();
        black_box(body());
        samples.push(it.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples), total_iters: iters }
}

/// Identity function the optimizer cannot see through.
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Write a bench payload to `BENCH_<name>.json` (in `EADGO_BENCH_OUT_DIR`,
/// default the working directory) so CI can upload the per-PR perf
/// trajectory as a workflow artifact. Returns the path written.
pub fn emit_bench_json(
    name: &str,
    payload: &crate::util::json::Json,
) -> anyhow::Result<std::path::PathBuf> {
    let dir = std::env::var("EADGO_BENCH_OUT_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    crate::util::json::write_file(&path, payload)?;
    eprintln!("bench payload written to {}", path.display());
    Ok(path)
}

/// Serialize a [`BenchResult`] list for [`emit_bench_json`].
pub fn results_to_json(results: &[BenchResult]) -> crate::util::json::Json {
    let mut arr = Vec::with_capacity(results.len());
    for r in results {
        let mut o = crate::util::json::Json::obj();
        o.set("name", r.name.as_str())
            .set("mean_ms", r.summary.mean * 1e3)
            .set("p50_ms", r.summary.p50 * 1e3)
            .set("p95_ms", r.summary.p95 * 1e3)
            .set("iters", r.total_iters as f64);
        arr.push(o);
    }
    crate::util::json::Json::Arr(arr)
}

/// A named collection of benches with uniform reporting — what the
/// `benches/*.rs` binaries build on.
pub struct BenchSuite {
    /// Suite title, printed by [`BenchSuite::banner`].
    pub title: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

/// Was the fast bench profile requested? `cargo bench -- --quick` or
/// `EADGO_BENCH_QUICK=1` (the CI bench-smoke job sets the latter).
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("EADGO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

impl BenchSuite {
    /// A suite using the default (or `--quick`) config.
    pub fn new(title: &str) -> BenchSuite {
        let cfg = if quick_requested() { BenchConfig::quick() } else { BenchConfig::default() };
        BenchSuite { title: title.to_string(), cfg, results: Vec::new() }
    }

    /// A suite with an explicit config.
    pub fn with_config(title: &str, cfg: BenchConfig) -> BenchSuite {
        BenchSuite { title: title.to_string(), cfg, results: Vec::new() }
    }

    /// The measurement config in effect.
    pub fn config(&self) -> &BenchConfig {
        &self.cfg
    }

    /// Measure one body, print a summary line, and record the result.
    pub fn run<T>(&mut self, name: &str, body: impl FnMut() -> T) -> &BenchResult {
        let r = bench(name, &self.cfg, body);
        eprintln!(
            "  {:<40} mean {:>10.4} ms   p50 {:>10.4} ms   p95 {:>10.4} ms   ({} iters)",
            r.name,
            r.summary.mean * 1e3,
            r.summary.p50 * 1e3,
            r.summary.p95 * 1e3,
            r.total_iters
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// All results recorded so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the suite banner.
    pub fn banner(&self) {
        eprintln!("\n=== {} ===", self.title);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig { warmup_secs: 0.0, measure_secs: 0.01, min_iters: 3, max_iters: 50 };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.total_iters >= 3);
    }

    #[test]
    fn iteration_caps_respected() {
        let cfg = BenchConfig { warmup_secs: 0.0, measure_secs: 10.0, min_iters: 1, max_iters: 7 };
        let r = bench("fast", &cfg, || 1 + 1);
        assert!(r.total_iters <= 7);
    }

    #[test]
    fn suite_collects() {
        let mut s =
            BenchSuite::with_config("t", BenchConfig { warmup_secs: 0.0, measure_secs: 0.005, min_iters: 2, max_iters: 10 });
        s.run("a", || 42);
        s.run("b", || 43);
        assert_eq!(s.results().len(), 2);
    }
}
