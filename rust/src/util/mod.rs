//! Offline substrates: the crates we would normally pull from crates.io
//! (serde_json, rand, criterion, clap, proptest) rebuilt as small, focused
//! modules so the whole project compiles from the vendored `xla` dependency
//! set alone.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Monotonic wallclock helper: returns seconds elapsed while running `f`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}
