//! Offline substrates: the crates we would normally pull from crates.io
//! (serde_json, rand, criterion, clap, proptest) rebuilt as small, focused
//! modules so the whole project compiles from the vendored `xla` dependency
//! set alone.

/// Micro-benchmark harness (criterion substitute).
pub mod bench;
/// Tiny command-line parser (clap substitute).
pub mod cli;
/// Minimal JSON value, parser, and writer (serde_json substitute).
pub mod json;
/// Property-based testing helper (proptest substitute).
pub mod prop;
/// Deterministic PRNG (rand substitute).
pub mod rng;
/// Summary statistics, percentiles, regression, rank correlation.
pub mod stats;

/// Monotonic wallclock helper: returns seconds elapsed while running `f`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}
