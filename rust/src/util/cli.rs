//! Tiny command-line parser — offline substitute for `clap`.
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Produces the usage text for `eadgo --help`.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand (if any), named options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (excluding argv[0]). The first non-`--` token is
    /// treated as the subcommand when `with_subcommand` is set.
    pub fn parse(raw: &[String], with_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    args.opts.insert(rest.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if with_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env(with_subcommand: bool) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, with_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{s}`")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{s}`")),
        }
    }

    /// All `--key value` options that were consumed (for logging).
    pub fn options(&self) -> impl Iterator<Item = (&str, &str)> {
        self.opts.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str], sub: bool) -> Args {
        Args::parse(&tokens.iter().map(|s| s.to_string()).collect::<Vec<_>>(), sub)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["optimize", "--model", "squeezenet", "--w=0.5", "--verbose"], true);
        assert_eq!(a.subcommand.as_deref(), Some("optimize"));
        assert_eq!(a.get("model"), Some("squeezenet"));
        assert_eq!(a.get_f64("w", 1.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positionals() {
        let a = parse(&["run", "a.json", "b.json"], true);
        assert_eq!(a.positional, vec!["a.json", "b.json"]);
    }

    #[test]
    fn no_subcommand_mode() {
        let a = parse(&["a.json", "--n", "3"], false);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["a.json"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["bench", "--quick"], true);
        assert!(a.flag("quick"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--w", "abc"], true);
        assert!(a.get_f64("w", 1.0).is_err());
    }
}
