//! Tiny command-line parser — offline substitute for `clap`.
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Produces the usage text for `eadgo --help`.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand (if any), named options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand token, when parsed with `with_subcommand`.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional (non-option) arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (excluding argv[0]). The first non-`--` token is
    /// treated as the subcommand when `with_subcommand` is set.
    pub fn parse(raw: &[String], with_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    args.opts.insert(rest.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if with_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process arguments (excluding argv[0]).
    pub fn from_env(with_subcommand: bool) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, with_subcommand)
    }

    /// Was the bare flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name value` / `--name=value`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    /// As [`Args::get`] with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse `--name` as f64 (default when absent, error on junk).
    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{s}`")),
        }
    }

    /// Parse `--name` as usize (default when absent, error on junk).
    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{s}`")),
        }
    }

    /// All `--key value` options that were consumed (for logging).
    pub fn options(&self) -> impl Iterator<Item = (&str, &str)> {
        self.opts.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Reject any option or flag not in `allowed` — mistyped flags become
    /// a clean `anyhow` error (with a nearest-match hint) instead of being
    /// silently ignored. A name is checked regardless of whether it parsed
    /// as `--key value` or a bare `--flag`.
    pub fn require_known(&self, allowed: &[&str]) -> anyhow::Result<()> {
        let check = |name: &str| -> anyhow::Result<()> {
            if allowed.contains(&name) {
                return Ok(());
            }
            let hint = allowed
                .iter()
                .filter(|k| edit_distance(name, k) <= 2)
                .min_by_key(|k| edit_distance(name, k))
                .map(|k| format!(" (did you mean `--{k}`?)"))
                .unwrap_or_default();
            anyhow::bail!("unknown option `--{name}`{hint}")
        };
        for (k, _) in self.opts.iter() {
            check(k)?;
        }
        for f in &self.flags {
            check(f)?;
        }
        Ok(())
    }
}

/// Levenshtein distance, for the did-you-mean hint (tiny inputs only).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str], sub: bool) -> Args {
        Args::parse(&tokens.iter().map(|s| s.to_string()).collect::<Vec<_>>(), sub)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["optimize", "--model", "squeezenet", "--w=0.5", "--verbose"], true);
        assert_eq!(a.subcommand.as_deref(), Some("optimize"));
        assert_eq!(a.get("model"), Some("squeezenet"));
        assert_eq!(a.get_f64("w", 1.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positionals() {
        let a = parse(&["run", "a.json", "b.json"], true);
        assert_eq!(a.positional, vec!["a.json", "b.json"]);
    }

    #[test]
    fn no_subcommand_mode() {
        let a = parse(&["a.json", "--n", "3"], false);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["a.json"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["bench", "--quick"], true);
        assert!(a.flag("quick"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--w", "abc"], true);
        assert!(a.get_f64("w", 1.0).is_err());
    }

    #[test]
    fn unknown_options_rejected_with_hint() {
        let a = parse(&["optimize", "--modell", "vgg", "--quick"], true);
        let err = a.require_known(&["model", "quick"]).unwrap_err().to_string();
        assert!(err.contains("--modell"), "{err}");
        assert!(err.contains("did you mean `--model`"), "{err}");
        assert!(a.require_known(&["modell", "quick"]).is_ok());
        // flags are checked too
        let b = parse(&["x", "--quik"], true);
        assert!(b.require_known(&["quick"]).is_err());
        assert!(b.require_known(&["quik"]).is_ok());
    }

    #[test]
    fn edit_distance_sane() {
        assert_eq!(edit_distance("model", "model"), 0);
        assert_eq!(edit_distance("modell", "model"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
