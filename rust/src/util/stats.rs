//! Measurement statistics: summary stats, percentiles, and robust
//! aggregation for the profiler and bench harness.

/// Summary of a sample of measurements (e.g. per-iteration wallclock).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator).
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (linear-interpolated 50th percentile).
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile (the serving-latency SLO quantile).
    pub p99: f64,
}

impl Summary {
    /// Summarize a non-empty sample. Panics on an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// Relative standard deviation — the profiler re-measures while this is
    /// above its noise threshold (mirrors the paper's "measure for at least
    /// another 4 seconds" stabilization).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Trimmed mean: drop the top & bottom `trim_frac` of samples before
/// averaging. The profiler uses this to shed scheduler-noise outliers on a
/// busy 1-core host.
pub fn trimmed_mean(samples: &[f64], trim_frac: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((sorted.len() as f64) * trim_frac).floor() as usize;
    let kept = &sorted[k..sorted.len() - k];
    let kept = if kept.is_empty() { &sorted[..] } else { kept };
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// Geometric mean — used when summarizing speedup ratios across models.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Ordinary least squares fit y = a + b x, returns (a, b, r2). Used by the
/// energy-model calibration to fit power-vs-intensity curves.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (a, b, r2)
}

/// Mean absolute percentage error — Table 2 reports the cost-model accuracy;
/// we quantify it with MAPE.
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    assert!(!actual.is_empty());
    let mut total = 0.0;
    for (a, p) in actual.iter().zip(predicted) {
        total += ((a - p) / a).abs();
    }
    100.0 * total / actual.len() as f64
}

/// Kendall rank correlation (tau-a). The paper argues the cost model's value
/// is *order preservation* ("correctly projects the orders of the
/// assignments") — tau quantifies exactly that claim for Table 2.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    assert!(n >= 2);
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            let s = da * db;
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    (concordant - discordant) as f64 / (n * (n - 1) / 2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let s = Summary::of(&samples);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p99 - 197.01).abs() < 1e-9, "{}", s.p99);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_sheds_outlier() {
        let samples = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 100.0];
        let tm = trimmed_mean(&samples, 0.1);
        assert!((tm - 1.0).abs() < 1e-9, "tm={tm}");
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mape_known() {
        let m = mape(&[100.0, 200.0], &[110.0, 180.0]);
        assert!((m - 10.0).abs() < 1e-9);
    }

    #[test]
    fn kendall_perfect_and_inverted() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((kendall_tau(&a, &b) - 1.0).abs() < 1e-12);
        let c = [40.0, 30.0, 20.0, 10.0];
        assert!((kendall_tau(&a, &c) + 1.0).abs() < 1e-12);
    }
}
