//! Deterministic PRNG (SplitMix64 + xoshiro256**) — offline substitute for
//! the `rand` crate. Used for synthetic tensors, randomized property tests,
//! and the arbitrary starting assignment of the inner search (paper
//! Algorithm 2 line 2, "Pick A ∈ S arbitrarily").

/// xoshiro256** seeded via SplitMix64. Passes BigCrush per the authors;
/// more than adequate for test-data generation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator (SplitMix64 state expansion).
    pub fn seed_from(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// The next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection to avoid modulo bias.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller (used for weight init).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Choose a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a vector with iid uniform values in [lo, hi) — synthetic tensor data.
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// Fork an independent stream (for parallel/isolated consumers).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }
}

fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            // each bucket expected 10_000; allow ±5%
            assert!((9500..10500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn int_range_inclusive_bounds_hit() {
        let mut r = Rng::seed_from(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            let v = r.int_range(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
