//! Minimal JSON value type, recursive-descent parser, and pretty writer.
//!
//! Offline substitute for `serde_json`: the profile database, artifact
//! manifest, configuration files, and experiment records are all persisted
//! as JSON through this module.
//!
//! Supported: the full JSON grammar (RFC 8259) minus `\u` surrogate pairs
//! beyond the BMP (encoded lossily as U+FFFD). Numbers are stored as `f64`;
//! integers round-trip exactly up to 2^53 which is ample for our counters.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic —
/// important for reproducible profile-DB files and golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object (programmer error).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to i64.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// The numeric value as usize (negative numbers yield `None`).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fetch `key` as f64 or return an error mentioning the key — the common
    /// pattern when loading persisted records.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| JsonError::new(format!("missing/invalid number field `{key}`")))
    }

    /// As [`Json::req_f64`] for string fields.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::new(format!("missing/invalid string field `{key}`")))
    }

    /// Compact one-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

/// Format an f64 the way JSON expects: integers without a trailing `.0`
/// would be ambiguous on reload, so we keep enough precision to round-trip.
fn fmt_num(n: f64) -> String {
    if !n.is_finite() {
        // JSON has no NaN/Inf; persist as null-like sentinel strings is
        // worse than clamping — we clamp to a huge magnitude.
        return if n.is_nan() {
            "0".to_string()
        } else if n > 0.0 {
            "1e308".to_string()
        } else {
            "-1e308".to_string()
        };
    }
    if n == n.trunc() && n.abs() < 9.0e15 {
        format!("{}", n as i64)
    } else {
        // 17 significant digits round-trips every f64.
        let s = format!("{n:.17e}");
        // Prefer the shortest of {:?} (shortest round-trip in rust) if valid.
        let dbg = format!("{n:?}");
        if dbg.parse::<f64>() == Ok(n) {
            dbg
        } else {
            s
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset for debuggability.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the input (0 for semantic errors).
    pub offset: usize,
}

impl JsonError {
    fn new(msg: String) -> JsonError {
        JsonError { msg, offset: 0 }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing garbage after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal, expected `{lit}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number `{text}`")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("non-utf8 \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 3; // 4 hex chars minus the +1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar, not just one byte.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }
}

/// Read + parse a JSON file.
pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Serialize + write a JSON file (pretty, trailing newline), creating parent
/// directories as needed. Writes via a temp file + rename so a crash never
/// leaves a truncated database behind.
pub fn write_file(path: &std::path::Path, value: &Json) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, value.to_string_pretty())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let mut obj = Json::obj();
        obj.set("name", "conv2d 3x3")
            .set("time_ms", 0.165)
            .set("count", 1000usize)
            .set("flags", vec![true, false])
            .set("nested", {
                let mut n = Json::obj();
                n.set("pi", std::f64::consts::PI);
                n
            });
        for text in [obj.to_string_compact(), obj.to_string_pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, obj);
        }
    }

    #[test]
    fn roundtrip_awkward_floats() {
        for x in [0.1, 1e-12, 1.0000000000000002, 9007199254740992.0, -0.0] {
            let text = Json::Num(x).to_string_compact();
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "failed for {x}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "quote\" slash\\ newline\n tab\t unicode\u{1F600} ctrl\u{1}";
        let text = Json::Str(s.to_string()).to_string_compact();
        assert_eq!(parse(&text).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(parse(r#""A""#).unwrap().as_str().unwrap(), "A");
    }

    #[test]
    fn errors_carry_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("[1, 2").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eadgo_json_test");
        let path = dir.join("db.json");
        let mut obj = Json::obj();
        obj.set("k", 1.5);
        write_file(&path, &obj).unwrap();
        assert_eq!(read_file(&path).unwrap(), obj);
        std::fs::remove_dir_all(&dir).ok();
    }
}
