//! Property-based testing helper — offline substitute for `proptest`.
//!
//! `check(cases, |rng| ...)` runs a closure over many deterministic random
//! seeds; on failure it reports the failing seed so the case can be replayed
//! with `check_seed`. No shrinking (cases are built small on purpose), but
//! failures are fully reproducible.

use crate::util::rng::Rng;

/// Default number of cases per property (override with EADGO_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("EADGO_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `cases` deterministic seeds. `prop` returns
/// `Err(description)` (or panics) to signal failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xEAD60u64 ^ ((case as u64) << 16);
        let mut rng = Rng::seed_from(seed);
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng))) {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay: eadgo::util::prop::check_seed({seed:#x}, ...)"
            ),
            Err(p) => {
                let msg = p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<panic>".to_string());
                panic!("property `{name}` panicked on case {case} (seed {seed:#x}): {msg}");
            }
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::seed_from(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay of seed {seed:#x} failed: {msg}");
    }
}

/// Assert two f32 slices are element-wise close; returns Err with the first
/// offending index (the workhorse of tensor-equivalence property tests).
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!(
                "mismatch at [{i}]: {x} vs {y} (|diff|={} > tol={tol}); lengths {}",
                (x - y).abs(),
                a.len()
            ));
        }
        if x.is_nan() != y.is_nan() {
            return Err(format!("NaN mismatch at [{i}]: {x} vs {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 10, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |_| Err("nope".to_string()));
    }

    #[test]
    fn close_check() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, 1e-5).is_err());
    }
}
