//! The profiler: populates the cost database with per-(node-signature,
//! algorithm) measurements (paper §3.2/§4.1).
//!
//! Two providers mirror the substitution documented in DESIGN.md:
//! - [`SimV100Provider`] — the analytical V100 model (nvidia-smi substitute),
//!   used for all paper-table reproductions.
//! - [`CpuProvider`] — *real* wallclock measurement of each algorithm's rust
//!   implementation (and PJRT artifact when available), with power modeled
//!   from measured utilization; used by the end-to-end CPU examples.
//!
//! Mirroring the paper's methodology ("we run a graph for 4 seconds before
//! sampling ... and measure for at least another 4 seconds"), the CPU
//! provider warms up, then measures until the relative standard deviation
//! stabilizes (scaled down for a 1-core host).

use crate::algo::{Algorithm, AlgorithmRegistry};
use crate::cost::{CostDb, NodeCost};
use crate::energysim::{
    nhwc_bytes_factor, node_work, DeviceId, EnergyModel, FreqId, FreqState, Layout, LinkModel,
    Work,
};
use crate::engine::exec::execute_node;
use crate::engine::pjrt::PjrtEngine;
use crate::graph::{Graph, OpKind, TensorShape};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats;
use std::time::Instant;

/// Anything that can produce a (time, power) profile for one node+algorithm.
///
/// Providers are shared by the parallel search workers through the
/// [`crate::cost::CostOracle`], so they take `&self` (interior mutability
/// for any internal state) and must be `Send + Sync`.
pub trait CostProvider: Send + Sync {
    /// Human-readable provider name, recorded as measurement provenance.
    fn provider_name(&self) -> String;

    /// The DVFS states the measured device exposes (ascending; last =
    /// nominal). Default: none — the device runs one fixed clock and only
    /// `FreqId::NOMINAL` measurements are meaningful.
    fn freq_states(&self) -> Vec<FreqState> {
        Vec::new()
    }

    /// The devices this provider can measure, each with its own DVFS table
    /// (same convention as [`CostProvider::freq_states`]: ascending, last =
    /// nominal). Default: one entry — the primary GPU with
    /// `freq_states()` — so single-device providers are untouched by the
    /// placement axis. Heterogeneous providers override this; `DeviceId::GPU`
    /// must always be the first entry.
    fn device_states(&self) -> Vec<(DeviceId, Vec<FreqState>)> {
        vec![(DeviceId::GPU, self.freq_states())]
    }

    /// The link model charged when a tensor crosses between two of this
    /// provider's devices. `None` (the default) means the provider exposes a
    /// single device and no transfer is ever charged.
    fn link_model(&self) -> Option<LinkModel> {
        None
    }

    /// Measure one `(signature, algorithm)` pair at the given DVFS state.
    fn measure(
        &self,
        sig: &str,
        op: &OpKind,
        in_shapes: &[TensorShape],
        out_shapes: &[TensorShape],
        algo: Algorithm,
        freq: FreqId,
    ) -> NodeCost;
}

/// Simulated V100 provider (the default).
pub struct SimV100Provider {
    /// The analytic device model backing every measurement.
    pub model: EnergyModel,
}

impl SimV100Provider {
    /// Build a provider whose measurement noise is derived from `seed`.
    pub fn new(seed: u64) -> SimV100Provider {
        SimV100Provider { model: EnergyModel::v100(seed) }
    }
}

impl CostProvider for SimV100Provider {
    fn provider_name(&self) -> String {
        self.model.spec.name.clone()
    }

    fn freq_states(&self) -> Vec<FreqState> {
        self.model.spec.freq_states.clone()
    }

    fn measure(
        &self,
        sig: &str,
        op: &OpKind,
        in_shapes: &[TensorShape],
        out_shapes: &[TensorShape],
        algo: Algorithm,
        freq: FreqId,
    ) -> NodeCost {
        let mut w = node_work(op, in_shapes, out_shapes);
        // The layout axis reprices the memory path only; NCHW (bit clear)
        // skips the multiply entirely so pre-layout requests stay
        // bit-identical.
        if freq.layout() == Layout::NHWC {
            w.bytes *= nhwc_bytes_factor(op, in_shapes);
        }
        // Strip the layout bit before the model sees the state: DVFS table
        // lookups and jitter keys are layout-independent.
        let c = self.model.measured_cost_at(sig, &w, algo, freq.local());
        NodeCost { time_ms: c.time_ms, power_w: c.power_w }
    }
}

/// Simulated heterogeneous board: the V100 plus a DLA-like low-power block
/// behind a shared-DRAM link. Measurements route by the packed device bits
/// of the requested [`FreqId`]; each device model sees only its device-local
/// state, so GPU measurements are bit-identical to [`SimV100Provider`]'s.
pub struct SimHeteroProvider {
    /// Per-device analytic models, indexed by `DeviceId` order (GPU first).
    pub models: Vec<(DeviceId, EnergyModel)>,
    /// Transfer cost charged at device boundaries.
    pub link: LinkModel,
}

impl SimHeteroProvider {
    /// Build a GPU+DLA provider. The GPU model uses `seed` exactly as
    /// [`SimV100Provider::new`] does; the DLA model derives a distinct seed
    /// so the two devices draw independent measurement noise.
    pub fn new(seed: u64) -> SimHeteroProvider {
        SimHeteroProvider {
            models: vec![
                (DeviceId::GPU, EnergyModel::v100(seed)),
                (DeviceId::DLA, EnergyModel::dla(seed.wrapping_add(0x0D1A))),
            ],
            link: LinkModel::shared_dram(),
        }
    }

    fn model_for(&self, dev: DeviceId) -> &EnergyModel {
        self.models
            .iter()
            .find(|(d, _)| *d == dev)
            .map(|(_, m)| m)
            .unwrap_or_else(|| panic!("no model for device `{}`", dev.name()))
    }
}

impl CostProvider for SimHeteroProvider {
    fn provider_name(&self) -> String {
        let names: Vec<&str> = self.models.iter().map(|(_, m)| m.spec.name.as_str()).collect();
        names.join("+")
    }

    fn freq_states(&self) -> Vec<FreqState> {
        // The legacy single-device view is the GPU.
        self.model_for(DeviceId::GPU).spec.freq_states.clone()
    }

    fn device_states(&self) -> Vec<(DeviceId, Vec<FreqState>)> {
        self.models.iter().map(|(d, m)| (*d, m.spec.freq_states.clone())).collect()
    }

    fn link_model(&self) -> Option<LinkModel> {
        Some(self.link)
    }

    fn measure(
        &self,
        sig: &str,
        op: &OpKind,
        in_shapes: &[TensorShape],
        out_shapes: &[TensorShape],
        algo: Algorithm,
        freq: FreqId,
    ) -> NodeCost {
        let model = self.model_for(freq.device());
        let mut w = node_work(op, in_shapes, out_shapes);
        if freq.layout() == Layout::NHWC {
            w.bytes *= nhwc_bytes_factor(op, in_shapes);
        }
        // Strip the device and layout bits: each model is device-local, so
        // its DVFS table lookups and jitter keys match a single-device
        // provider's.
        let c = model.measured_cost_at(sig, &w, algo, freq.local());
        NodeCost { time_ms: c.time_ms, power_w: c.power_w }
    }
}

/// Real-measurement provider: times the algorithm implementation on this
/// host (PJRT artifact when loaded, reference op otherwise) and models power
/// from achieved utilization.
pub struct CpuProvider<'rt> {
    /// PJRT runtime to time compiled artifacts through (reference-op
    /// fallback when `None` or the artifact is missing).
    pub runtime: Option<&'rt Runtime>,
    /// Device model used to translate measured utilization into power.
    pub power_model: EnergyModel,
    /// Measurement budget per (node, algorithm), seconds.
    pub budget_s: f64,
    /// Input-synthesis RNG, behind a mutex: `measure` takes `&self` so the
    /// provider can be shared by parallel search workers.
    rng: std::sync::Mutex<Rng>,
}

impl<'rt> CpuProvider<'rt> {
    /// Build a provider measuring on this host (PJRT-hybrid when a loaded
    /// runtime is supplied).
    pub fn new(runtime: Option<&'rt Runtime>) -> CpuProvider<'rt> {
        CpuProvider {
            runtime,
            power_model: EnergyModel {
                spec: crate::energysim::GpuSpec::cpu_1core(),
                seed: 0,
                noise: 0.0,
            },
            budget_s: 0.05,
            rng: std::sync::Mutex::new(Rng::seed_from(0xC0FFEE)),
        }
    }

    fn power_from_utilization(&self, w: &Work, algo: Algorithm, time_s: f64) -> f64 {
        let spec = &self.power_model.spec;
        let p = crate::energysim::algo_profile(algo);
        let t_c = (w.flops * p.flops_factor) / spec.peak_flops;
        let t_m = (w.bytes * p.bytes_factor) / spec.peak_bw;
        let u_c = (t_c / time_s).min(1.0);
        let u_m = (t_m / time_s).min(1.0);
        let draw = (0.7 * u_c + 0.3 * u_m).min(1.0) * p.intensity;
        (spec.idle_power + (spec.max_power - spec.idle_power) * draw).min(spec.max_power)
    }
}

impl CostProvider for CpuProvider<'_> {
    fn provider_name(&self) -> String {
        format!("cpu-measured({})", if self.runtime.is_some() { "pjrt+ref" } else { "ref" })
    }

    // No freq_states override: the CPU host runs one fixed clock, so the
    // oracle only ever asks for `FreqId::NOMINAL` and DVFS search modes
    // degenerate to the nominal-only search.
    fn measure(
        &self,
        sig: &str,
        op: &OpKind,
        in_shapes: &[TensorShape],
        out_shapes: &[TensorShape],
        algo: Algorithm,
        _freq: FreqId,
    ) -> NodeCost {
        // Synthesize inputs (RNG locked only for synthesis, not timing).
        let inputs: Vec<Tensor> = {
            let mut rng = self.rng.lock().unwrap();
            in_shapes.iter().map(|s| Tensor::rand(s, &mut rng, -1.0, 1.0)).collect()
        };
        let input_refs: Vec<&Tensor> = inputs.iter().collect();
        let key = PjrtEngine::node_key(sig, algo);
        let use_pjrt = self.runtime.map(|rt| rt.has(&key)).unwrap_or(false);

        let run = || -> anyhow::Result<()> {
            if use_pjrt {
                self.runtime.unwrap().execute(&key, &input_refs)?;
            } else {
                execute_node(op, algo, &input_refs)?;
            }
            Ok(())
        };
        // Warmup once (allocator, caches), then measure within budget.
        let _ = run();
        let mut samples = Vec::new();
        let t_start = Instant::now();
        while t_start.elapsed().as_secs_f64() < self.budget_s || samples.len() < 3 {
            let t0 = Instant::now();
            if run().is_err() {
                // Algorithm inapplicable or artifact mismatch: report an
                // effectively-infinite cost so the search never picks it.
                return NodeCost { time_ms: f64::INFINITY, power_w: f64::INFINITY };
            }
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 200 {
                break;
            }
        }
        let time_s = stats::trimmed_mean(&samples, 0.1);
        let w = node_work(op, in_shapes, out_shapes);
        let power = self.power_from_utilization(&w, algo, time_s.max(1e-9));
        NodeCost { time_ms: time_s * 1e3, power_w: power }
    }
}

/// Result of a profiling pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProfileReport {
    /// Pairs measured in this pass.
    pub measured: usize,
    /// Pairs already present in the database (the paper's warm-cache case).
    pub cached: usize,
}

/// Ensure the database has a profile for every (signature, algorithm) pair
/// appearing in `g`. Nodes with identical signatures are measured once.
///
/// Standalone (db + provider, no cache) variant for callers that do not
/// hold a [`crate::cost::CostOracle`]; the optimizer and CLI go through
/// [`crate::cost::CostOracle::profile_graph`] instead.
pub fn ensure_profiled(
    g: &Graph,
    reg: &AlgorithmRegistry,
    db: &mut CostDb,
    provider: &dyn CostProvider,
) -> anyhow::Result<ProfileReport> {
    let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!(e))?;
    ensure_profiled_with(g, &shapes, reg, db, provider)
}

/// As [`ensure_profiled`] with pre-computed shapes (search hot path).
pub fn ensure_profiled_with(
    g: &Graph,
    shapes: &[Vec<TensorShape>],
    reg: &AlgorithmRegistry,
    db: &mut CostDb,
    provider: &dyn CostProvider,
) -> anyhow::Result<ProfileReport> {
    let mut report = ProfileReport::default();
    let prov_name = provider.provider_name();
    for (id, node) in g.nodes() {
        if node.op.is_constant_space() || matches!(node.op, OpKind::Input { .. }) {
            continue;
        }
        let in_shapes: Vec<TensorShape> = node
            .inputs
            .iter()
            .map(|p| shapes[p.node.0][p.port].clone())
            .collect();
        let out_shapes = &shapes[id.0];
        let sig = node.op.signature(&in_shapes);
        for algo in reg.applicable(&node.op, &in_shapes) {
            if db.contains(&sig, algo) {
                report.cached += 1;
                continue;
            }
            let cost =
                provider.measure(&sig, &node.op, &in_shapes, out_shapes, algo, FreqId::NOMINAL);
            db.insert(&sig, algo, cost, &prov_name);
            report.measured += 1;
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, PortRef};

    fn small_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[x, w],
            "c",
        );
        // second conv with IDENTICAL signature: must not re-measure
        let w2 = g.add1(OpKind::weight(vec![4, 3, 3, 3], 2), &[], "w2");
        let c2 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[x, w2],
            "c2",
        );
        let add = g.add1(OpKind::Add, &[c, c2], "add");
        g.outputs = vec![PortRef::of(add)];
        g
    }

    #[test]
    fn sim_provider_profiles_all_pairs_once() {
        let g = small_graph();
        let reg = AlgorithmRegistry::new();
        let mut db = CostDb::new();
        let prov = SimV100Provider::new(7);
        let rep = ensure_profiled(&g, &reg, &mut db, &prov).unwrap();
        // conv has 3 algorithms (A, B, winograd) but the two convs share a
        // signature; add has 1 → 3 measured for conv + 1 add, 3 cached.
        assert_eq!(rep.measured, 4);
        assert_eq!(rep.cached, 3);
        // re-run: everything cached
        let rep2 = ensure_profiled(&g, &reg, &mut db, &prov).unwrap();
        assert_eq!(rep2.measured, 0);
        assert_eq!(rep2.cached, 7);
    }

    #[test]
    fn sim_profiles_are_deterministic() {
        let g = small_graph();
        let reg = AlgorithmRegistry::new();
        let mut db1 = CostDb::new();
        let mut db2 = CostDb::new();
        ensure_profiled(&g, &reg, &mut db1, &SimV100Provider::new(7)).unwrap();
        ensure_profiled(&g, &reg, &mut db2, &SimV100Provider::new(7)).unwrap();
        assert_eq!(db1.to_json().to_string_compact(), db2.to_json().to_string_compact());
    }

    #[test]
    fn hetero_provider_routes_by_device_and_matches_v100_on_gpu() {
        let g = small_graph();
        let shapes = g.infer_shapes().unwrap();
        let sig = g.node_signature(crate::graph::NodeId(2), &shapes);
        let node = g.node(crate::graph::NodeId(2));
        let in_shapes: Vec<TensorShape> =
            node.inputs.iter().map(|p| shapes[p.node.0][p.port].clone()).collect();
        let out_shapes = &shapes[2];
        let v100 = SimV100Provider::new(7);
        let hetero = SimHeteroProvider::new(7);
        for freq in [
            FreqId::NOMINAL,
            FreqId(900),
            FreqId::NOMINAL.with_layout(Layout::NHWC),
        ] {
            let a = v100.measure(&sig, &node.op, &in_shapes, out_shapes, Algorithm::ConvDirect, freq);
            let b = hetero.measure(&sig, &node.op, &in_shapes, out_shapes, Algorithm::ConvDirect, freq);
            assert_eq!(a.time_ms.to_bits(), b.time_ms.to_bits(), "GPU route must be bit-identical");
            assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        }
        let dla_nom = FreqId::on(DeviceId::DLA, 0);
        let d = hetero.measure(&sig, &node.op, &in_shapes, out_shapes, Algorithm::ConvDirect, dla_nom);
        let g_cost = hetero.measure(&sig, &node.op, &in_shapes, out_shapes, Algorithm::ConvDirect, FreqId::NOMINAL);
        assert!(d.time_ms > g_cost.time_ms, "DLA is slower");
        assert!(d.time_ms * d.power_w < g_cost.time_ms * g_cost.power_w, "DLA is cheaper on energy");
        // Two devices, GPU first; link model present.
        let devs = hetero.device_states();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].0, DeviceId::GPU);
        assert!(hetero.link_model().is_some());
        assert!(v100.link_model().is_none());
        assert_eq!(v100.device_states().len(), 1);
    }

    #[test]
    fn nhwc_reprices_the_memory_path_per_op() {
        let prov = SimV100Provider::new(7);
        let nchw = FreqId::NOMINAL;
        let nhwc = FreqId::NOMINAL.with_layout(Layout::NHWC);

        // Tensor-core-aligned 1x1 conv at a memory-bound shape (low
        // channel count, large spatial): NHWC is cheaper.
        let conv = OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        let conv_in = vec![vec![1, 16, 128, 128], vec![16, 16, 1, 1]];
        let conv_out = vec![vec![1, 16, 128, 128]];
        let sig = conv.signature(&conv_in);
        let a = prov.measure(&sig, &conv, &conv_in, &conv_out, Algorithm::Conv1x1Gemm, nchw);
        let b = prov.measure(&sig, &conv, &conv_in, &conv_out, Algorithm::Conv1x1Gemm, nhwc);
        assert!(b.time_ms < a.time_ms, "aligned conv must get cheaper in NHWC");

        // Depthwise conv walks channels-last badly: NHWC is dearer.
        let dw = OpKind::DwConv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
        };
        let dw_in = vec![vec![1, 32, 128, 128], vec![32, 1, 3, 3]];
        let dw_out = vec![vec![1, 32, 128, 128]];
        let dsig = dw.signature(&dw_in);
        let da = prov.measure(&dsig, &dw, &dw_in, &dw_out, Algorithm::DwDirect, nchw);
        let db = prov.measure(&dsig, &dw, &dw_in, &dw_out, Algorithm::DwDirect, nhwc);
        assert!(db.time_ms > da.time_ms, "depthwise must get dearer in NHWC");

        // Layout-neutral ops are bit-identical across the layout bit.
        let relu = OpKind::Relu;
        let r_in = vec![vec![1, 8, 32, 32]];
        let rsig = relu.signature(&r_in);
        let ra = prov.measure(&rsig, &relu, &r_in, &r_in, Algorithm::Passthrough, nchw);
        let rb = prov.measure(&rsig, &relu, &r_in, &r_in, Algorithm::Passthrough, nhwc);
        assert_eq!(ra.time_ms.to_bits(), rb.time_ms.to_bits());
        assert_eq!(ra.power_w.to_bits(), rb.power_w.to_bits());
    }

    #[test]
    fn cpu_provider_measures_real_time() {
        let g = small_graph();
        let reg = AlgorithmRegistry::new();
        let mut db = CostDb::new();
        let mut prov = CpuProvider::new(None);
        prov.budget_s = 0.005;
        ensure_profiled(&g, &reg, &mut db, &prov).unwrap();
        let shapes = g.infer_shapes().unwrap();
        let sig = g.node_signature(crate::graph::NodeId(2), &shapes);
        let c = db.get(&sig, Algorithm::ConvDirect).unwrap();
        assert!(c.time_ms > 0.0 && c.time_ms.is_finite());
        assert!(c.power_w >= 10.0);
    }
}
