//! Reference implementations of the non-convolution operators: matmul,
//! elementwise, pooling, batch-norm (inference), concat/split, softmax.
//!
//! Every function here is the semantic ground truth the substitution engine
//! verifies against — keep them simple and obviously correct; the optimized
//! paths live in the PJRT artifacts and the blocked matmul below.

use super::Tensor;

/// Dense matmul C[M,N] = A[M,K] @ B[K,N], naive triple loop (ground truth).
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner-dim mismatch: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        for p in 0..k {
            let av = ad[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Cache-blocked matmul — the "fast GEMM" algorithm variant for MatMul
/// nodes. Identical results to `matmul_naive` up to f32 reassociation.
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    const BM: usize = 32;
    const BN: usize = 64;
    const BK: usize = 32;
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner-dim mismatch: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i0 in (0..m).step_by(BM) {
        for p0 in (0..k).step_by(BK) {
            for j0 in (0..n).step_by(BN) {
                let imax = (i0 + BM).min(m);
                let pmax = (p0 + BK).min(k);
                let jmax = (j0 + BN).min(n);
                for i in i0..imax {
                    for p in p0..pmax {
                        let av = ad[i * k + p];
                        let brow = &bd[p * n + j0..p * n + jmax];
                        let orow = &mut out[i * n + j0..i * n + jmax];
                        for (o, bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// ReLU, elementwise.
pub fn relu(x: &Tensor) -> Tensor {
    Tensor::new(x.shape().to_vec(), x.data().iter().map(|v| v.max(0.0)).collect())
}

/// Sigmoid, elementwise.
pub fn sigmoid(x: &Tensor) -> Tensor {
    Tensor::new(
        x.shape().to_vec(),
        x.data().iter().map(|v| 1.0 / (1.0 + (-v).exp())).collect(),
    )
}

/// Elementwise addition of same-shape tensors.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "add shape mismatch");
    Tensor::new(
        a.shape().to_vec(),
        a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect(),
    )
}

/// Elementwise multiplication.
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "mul shape mismatch");
    Tensor::new(
        a.shape().to_vec(),
        a.data().iter().zip(b.data()).map(|(x, y)| x * y).collect(),
    )
}

/// Add a per-channel bias [C] to an NCHW tensor.
pub fn bias_add_nchw(x: &Tensor, bias: &Tensor) -> Tensor {
    let (n, c, h, w) = x.dims4();
    assert_eq!(bias.shape(), &[c], "bias shape mismatch");
    let mut out = x.clone();
    let hw = h * w;
    for ni in 0..n {
        for ci in 0..c {
            let b = bias.data()[ci];
            let base = (ni * c + ci) * hw;
            for o in &mut out.data_mut()[base..base + hw] {
                *o += b;
            }
        }
    }
    out
}

/// Batch normalization at inference time: y = gamma*(x-mean)/sqrt(var+eps)+beta.
/// `params` are four [C] tensors: gamma, beta, mean, var.
pub fn batchnorm_nchw(x: &Tensor, gamma: &Tensor, beta: &Tensor, mean: &Tensor, var: &Tensor, eps: f32) -> Tensor {
    let (n, c, h, w) = x.dims4();
    for t in [gamma, beta, mean, var] {
        assert_eq!(t.shape(), &[c], "batchnorm param shape mismatch");
    }
    let mut out = x.clone();
    let hw = h * w;
    for ci in 0..c {
        // Fold into scale & shift once per channel.
        let scale = gamma.data()[ci] / (var.data()[ci] + eps).sqrt();
        let shift = beta.data()[ci] - mean.data()[ci] * scale;
        for ni in 0..n {
            let base = (ni * c + ci) * hw;
            for o in &mut out.data_mut()[base..base + hw] {
                *o = *o * scale + shift;
            }
        }
    }
    out
}

/// Max pooling over NCHW with kernel (kh,kw), stride (sh,sw), padding (ph,pw).
/// Padded cells are -inf (never selected).
pub fn maxpool_nchw(x: &Tensor, kh: usize, kw: usize, sh: usize, sw: usize, ph: usize, pw: usize) -> Tensor {
    pool_nchw(x, kh, kw, sh, sw, ph, pw, true)
}

/// Average pooling; divisor counts only in-bounds cells (cuDNN's
/// `CUDNN_POOLING_AVERAGE_COUNT_EXCLUDE_PADDING`, TF "SAME" semantics).
pub fn avgpool_nchw(x: &Tensor, kh: usize, kw: usize, sh: usize, sw: usize, ph: usize, pw: usize) -> Tensor {
    pool_nchw(x, kh, kw, sh, sw, ph, pw, false)
}

fn pool_nchw(x: &Tensor, kh: usize, kw: usize, sh: usize, sw: usize, ph: usize, pw: usize, is_max: bool) -> Tensor {
    let (n, c, h, w) = x.dims4();
    let oh = (h + 2 * ph - kh) / sh + 1;
    let ow = (w + 2 * pw - kw) / sw + 1;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = if is_max { f32::NEG_INFINITY } else { 0.0 };
                    let mut count = 0usize;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * sh + ky) as isize - ph as isize;
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                continue;
                            }
                            let v = x.at4(ni, ci, iy as usize, ix as usize);
                            if is_max {
                                acc = acc.max(v);
                            } else {
                                acc += v;
                            }
                            count += 1;
                        }
                    }
                    *out.at4_mut(ni, ci, oy, ox) =
                        if is_max { acc } else if count > 0 { acc / count as f32 } else { 0.0 };
                }
            }
        }
    }
    out
}

/// Global average pooling: [N,C,H,W] -> [N,C,1,1].
pub fn global_avgpool_nchw(x: &Tensor) -> Tensor {
    let (n, c, h, w) = x.dims4();
    let mut out = Tensor::zeros(&[n, c, 1, 1]);
    let hw = (h * w) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0.0;
            for hy in 0..h {
                for wx in 0..w {
                    acc += x.at4(ni, ci, hy, wx);
                }
            }
            *out.at4_mut(ni, ci, 0, 0) = acc / hw;
        }
    }
    out
}

/// Concatenate tensors of equal rank along an arbitrary axis.
pub fn concat_axis(parts: &[&Tensor], axis: usize) -> Tensor {
    assert!(!parts.is_empty());
    let rank = parts[0].rank();
    assert!(axis < rank, "concat axis {axis} out of range for rank {rank}");
    let mut out_shape = parts[0].shape().to_vec();
    for p in &parts[1..] {
        assert_eq!(p.rank(), rank, "concat rank mismatch");
        for d in 0..rank {
            if d != axis {
                assert_eq!(p.shape()[d], out_shape[d], "concat non-axis dim mismatch");
            }
        }
        out_shape[axis] += p.shape()[axis];
    }
    // outer = product of dims before axis; inner = product after axis.
    let outer: usize = out_shape[..axis].iter().product();
    let inner: usize = out_shape[axis + 1..].iter().product();
    let mut data = Vec::with_capacity(out_shape.iter().product());
    for o in 0..outer {
        for p in parts {
            let pa = p.shape()[axis];
            let chunk = pa * inner;
            data.extend_from_slice(&p.data()[o * chunk..(o + 1) * chunk]);
        }
    }
    Tensor::new(out_shape, data)
}

/// Split a tensor along an arbitrary axis into parts of the given sizes.
pub fn split_axis(x: &Tensor, axis: usize, sizes: &[usize]) -> Vec<Tensor> {
    let rank = x.rank();
    assert!(axis < rank, "split axis {axis} out of range");
    assert_eq!(sizes.iter().sum::<usize>(), x.shape()[axis], "split sizes mismatch");
    let outer: usize = x.shape()[..axis].iter().product();
    let inner: usize = x.shape()[axis + 1..].iter().product();
    let total_axis = x.shape()[axis];
    let mut outs = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for &sz in sizes {
        let mut shape = x.shape().to_vec();
        shape[axis] = sz;
        let mut data = Vec::with_capacity(shape.iter().product());
        for o in 0..outer {
            let base = (o * total_axis + off) * inner;
            data.extend_from_slice(&x.data()[base..base + sz * inner]);
        }
        outs.push(Tensor::new(shape, data));
        off += sz;
    }
    outs
}

/// Concatenate along the channel axis (axis=1) of NCHW tensors.
pub fn concat_channels(parts: &[&Tensor]) -> Tensor {
    assert!(!parts.is_empty());
    let (n, _, h, w) = parts[0].dims4();
    let mut c_total = 0;
    for p in parts {
        let (pn, pc, phh, pww) = p.dims4();
        assert_eq!((pn, phh, pww), (n, h, w), "concat non-channel dims must match");
        c_total += pc;
    }
    let mut out = Tensor::zeros(&[n, c_total, h, w]);
    let hw = h * w;
    for ni in 0..n {
        let mut c_off = 0;
        for p in parts {
            let pc = p.shape()[1];
            for ci in 0..pc {
                let src = &p.data()[(ni * pc + ci) * hw..(ni * pc + ci + 1) * hw];
                let dst_base = (ni * c_total + c_off + ci) * hw;
                out.data_mut()[dst_base..dst_base + hw].copy_from_slice(src);
            }
            c_off += pc;
        }
    }
    out
}

/// Split along the channel axis into parts of the given channel counts.
pub fn split_channels(x: &Tensor, channel_counts: &[usize]) -> Vec<Tensor> {
    let (n, c, h, w) = x.dims4();
    assert_eq!(channel_counts.iter().sum::<usize>(), c, "split channel sum mismatch");
    let hw = h * w;
    let mut outs = Vec::with_capacity(channel_counts.len());
    let mut c_off = 0;
    for &pc in channel_counts {
        let mut part = Tensor::zeros(&[n, pc, h, w]);
        for ni in 0..n {
            for ci in 0..pc {
                let src_base = (ni * c + c_off + ci) * hw;
                let dst_base = (ni * pc + ci) * hw;
                part.data_mut()[dst_base..dst_base + hw]
                    .copy_from_slice(&x.data()[src_base..src_base + hw]);
            }
        }
        outs.push(part);
        c_off += pc;
    }
    outs
}

/// Row-wise softmax of a [N, K] tensor (classifier head).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (n, k) = x.dims2();
    let mut out = x.clone();
    for i in 0..n {
        let row = &mut out.data_mut()[i * k..(i + 1) * k];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Flatten [N, C, H, W] -> [N, C*H*W] (for FC heads).
pub fn flatten(x: &Tensor) -> Tensor {
    let (n, c, h, w) = x.dims4();
    x.clone().reshape(&[n, c * h * w])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul_naive(&a, &i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul_naive(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::seed_from(42);
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (33, 65, 70), (64, 64, 64)] {
            let a = Tensor::rand(&[m, k], &mut rng, -1.0, 1.0);
            let b = Tensor::rand(&[k, n], &mut rng, -1.0, 1.0);
            let x = matmul_naive(&a, &b);
            let y = matmul_blocked(&a, &b);
            assert_close(x.data(), y.data(), 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn relu_clamps() {
        let x = Tensor::new(vec![4], vec![-1.0, 0.0, 2.0, -0.5]);
        assert_eq!(relu(&x).data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn add_mul_elementwise() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3], vec![4., 5., 6.]);
        assert_eq!(add(&a, &b).data(), &[5., 7., 9.]);
        assert_eq!(mul(&a, &b).data(), &[4., 10., 18.]);
    }

    #[test]
    fn bias_add_per_channel() {
        let x = Tensor::zeros(&[1, 2, 2, 2]);
        let b = Tensor::new(vec![2], vec![1.0, -1.0]);
        let y = bias_add_nchw(&x, &b);
        assert_eq!(y.at4(0, 0, 1, 1), 1.0);
        assert_eq!(y.at4(0, 1, 0, 0), -1.0);
    }

    #[test]
    fn batchnorm_normalizes() {
        let x = Tensor::new(vec![1, 1, 1, 2], vec![2.0, 4.0]);
        let gamma = Tensor::new(vec![1], vec![1.0]);
        let beta = Tensor::new(vec![1], vec![0.0]);
        let mean = Tensor::new(vec![1], vec![3.0]);
        let var = Tensor::new(vec![1], vec![1.0]);
        let y = batchnorm_nchw(&x, &gamma, &beta, &mean, &var, 0.0);
        assert_close(y.data(), &[-1.0, 1.0], 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn maxpool_2x2() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let y = maxpool_nchw(&x, 2, 2, 2, 2, 0, 0);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data(), &[4.0]);
    }

    #[test]
    fn maxpool_with_padding() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let y = maxpool_nchw(&x, 3, 3, 2, 2, 1, 1);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data(), &[4.0]);
    }

    #[test]
    fn avgpool_excludes_padding() {
        let x = Tensor::full(&[1, 1, 2, 2], 2.0);
        let y = avgpool_nchw(&x, 3, 3, 1, 1, 1, 1);
        // every window averages only in-bounds 2.0s -> all outputs 2.0
        assert!(y.data().iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn global_avgpool() {
        let x = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 3.0, 10.0, 20.0]);
        let y = global_avgpool_nchw(&x);
        assert_eq!(y.shape(), &[1, 2, 1, 1]);
        assert_close(y.data(), &[2.0, 15.0], 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn concat_axis_matches_channel_specialization() {
        let mut rng = Rng::seed_from(21);
        let a = Tensor::rand(&[2, 3, 4, 4], &mut rng, -1.0, 1.0);
        let b = Tensor::rand(&[2, 5, 4, 4], &mut rng, -1.0, 1.0);
        assert_eq!(concat_axis(&[&a, &b], 1), concat_channels(&[&a, &b]));
    }

    #[test]
    fn concat_split_axis0_roundtrip() {
        let mut rng = Rng::seed_from(22);
        let a = Tensor::rand(&[4, 3, 3, 3], &mut rng, -1.0, 1.0);
        let b = Tensor::rand(&[6, 3, 3, 3], &mut rng, -1.0, 1.0);
        let cat = concat_axis(&[&a, &b], 0);
        assert_eq!(cat.shape(), &[10, 3, 3, 3]);
        let parts = split_axis(&cat, 0, &[4, 6]);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn concat_split_rank1() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![3], vec![3.0, 4.0, 5.0]);
        let cat = concat_axis(&[&a, &b], 0);
        assert_eq!(cat.data(), &[1.0, 2.0, 3.0, 4.0, 5.0]);
        let parts = split_axis(&cat, 0, &[2, 3]);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn concat_split_roundtrip() {
        let mut rng = Rng::seed_from(9);
        let a = Tensor::rand(&[2, 3, 4, 4], &mut rng, -1.0, 1.0);
        let b = Tensor::rand(&[2, 5, 4, 4], &mut rng, -1.0, 1.0);
        let cat = concat_channels(&[&a, &b]);
        assert_eq!(cat.shape(), &[2, 8, 4, 4]);
        let parts = split_channels(&cat, &[3, 5]);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let y = softmax_rows(&x);
        for i in 0..2 {
            let s: f32 = y.data()[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn flatten_shape() {
        let x = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(flatten(&x).shape(), &[2, 60]);
    }
}
