//! Dense f32 tensor + reference CPU operator implementations.
//!
//! This is the substrate replacing MetaFlow's built-in inference engine: a
//! small, obviously-correct executor used to (a) verify that graph
//! substitutions preserve semantics, (b) serve as the `Reference` backend of
//! [`crate::engine`], and (c) provide per-algorithm rust implementations
//! (direct / im2col / Winograd convolution) whose wallclock differences feed
//! the profiler when no PJRT artifact matches a node signature.
//!
//! Layout is NCHW throughout (matching the paper's cuDNN default).

/// Dense 2-D convolution: direct, im2col, and 1x1-GEMM algorithms.
pub mod conv;
/// Depthwise convolution algorithms.
pub mod depthwise;
/// Elementwise/pooling/normalization reference ops.
pub mod ops;
/// Winograd F(2x2, 3x3) convolution.
pub mod winograd;

use crate::util::rng::Rng;
use std::fmt;

/// A dense, row-major f32 tensor of arbitrary rank.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Build from a shape and matching data. Panics on length mismatch.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: &[usize], value: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![value; shape.iter().product()] }
    }

    /// Uniform random tensor in [lo, hi) — synthetic activations/weights.
    pub fn rand(shape: &[usize], rng: &mut Rng, lo: f32, hi: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: rng.f32_vec(shape.iter().product(), lo, hi) }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major element slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its elements.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?} mismatch",
            self.shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// NCHW accessor for 4-d tensors.
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        let (_, cc, hh, ww) = self.dims4();
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    /// Mutable NCHW accessor for 4-d tensors.
    #[inline]
    pub fn at4_mut(&mut self, n: usize, c: usize, h: usize, w: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 4);
        let (_, cc, hh, ww) = self.dims4();
        &mut self.data[((n * cc + c) * hh + h) * ww + w]
    }

    /// (N, C, H, W) of a rank-4 tensor.
    pub fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.rank(), 4, "dims4 on rank-{} tensor", self.rank());
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    /// (rows, cols) of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "dims2 on rank-{} tensor", self.rank());
        (self.shape[0], self.shape[1])
    }

    /// Maximum absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in max_abs_diff");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Are all elements finite? (failure-injection tests poison tensors)
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        assert_eq!(t.at4(0, 1, 1, 0), 6.0);
        assert_eq!(t.dims4(), (1, 2, 2, 2));
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn rand_deterministic() {
        let mut r1 = Rng::seed_from(1);
        let mut r2 = Rng::seed_from(1);
        let a = Tensor::rand(&[2, 3], &mut r1, -1.0, 1.0);
        let b = Tensor::rand(&[2, 3], &mut r2, -1.0, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
