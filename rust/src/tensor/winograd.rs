//! Winograd F(2×2, 3×3) convolution — the third convolution algorithm.
//!
//! Replaces the 9 multiplies of a direct 3×3 tap with 16 multiplies per
//! 2×2 output tile (vs 36 direct): a 2.25× multiply reduction, at the cost
//! of extra adds and transform memory. Applicability mirrors cuDNN's
//! `CUDNN_CONVOLUTION_FWD_ALGO_WINOGRAD`: 3×3 kernel, stride 1 only —
//! exactly the "algorithm C is not applicable to this operation" behaviour
//! the paper's Table 1 shows.
//!
//! Transforms (Lavin & Gray 2016):
//! ```text
//! Y = Aᵀ [ (G g Gᵀ) ⊙ (Bᵀ d B) ] A
//! Bᵀ = [1 0 -1 0; 0 1 1 0; 0 -1 1 0; 0 1 0 -1]
//! G  = [1 0 0; ½ ½ ½; ½ -½ ½; 0 0 1]
//! Aᵀ = [1 1 1 0; 0 1 -1 -1]
//! ```

use super::conv::out_dim;
use super::Tensor;

/// Is Winograd F(2,3) applicable to this conv configuration?
pub fn applicable(r: usize, s: usize, stride: (usize, usize)) -> bool {
    r == 3 && s == 3 && stride == (1, 1)
}

/// 4x4 input-tile transform: Bᵀ d B.
#[inline]
fn transform_input(d: &[[f32; 4]; 4]) -> [[f32; 4]; 4] {
    // t = Bᵀ d  (rows combined)
    let mut t = [[0.0f32; 4]; 4];
    for j in 0..4 {
        t[0][j] = d[0][j] - d[2][j];
        t[1][j] = d[1][j] + d[2][j];
        t[2][j] = d[2][j] - d[1][j];
        t[3][j] = d[1][j] - d[3][j];
    }
    // u = t B (columns combined)
    let mut u = [[0.0f32; 4]; 4];
    for i in 0..4 {
        u[i][0] = t[i][0] - t[i][2];
        u[i][1] = t[i][1] + t[i][2];
        u[i][2] = t[i][2] - t[i][1];
        u[i][3] = t[i][1] - t[i][3];
    }
    u
}

/// 3x3 filter transform: G g Gᵀ -> 4x4.
#[inline]
fn transform_filter(g: &[[f32; 3]; 3]) -> [[f32; 4]; 4] {
    // t = G g : 4x3
    let mut t = [[0.0f32; 3]; 4];
    for j in 0..3 {
        t[0][j] = g[0][j];
        t[1][j] = 0.5 * (g[0][j] + g[1][j] + g[2][j]);
        t[2][j] = 0.5 * (g[0][j] - g[1][j] + g[2][j]);
        t[3][j] = g[2][j];
    }
    // u = t Gᵀ : 4x4
    let mut u = [[0.0f32; 4]; 4];
    for i in 0..4 {
        u[i][0] = t[i][0];
        u[i][1] = 0.5 * (t[i][0] + t[i][1] + t[i][2]);
        u[i][2] = 0.5 * (t[i][0] - t[i][1] + t[i][2]);
        u[i][3] = t[i][2];
    }
    u
}

/// Output transform: Aᵀ m A -> 2x2.
#[inline]
fn transform_output(m: &[[f32; 4]; 4]) -> [[f32; 2]; 2] {
    // t = Aᵀ m : 2x4
    let mut t = [[0.0f32; 4]; 2];
    for j in 0..4 {
        t[0][j] = m[0][j] + m[1][j] + m[2][j];
        t[1][j] = m[1][j] - m[2][j] - m[3][j];
    }
    // y = t A : 2x2
    [
        [t[0][0] + t[0][1] + t[0][2], t[0][1] - t[0][2] - t[0][3]],
        [t[1][0] + t[1][1] + t[1][2], t[1][1] - t[1][2] - t[1][3]],
    ]
}

/// Winograd F(2×2,3×3) convolution. Panics if `!applicable(r, s, stride)`.
pub fn conv2d_winograd(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    pad: (usize, usize),
) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (k, wc, r, s) = w.dims4();
    assert_eq!(c, wc, "conv channel mismatch");
    assert!(applicable(r, s, (1, 1)), "winograd requires 3x3 stride-1");
    let (ph, pw) = pad;
    let oh = out_dim(h, 3, 1, ph);
    let ow = out_dim(wid, 3, 1, pw);

    // Pre-transform all filters: [K, C, 4, 4].
    let mut uf = vec![[[0.0f32; 4]; 4]; k * c];
    for ki in 0..k {
        for ci in 0..c {
            let mut g = [[0.0f32; 3]; 3];
            for (ry, row) in g.iter_mut().enumerate() {
                for (sx, v) in row.iter_mut().enumerate() {
                    *v = w.at4(ki, ci, ry, sx);
                }
            }
            uf[ki * c + ci] = transform_filter(&g);
        }
    }

    let tiles_y = oh.div_ceil(2);
    let tiles_x = ow.div_ceil(2);
    let mut out = Tensor::zeros(&[n, k, oh, ow]);

    for ni in 0..n {
        for ty in 0..tiles_y {
            for tx in 0..tiles_x {
                // Gather the 4x4 input tile per channel (with padding), and
                // transform once; reuse across all K filters.
                let mut ud = vec![[[0.0f32; 4]; 4]; c];
                for (ci, slot) in ud.iter_mut().enumerate() {
                    let mut d = [[0.0f32; 4]; 4];
                    for dy in 0..4 {
                        let iy = (ty * 2 + dy) as isize - ph as isize;
                        for dx in 0..4 {
                            let ix = (tx * 2 + dx) as isize - pw as isize;
                            d[dy][dx] = if iy < 0
                                || ix < 0
                                || iy >= h as isize
                                || ix >= wid as isize
                            {
                                0.0
                            } else {
                                x.at4(ni, ci, iy as usize, ix as usize)
                            };
                        }
                    }
                    *slot = transform_input(&d);
                }
                for ki in 0..k {
                    // Elementwise accumulate over channels in transform space.
                    let mut m = [[0.0f32; 4]; 4];
                    for ci in 0..c {
                        let f = &uf[ki * c + ci];
                        let dt = &ud[ci];
                        for i in 0..4 {
                            for j in 0..4 {
                                m[i][j] += f[i][j] * dt[i][j];
                            }
                        }
                    }
                    let y = transform_output(&m);
                    let b = bias.map_or(0.0, |t| t.data()[ki]);
                    for dy in 0..2 {
                        let oy = ty * 2 + dy;
                        if oy >= oh {
                            continue;
                        }
                        for dx in 0..2 {
                            let ox = tx * 2 + dx;
                            if ox >= ow {
                                continue;
                            }
                            *out.at4_mut(ni, ki, oy, ox) = y[dy][dx] + b;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv::conv2d_direct;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn applicability_rules() {
        assert!(applicable(3, 3, (1, 1)));
        assert!(!applicable(3, 3, (2, 2)));
        assert!(!applicable(1, 1, (1, 1)));
        assert!(!applicable(5, 5, (1, 1)));
    }

    #[test]
    fn winograd_matches_direct_even_sizes() {
        let mut rng = Rng::seed_from(31);
        let x = Tensor::rand(&[1, 2, 8, 8], &mut rng, -1.0, 1.0);
        let w = Tensor::rand(&[3, 2, 3, 3], &mut rng, -0.5, 0.5);
        let y0 = conv2d_direct(&x, &w, None, (1, 1), (1, 1));
        let y1 = conv2d_winograd(&x, &w, None, (1, 1));
        assert_eq!(y0.shape(), y1.shape());
        assert_close(y0.data(), y1.data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn winograd_matches_direct_odd_sizes_and_bias() {
        let mut rng = Rng::seed_from(32);
        for (h, w, pad) in [(7, 7, (1, 1)), (5, 9, (1, 1)), (6, 6, (0, 0)), (9, 5, (0, 0))] {
            let x = Tensor::rand(&[2, 3, h, w], &mut rng, -1.0, 1.0);
            let wt = Tensor::rand(&[4, 3, 3, 3], &mut rng, -0.5, 0.5);
            let b = Tensor::rand(&[4], &mut rng, -0.2, 0.2);
            let y0 = conv2d_direct(&x, &wt, Some(&b), (1, 1), pad);
            let y1 = conv2d_winograd(&x, &wt, Some(&b), pad);
            assert_eq!(y0.shape(), y1.shape());
            assert_close(y0.data(), y1.data(), 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "winograd requires")]
    fn winograd_rejects_5x5() {
        let x = Tensor::zeros(&[1, 1, 8, 8]);
        let w = Tensor::zeros(&[1, 1, 5, 5]);
        conv2d_winograd(&x, &w, None, (2, 2));
    }
}
