//! Depthwise convolution algorithms (MobileNet's building block).
//!
//! Weight layout `[C, 1, R, S]`, channel multiplier 1. Two algorithms,
//! mirroring the dense-conv situation: a direct sliding window and a
//! per-channel Winograd F(2×2,3×3) (applicable 3×3 stride-1 only).

use super::conv::out_dim;
use super::winograd;
use super::Tensor;

/// Direct depthwise convolution (per-tap row-saxpy form, like
/// [`super::conv::conv2d_direct`]).
pub fn dwconv2d_direct(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (wc, mult, r, s) = w.dims4();
    assert_eq!(wc, c, "depthwise weight channel mismatch");
    assert_eq!(mult, 1, "depthwise channel multiplier must be 1");
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    let oh = out_dim(h, r, sh, ph);
    let ow = out_dim(wid, s, sw, pw);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let xd = x.data();
    let wd = w.data();
    let od = out.data_mut();
    for ni in 0..n {
        for ci in 0..c {
            let out_base = (ni * c + ci) * oh * ow;
            if let Some(b) = bias {
                let bv = b.data()[ci];
                for v in &mut od[out_base..out_base + oh * ow] {
                    *v = bv;
                }
            }
            let x_base = (ni * c + ci) * h * wid;
            let w_base = ci * r * s;
            for ry in 0..r {
                for sx in 0..s {
                    let wv = wd[w_base + ry * s + sx];
                    if wv == 0.0 {
                        continue;
                    }
                    let oy_lo = ph.saturating_sub(ry).div_ceil(sh);
                    let oy_hi = if h + ph > ry { ((h + ph - ry - 1) / sh + 1).min(oh) } else { 0 };
                    let ox_lo = pw.saturating_sub(sx).div_ceil(sw);
                    let ox_hi = if wid + pw > sx { ((wid + pw - sx - 1) / sw + 1).min(ow) } else { 0 };
                    if oy_lo >= oy_hi || ox_lo >= ox_hi {
                        continue;
                    }
                    for oy in oy_lo..oy_hi {
                        let iy = oy * sh + ry - ph;
                        let xrow = x_base + iy * wid;
                        let orow = out_base + oy * ow;
                        for ox in ox_lo..ox_hi {
                            od[orow + ox] += wv * xd[xrow + ox * sw + sx - pw];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Per-channel Winograd F(2×2,3×3) depthwise conv: each channel is a
/// single-channel dense conv, so the dense Winograd kernel applies
/// channel-by-channel. Requires 3×3 stride-1.
pub fn dwconv2d_winograd(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    pad: (usize, usize),
) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (wc, mult, r, s) = w.dims4();
    assert_eq!(wc, c);
    assert_eq!(mult, 1);
    assert!(winograd::applicable(r, s, (1, 1)), "dw winograd requires 3x3 stride-1");
    let oh = out_dim(h, 3, 1, pad.0);
    let ow = out_dim(wid, 3, 1, pad.1);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let hw = h * wid;
    for ci in 0..c {
        // Per-channel slabs as [N, 1, H, W] / [1, 1, 3, 3].
        let mut xc = Tensor::zeros(&[n, 1, h, wid]);
        for ni in 0..n {
            xc.data_mut()[ni * hw..(ni + 1) * hw]
                .copy_from_slice(&x.data()[(ni * c + ci) * hw..(ni * c + ci + 1) * hw]);
        }
        let wcst = Tensor::new(vec![1, 1, 3, 3], w.data()[ci * 9..(ci + 1) * 9].to_vec());
        let bc = bias.map(|b| Tensor::new(vec![1], vec![b.data()[ci]]));
        let yc = winograd::conv2d_winograd(&xc, &wcst, bc.as_ref(), pad);
        let ohw = oh * ow;
        for ni in 0..n {
            out.data_mut()[(ni * c + ci) * ohw..(ni * c + ci + 1) * ohw]
                .copy_from_slice(&yc.data()[ni * ohw..(ni + 1) * ohw]);
        }
    }
    out
}

/// Ground-truth naive depthwise conv (tests only).
#[cfg(test)]
fn dwconv2d_naive(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (_, _, r, s) = w.dims4();
    let oh = out_dim(h, r, stride.0, pad.0);
    let ow = out_dim(wid, s, stride.1, pad.1);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bias.map_or(0.0, |b| b.data()[ci]);
                    for ry in 0..r {
                        for sx in 0..s {
                            let iy = (oy * stride.0 + ry) as isize - pad.0 as isize;
                            let ix = (ox * stride.1 + sx) as isize - pad.1 as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wid as isize {
                                continue;
                            }
                            acc += x.at4(ni, ci, iy as usize, ix as usize)
                                * w.at4(ci, 0, ry, sx);
                        }
                    }
                    *out.at4_mut(ni, ci, oy, ox) = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn direct_matches_naive() {
        let mut rng = Rng::seed_from(61);
        for (n, c, h, w, r, st, pd) in [
            (1, 3, 8, 8, 3, (1, 1), (1, 1)),
            (2, 4, 9, 7, 3, (2, 2), (1, 1)),
            (1, 2, 6, 6, 5, (1, 1), (2, 2)),
            (1, 5, 8, 8, 3, (2, 2), (0, 0)),
        ] {
            let x = Tensor::rand(&[n, c, h, w], &mut rng, -1.0, 1.0);
            let wt = Tensor::rand(&[c, 1, r, r], &mut rng, -0.5, 0.5);
            let b = Tensor::rand(&[c], &mut rng, -0.1, 0.1);
            let got = dwconv2d_direct(&x, &wt, Some(&b), st, pd);
            let want = dwconv2d_naive(&x, &wt, Some(&b), st, pd);
            assert_eq!(got.shape(), want.shape());
            assert_close(got.data(), want.data(), 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn winograd_matches_naive() {
        let mut rng = Rng::seed_from(62);
        for (h, w, pad) in [(8, 8, (1, 1)), (7, 9, (1, 1)), (6, 6, (0, 0))] {
            let x = Tensor::rand(&[2, 3, h, w], &mut rng, -1.0, 1.0);
            let wt = Tensor::rand(&[3, 1, 3, 3], &mut rng, -0.5, 0.5);
            let b = Tensor::rand(&[3], &mut rng, -0.1, 0.1);
            let got = dwconv2d_winograd(&x, &wt, Some(&b), pad);
            let want = dwconv2d_naive(&x, &wt, Some(&b), (1, 1), pad);
            assert_close(got.data(), want.data(), 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "multiplier")]
    fn rejects_channel_multiplier() {
        let x = Tensor::zeros(&[1, 2, 4, 4]);
        let w = Tensor::zeros(&[2, 3, 3, 3]);
        dwconv2d_direct(&x, &w, None, (1, 1), (1, 1));
    }
}
