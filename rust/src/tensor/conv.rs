//! Convolution algorithms: direct (ground truth) and im2col+GEMM.
//!
//! These correspond to the paper's per-node "algorithms" (cuDNN's
//! IMPLICIT_GEMM vs GEMM vs WINOGRAD ...): semantically identical, very
//! different compute/memory profiles. Winograd lives in
//! [`super::winograd`].

use super::ops::matmul_blocked;
use super::Tensor;

/// Output spatial size for a conv/pool dimension.
pub fn out_dim(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    assert!(
        input + 2 * pad >= kernel,
        "conv output would be empty: in={input} k={kernel} pad={pad}"
    );
    (input + 2 * pad - kernel) / stride + 1
}

/// Direct convolution, NCHW input [N,C,H,W], weight [K,C,R,S], optional
/// bias [K]. Sliding-window semantics, implemented as per-tap row "saxpy"
/// so the inner loop is a contiguous slice walk instead of 4-d index math
/// (≈10× over the naive 7-loop form on this host; see EXPERIMENTS.md §Perf.
/// Semantics are pinned to the naive form by the tests below and the
/// Pallas/ref cross-checks).
pub fn conv2d_direct(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (k, wc, r, s) = w.dims4();
    assert_eq!(c, wc, "conv channel mismatch: input {c} vs weight {wc}");
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    let oh = out_dim(h, r, sh, ph);
    let ow = out_dim(wid, s, sw, pw);
    let mut out = Tensor::zeros(&[n, k, oh, ow]);
    let xd = x.data();
    let wd = w.data();
    let od = out.data_mut();
    for ni in 0..n {
        for ki in 0..k {
            let out_base = (ni * k + ki) * oh * ow;
            if let Some(b) = bias {
                let bv = b.data()[ki];
                for v in &mut od[out_base..out_base + oh * ow] {
                    *v = bv;
                }
            }
            for ci in 0..c {
                let x_base = (ni * c + ci) * h * wid;
                let w_base = (ki * c + ci) * r * s;
                for ry in 0..r {
                    for sx in 0..s {
                        let wv = wd[w_base + ry * s + sx];
                        if wv == 0.0 {
                            continue;
                        }
                        // Valid output-row range for this tap:
                        // 0 <= oy*sh + ry - ph < h
                        let oy_lo = ph.saturating_sub(ry).div_ceil(sh);
                        let oy_hi = if h + ph > ry { ((h + ph - ry - 1) / sh + 1).min(oh) } else { 0 };
                        // Valid output-col range: 0 <= ox*sw + sx - pw < wid
                        let ox_lo = pw.saturating_sub(sx).div_ceil(sw);
                        let ox_hi = if wid + pw > sx { ((wid + pw - sx - 1) / sw + 1).min(ow) } else { 0 };
                        if oy_lo >= oy_hi || ox_lo >= ox_hi {
                            continue;
                        }
                        for oy in oy_lo..oy_hi {
                            let iy = oy * sh + ry - ph;
                            let xrow = x_base + iy * wid;
                            let orow = out_base + oy * ow;
                            if sw == 1 {
                                let ix0 = ox_lo + sx - pw;
                                let len = ox_hi - ox_lo;
                                let xs = &xd[xrow + ix0..xrow + ix0 + len];
                                let os = &mut od[orow + ox_lo..orow + ox_lo + len];
                                for (o, &xv) in os.iter_mut().zip(xs) {
                                    *o += wv * xv;
                                }
                            } else {
                                for ox in ox_lo..ox_hi {
                                    let ix = ox * sw + sx - pw;
                                    od[orow + ox] += wv * xd[xrow + ix];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// im2col: unfold input patches into a [C*R*S, OH*OW] matrix (per image).
pub fn im2col(
    x: &Tensor,
    n_idx: usize,
    r: usize,
    s: usize,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    let (_, c, h, w) = x.dims4();
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    let oh = out_dim(h, r, sh, ph);
    let ow = out_dim(w, s, sw, pw);
    let rows = c * r * s;
    let cols = oh * ow;
    let mut out = vec![0.0f32; rows * cols];
    for ci in 0..c {
        for ry in 0..r {
            for sx in 0..s {
                let row = (ci * r + ry) * s + sx;
                for oy in 0..oh {
                    let iy = (oy * sh + ry) as isize - ph as isize;
                    for ox in 0..ow {
                        let ix = (ox * sw + sx) as isize - pw as isize;
                        let v = if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            0.0
                        } else {
                            x.at4(n_idx, ci, iy as usize, ix as usize)
                        };
                        out[row * cols + oy * ow + ox] = v;
                    }
                }
            }
        }
    }
    Tensor::new(vec![rows, cols], out)
}

/// im2col + GEMM convolution. Trades extra memory traffic (the unfolded
/// patch matrix is R*S× the input) for a single large cache-friendly GEMM —
/// typically faster for big channel counts, and with a very different
/// power/energy profile than direct convolution (the Table 1 phenomenon).
pub fn conv2d_im2col(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (k, wc, r, s) = w.dims4();
    assert_eq!(c, wc, "conv channel mismatch");
    let oh = out_dim(h, r, stride.0, pad.0);
    let ow = out_dim(wid, s, stride.1, pad.1);
    // Weight as [K, C*R*S] (already contiguous in NCHW weight layout).
    let wmat = w.clone().reshape(&[k, c * r * s]);
    let mut out = Tensor::zeros(&[n, k, oh, ow]);
    for ni in 0..n {
        let cols = im2col(x, ni, r, s, stride, pad); // [C*R*S, OH*OW]
        let prod = matmul_blocked(&wmat, &cols); // [K, OH*OW]
        let dst_base = ni * k * oh * ow;
        out.data_mut()[dst_base..dst_base + k * oh * ow].copy_from_slice(prod.data());
        if let Some(b) = bias {
            for ki in 0..k {
                let bb = b.data()[ki];
                let base = dst_base + ki * oh * ow;
                for v in &mut out.data_mut()[base..base + oh * ow] {
                    *v += bb;
                }
            }
        }
    }
    out
}

/// 1x1 ("pointwise") convolution as a pure GEMM — the fastest path for the
/// squeeze layers of SqueezeNet and inception branch reducers.
pub fn conv2d_1x1_gemm(x: &Tensor, w: &Tensor, bias: Option<&Tensor>, stride: (usize, usize)) -> Tensor {
    let (n, c, h, wid) = x.dims4();
    let (k, wc, r, s) = w.dims4();
    assert_eq!((r, s), (1, 1), "conv2d_1x1_gemm requires a 1x1 kernel");
    assert_eq!(c, wc);
    let (sh, sw) = stride;
    if (sh, sw) == (1, 1) {
        let wmat = w.clone().reshape(&[k, c]);
        let mut out = Tensor::zeros(&[n, k, h, wid]);
        let hw = h * wid;
        for ni in 0..n {
            // input channel-major slab [C, H*W] is contiguous in NCHW
            let xin = Tensor::new(
                vec![c, hw],
                x.data()[ni * c * hw..(ni + 1) * c * hw].to_vec(),
            );
            let prod = matmul_blocked(&wmat, &xin);
            let base = ni * k * hw;
            out.data_mut()[base..base + k * hw].copy_from_slice(prod.data());
        }
        if let Some(b) = bias {
            out = super::ops::bias_add_nchw(&out, b);
        }
        out
    } else {
        // Strided 1x1: subsample, then GEMM path on the smaller tensor.
        let oh = out_dim(h, 1, sh, 0);
        let ow = out_dim(wid, 1, sw, 0);
        let mut sub = Tensor::zeros(&[n, c, oh, ow]);
        for ni in 0..n {
            for ci in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        *sub.at4_mut(ni, ci, oy, ox) = x.at4(ni, ci, oy * sh, ox * sw);
                    }
                }
            }
        }
        conv2d_1x1_gemm(&sub, w, bias, (1, 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn out_dim_formula() {
        assert_eq!(out_dim(32, 3, 1, 1), 32);
        assert_eq!(out_dim(32, 3, 2, 1), 16);
        assert_eq!(out_dim(7, 7, 1, 0), 1);
    }

    #[test]
    fn direct_identity_kernel() {
        // 1x1 kernel of 1.0 on single channel = identity.
        let mut rng = Rng::seed_from(5);
        let x = Tensor::rand(&[1, 1, 4, 4], &mut rng, -1.0, 1.0);
        let w = Tensor::new(vec![1, 1, 1, 1], vec![1.0]);
        let y = conv2d_direct(&x, &w, None, (1, 1), (0, 0));
        assert_eq!(y, x);
    }

    #[test]
    fn direct_known_3x3() {
        // All-ones 3x3 input and kernel, pad 1: center output = 9, corner = 4.
        let x = Tensor::full(&[1, 1, 3, 3], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = conv2d_direct(&x, &w, None, (1, 1), (1, 1));
        assert_eq!(y.at4(0, 0, 1, 1), 9.0);
        assert_eq!(y.at4(0, 0, 0, 0), 4.0);
        assert_eq!(y.at4(0, 0, 0, 1), 6.0);
    }

    #[test]
    fn direct_bias() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        let w = Tensor::full(&[2, 1, 1, 1], 1.0);
        let b = Tensor::new(vec![2], vec![0.5, -0.5]);
        let y = conv2d_direct(&x, &w, Some(&b), (1, 1), (0, 0));
        assert_eq!(y.at4(0, 0, 0, 0), 0.5);
        assert_eq!(y.at4(0, 1, 1, 1), -0.5);
    }

    #[test]
    fn im2col_matches_direct_across_shapes() {
        let mut rng = Rng::seed_from(77);
        for (n, c, h, w, k, r, s, st, pd) in [
            (1, 1, 5, 5, 1, 3, 3, (1, 1), (1, 1)),
            (2, 3, 8, 8, 4, 3, 3, (1, 1), (1, 1)),
            (1, 4, 9, 7, 2, 5, 3, (2, 2), (2, 1)),
            (1, 2, 6, 6, 3, 1, 1, (1, 1), (0, 0)),
            (2, 3, 7, 7, 5, 3, 3, (2, 2), (0, 0)),
        ] {
            let x = Tensor::rand(&[n, c, h, w], &mut rng, -1.0, 1.0);
            let wt = Tensor::rand(&[k, c, r, s], &mut rng, -0.5, 0.5);
            let b = Tensor::rand(&[k], &mut rng, -0.1, 0.1);
            let y0 = conv2d_direct(&x, &wt, Some(&b), st, pd);
            let y1 = conv2d_im2col(&x, &wt, Some(&b), st, pd);
            assert_eq!(y0.shape(), y1.shape());
            assert_close(y0.data(), y1.data(), 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn gemm_1x1_matches_direct() {
        let mut rng = Rng::seed_from(123);
        for (stride,) in [((1usize, 1usize),), ((2, 2),)] {
            let x = Tensor::rand(&[2, 6, 8, 8], &mut rng, -1.0, 1.0);
            let w = Tensor::rand(&[4, 6, 1, 1], &mut rng, -0.5, 0.5);
            let b = Tensor::rand(&[4], &mut rng, -0.1, 0.1);
            let y0 = conv2d_direct(&x, &w, Some(&b), stride, (0, 0));
            let y1 = conv2d_1x1_gemm(&x, &w, Some(&b), stride);
            assert_eq!(y0.shape(), y1.shape());
            assert_close(y0.data(), y1.data(), 1e-4, 1e-4).unwrap();
        }
    }
}
