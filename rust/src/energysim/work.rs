//! Nominal work (FLOPs, bytes) of a node — the input to the roofline model.
//!
//! "Nominal" means the algorithm-independent work of the mathematical
//! operator: direct-convolution FLOPs and minimal tensor traffic. Per-
//! algorithm scaling (Winograd's multiply reduction, im2col's workspace
//! traffic) is applied by [`super::algo_profile`].

use crate::graph::{OpKind, TensorShape};

/// FLOPs and bytes moved for one execution of a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Work {
    /// Floating-point operations per execution.
    pub flops: f64,
    /// Bytes moved per execution (inputs + outputs, f32).
    pub bytes: f64,
}

impl Work {
    /// No work (constant-space and input nodes).
    pub const ZERO: Work = Work { flops: 0.0, bytes: 0.0 };

    /// Arithmetic intensity, FLOP/byte.
    pub fn intensity(&self) -> f64 {
        if self.bytes > 0.0 {
            self.flops / self.bytes
        } else {
            0.0
        }
    }
}

const F32: f64 = 4.0;

fn numel(s: &TensorShape) -> f64 {
    s.iter().product::<usize>() as f64
}

/// Nominal work of `op` given its input shapes and inferred output shapes.
/// Constant-space ops (weights, folds) report zero: they never execute on
/// the request path.
pub fn node_work(op: &OpKind, in_shapes: &[TensorShape], out_shapes: &[TensorShape]) -> Work {
    let in_bytes: f64 = in_shapes.iter().map(numel).sum::<f64>() * F32;
    let out_bytes: f64 = out_shapes.iter().map(numel).sum::<f64>() * F32;
    let touch = in_bytes + out_bytes;
    match op {
        OpKind::Input { .. } => Work::ZERO,
        op if op.is_constant_space() => Work::ZERO,
        OpKind::Conv2d { has_bias, has_residual, act, .. } => {
            let w = &in_shapes[1];
            let (k, c, r, s) = (w[0] as f64, w[1] as f64, w[2] as f64, w[3] as f64);
            let out = &out_shapes[0];
            let (n, oh, ow) = (out[0] as f64, out[2] as f64, out[3] as f64);
            let mut flops = 2.0 * n * k * c * r * s * oh * ow;
            let out_elems = n * k * oh * ow;
            if *has_bias {
                flops += out_elems;
            }
            if *has_residual {
                flops += out_elems;
            }
            if !matches!(act, crate::graph::Activation::None) {
                flops += out_elems;
            }
            Work { flops, bytes: touch }
        }
        OpKind::DwConv2d { has_bias, act, .. } => {
            let w = &in_shapes[1];
            let (r, ss) = (w[2] as f64, w[3] as f64);
            let out = &out_shapes[0];
            let (n, c, oh, ow) = (out[0] as f64, out[1] as f64, out[2] as f64, out[3] as f64);
            let mut flops = 2.0 * n * c * r * ss * oh * ow;
            let out_elems = n * c * oh * ow;
            if *has_bias {
                flops += out_elems;
            }
            if !matches!(act, crate::graph::Activation::None) {
                flops += out_elems;
            }
            Work { flops, bytes: touch }
        }
        OpKind::MatMul { act, has_bias } => {
            let (m, k) = (in_shapes[0][0] as f64, in_shapes[0][1] as f64);
            let n = in_shapes[1][1] as f64;
            let mut flops = 2.0 * m * k * n;
            let out_elems = m * n;
            if *has_bias {
                flops += out_elems;
            }
            if !matches!(act, crate::graph::Activation::None) {
                flops += out_elems;
            }
            Work { flops, bytes: touch }
        }
        OpKind::MaxPool { k, .. } | OpKind::AvgPool { k, .. } => {
            let window = (k.0 * k.1) as f64;
            Work { flops: numel(&out_shapes[0]) * window, bytes: touch }
        }
        OpKind::GlobalAvgPool => Work { flops: numel(&in_shapes[0]), bytes: touch },
        OpKind::BatchNorm { .. } => Work { flops: 2.0 * numel(&in_shapes[0]), bytes: touch },
        OpKind::Relu | OpKind::Sigmoid | OpKind::Add | OpKind::AddRelu | OpKind::Mul => {
            Work { flops: numel(&out_shapes[0]), bytes: touch }
        }
        OpKind::Softmax => Work { flops: 4.0 * numel(&in_shapes[0]), bytes: touch },
        // Pure data movement.
        OpKind::Concat { .. } | OpKind::Split { .. } | OpKind::Flatten => {
            Work { flops: 0.0, bytes: touch }
        }
        _ => Work { flops: 0.0, bytes: touch },
    }
}

/// Relative memory-path cost of executing `op` in NHWC instead of NCHW —
/// a multiplier on the node's nominal bytes. The signs mirror production
/// measurements: channels-last feeds the tensor-core conv path without the
/// implicit transposes cuDNN inserts for NCHW (a win when the channel dims
/// vectorize, i.e. are multiples of 8), while the depthwise path loses its
/// per-channel spatial locality in NHWC. GEMM tiles channels-last cleanly
/// when its reduction/output dims align. Element-wise and data-movement ops
/// are layout-oblivious (factor 1).
pub fn nhwc_bytes_factor(op: &OpKind, in_shapes: &[TensorShape]) -> f64 {
    match op {
        OpKind::Conv2d { .. } => {
            let w = &in_shapes[1]; // [K, C, R, S]
            let (cout, cin) = (w[0], w[1]);
            if cin % 8 == 0 && cout % 8 == 0 {
                0.82
            } else {
                1.12
            }
        }
        // Depthwise has no channel reduction to vectorize; NHWC scatters
        // each channel's spatial window across the innermost stride.
        OpKind::DwConv2d { .. } => 1.30,
        OpKind::MatMul { .. } => {
            let k = in_shapes[0][1];
            let n = in_shapes[1][1];
            if k % 8 == 0 && n % 8 == 0 {
                0.90
            } else {
                1.05
            }
        }
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Activation;

    #[test]
    fn conv_flops_formula() {
        let op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        let w = node_work(
            &op,
            &[vec![1, 64, 32, 32], vec![64, 64, 3, 3]],
            &[vec![1, 64, 32, 32]],
        );
        let expect = 2.0 * 64.0 * 64.0 * 9.0 * 32.0 * 32.0;
        assert!((w.flops - expect).abs() < 1.0);
        assert!(w.bytes > 0.0);
    }

    #[test]
    fn bias_act_residual_add_flops() {
        let base = OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        let fused = OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::Relu,
            has_bias: true,
            has_residual: true,
        };
        let ins_base = vec![vec![1, 8, 8, 8], vec![8, 8, 1, 1]];
        let ins_fused = vec![
            vec![1, 8, 8, 8],
            vec![8, 8, 1, 1],
            vec![8],
            vec![1, 8, 8, 8],
        ];
        let outs = vec![vec![1, 8, 8, 8]];
        let w0 = node_work(&base, &ins_base, &outs);
        let w1 = node_work(&fused, &ins_fused, &outs);
        let out_elems = 8.0 * 8.0 * 8.0;
        assert!((w1.flops - w0.flops - 3.0 * out_elems).abs() < 1.0);
    }

    #[test]
    fn weights_are_free() {
        let op = OpKind::weight(vec![64, 64, 3, 3], 0);
        assert_eq!(node_work(&op, &[], &[vec![64, 64, 3, 3]]), Work::ZERO);
    }

    #[test]
    fn matmul_flops() {
        let w = node_work(&OpKind::matmul(), &[vec![4, 8], vec![8, 16]], &[vec![4, 16]]);
        assert!((w.flops - 2.0 * 4.0 * 8.0 * 16.0).abs() < 1e-9);
    }

    #[test]
    fn fused_matmul_adds_epilogue_flops() {
        let base = node_work(&OpKind::matmul(), &[vec![4, 8], vec![8, 16]], &[vec![4, 16]]);
        let fused = node_work(
            &OpKind::MatMul { act: Activation::Relu, has_bias: true },
            &[vec![4, 8], vec![8, 16], vec![4, 16]],
            &[vec![4, 16]],
        );
        let out_elems = 4.0 * 16.0;
        assert!((fused.flops - base.flops - 2.0 * out_elems).abs() < 1e-9);
    }

    #[test]
    fn nhwc_factor_signs() {
        let conv_aligned = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        // Aligned channels: NHWC wins conv.
        assert!(nhwc_bytes_factor(&conv_aligned, &[vec![1, 64, 32, 32], vec![64, 64, 3, 3]]) < 1.0);
        // Ragged channels: NHWC loses conv.
        assert!(nhwc_bytes_factor(&conv_aligned, &[vec![1, 3, 32, 32], vec![23, 3, 3, 3]]) > 1.0);
        // Depthwise always prefers NCHW.
        let dw = OpKind::DwConv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
        };
        assert!(nhwc_bytes_factor(&dw, &[vec![1, 64, 32, 32], vec![64, 1, 3, 3]]) > 1.0);
        // Aligned matmul wins, ragged loses, elementwise is oblivious.
        assert!(nhwc_bytes_factor(&OpKind::matmul(), &[vec![4, 8], vec![8, 16]]) < 1.0);
        assert!(nhwc_bytes_factor(&OpKind::matmul(), &[vec![4, 7], vec![7, 9]]) > 1.0);
        assert_eq!(nhwc_bytes_factor(&OpKind::Relu, &[vec![1, 8, 4, 4]]), 1.0);
    }

    #[test]
    fn concat_is_pure_traffic() {
        let w = node_work(
            &OpKind::Concat { axis: 1 },
            &[vec![1, 3, 4, 4], vec![1, 5, 4, 4]],
            &[vec![1, 8, 4, 4]],
        );
        assert_eq!(w.flops, 0.0);
        assert_eq!(w.bytes, 4.0 * (48.0 + 80.0 + 128.0));
    }

    #[test]
    fn intensity_math() {
        let w = Work { flops: 100.0, bytes: 50.0 };
        assert_eq!(w.intensity(), 2.0);
        assert_eq!(Work::ZERO.intensity(), 0.0);
    }
}
