//! Energy/power simulator — the substitute for the paper's V100 + nvidia-smi
//! measurement substrate (see DESIGN.md §Hardware-Adaptation).
//!
//! The model is a classic two-resource roofline with a utilization-driven
//! power curve:
//!
//! ```text
//! t_compute = flops  / (peak_flops * eff_c(algo))
//! t_memory  = bytes  / (peak_bw    * eff_m(algo))
//! time      = max(t_compute, t_memory) + launch_overhead
//! P         = P_idle + (P_max - P_idle) * intensity(algo)
//!                     * (0.7 * U_compute + 0.3 * U_memory)
//! energy    = P * time
//! ```
//!
//! where `U_compute = t_compute/time`, `U_memory = t_memory/time`. Because
//! different algorithms execute *different work* (Winograd multiplies 2.25×
//! fewer, im2col moves ~3× more bytes) and run the units at different
//! intensities, the simulator reproduces the paper's Table-1 phenomenon:
//! a slower algorithm can draw so much less power that it wins on energy —
//! the signal the whole optimization exploits.
//!
//! "Measurement" adds a small deterministic, seed-hashed noise so that
//! (a) repeated profiles are reproducible, and (b) the cost model's
//! estimates differ from "actual" whole-graph runs the way Table 2 shows
//! (actual time a few % higher: per-node dispatch overhead; actual power a
//! few % lower: idle gaps between kernels).
//!
//! ## DVFS (dynamic voltage and frequency scaling)
//!
//! Real GPUs expose a table of core-clock states, and frequency is the
//! single largest energy knob ("The Impact of GPU DVFS on the Energy and
//! Performance of Deep Learning", arXiv:1905.11012). The simulator models a
//! state `f` with clock ratio `s = f/f_nom` and per-state voltage `V(f)`:
//!
//! ```text
//! peak_flops(f) = peak_flops · s              (core-clock bound)
//! peak_bw(f)    = peak_bw                     (memory clock is independent)
//! P_dyn(f)      = P_dyn · s · (V(f)/V_nom)²   (CMOS dynamic power ~ f·V²)
//! P(f)          = P_idle + P_dyn(f) · draw
//! ```
//!
//! Because idle power is paid for the whole (longer) runtime while dynamic
//! power shrinks with `s·V²`, energy per inference is minimized at a
//! frequency *below* the maximum — the empirical "sweet spot" of
//! arXiv:1905.11012 — and memory-bound nodes can be down-clocked with no
//! latency cost at all (their `max(t_c, t_m)` is pinned by `t_m`). That
//! per-node asymmetry is what the `--dvfs per-node` search exploits.

/// Nominal work (FLOPs, bytes) per operator.
pub mod work;

use crate::algo::Algorithm;
use crate::graph::canonical::Fnv;
pub use work::{nhwc_bytes_factor, node_work, Work};

/// A DVFS frequency state: the core clock in MHz and the voltage the board
/// runs that clock at (the `V(f)` of the `f·V²` dynamic-power law).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FreqState {
    /// Core clock, MHz.
    pub mhz: u16,
    /// Board voltage at this clock, volts.
    pub volt: f64,
}

/// A device class in a heterogeneous accelerator mix. Device 0 is always
/// the primary GPU — every pre-placement `FreqId` implicitly named it, so
/// single-device plans are bit-identical to the pre-placement pipeline by
/// construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct DeviceId(pub u8);

impl DeviceId {
    /// The primary GPU (device 0) — the pre-placement implicit device.
    pub const GPU: DeviceId = DeviceId(0);
    /// The low-power DLA-like accelerator (device 1).
    pub const DLA: DeviceId = DeviceId(1);

    /// Canonical device name ("gpu", "dla").
    pub fn name(&self) -> &'static str {
        match self.0 {
            0 => "gpu",
            1 => "dla",
            _ => "unknown",
        }
    }

    /// Parse a canonical device name. Unknown names are `None` — the CLI
    /// layers a did-you-mean on top.
    pub fn parse(name: &str) -> Option<DeviceId> {
        match name {
            "gpu" => Some(DeviceId::GPU),
            "dla" => Some(DeviceId::DLA),
            _ => None,
        }
    }
}

/// All device names the simulator knows, in `DeviceId` order.
pub const DEVICE_NAMES: &[&str] = &["gpu", "dla"];

/// A tensor memory layout. Layout 0 (NCHW) is the implicit layout every
/// pre-layout plan ran in, so all existing `FreqId` bit patterns (and
/// therefore profiles, resolve-cache keys, and manifests) are preserved by
/// construction when the layout axis is off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Layout {
    /// Channels-first (the framework default; favors the depthwise path).
    #[default]
    NCHW,
    /// Channels-last (tensor-core-friendly; favors conv at aligned shapes).
    NHWC,
}

impl Layout {
    /// Canonical layout name ("nchw", "nhwc").
    pub fn name(&self) -> &'static str {
        match self {
            Layout::NCHW => "nchw",
            Layout::NHWC => "nhwc",
        }
    }

    /// Parse a canonical layout name. Unknown names are `None` — the CLI
    /// layers a did-you-mean on top.
    pub fn parse(name: &str) -> Option<Layout> {
        match name {
            "nchw" => Some(Layout::NCHW),
            "nhwc" => Some(Layout::NHWC),
            _ => None,
        }
    }
}

/// All layout names the simulator knows, in `Layout` order.
pub const LAYOUT_NAMES: &[&str] = &["nchw", "nhwc"];

/// Bit position of the device index inside a packed [`FreqId`].
const DEVICE_SHIFT: u16 = 12;
/// Bit position of the layout flag inside a packed [`FreqId`].
const LAYOUT_SHIFT: u16 = 15;
/// Mask of the device index field inside a packed [`FreqId`].
const DEVICE_MASK: u16 = 0x7;
/// Mask of the device-local MHz field inside a packed [`FreqId`].
const MHZ_MASK: u16 = (1 << DEVICE_SHIFT) - 1;

/// A (device, frequency, layout) choice packed into one `u16`: bit 15
/// carries the tensor layout (0 = NCHW), bits 12..15 the device index, and
/// bits 0..12 the device-local core clock in MHz. The reserved local value
/// 0 means "that device's nominal (maximum) clock".
///
/// Device 0 (the GPU) in layout NCHW packs to the raw MHz value, so every
/// pre-placement, pre-layout `FreqId` — including `FreqId::NOMINAL` (0 =
/// GPU at nominal, NCHW) — keeps its exact bit pattern, profiles its exact
/// database keys, and `--dvfs off` stays exactly the nominal-only search.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct FreqId(pub u16);

impl FreqId {
    /// The GPU's nominal (maximum) clock — the pre-DVFS, pre-placement
    /// default.
    pub const NOMINAL: FreqId = FreqId(0);

    /// Pack a device and a device-local clock (MHz; 0 = that device's
    /// nominal state) in the default NCHW layout. Local clocks above
    /// 4095 MHz don't fit the packed field and are a programming error, as
    /// are device indexes above 7 (bit 15 belongs to the layout flag).
    pub fn on(device: DeviceId, mhz: u16) -> FreqId {
        debug_assert!(mhz <= MHZ_MASK, "device-local clock {mhz} MHz exceeds the packed field");
        debug_assert!(
            (device.0 as u16) <= DEVICE_MASK,
            "device index {} exceeds the packed field",
            device.0
        );
        FreqId((((device.0 as u16) & DEVICE_MASK) << DEVICE_SHIFT) | (mhz & MHZ_MASK))
    }

    /// The device this state runs on.
    pub fn device(&self) -> DeviceId {
        DeviceId(((self.0 >> DEVICE_SHIFT) & DEVICE_MASK) as u8)
    }

    /// The tensor layout this state computes in.
    pub fn layout(&self) -> Layout {
        if self.0 >> LAYOUT_SHIFT == 0 {
            Layout::NCHW
        } else {
            Layout::NHWC
        }
    }

    /// The same (device, clock) state in another layout.
    pub fn with_layout(&self, layout: Layout) -> FreqId {
        match layout {
            Layout::NCHW => FreqId(self.0 & !(1 << LAYOUT_SHIFT)),
            Layout::NHWC => FreqId(self.0 | (1 << LAYOUT_SHIFT)),
        }
    }

    /// The device-local core clock in MHz (0 = that device's nominal).
    pub fn mhz(&self) -> u16 {
        self.0 & MHZ_MASK
    }

    /// The same state stripped of its device bits — what device-local
    /// models ([`GpuSpec`], [`EnergyModel`]) consume.
    pub fn local(&self) -> FreqId {
        FreqId(self.mhz())
    }

    /// Whether this is its device's nominal (maximum) clock.
    pub fn is_nominal(&self) -> bool {
        self.mhz() == 0
    }

    /// Human-readable label ("nominal", "900MHz", "dla", "dla@640MHz");
    /// non-default layouts append a "+nhwc" suffix.
    pub fn describe(&self) -> String {
        let base = match (self.device(), self.mhz()) {
            (DeviceId::GPU, 0) => "nominal".to_string(),
            (DeviceId::GPU, m) => format!("{m}MHz"),
            (d, 0) => d.name().to_string(),
            (d, m) => format!("{}@{m}MHz", d.name()),
        };
        match self.layout() {
            Layout::NCHW => base,
            Layout::NHWC => format!("{base}+nhwc"),
        }
    }
}

/// Static description of the simulated device.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    /// Device name, recorded as measurement provenance.
    pub name: String,
    /// Peak f32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak memory bandwidth, bytes/s.
    pub peak_bw: f64,
    /// Idle (base) board power, W.
    pub idle_power: f64,
    /// Board power limit (TDP), W.
    pub max_power: f64,
    /// Fixed per-kernel launch overhead, seconds.
    pub launch_overhead_s: f64,
    /// Per-node framework dispatch overhead in whole-graph runs, seconds
    /// (MetaFlow-engine analogue; the reason "actual" time > estimated).
    pub dispatch_overhead_s: f64,
    /// Fraction of launch overhead hidden by pipelining in whole-graph runs.
    pub launch_overlap: f64,
    /// DVFS states the device exposes, ascending by clock; the last entry
    /// is the nominal (maximum) state. Empty = the device does not support
    /// frequency scaling (only `FreqId::NOMINAL` is valid then).
    pub freq_states: Vec<FreqState>,
}

impl GpuSpec {
    /// NVIDIA Tesla V100 (PCIe 16GB): 14 TFLOP/s fp32, 900 GB/s HBM2,
    /// ~40 W idle, 250–300 W TDP. Overheads from published kernel-launch
    /// microbenchmarks (~5 µs) plus a framework dispatch cost.
    pub fn v100() -> GpuSpec {
        GpuSpec {
            name: "sim-v100".into(),
            peak_flops: 14.0e12,
            peak_bw: 900.0e9,
            idle_power: 40.0,
            max_power: 300.0,
            launch_overhead_s: 5.0e-6,
            dispatch_overhead_s: 2.2e-6,
            launch_overlap: 0.35,
            // The V100 exposes 135–1380 MHz SM clocks in 7.5 MHz steps;
            // coarsened to 7 levels (finer near the top, where the
            // energy/latency trade is tightest). V(f) follows the board's
            // roughly linear volt/clock curve between ~0.80 V and 1.05 V.
            freq_states: v100_freq_curve(),
        }
    }

    /// A single-core CPU-ish device, used when interpreting real PJRT
    /// wallclock measurements (power model only; time is measured).
    pub fn cpu_1core() -> GpuSpec {
        GpuSpec {
            name: "cpu-1core".into(),
            peak_flops: 5.0e9,
            peak_bw: 10.0e9,
            idle_power: 10.0,
            max_power: 35.0,
            launch_overhead_s: 1.0e-6,
            dispatch_overhead_s: 1.0e-6,
            launch_overlap: 0.0,
            freq_states: Vec::new(),
        }
    }

    /// A DLA-like fixed-function inference accelerator sharing the board:
    /// an order of magnitude below the GPU on peak throughput and memory
    /// path, but with a far lower power envelope — slower per node, yet
    /// often cheaper per joule, which is exactly the placement trade the
    /// heterogeneous search exploits (AxoNN's GPU+DLA pattern).
    pub fn dla() -> GpuSpec {
        GpuSpec {
            name: "sim-dla".into(),
            peak_flops: 2.2e12,
            peak_bw: 60.0e9,
            idle_power: 4.0,
            max_power: 18.0,
            // Fixed-function pipeline: cheaper launches, but every node
            // goes through the same firmware dispatch path.
            launch_overhead_s: 8.0e-6,
            dispatch_overhead_s: 3.0e-6,
            launch_overlap: 0.20,
            freq_states: dla_freq_curve(),
        }
    }

    /// Nominal (maximum) core clock in MHz; 0 when the device exposes no
    /// frequency table.
    pub fn nominal_mhz(&self) -> u16 {
        self.freq_states.last().map(|s| s.mhz).unwrap_or(0)
    }

    /// Is `f` (by value or by being the max table entry) the nominal state?
    pub fn is_nominal(&self, f: FreqId) -> bool {
        f.is_nominal() || f.0 >= self.nominal_mhz()
    }

    /// The canonical spec of an addressable device class (`None` for
    /// device indexes the simulator does not model). This is the mapping
    /// the fault-injection layer uses to resolve power caps and nominal
    /// clocks; it must stay consistent with `SimHeteroProvider`.
    pub fn for_device(d: DeviceId) -> Option<GpuSpec> {
        match d {
            DeviceId::GPU => Some(GpuSpec::v100()),
            DeviceId::DLA => Some(GpuSpec::dla()),
            _ => None,
        }
    }

    /// The DVFS states still reachable under a thermal clock cap of
    /// `max_mhz` (ascending, possibly empty when the cap sits below the
    /// whole table).
    pub fn capped_states(&self, max_mhz: u16) -> Vec<FreqState> {
        self.freq_states.iter().filter(|s| s.mhz <= max_mhz).copied().collect()
    }

    /// The highest core clock whose modeled full-draw board power fits a
    /// `watts` budget: `P(f) = P_idle + (P_max − P_idle) · s · (V/V_nom)²`
    /// evaluated per table state. Returns `None` when the budget covers
    /// the nominal state (the cap is a no-op); a budget below even the
    /// lowest state clamps to the lowest state — the board throttles, it
    /// does not power off.
    pub fn max_mhz_under_power(&self, watts: f64) -> Option<u16> {
        let nom = self.freq_states.last()?;
        let power_at = |s: &FreqState| {
            let clock = s.mhz as f64 / nom.mhz as f64;
            let v = s.volt / nom.volt;
            self.idle_power + (self.max_power - self.idle_power) * clock * v * v
        };
        if watts >= power_at(nom) {
            return None;
        }
        self.freq_states
            .iter()
            .rev()
            .find(|s| power_at(s) <= watts)
            .or(self.freq_states.first())
            .map(|s| s.mhz)
    }

    /// Clock and dynamic-power scale factors of a frequency state:
    /// `(s, s·(V(f)/V_nom)²)`. Nominal (and unknown) states scale by 1.
    pub fn dvfs_scale(&self, f: FreqId) -> (f64, f64) {
        if self.is_nominal(f) {
            return (1.0, 1.0);
        }
        let Some(nom) = self.freq_states.last() else { return (1.0, 1.0) };
        // Nearest table state at or below the requested clock (exact for
        // table members; robust against off-table values).
        let state = self
            .freq_states
            .iter()
            .rev()
            .find(|s| s.mhz <= f.0)
            .unwrap_or(&self.freq_states[0]);
        let s = state.mhz as f64 / nom.mhz as f64;
        let v = state.volt / nom.volt;
        (s, s * v * v)
    }
}

/// The coarsened V100 DVFS table (see [`GpuSpec::v100`]).
fn v100_freq_curve() -> Vec<FreqState> {
    // V(f) ≈ 0.65 + 0.40 · f/f_nom — linear volt/clock curve, ~0.80 V at
    // the lowest state, 1.05 V at the nominal 1380 MHz.
    [510u16, 705, 900, 1095, 1230, 1327, 1380]
        .iter()
        .map(|&mhz| FreqState { mhz, volt: 0.65 + 0.40 * mhz as f64 / 1380.0 })
        .collect()
}

/// The DLA clock table (see [`GpuSpec::dla`]): four coarse states, nominal
/// at 1280 MHz, on a shallower volt/clock curve than the GPU (the block
/// runs near threshold voltage already).
fn dla_freq_curve() -> Vec<FreqState> {
    [320u16, 640, 960, 1280]
        .iter()
        .map(|&mhz| FreqState { mhz, volt: 0.55 + 0.25 * mhz as f64 / 1280.0 })
        .collect()
}

/// Cost model of the interconnect a tensor crosses when adjacent nodes are
/// placed on different devices (the AxoNN per-transition term): a fixed
/// per-transfer handshake plus a bandwidth/energy term per byte moved.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Fixed per-transfer latency, seconds (sync + descriptor setup).
    pub latency_s: f64,
    /// Link bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Data-movement energy, joules per byte (DRAM round trip + link PHY).
    pub energy_per_byte: f64,
    /// Fixed per-transfer energy, joules.
    pub energy_per_transfer: f64,
}

impl LinkModel {
    /// The shared-DRAM path between the GPU and the DLA block: tensors
    /// round-trip through device memory rather than a dedicated fabric.
    pub fn shared_dram() -> LinkModel {
        LinkModel {
            latency_s: 12.0e-6,
            bandwidth: 16.0e9,
            energy_per_byte: 250.0e-12,
            energy_per_transfer: 25.0e-6,
        }
    }

    /// Cost of moving `bytes` across the link once, in the table's units:
    /// milliseconds and millijoules-per-inference (the same `ms × W` unit
    /// [`SimCost::energy_j`] uses, i.e. J per 1000 inferences).
    pub fn transfer_cost(&self, bytes: f64) -> (f64, f64) {
        let time_ms = (self.latency_s + bytes / self.bandwidth) * 1e3;
        let energy_mj = (self.energy_per_transfer + bytes * self.energy_per_byte) * 1e3;
        (time_ms, energy_mj)
    }
}

/// Cost model of an implicit layout transpose: when adjacent nodes compute
/// in different tensor layouts, the consumer re-tiles its input on the way
/// in. A transpose is bandwidth-bound (read + write one tensor through
/// on-chip staging), so the model is a fixed kernel launch plus a per-byte
/// bandwidth/energy term — much cheaper than a device transfer, but charged
/// on every layout-boundary edge, which is what keeps the search from
/// flip-flopping layouts node-by-node.
#[derive(Debug, Clone, Copy)]
pub struct TransposeModel {
    /// Fixed per-transpose latency, seconds (kernel launch + tiling setup).
    pub latency_s: f64,
    /// Effective re-tiling bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Data-movement energy, joules per byte (one DRAM round trip).
    pub energy_per_byte: f64,
}

impl TransposeModel {
    /// The on-device NCHW↔NHWC re-tiling kernel.
    pub fn on_device() -> TransposeModel {
        TransposeModel { latency_s: 3.0e-6, bandwidth: 300.0e9, energy_per_byte: 80.0e-12 }
    }

    /// Cost of transposing `bytes` once, in the table's units: milliseconds
    /// and millijoules-per-inference (same convention as
    /// [`LinkModel::transfer_cost`]).
    pub fn transpose_cost(&self, bytes: f64) -> (f64, f64) {
        let time_ms = (self.latency_s + bytes / self.bandwidth) * 1e3;
        let energy_mj = (bytes * self.energy_per_byte) * 1e3;
        (time_ms, energy_mj)
    }
}

/// Per-algorithm execution character: how efficiently it drives each
/// resource, how it scales the nominal work, and how hot it runs the chip.
/// Calibrated so the Table-1 inversions occur; see module docs.
#[derive(Debug, Clone, Copy)]
pub struct AlgoProfile {
    /// Fraction of peak FLOP/s this algorithm achieves.
    pub compute_eff: f64,
    /// Fraction of peak bandwidth this algorithm achieves.
    pub mem_eff: f64,
    /// Multiplier on nominal FLOPs (Winograd < 1).
    pub flops_factor: f64,
    /// Multiplier on nominal bytes (im2col > 1: patch-matrix traffic).
    pub bytes_factor: f64,
    /// Power intensity: how hard the active units draw relative to TDP.
    pub intensity: f64,
    /// Occupancy knee, FLOPs: kernels smaller than this underutilize the
    /// device (wave quantization / tiling inefficiency). Effective compute
    /// efficiency is scaled by `f/(f + occ_flops)` — GEMM-style algorithms
    /// amortize small problems better than direct loops, so the knee
    /// differs per algorithm. This per-(algorithm, size) interaction is
    /// what makes different nodes flip algorithms at different tradeoff
    /// weights (the paper's smooth Table-4 frontier).
    pub occ_flops: f64,
}

/// The calibrated profile table. The *relative* character mirrors cuDNN
/// measurements on V100 (GEMM-based convs run hot and fast; direct convs
/// run cool; Winograd does less arithmetic).
pub fn algo_profile(algo: Algorithm) -> AlgoProfile {
    match algo {
        Algorithm::ConvIm2col => AlgoProfile {
            compute_eff: 0.58,
            mem_eff: 0.70,
            flops_factor: 1.0,
            bytes_factor: 3.2,
            intensity: 1.00,
            occ_flops: 1.5e6,
        },
        Algorithm::ConvDirect => AlgoProfile {
            compute_eff: 0.42,
            mem_eff: 0.55,
            flops_factor: 1.0,
            bytes_factor: 1.0,
            intensity: 0.45,
            occ_flops: 6.0e6,
        },
        Algorithm::ConvWinograd => AlgoProfile {
            compute_eff: 0.48,
            mem_eff: 0.60,
            flops_factor: 1.0 / 2.25,
            bytes_factor: 1.9,
            intensity: 0.82,
            occ_flops: 3.0e6,
        },
        Algorithm::Conv1x1Gemm => AlgoProfile {
            compute_eff: 0.62,
            mem_eff: 0.75,
            flops_factor: 1.0,
            bytes_factor: 1.0,
            intensity: 0.90,
            occ_flops: 1.0e6,
        },
        Algorithm::DwDirect => AlgoProfile {
            // Depthwise is bandwidth-bound (no channel reduction): low
            // compute efficiency, cool-running.
            compute_eff: 0.20,
            mem_eff: 0.60,
            flops_factor: 1.0,
            bytes_factor: 1.0,
            intensity: 0.40,
            occ_flops: 2.0e6,
        },
        Algorithm::DwWinograd => AlgoProfile {
            compute_eff: 0.26,
            mem_eff: 0.55,
            flops_factor: 1.0 / 2.25,
            bytes_factor: 1.6,
            intensity: 0.55,
            occ_flops: 3.0e6,
        },
        Algorithm::GemmBlocked => AlgoProfile {
            compute_eff: 0.65,
            mem_eff: 0.75,
            flops_factor: 1.0,
            bytes_factor: 1.0,
            intensity: 0.95,
            occ_flops: 1.0e6,
        },
        Algorithm::GemmNaive => AlgoProfile {
            compute_eff: 0.18,
            mem_eff: 0.40,
            flops_factor: 1.0,
            bytes_factor: 1.0,
            intensity: 0.45,
            occ_flops: 8.0e6,
        },
        Algorithm::Passthrough => AlgoProfile {
            compute_eff: 0.25,
            mem_eff: 0.65,
            flops_factor: 1.0,
            bytes_factor: 1.0,
            intensity: 0.38,
            occ_flops: 0.5e6,
        },
    }
}

/// Time/power/energy of one node under one algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimCost {
    /// Inference time, milliseconds (paper's Time column).
    pub time_ms: f64,
    /// Average power, watts (paper's Power column).
    pub power_w: f64,
}

impl SimCost {
    /// Energy per 1000 inferences in joules — numerically equal to
    /// `time_ms * power_w` (ms × W = mJ per inference = J per 1000).
    pub fn energy_j(&self) -> f64 {
        self.time_ms * self.power_w
    }
}

/// The simulator: a [`GpuSpec`] plus a calibration seed driving the
/// deterministic measurement noise.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Static device description (roofline peaks, power, DVFS table).
    pub spec: GpuSpec,
    /// Calibration seed driving the deterministic measurement noise.
    pub seed: u64,
    /// Measurement-noise amplitude (relative, e.g. 0.015 = ±1.5%).
    pub noise: f64,
}

impl EnergyModel {
    /// The simulated V100 with ±1.5% seed-hashed measurement noise.
    pub fn v100(seed: u64) -> EnergyModel {
        EnergyModel { spec: GpuSpec::v100(), seed, noise: 0.015 }
    }

    /// The simulated DLA block with ±1.5% seed-hashed measurement noise.
    /// Callers pass a device-distinct seed so GPU and DLA measurements of
    /// the same signature draw independent noise.
    pub fn dla(seed: u64) -> EnergyModel {
        EnergyModel { spec: GpuSpec::dla(), seed, noise: 0.015 }
    }

    /// Noise multiplier in [1-noise, 1+noise], deterministic per key.
    fn jitter(&self, key: &str, salt: u64) -> f64 {
        let mut h = Fnv::default();
        h.write_u64(self.seed);
        h.write(key.as_bytes());
        h.write_u64(salt);
        let unit = (h.finish() >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
        1.0 + self.noise * (2.0 * unit - 1.0)
    }

    /// Ideal (noise-free) roofline cost of executing `work` with `algo` at
    /// the nominal clock.
    pub fn ideal_cost(&self, w: &Work, algo: Algorithm) -> SimCost {
        self.ideal_cost_at(w, algo, FreqId::NOMINAL)
    }

    /// Ideal (noise-free) roofline cost at DVFS state `freq`: compute
    /// throughput scales with the clock ratio `s`, memory bandwidth and
    /// launch overhead do not, and the dynamic power term scales with
    /// `s·V(f)²` (see the module docs). `FreqId::NOMINAL` reproduces the
    /// pre-DVFS model bit-for-bit.
    pub fn ideal_cost_at(&self, w: &Work, algo: Algorithm, freq: FreqId) -> SimCost {
        let (s_clock, s_dyn) = self.spec.dvfs_scale(freq);
        let p = algo_profile(algo);
        let flops = w.flops * p.flops_factor;
        let bytes = w.bytes * p.bytes_factor;
        // Occupancy: small kernels underutilize the device, with a knee
        // that depends on the algorithm's launch/tiling granularity.
        // (Occupancy is a tiling/geometry property — clock-independent.)
        let occ = if flops > 0.0 { (flops / (flops + p.occ_flops)).max(0.05) } else { 1.0 };
        let t_c = flops / (self.spec.peak_flops * p.compute_eff * occ) / s_clock;
        let t_m = bytes / (self.spec.peak_bw * p.mem_eff);
        let t_busy = t_c.max(t_m);
        let time = t_busy + self.spec.launch_overhead_s;
        let u_c = if time > 0.0 { t_c / time } else { 0.0 };
        let u_m = if time > 0.0 { t_m / time } else { 0.0 };
        // Underoccupied kernels leave units idle: damp the draw by √occ.
        let draw = (0.7 * u_c + 0.3 * u_m).min(1.0) * p.intensity * occ.sqrt();
        let power = (self.spec.idle_power
            + (self.spec.max_power - self.spec.idle_power) * draw * s_dyn)
            .min(self.spec.max_power);
        SimCost { time_ms: time * 1e3, power_w: power }
    }

    /// "Measured" per-node cost: roofline + deterministic measurement noise.
    /// This is what the profiler writes into the cost database (the paper's
    /// per-node nvidia-smi measurement step).
    pub fn measured_cost(&self, sig: &str, w: &Work, algo: Algorithm) -> SimCost {
        self.measured_cost_at(sig, w, algo, FreqId::NOMINAL)
    }

    /// As [`EnergyModel::measured_cost`] at a DVFS state. Nominal states
    /// use the original jitter key, so pre-DVFS profiles are reproduced
    /// bit-for-bit; each non-nominal state gets its own measurement noise.
    pub fn measured_cost_at(&self, sig: &str, w: &Work, algo: Algorithm, freq: FreqId) -> SimCost {
        let ideal = self.ideal_cost_at(w, algo, freq);
        if self.spec.is_nominal(freq) {
            return SimCost {
                time_ms: ideal.time_ms * self.jitter(sig, 1),
                power_w: ideal.power_w * self.jitter(sig, 2),
            };
        }
        let key = format!("{sig}@f{}", freq.0);
        SimCost {
            time_ms: ideal.time_ms * self.jitter(&key, 1),
            power_w: ideal.power_w * self.jitter(&key, 2),
        }
    }

    /// "Actual" whole-graph execution (the paper's Table-2 ACTUAL rows):
    /// sums node busy times, partially hides launch overhead, adds framework
    /// dispatch per node, and averages power *including the idle slack* —
    /// so actual time lands a few percent above the additive estimate and
    /// actual power a bit below it, with the same signs as the paper. Each
    /// node runs at its assigned DVFS state (all-`NOMINAL` = pre-DVFS run).
    pub fn graph_run(&self, nodes: &[(String, Work, Algorithm, FreqId)]) -> SimCost {
        let mut sum_t = 0.0; // additive-estimate time (per-node measured)
        let mut sum_e = 0.0; // additive-estimate energy
        for (sig, w, algo, freq) in nodes {
            let c = self.measured_cost_at(sig, w, *algo, *freq);
            sum_t += c.time_ms * 1e-3;
            sum_e += c.power_w * c.time_ms * 1e-3;
        }
        // Per node: framework dispatch is paid in full, a fraction of the
        // launch overhead (already inside each per-node time) is hidden by
        // pipelining. Net per-node extra runs at idle power — so actual
        // time lands a few % above the additive estimate and actual power
        // a few % below it (the Table-2 signs).
        let extra_per_node =
            self.spec.dispatch_overhead_s - self.spec.launch_overhead_s * self.spec.launch_overlap;
        let extra_s = nodes.len() as f64 * extra_per_node;
        let total_s = sum_t + extra_s;
        let energy_j = sum_e + extra_s.max(0.0) * self.spec.idle_power;
        let jit = self.jitter("graph_run", nodes.len() as u64);
        let time_ms = total_s * 1e3 * jit;
        let power_w = if total_s > 0.0 { energy_j / total_s } else { 0.0 };
        SimCost { time_ms, power_w: power_w * self.jitter("graph_power", nodes.len() as u64) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_work() -> Work {
        // 3x3 conv, 64->64 channels, 32x32 input, batch 1
        Work {
            flops: 2.0 * 64.0 * 64.0 * 9.0 * 32.0 * 32.0,
            bytes: 4.0 * (64.0 * 32.0 * 32.0 * 2.0 + 64.0 * 64.0 * 9.0),
        }
    }

    #[test]
    fn direct_cooler_than_im2col() {
        let m = EnergyModel::v100(7);
        let a = m.ideal_cost(&conv_work(), Algorithm::ConvIm2col);
        let b = m.ideal_cost(&conv_work(), Algorithm::ConvDirect);
        assert!(b.power_w < a.power_w, "direct {} vs im2col {}", b.power_w, a.power_w);
        assert!(b.time_ms > a.time_ms, "direct should be slower on compute-bound conv");
    }

    #[test]
    fn table1_inversion_exists() {
        // For a compute-heavy 3x3 conv, Winograd should win on both time and
        // energy (paper conv3 / algorithm C), and direct should beat im2col
        // on energy while losing on time (conv1 A vs B character).
        let m = EnergyModel::v100(7);
        let w = conv_work();
        let a = m.ideal_cost(&w, Algorithm::ConvIm2col);
        let b = m.ideal_cost(&w, Algorithm::ConvDirect);
        let c = m.ideal_cost(&w, Algorithm::ConvWinograd);
        assert!(c.time_ms < a.time_ms);
        assert!(c.energy_j() < a.energy_j());
        assert!(b.energy_j() < a.energy_j(), "B energy {} vs A {}", b.energy_j(), a.energy_j());
    }

    #[test]
    fn power_within_board_limits() {
        let m = EnergyModel::v100(1);
        for algo in [
            Algorithm::ConvIm2col,
            Algorithm::ConvDirect,
            Algorithm::ConvWinograd,
            Algorithm::Passthrough,
        ] {
            let c = m.ideal_cost(&conv_work(), algo);
            assert!(c.power_w >= m.spec.idle_power && c.power_w <= m.spec.max_power);
        }
    }

    #[test]
    fn measurement_noise_small_and_deterministic() {
        let m = EnergyModel::v100(42);
        let w = conv_work();
        let x = m.measured_cost("sig", &w, Algorithm::ConvIm2col);
        let y = m.measured_cost("sig", &w, Algorithm::ConvIm2col);
        assert_eq!(x, y);
        let ideal = m.ideal_cost(&w, Algorithm::ConvIm2col);
        assert!((x.time_ms / ideal.time_ms - 1.0).abs() <= m.noise + 1e-9);
    }

    #[test]
    fn graph_run_slower_than_sum_and_cooler() {
        let m = EnergyModel::v100(3);
        let nodes: Vec<(String, Work, Algorithm, FreqId)> = (0..20)
            .map(|i| (format!("n{i}"), conv_work(), Algorithm::ConvIm2col, FreqId::NOMINAL))
            .collect();
        let run = m.graph_run(&nodes);
        let est_time: f64 = nodes
            .iter()
            .map(|(s, w, a, f)| m.measured_cost_at(s, w, *a, *f).time_ms)
            .sum();
        let est_energy: f64 = nodes
            .iter()
            .map(|(s, w, a, f)| {
                let c = m.measured_cost_at(s, w, *a, *f);
                c.energy_j()
            })
            .sum();
        let est_power = est_energy / est_time;
        assert!(run.time_ms > est_time * 0.97, "actual {} vs est {}", run.time_ms, est_time);
        assert!(run.power_w < est_power * 1.03, "actual {} vs est {}", run.power_w, est_power);
    }

    #[test]
    fn energy_is_time_times_power() {
        let c = SimCost { time_ms: 0.0195, power_w: 144.5 };
        assert!((c.energy_j() - 2.81775).abs() < 1e-9);
    }

    #[test]
    fn nominal_freq_reproduces_pre_dvfs_costs_bitwise() {
        let m = EnergyModel::v100(7);
        let w = conv_work();
        for algo in [Algorithm::ConvIm2col, Algorithm::ConvDirect, Algorithm::Passthrough] {
            let a = m.ideal_cost(&w, algo);
            let b = m.ideal_cost_at(&w, algo, FreqId::NOMINAL);
            // The max table state IS the nominal state.
            let c = m.ideal_cost_at(&w, algo, FreqId(m.spec.nominal_mhz()));
            assert_eq!(a.time_ms.to_bits(), b.time_ms.to_bits());
            assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
            assert_eq!(a.time_ms.to_bits(), c.time_ms.to_bits());
            let ma = m.measured_cost("s", &w, algo);
            let mb = m.measured_cost_at("s", &w, algo, FreqId::NOMINAL);
            assert_eq!(ma, mb);
        }
    }

    #[test]
    fn dvfs_monotone_in_frequency() {
        // Time non-increasing and power non-decreasing as the clock rises
        // (ideal model; the property test covers random work shapes).
        let m = EnergyModel::v100(7);
        let w = conv_work();
        for algo in [Algorithm::ConvIm2col, Algorithm::ConvDirect, Algorithm::ConvWinograd] {
            let mut prev: Option<SimCost> = None;
            for st in &m.spec.freq_states {
                let c = m.ideal_cost_at(&w, algo, FreqId(st.mhz));
                if let Some(p) = prev {
                    assert!(c.time_ms <= p.time_ms + 1e-12, "{algo:?}: time rose with clock");
                    assert!(c.power_w >= p.power_w - 1e-12, "{algo:?}: power fell with clock");
                }
                prev = Some(c);
            }
        }
    }

    #[test]
    fn dvfs_sweet_spot_below_max_frequency() {
        // The arXiv:1905.11012 phenomenon: for a compute-bound conv the
        // energy-optimal clock is strictly below the maximum but above the
        // minimum (idle power punishes very low clocks).
        let m = EnergyModel::v100(7);
        let w = conv_work();
        let energies: Vec<f64> = m
            .spec
            .freq_states
            .iter()
            .map(|st| m.ideal_cost_at(&w, Algorithm::ConvIm2col, FreqId(st.mhz)).energy_j())
            .collect();
        let best = energies
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(best > 0, "lowest clock should not be energy-optimal (idle power)");
        assert!(best < energies.len() - 1, "max clock should not be energy-optimal");
    }

    #[test]
    fn memory_bound_work_downclocks_for_free() {
        // Bandwidth-bound work: time pinned by t_m, so a lower clock costs
        // no (ideal) time but strictly less power → strictly less energy.
        let m = EnergyModel::v100(7);
        let w = Work { flops: 1.0e5, bytes: 64.0e6 }; // ~0.0016 flop/byte
        let lo = FreqId(m.spec.freq_states[2].mhz); // 900 MHz
        let a = m.ideal_cost_at(&w, Algorithm::Passthrough, lo);
        let b = m.ideal_cost(&w, Algorithm::Passthrough);
        assert_eq!(a.time_ms.to_bits(), b.time_ms.to_bits(), "memory-bound time must not move");
        assert!(a.power_w < b.power_w, "downclocked power {} vs nominal {}", a.power_w, b.power_w);
        assert!(a.energy_j() < b.energy_j());
    }

    #[test]
    fn freq_id_describe_and_scale() {
        assert_eq!(FreqId::NOMINAL.describe(), "nominal");
        assert_eq!(FreqId(900).describe(), "900MHz");
        let spec = GpuSpec::v100();
        assert_eq!(spec.nominal_mhz(), 1380);
        assert_eq!(spec.dvfs_scale(FreqId::NOMINAL), (1.0, 1.0));
        assert_eq!(spec.dvfs_scale(FreqId(1380)), (1.0, 1.0));
        let (s, d) = spec.dvfs_scale(FreqId(900));
        assert!((s - 900.0 / 1380.0).abs() < 1e-12);
        assert!(d < s, "voltage drop makes dynamic power fall faster than clock");
        // CPU spec has no table: everything is nominal.
        let cpu = GpuSpec::cpu_1core();
        assert_eq!(cpu.dvfs_scale(FreqId(900)), (1.0, 1.0));
    }

    #[test]
    fn freq_id_device_packing_roundtrips() {
        // GPU states pack to their raw MHz value (pre-placement bit pattern).
        assert_eq!(FreqId::on(DeviceId::GPU, 0), FreqId::NOMINAL);
        assert_eq!(FreqId::on(DeviceId::GPU, 900), FreqId(900));
        for (dev, mhz) in [(DeviceId::GPU, 0u16), (DeviceId::GPU, 1380), (DeviceId::DLA, 0), (DeviceId::DLA, 640)] {
            let f = FreqId::on(dev, mhz);
            assert_eq!(f.device(), dev);
            assert_eq!(f.mhz(), mhz);
            assert_eq!(f.local(), FreqId(mhz));
            assert_eq!(f.is_nominal(), mhz == 0);
        }
        assert_eq!(FreqId::on(DeviceId::DLA, 0).describe(), "dla");
        assert_eq!(FreqId::on(DeviceId::DLA, 640).describe(), "dla@640MHz");
        assert_eq!(DeviceId::parse("gpu"), Some(DeviceId::GPU));
        assert_eq!(DeviceId::parse("dla"), Some(DeviceId::DLA));
        assert_eq!(DeviceId::parse("tpu"), None);
        assert_eq!(DeviceId::DLA.name(), "dla");
    }

    #[test]
    fn freq_id_layout_packing_roundtrips() {
        // Every pre-layout bit pattern IS an NCHW state.
        assert_eq!(FreqId::NOMINAL.layout(), Layout::NCHW);
        assert_eq!(FreqId(900).layout(), Layout::NCHW);
        assert_eq!(FreqId::on(DeviceId::DLA, 640).layout(), Layout::NCHW);
        for base in [FreqId::NOMINAL, FreqId(900), FreqId::on(DeviceId::DLA, 640)] {
            let n = base.with_layout(Layout::NHWC);
            assert_eq!(n.layout(), Layout::NHWC);
            // Layout is orthogonal to the (device, clock) fields.
            assert_eq!(n.device(), base.device());
            assert_eq!(n.mhz(), base.mhz());
            assert_eq!(n.local(), base.local());
            assert_eq!(n.is_nominal(), base.is_nominal());
            // with_layout(NCHW) strips the bit back to the original.
            assert_eq!(n.with_layout(Layout::NCHW), base);
            assert_eq!(base.with_layout(Layout::NCHW), base);
        }
        assert_eq!(FreqId::NOMINAL.with_layout(Layout::NHWC).describe(), "nominal+nhwc");
        assert_eq!(FreqId(900).with_layout(Layout::NHWC).describe(), "900MHz+nhwc");
        assert_eq!(
            FreqId::on(DeviceId::DLA, 640).with_layout(Layout::NHWC).describe(),
            "dla@640MHz+nhwc"
        );
        assert_eq!(Layout::parse("nchw"), Some(Layout::NCHW));
        assert_eq!(Layout::parse("nhwc"), Some(Layout::NHWC));
        assert_eq!(Layout::parse("nhcw"), None);
        assert_eq!(Layout::NHWC.name(), "nhwc");
    }

    #[test]
    fn transpose_model_cost_scales_with_bytes() {
        let t = TransposeModel::on_device();
        let (t0, e0) = t.transpose_cost(0.0);
        let (t1, e1) = t.transpose_cost(1.0e6);
        // The launch is charged even for empty transposes; energy is pure
        // data movement.
        assert!(t0 > 0.0 && e0 == 0.0);
        assert!(t1 > t0 && e1 > e0);
        // A transpose is much cheaper than a device transfer of the same
        // tensor (on-chip re-tiling vs a shared-DRAM round trip).
        let link = LinkModel::shared_dram();
        let (lt, le) = link.transfer_cost(1.0e6);
        assert!(t1 < lt && e1 < le);
    }

    #[test]
    fn dla_slower_but_cheaper_on_energy() {
        // The placement trade: DLA loses on latency but wins on energy for
        // a typical conv node.
        let gpu = EnergyModel::v100(7);
        let dla = EnergyModel::dla(7);
        let w = conv_work();
        let g = gpu.ideal_cost(&w, Algorithm::ConvIm2col);
        let d = dla.ideal_cost(&w, Algorithm::ConvIm2col);
        assert!(d.time_ms > g.time_ms, "DLA {} ms vs GPU {} ms", d.time_ms, g.time_ms);
        assert!(d.energy_j() < g.energy_j(), "DLA {} mJ vs GPU {} mJ", d.energy_j(), g.energy_j());
    }

    #[test]
    fn capped_states_filter_the_clock_table() {
        let spec = GpuSpec::v100();
        let capped = spec.capped_states(1000);
        assert_eq!(capped.iter().map(|s| s.mhz).collect::<Vec<_>>(), vec![510, 705, 900]);
        assert!(spec.capped_states(100).is_empty(), "cap below the table masks everything");
        assert_eq!(spec.capped_states(4095).len(), spec.freq_states.len());
    }

    #[test]
    fn power_cap_maps_monotonically_to_clocks() {
        let spec = GpuSpec::v100();
        assert_eq!(spec.max_mhz_under_power(300.0), None, "TDP budget is a no-op");
        assert_eq!(spec.max_mhz_under_power(1.0), Some(510), "starvation clamps to the floor");
        let mut prev = 0u16;
        for w in [80.0, 120.0, 160.0, 200.0, 250.0] {
            let cap = spec.max_mhz_under_power(w).expect("sub-TDP budget must cap");
            assert!(cap >= prev, "cap must grow with the budget: {cap} at {w} W after {prev}");
            assert!(cap < spec.nominal_mhz());
            prev = cap;
        }
        // A device with no frequency table cannot be capped.
        assert_eq!(GpuSpec::cpu_1core().max_mhz_under_power(1.0), None);
        // The canonical device map stays consistent with the providers.
        assert_eq!(GpuSpec::for_device(DeviceId::GPU).unwrap().name, "sim-v100");
        assert_eq!(GpuSpec::for_device(DeviceId::DLA).unwrap().name, "sim-dla");
        assert!(GpuSpec::for_device(DeviceId(5)).is_none());
    }

    #[test]
    fn link_model_transfer_cost_scales_with_bytes() {
        let link = LinkModel::shared_dram();
        let (t0, e0) = link.transfer_cost(0.0);
        let (t1, e1) = link.transfer_cost(1.0e6);
        // Fixed overheads are charged even for empty transfers.
        assert!(t0 > 0.0 && e0 > 0.0);
        assert!(t1 > t0 && e1 > e0);
        // 1 MB at 16 GB/s ≈ 62 µs + 12 µs handshake.
        assert!((t1 - (12.0e-6 + 1.0e6 / 16.0e9) * 1e3).abs() < 1e-9);
    }
}
