//! Cost model (paper §3.2): per-node profiles, additive graph costs, and
//! user-selectable cost functions.
//!
//! ```text
//! Energy(G,A) = Σ_n Energy(n, A(n))      Time(G,A) = Σ_n Time(n, A(n))
//! Power(G,A)  = Energy(G,A) / Time(G,A)
//! ```
//!
//! Profiles are keyed by node *signature* (operator + attributes + input
//! shapes) so "nodes (even for different graphs) with the same parameters
//! only need to be measured once. The measured values are stored in a
//! database and persisted onto disk for future lookup."

/// The persisted profile database.
pub mod db;
/// The thread-safe cost oracle (resolve cache + interner + provider).
pub mod oracle;

pub use db::CostDb;
pub use oracle::{CostOracle, DeltaBase, SigId, SigInterner, TableBuildStats};

use crate::algo::{Algorithm, AlgorithmRegistry, Assignment};
use crate::energysim::FreqId;
use crate::graph::{Graph, NodeId};
use std::sync::Arc;

/// Measured cost of one (node-signature, algorithm) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeCost {
    /// Inference time, milliseconds.
    pub time_ms: f64,
    /// Average power, watts.
    pub power_w: f64,
}

impl NodeCost {
    /// Energy in J per 1000 inferences (= mJ per inference = ms × W).
    pub fn energy_j(&self) -> f64 {
        self.time_ms * self.power_w
    }
}

/// Additive whole-graph cost under one assignment.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GraphCost {
    /// Inference time, milliseconds.
    pub time_ms: f64,
    /// Energy in J per 1000 inferences (= mJ per inference).
    pub energy_j: f64,
    /// The DVFS state this cost was evaluated at, when the whole plan ran
    /// at one: the chosen state of a `--dvfs per-graph` plan. `NOMINAL`
    /// for pre-DVFS plans *and* for mixed per-node plans (whose true
    /// per-node states live in the [`Assignment`]). Metadata only — never
    /// read by the objective.
    pub freq: FreqId,
}

impl GraphCost {
    /// Average power in watts (energy-to-time ratio).
    pub fn power_w(&self) -> f64 {
        if self.time_ms > 0.0 {
            self.energy_j / self.time_ms
        } else {
            0.0
        }
    }

    /// Accumulate one node's cost (the paper's additive model).
    pub fn add(&self, c: &NodeCost) -> GraphCost {
        GraphCost {
            time_ms: self.time_ms + c.time_ms,
            energy_j: self.energy_j + c.energy_j(),
            freq: self.freq,
        }
    }
}

/// The user-facing optimization objective (paper §3.2 lists exactly these).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CostFunction {
    /// Best inference time.
    Time,
    /// Best energy.
    Energy,
    /// Minimum average power (energy-to-time ratio).
    Power,
    /// `w·E/E₀ + (1-w)·T/T₀` — linear combination of *normalized* energy
    /// and time (§4.4 normalizes "so that the weight w makes better
    /// sense"). With norms of 1.0 it is the raw linear combination.
    Linear {
        /// Weight on energy (1-w goes to time).
        w: f64,
        /// Time normalization constant T₀.
        t_norm: f64,
        /// Energy normalization constant E₀.
        e_norm: f64,
    },
    /// `E^w · T^(1-w)` — the product form.
    Product {
        /// Exponent on energy (1-w goes to time).
        w: f64,
    },
    /// `w·P/P₀ + (1-w)·E/E₀` — Table 3's "0.5power+0.5energy" objective.
    PowerEnergy {
        /// Weight on power (1-w goes to energy).
        w: f64,
        /// Power normalization constant P₀.
        p_norm: f64,
        /// Energy normalization constant E₀.
        e_norm: f64,
    },
}

impl CostFunction {
    /// Linear combination with unit norms (call [`CostFunction::normalized`]
    /// with the origin graph's cost before searching).
    pub fn linear(w: f64) -> CostFunction {
        assert!((0.0..=1.0).contains(&w), "weight must be in [0,1]");
        CostFunction::Linear { w, t_norm: 1.0, e_norm: 1.0 }
    }

    /// Power/energy combination with unit norms (normalize before use).
    pub fn power_energy(w: f64) -> CostFunction {
        assert!((0.0..=1.0).contains(&w), "weight must be in [0,1]");
        CostFunction::PowerEnergy { w, p_norm: 1.0, e_norm: 1.0 }
    }

    /// Rescale normalization constants to a baseline cost (typically the
    /// origin graph under the default assignment).
    pub fn normalized(self, baseline: &GraphCost) -> CostFunction {
        match self {
            CostFunction::Linear { w, .. } => CostFunction::Linear {
                w,
                t_norm: baseline.time_ms.max(1e-12),
                e_norm: baseline.energy_j.max(1e-12),
            },
            CostFunction::PowerEnergy { w, .. } => CostFunction::PowerEnergy {
                w,
                p_norm: baseline.power_w().max(1e-12),
                e_norm: baseline.energy_j.max(1e-12),
            },
            other => other,
        }
    }

    /// Evaluate the objective on a graph cost (lower is better).
    pub fn eval(&self, gc: &GraphCost) -> f64 {
        match self {
            CostFunction::Time => gc.time_ms,
            CostFunction::Energy => gc.energy_j,
            CostFunction::Power => gc.power_w(),
            CostFunction::Linear { w, t_norm, e_norm } => {
                w * gc.energy_j / e_norm + (1.0 - w) * gc.time_ms / t_norm
            }
            CostFunction::Product { w } => {
                gc.energy_j.max(1e-12).powf(*w) * gc.time_ms.max(1e-12).powf(1.0 - w)
            }
            CostFunction::PowerEnergy { w, p_norm, e_norm } => {
                w * gc.power_w() / p_norm + (1.0 - w) * gc.energy_j / e_norm
            }
        }
    }

    /// Is the objective a per-node-separable (additive) function? The paper
    /// §3.3: "for any cost function that is a linear combination of
    /// inference time and energy, the inner search with d=1 is sufficient".
    /// Power and Product are ratios/products of sums — not separable.
    pub fn is_additive(&self) -> bool {
        matches!(self, CostFunction::Time | CostFunction::Energy | CostFunction::Linear { .. })
    }

    /// The inner-search neighborhood distance the paper recommends (§4.1):
    /// d=1 for linear combinations, d=2 otherwise.
    pub fn recommended_inner_distance(&self) -> usize {
        if self.is_additive() {
            1
        } else {
            2
        }
    }

    /// Human-readable objective label (CLI/report output).
    pub fn describe(&self) -> String {
        match self {
            CostFunction::Time => "best_time".into(),
            CostFunction::Energy => "best_energy".into(),
            CostFunction::Power => "best_power".into(),
            CostFunction::Linear { w, .. } => format!("{:.2}*energy+{:.2}*time", w, 1.0 - w),
            CostFunction::Product { w } => format!("energy^{w:.2}*time^{:.2}", 1.0 - w),
            CostFunction::PowerEnergy { w, .. } => {
                format!("{:.2}*power+{:.2}*energy", w, 1.0 - w)
            }
        }
    }
}

/// One per-node frequency slab: the (algorithm, cost) options available at
/// a single DVFS state, `Arc`-shared with the oracle's resolve cache.
pub type FreqSlab = (FreqId, Arc<Vec<(Algorithm, NodeCost)>>);

/// Per-graph cost lookup table: for every runtime node, the cost of each
/// applicable (algorithm, frequency) pair, resolved once from the
/// database. This is the inner search's working set — after `build`, cost
/// evaluation never touches the DB or the graph again (hot-path
/// optimization, see EXPERIMENTS.md §Perf).
///
/// Options are grouped into **frequency slabs** — one `(FreqId, options)`
/// entry per resolved DVFS state, `NOMINAL` first, so a pre-DVFS table is
/// exactly one nominal slab per node and the off-mode hot path is
/// unchanged. Slabs are `Arc`-shared with the [`CostOracle`] resolve
/// cache, so a cache hit during candidate evaluation is a pointer bump,
/// not a copy of the options vector.
#[derive(Debug, Clone)]
pub struct GraphCostTable {
    /// entries[node] = frequency slabs; empty for zero-cost nodes.
    entries: Vec<Vec<FreqSlab>>,
}

impl GraphCostTable {
    /// Assemble from pre-resolved nominal-clock per-node entries.
    pub fn from_entries(entries: Vec<Vec<(Algorithm, NodeCost)>>) -> GraphCostTable {
        GraphCostTable {
            entries: entries
                .into_iter()
                .map(|v| {
                    if v.is_empty() {
                        Vec::new()
                    } else {
                        vec![(FreqId::NOMINAL, Arc::new(v))]
                    }
                })
                .collect(),
        }
    }

    /// Assemble from already-shared nominal per-node entries (the cost
    /// oracle's zero-copy path: nodes reference the resolve cache's own
    /// vectors).
    pub fn from_shared(entries: Vec<Arc<Vec<(Algorithm, NodeCost)>>>) -> GraphCostTable {
        GraphCostTable {
            entries: entries
                .into_iter()
                .map(|v| if v.is_empty() { Vec::new() } else { vec![(FreqId::NOMINAL, v)] })
                .collect(),
        }
    }

    /// Assemble from per-node frequency slabs (the DVFS-aware oracle path).
    pub fn from_freq_slabs(entries: Vec<Vec<FreqSlab>>) -> GraphCostTable {
        GraphCostTable { entries }
    }

    /// Build from a profiled database. Errors if any (signature, algorithm)
    /// pair is missing — run the profiler first.
    pub fn build(g: &Graph, reg: &AlgorithmRegistry, db: &CostDb) -> anyhow::Result<GraphCostTable> {
        let shapes = g
            .infer_shapes()
            .map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        GraphCostTable::build_with(g, &shapes, reg, db)
    }

    /// As [`GraphCostTable::build`] with pre-computed shapes (search hot path).
    pub fn build_with(
        g: &Graph,
        shapes: &[Vec<crate::graph::TensorShape>],
        reg: &AlgorithmRegistry,
        db: &CostDb,
    ) -> anyhow::Result<GraphCostTable> {
        let mut entries = vec![Vec::new(); g.len()];
        for (id, node) in g.nodes() {
            if node.op.is_constant_space() || matches!(node.op, crate::graph::OpKind::Input { .. }) {
                continue;
            }
            let in_shapes: Vec<_> = node
                .inputs
                .iter()
                .map(|p| shapes[p.node.0][p.port].clone())
                .collect();
            let sig = node.op.signature(&in_shapes);
            for algo in reg.applicable(&node.op, &in_shapes) {
                let cost = db.get(&sig, algo).ok_or_else(|| {
                    anyhow::anyhow!("cost db missing ({sig}, {}) — run the profiler", algo.name())
                })?;
                entries[id.0].push((algo, cost));
            }
        }
        Ok(GraphCostTable::from_entries(entries))
    }

    /// Additive cost of the graph under `a` (paper's cost model), each node
    /// priced at its assigned (algorithm, frequency) pair.
    pub fn eval(&self, a: &Assignment) -> GraphCost {
        let mut gc = GraphCost::default();
        for (i, slabs) in self.entries.iter().enumerate() {
            if slabs.is_empty() {
                continue;
            }
            let id = NodeId(i);
            let chosen = a.get(id).expect("assignment missing runtime node");
            let cost = self
                .options_at(id, a.freq(id))
                .iter()
                .find(|(al, _)| *al == chosen)
                .unwrap_or_else(|| {
                    panic!("({chosen:?}, {}) not applicable to node {i}", a.freq(id).describe())
                })
                .1;
            gc = gc.add(&cost);
        }
        gc.freq = a.uniform_freq();
        gc
    }

    /// Nominal-clock cost options of one node (the pre-DVFS view; empty
    /// when the table was built at non-nominal states only).
    pub fn node_options(&self, id: NodeId) -> &[(Algorithm, NodeCost)] {
        self.entries[id.0]
            .iter()
            .find(|(f, _)| f.is_nominal())
            .map(|(_, v)| &v[..])
            .unwrap_or(&[])
    }

    /// All frequency slabs of one node (`NOMINAL` first).
    pub fn freq_options(&self, id: NodeId) -> &[FreqSlab] {
        &self.entries[id.0]
    }

    /// Cost options of one node at one DVFS state (empty if unresolved).
    pub fn options_at(&self, id: NodeId, freq: FreqId) -> &[(Algorithm, NodeCost)] {
        self.entries[id.0]
            .iter()
            .find(|(f, _)| *f == freq)
            .map(|(_, v)| &v[..])
            .unwrap_or(&[])
    }

    /// Total (algorithm, frequency) options of a node — the inner search's
    /// per-node decision count.
    pub fn option_count(&self, id: NodeId) -> usize {
        self.entries[id.0].iter().map(|(_, v)| v.len()).sum()
    }

    /// The `k`-th (frequency, algorithm) option of a node, slab-major —
    /// for random starts over the joint space.
    pub fn option_nth(&self, id: NodeId, mut k: usize) -> (FreqId, Algorithm) {
        for (f, slab) in &self.entries[id.0] {
            if k < slab.len() {
                return (*f, slab[k].0);
            }
            k -= slab.len();
        }
        panic!("option index out of range for node {}", id.0);
    }

    /// A copy of the table restricted to one frequency slab per node —
    /// the per-state view the per-graph DVFS search evaluates (cheap:
    /// slabs are `Arc`-shared, so this clones pointers, not options).
    /// Nodes without a slab at `freq` end up empty, exactly like a table
    /// built at `&[freq]` directly.
    pub fn restrict_to_freq(&self, freq: FreqId) -> GraphCostTable {
        GraphCostTable {
            entries: self
                .entries
                .iter()
                .map(|slabs| slabs.iter().filter(|(f, _)| *f == freq).cloned().collect())
                .collect(),
        }
    }

    /// Nodes that actually carry cost choices.
    pub fn costed_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(i, _)| NodeId(i))
    }

    /// Incremental re-evaluation: `base` with node `id` switched from its
    /// current (algorithm, frequency) pair to `(new_algo, new_freq)`.
    /// O(#options-of-node), not O(n).
    pub fn eval_swap(
        &self,
        base: GraphCost,
        a: &Assignment,
        id: NodeId,
        new_algo: Algorithm,
        new_freq: FreqId,
    ) -> GraphCost {
        let old_algo = a.get(id).expect("swap on non-runtime node");
        let old_freq = a.freq(id);
        let find = |al: Algorithm, f: FreqId| {
            self.options_at(id, f)
                .iter()
                .find(|(x, _)| *x == al)
                .expect("(algorithm, frequency) not applicable")
                .1
        };
        let old = find(old_algo, old_freq);
        let new = find(new_algo, new_freq);
        GraphCost {
            time_ms: base.time_ms - old.time_ms + new.time_ms,
            energy_j: base.energy_j - old.energy_j() + new.energy_j(),
            freq: if new_freq == old_freq { base.freq } else { FreqId::NOMINAL },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_identity() {
        let c = NodeCost { time_ms: 2.0, power_w: 50.0 };
        assert_eq!(c.energy_j(), 100.0);
    }

    #[test]
    fn graph_cost_accumulates() {
        let gc = GraphCost::default()
            .add(&NodeCost { time_ms: 1.0, power_w: 100.0 })
            .add(&NodeCost { time_ms: 3.0, power_w: 50.0 });
        assert_eq!(gc.time_ms, 4.0);
        assert_eq!(gc.energy_j, 250.0);
        assert!((gc.power_w() - 62.5).abs() < 1e-12);
    }

    #[test]
    fn cost_functions_evaluate() {
        let gc = GraphCost { time_ms: 2.0, energy_j: 100.0, ..Default::default() };
        assert_eq!(CostFunction::Time.eval(&gc), 2.0);
        assert_eq!(CostFunction::Energy.eval(&gc), 100.0);
        assert_eq!(CostFunction::Power.eval(&gc), 50.0);
        let lin = CostFunction::linear(0.5);
        assert!((lin.eval(&gc) - (0.5 * 100.0 + 0.5 * 2.0)).abs() < 1e-12);
        let prod = CostFunction::Product { w: 0.5 };
        assert!((prod.eval(&gc) - (100.0f64.sqrt() * 2.0f64.sqrt())).abs() < 1e-9);
    }

    #[test]
    fn normalization_makes_baseline_unit_cost() {
        let baseline = GraphCost { time_ms: 2.0, energy_j: 100.0, ..Default::default() };
        let lin = CostFunction::linear(0.3).normalized(&baseline);
        assert!((lin.eval(&baseline) - 1.0).abs() < 1e-12);
        let pe = CostFunction::power_energy(0.5).normalized(&baseline);
        assert!((pe.eval(&baseline) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn additivity_classification() {
        assert!(CostFunction::Time.is_additive());
        assert!(CostFunction::Energy.is_additive());
        assert!(CostFunction::linear(0.7).is_additive());
        assert!(!CostFunction::Power.is_additive());
        assert!(!CostFunction::Product { w: 0.5 }.is_additive());
        assert_eq!(CostFunction::linear(0.7).recommended_inner_distance(), 1);
        assert_eq!(CostFunction::Power.recommended_inner_distance(), 2);
    }

    #[test]
    #[should_panic(expected = "weight")]
    fn linear_weight_range_checked() {
        CostFunction::linear(1.5);
    }
}
