//! Cost model (paper §3.2): per-node profiles, additive graph costs, and
//! user-selectable cost functions.
//!
//! ```text
//! Energy(G,A) = Σ_n Energy(n, A(n))      Time(G,A) = Σ_n Time(n, A(n))
//! Power(G,A)  = Energy(G,A) / Time(G,A)
//! ```
//!
//! Profiles are keyed by node *signature* (operator + attributes + input
//! shapes) so "nodes (even for different graphs) with the same parameters
//! only need to be measured once. The measured values are stored in a
//! database and persisted onto disk for future lookup."

/// The persisted profile database.
pub mod db;
/// Measured serving telemetry overlaying the database (feedback loop).
pub mod feedback;
/// The thread-safe cost oracle (resolve cache + interner + provider).
pub mod oracle;

pub use db::CostDb;
pub use feedback::{MeasuredRow, MeasuredStore};
pub use oracle::{
    ArgminStats, CandidateTable, CostOracle, DeltaBase, FeedbackApplied, SigId, SigInterner,
    TableBuildStats,
};

use crate::algo::{Algorithm, AlgorithmRegistry, Assignment};
use crate::energysim::{FreqId, LinkModel, TransposeModel};
use crate::graph::{Graph, NodeId, TensorShape};
use std::sync::Arc;

/// Measured cost of one (node-signature, algorithm) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeCost {
    /// Inference time, milliseconds.
    pub time_ms: f64,
    /// Average power, watts.
    pub power_w: f64,
}

impl NodeCost {
    /// Energy in J per 1000 inferences (= mJ per inference = ms × W).
    pub fn energy_j(&self) -> f64 {
        self.time_ms * self.power_w
    }
}

/// Additive whole-graph cost under one assignment.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GraphCost {
    /// Inference time, milliseconds.
    pub time_ms: f64,
    /// Energy in J per 1000 inferences (= mJ per inference).
    pub energy_j: f64,
    /// The DVFS state this cost was evaluated at, when the whole plan ran
    /// at one: the chosen state of a `--dvfs per-graph` plan. `NOMINAL`
    /// for pre-DVFS plans *and* for mixed per-node plans (whose true
    /// per-node states live in the [`Assignment`]). Metadata only — never
    /// read by the objective.
    pub freq: FreqId,
}

impl GraphCost {
    /// Average power in watts (energy-to-time ratio).
    pub fn power_w(&self) -> f64 {
        if self.time_ms > 0.0 {
            self.energy_j / self.time_ms
        } else {
            0.0
        }
    }

    /// Accumulate one node's cost (the paper's additive model).
    pub fn add(&self, c: &NodeCost) -> GraphCost {
        GraphCost {
            time_ms: self.time_ms + c.time_ms,
            energy_j: self.energy_j + c.energy_j(),
            freq: self.freq,
        }
    }
}

/// The user-facing optimization objective (paper §3.2 lists exactly these).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CostFunction {
    /// Best inference time.
    Time,
    /// Best energy.
    Energy,
    /// Minimum average power (energy-to-time ratio).
    Power,
    /// `w·E/E₀ + (1-w)·T/T₀` — linear combination of *normalized* energy
    /// and time (§4.4 normalizes "so that the weight w makes better
    /// sense"). With norms of 1.0 it is the raw linear combination.
    Linear {
        /// Weight on energy (1-w goes to time).
        w: f64,
        /// Time normalization constant T₀.
        t_norm: f64,
        /// Energy normalization constant E₀.
        e_norm: f64,
    },
    /// `E^w · T^(1-w)` — the product form.
    Product {
        /// Exponent on energy (1-w goes to time).
        w: f64,
    },
    /// `w·P/P₀ + (1-w)·E/E₀` — Table 3's "0.5power+0.5energy" objective.
    PowerEnergy {
        /// Weight on power (1-w goes to energy).
        w: f64,
        /// Power normalization constant P₀.
        p_norm: f64,
        /// Energy normalization constant E₀.
        e_norm: f64,
    },
}

impl CostFunction {
    /// Linear combination with unit norms (call [`CostFunction::normalized`]
    /// with the origin graph's cost before searching).
    pub fn linear(w: f64) -> CostFunction {
        assert!((0.0..=1.0).contains(&w), "weight must be in [0,1]");
        CostFunction::Linear { w, t_norm: 1.0, e_norm: 1.0 }
    }

    /// Power/energy combination with unit norms (normalize before use).
    pub fn power_energy(w: f64) -> CostFunction {
        assert!((0.0..=1.0).contains(&w), "weight must be in [0,1]");
        CostFunction::PowerEnergy { w, p_norm: 1.0, e_norm: 1.0 }
    }

    /// Rescale normalization constants to a baseline cost (typically the
    /// origin graph under the default assignment).
    pub fn normalized(self, baseline: &GraphCost) -> CostFunction {
        match self {
            CostFunction::Linear { w, .. } => CostFunction::Linear {
                w,
                t_norm: baseline.time_ms.max(1e-12),
                e_norm: baseline.energy_j.max(1e-12),
            },
            CostFunction::PowerEnergy { w, .. } => CostFunction::PowerEnergy {
                w,
                p_norm: baseline.power_w().max(1e-12),
                e_norm: baseline.energy_j.max(1e-12),
            },
            other => other,
        }
    }

    /// Evaluate the objective on a graph cost (lower is better).
    pub fn eval(&self, gc: &GraphCost) -> f64 {
        match self {
            CostFunction::Time => gc.time_ms,
            CostFunction::Energy => gc.energy_j,
            CostFunction::Power => gc.power_w(),
            CostFunction::Linear { w, t_norm, e_norm } => {
                w * gc.energy_j / e_norm + (1.0 - w) * gc.time_ms / t_norm
            }
            CostFunction::Product { w } => {
                gc.energy_j.max(1e-12).powf(*w) * gc.time_ms.max(1e-12).powf(1.0 - w)
            }
            CostFunction::PowerEnergy { w, p_norm, e_norm } => {
                w * gc.power_w() / p_norm + (1.0 - w) * gc.energy_j / e_norm
            }
        }
    }

    /// Is the objective a per-node-separable (additive) function? The paper
    /// §3.3: "for any cost function that is a linear combination of
    /// inference time and energy, the inner search with d=1 is sufficient".
    /// Power and Product are ratios/products of sums — not separable.
    pub fn is_additive(&self) -> bool {
        matches!(self, CostFunction::Time | CostFunction::Energy | CostFunction::Linear { .. })
    }

    /// The inner-search neighborhood distance the paper recommends (§4.1):
    /// d=1 for linear combinations, d=2 otherwise.
    pub fn recommended_inner_distance(&self) -> usize {
        if self.is_additive() {
            1
        } else {
            2
        }
    }

    /// The additive objective's contribution of a single node priced at
    /// `c` — defined exactly for the separable objectives
    /// ([`CostFunction::is_additive`]): comparing two options of one node
    /// by `node_value` is equivalent (in exact arithmetic) to comparing
    /// the whole-graph objective with that node swapped, which is what
    /// makes the per-row argmin context-free and memoizable.
    ///
    /// # Panics
    /// On non-additive objectives (`Power`, `Product`, `PowerEnergy`) —
    /// their per-node contribution is not defined.
    pub fn node_value(&self, c: &NodeCost) -> f64 {
        match self {
            CostFunction::Time => c.time_ms,
            CostFunction::Energy => c.energy_j(),
            CostFunction::Linear { w, t_norm, e_norm } => {
                w * c.energy_j() / e_norm + (1.0 - w) * c.time_ms / t_norm
            }
            other => panic!("node_value on non-additive objective {}", other.describe()),
        }
    }

    /// A hashable identity of an additive objective — the cost-function
    /// half of the per-row argmin memo key ([`CostOracle::argmin_for`]).
    /// `None` for non-additive objectives (their per-node optimum is not
    /// context-free, so it cannot be memoized per row).
    pub fn additive_key(&self) -> Option<AdditiveKey> {
        match self {
            CostFunction::Time => Some(AdditiveKey { kind: 0, a: 0, b: 0, c: 0 }),
            CostFunction::Energy => Some(AdditiveKey { kind: 1, a: 0, b: 0, c: 0 }),
            CostFunction::Linear { w, t_norm, e_norm } => Some(AdditiveKey {
                kind: 2,
                a: w.to_bits(),
                b: t_norm.to_bits(),
                c: e_norm.to_bits(),
            }),
            _ => None,
        }
    }

    /// Human-readable objective label (CLI/report output).
    pub fn describe(&self) -> String {
        match self {
            CostFunction::Time => "best_time".into(),
            CostFunction::Energy => "best_energy".into(),
            CostFunction::Power => "best_power".into(),
            CostFunction::Linear { w, .. } => format!("{:.2}*energy+{:.2}*time", w, 1.0 - w),
            CostFunction::Product { w } => format!("energy^{w:.2}*time^{:.2}", 1.0 - w),
            CostFunction::PowerEnergy { w, .. } => {
                format!("{:.2}*power+{:.2}*energy", w, 1.0 - w)
            }
        }
    }
}

/// A hashable identity of an additive [`CostFunction`] (discriminant plus
/// the exact bit patterns of its parameters). Built by
/// [`CostFunction::additive_key`]; two objectives with equal keys evaluate
/// every node cost to identical bits, so argmin memo entries keyed by it
/// are exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AdditiveKey {
    kind: u8,
    a: u64,
    b: u64,
    c: u64,
}

/// One per-node frequency slab: the (algorithm, cost) options available at
/// a single DVFS state, `Arc`-shared with the oracle's resolve cache.
pub type FreqSlab = (FreqId, Arc<Vec<(Algorithm, NodeCost)>>);

/// Sentinel for "no entry" in the dense slab/option indices.
const NO_SLOT: u8 = u8::MAX;

/// Dense per-node lookup into the frequency slabs: O(1) option resolution
/// for the inner search's `eval`/`eval_swap` hot path, replacing the
/// former linear `find` over `options_at`.
#[derive(Debug, Clone, Default)]
struct NodeSlabIndex {
    /// `algo_slot[Algorithm::ordinal]` = option position inside each slab
    /// (`NO_SLOT` = algorithm not applicable). Valid only when `uniform`.
    algo_slot: [u8; Algorithm::COUNT],
    /// `slab_of[dense frequency id]` = slab position (`NO_SLOT` = state
    /// unresolved for this node). Dense ids index the table's
    /// `freq_universe`.
    slab_of: Vec<u8>,
    /// Whether every slab of the node lists the same algorithms in the
    /// same order (always true for oracle-built tables — `resolve` walks
    /// `AlgorithmRegistry::applicable` deterministically per signature).
    /// When false, lookups fall back to a linear scan of the slab.
    uniform: bool,
}

/// One producer→consumer edge between two runtime-costed nodes, with the
/// pre-computed link cost the table charges if the two ever land on
/// different devices.
#[derive(Debug, Clone, Copy)]
pub struct TransferLink {
    /// Producing node.
    pub src: NodeId,
    /// Consuming node.
    pub dst: NodeId,
    /// Tensor size crossing the edge, bytes (f32 elements × 4).
    pub bytes: f64,
    /// Link latency if the edge crosses devices, milliseconds.
    pub time_ms: f64,
    /// Link energy if the edge crosses devices, mJ per inference (same
    /// `ms × W` unit as [`NodeCost::energy_j`]).
    pub energy_mj: f64,
    /// Re-tiling latency if the edge crosses layouts, milliseconds.
    pub transpose_ms: f64,
    /// Re-tiling energy if the edge crosses layouts, mJ per inference.
    pub transpose_mj: f64,
}

/// The transfer-cost overlay of a multi-device [`GraphCostTable`]: every
/// data edge between runtime-costed nodes, priced once at build time, plus
/// per-node incidence lists for O(degree) swap re-evaluation. Single-device
/// tables carry no overlay — their objective stays fully separable and the
/// pre-placement hot paths are untouched.
#[derive(Debug, Clone, Default)]
pub struct TransferLinks {
    /// All priced edges.
    edges: Vec<TransferLink>,
    /// `incident[node]` = indices into `edges` touching that node.
    incident: Vec<Vec<u32>>,
}

impl TransferLinks {
    /// Price every data edge between costed nodes of `g`: transfer costs
    /// under `link` (zero when `None` — layouts-only overlays never charge
    /// a device boundary), re-tiling costs always (the transpose kernel is
    /// device-independent). `costed[i]` marks nodes that carry cost options
    /// (constant-space and input nodes never execute, so edges from them
    /// move no runtime data).
    pub fn build(
        g: &Graph,
        shapes: &[Vec<TensorShape>],
        costed: &[bool],
        link: Option<&LinkModel>,
    ) -> TransferLinks {
        let transpose = TransposeModel::on_device();
        let mut edges = Vec::new();
        for (id, node) in g.nodes() {
            if !costed[id.0] {
                continue;
            }
            for p in &node.inputs {
                if !costed.get(p.node.0).copied().unwrap_or(false) {
                    continue;
                }
                let bytes = 4.0 * shapes[p.node.0][p.port].iter().product::<usize>() as f64;
                let (time_ms, energy_mj) =
                    link.map(|l| l.transfer_cost(bytes)).unwrap_or((0.0, 0.0));
                let (transpose_ms, transpose_mj) = transpose.transpose_cost(bytes);
                edges.push(TransferLink {
                    src: p.node,
                    dst: id,
                    bytes,
                    time_ms,
                    energy_mj,
                    transpose_ms,
                    transpose_mj,
                });
            }
        }
        TransferLinks::from_edges(edges, g.len())
    }

    /// Assemble from pre-priced edges over `n_nodes` nodes (the delta-table
    /// path prices edges straight off the candidate view, without
    /// materializing the graph).
    pub fn from_edges(edges: Vec<TransferLink>, n_nodes: usize) -> TransferLinks {
        let mut incident = vec![Vec::new(); n_nodes];
        for (ei, e) in edges.iter().enumerate() {
            incident[e.src.0].push(ei as u32);
            incident[e.dst.0].push(ei as u32);
        }
        TransferLinks { edges, incident }
    }

    /// All priced edges.
    pub fn edges(&self) -> &[TransferLink] {
        &self.edges
    }
}

/// Per-graph cost lookup table: for every runtime node, the cost of each
/// applicable (algorithm, frequency) pair, resolved once from the
/// database. This is the inner search's working set — after `build`, cost
/// evaluation never touches the DB or the graph again (hot-path
/// optimization, see EXPERIMENTS.md §Perf).
///
/// Options are grouped into **frequency slabs** — one `(FreqId, options)`
/// entry per resolved DVFS state, `NOMINAL` first, so a pre-DVFS table is
/// exactly one nominal slab per node and the off-mode hot path is
/// unchanged. Slabs are `Arc`-shared with the [`CostOracle`] resolve
/// cache, so a cache hit during candidate evaluation is a pointer bump,
/// not a copy of the options vector.
#[derive(Debug, Clone)]
pub struct GraphCostTable {
    /// entries[node] = frequency slabs; empty for zero-cost nodes.
    entries: Vec<Vec<FreqSlab>>,
    /// Distinct frequencies across the table, ascending (`NOMINAL` = 0
    /// sorts first) — the key space of each node's `slab_of` index.
    freq_universe: Vec<FreqId>,
    /// Dense per-node (algorithm → option, frequency → slab) indices,
    /// built once at construction.
    index: Vec<NodeSlabIndex>,
    /// Transfer-cost overlay, present only when the table's options span
    /// more than one device ([`GraphCostTable::attach_links`]).
    links: Option<Arc<TransferLinks>>,
}

/// Build the dense per-node indices for a slab table (one pass).
fn build_slab_index(entries: &[Vec<FreqSlab>]) -> (Vec<FreqId>, Vec<NodeSlabIndex>) {
    let mut universe: Vec<FreqId> =
        entries.iter().flat_map(|slabs| slabs.iter().map(|(f, _)| *f)).collect();
    universe.sort_unstable();
    universe.dedup();
    let index = entries
        .iter()
        .map(|slabs| {
            let mut ni = NodeSlabIndex {
                algo_slot: [NO_SLOT; Algorithm::COUNT],
                slab_of: vec![NO_SLOT; universe.len()],
                uniform: true,
            };
            for (si, (f, _)) in slabs.iter().enumerate() {
                let fi = universe.binary_search(f).expect("slab freq in universe");
                // First slab at a frequency wins, matching the linear
                // `find` the index replaces.
                if si < NO_SLOT as usize && ni.slab_of[fi] == NO_SLOT {
                    ni.slab_of[fi] = si as u8;
                }
            }
            if let Some((_, first)) = slabs.first() {
                ni.uniform = first.len() < NO_SLOT as usize
                    && slabs[1..].iter().all(|(_, slab)| {
                        slab.len() == first.len()
                            && slab.iter().zip(first.iter()).all(|((a, _), (b, _))| a == b)
                    });
                if ni.uniform {
                    for (oi, (algo, _)) in first.iter().enumerate() {
                        ni.algo_slot[algo.ordinal()] = oi as u8;
                    }
                }
            }
            ni
        })
        .collect();
    (universe, index)
}

impl GraphCostTable {
    /// Assemble from pre-resolved nominal-clock per-node entries.
    pub fn from_entries(entries: Vec<Vec<(Algorithm, NodeCost)>>) -> GraphCostTable {
        GraphCostTable::from_freq_slabs(
            entries
                .into_iter()
                .map(|v| {
                    if v.is_empty() {
                        Vec::new()
                    } else {
                        vec![(FreqId::NOMINAL, Arc::new(v))]
                    }
                })
                .collect(),
        )
    }

    /// Assemble from already-shared nominal per-node entries (the cost
    /// oracle's zero-copy path: nodes reference the resolve cache's own
    /// vectors).
    pub fn from_shared(entries: Vec<Arc<Vec<(Algorithm, NodeCost)>>>) -> GraphCostTable {
        GraphCostTable::from_freq_slabs(
            entries
                .into_iter()
                .map(|v| if v.is_empty() { Vec::new() } else { vec![(FreqId::NOMINAL, v)] })
                .collect(),
        )
    }

    /// Assemble from per-node frequency slabs (the DVFS-aware oracle
    /// path). Builds the dense (algorithm → option, frequency → slab)
    /// indices the hot-path lookups use.
    pub fn from_freq_slabs(entries: Vec<Vec<FreqSlab>>) -> GraphCostTable {
        let (freq_universe, index) = build_slab_index(&entries);
        GraphCostTable { entries, freq_universe, index, links: None }
    }

    /// Attach the boundary-cost overlay: price every data edge between
    /// costed nodes under `link` (device transfers) and the re-tiling
    /// kernel (layout transposes). Called by the oracle only when the
    /// table's frequency universe spans more than one device or layout —
    /// overlay-free tables evaluate exactly as before either axis existed.
    pub fn attach_links(&mut self, g: &Graph, shapes: &[Vec<TensorShape>], link: Option<&LinkModel>) {
        let costed: Vec<bool> = self.entries.iter().map(|e| !e.is_empty()).collect();
        self.links = Some(Arc::new(TransferLinks::build(g, shapes, &costed, link)));
    }

    /// Share an already-built overlay (the delta-table path: clean rows and
    /// links both come from the parent table's build).
    pub fn attach_links_shared(&mut self, links: Arc<TransferLinks>) {
        self.links = Some(links);
    }

    /// Whether a transfer-cost overlay is attached (iff the table spans
    /// devices). Gates the boundary-aware inner pass.
    pub fn has_links(&self) -> bool {
        self.links.is_some()
    }

    /// The transfer-cost overlay, if attached.
    pub fn links(&self) -> Option<&Arc<TransferLinks>> {
        self.links.as_ref()
    }

    /// Total transfer cost of `a`: the sum of link costs over edges whose
    /// endpoints sit on different devices, `(time_ms, energy_mj)`. Zero —
    /// with no floating-point terms added at all — when every edge stays
    /// on one device or no overlay is attached.
    pub fn transfer_cost(&self, a: &Assignment) -> (f64, f64) {
        let Some(links) = &self.links else { return (0.0, 0.0) };
        let (mut t, mut e) = (0.0, 0.0);
        for edge in &links.edges {
            if a.freq(edge.src).device() != a.freq(edge.dst).device() {
                t += edge.time_ms;
                e += edge.energy_mj;
            }
        }
        (t, e)
    }

    /// Total re-tiling cost of `a`: the sum of transpose costs over edges
    /// whose endpoints compute in different layouts, `(time_ms,
    /// energy_mj)`. Zero — with no floating-point terms added at all — when
    /// every edge stays in one layout or no overlay is attached.
    pub fn transpose_cost(&self, a: &Assignment) -> (f64, f64) {
        let Some(links) = &self.links else { return (0.0, 0.0) };
        let (mut t, mut e) = (0.0, 0.0);
        for edge in &links.edges {
            if a.freq(edge.src).layout() != a.freq(edge.dst).layout() {
                t += edge.transpose_ms;
                e += edge.transpose_mj;
            }
        }
        (t, e)
    }

    /// Build from a profiled database. Errors if any (signature, algorithm)
    /// pair is missing — run the profiler first.
    pub fn build(g: &Graph, reg: &AlgorithmRegistry, db: &CostDb) -> anyhow::Result<GraphCostTable> {
        let shapes = g
            .infer_shapes()
            .map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        GraphCostTable::build_with(g, &shapes, reg, db)
    }

    /// As [`GraphCostTable::build`] with pre-computed shapes (search hot path).
    pub fn build_with(
        g: &Graph,
        shapes: &[Vec<crate::graph::TensorShape>],
        reg: &AlgorithmRegistry,
        db: &CostDb,
    ) -> anyhow::Result<GraphCostTable> {
        let mut entries = vec![Vec::new(); g.len()];
        for (id, node) in g.nodes() {
            if node.op.is_constant_space() || matches!(node.op, crate::graph::OpKind::Input { .. }) {
                continue;
            }
            let in_shapes: Vec<_> = node
                .inputs
                .iter()
                .map(|p| shapes[p.node.0][p.port].clone())
                .collect();
            let sig = node.op.signature(&in_shapes);
            for algo in reg.applicable(&node.op, &in_shapes) {
                let cost = db.get(&sig, algo).ok_or_else(|| {
                    anyhow::anyhow!("cost db missing ({sig}, {}) — run the profiler", algo.name())
                })?;
                entries[id.0].push((algo, cost));
            }
        }
        Ok(GraphCostTable::from_entries(entries))
    }

    /// O(1) cost lookup of one node's (algorithm, frequency) option
    /// through the dense slab index. `None` when the state is unresolved
    /// or the algorithm not applicable.
    pub fn option_cost(&self, id: NodeId, algo: Algorithm, freq: FreqId) -> Option<NodeCost> {
        let ni = &self.index[id.0];
        let fi = self.freq_universe.binary_search(&freq).ok()?;
        let si = *ni.slab_of.get(fi)?;
        if si == NO_SLOT {
            return None;
        }
        let slab = &self.entries[id.0][si as usize].1;
        if ni.uniform {
            let oi = ni.algo_slot[algo.ordinal()];
            if oi == NO_SLOT {
                return None;
            }
            let (found, cost) = slab[oi as usize];
            debug_assert_eq!(found, algo, "slab index out of sync");
            Some(cost)
        } else {
            slab.iter().find(|(al, _)| *al == algo).map(|(_, c)| *c)
        }
    }

    /// Additive cost of the graph under `a` (paper's cost model), each node
    /// priced at its assigned (algorithm, frequency) pair — plus, when a
    /// boundary overlay is attached, the link cost of every edge whose
    /// endpoints land on different devices and the re-tiling cost of every
    /// edge whose endpoints compute in different layouts. Device- and
    /// layout-uniform assignments cross no boundary, so no boundary term
    /// is ever added (exact conservation, not `+ 0.0`).
    pub fn eval(&self, a: &Assignment) -> GraphCost {
        let mut gc = GraphCost::default();
        for (i, slabs) in self.entries.iter().enumerate() {
            if slabs.is_empty() {
                continue;
            }
            let id = NodeId(i);
            let chosen = a.get(id).expect("assignment missing runtime node");
            let cost = self.option_cost(id, chosen, a.freq(id)).unwrap_or_else(|| {
                panic!("({chosen:?}, {}) not applicable to node {i}", a.freq(id).describe())
            });
            gc = gc.add(&cost);
        }
        if let Some(links) = &self.links {
            for edge in &links.edges {
                let fs = a.freq(edge.src);
                let fd = a.freq(edge.dst);
                if fs.device() != fd.device() {
                    gc.time_ms += edge.time_ms;
                    gc.energy_j += edge.energy_mj;
                }
                // Layout boundaries re-tile even on one device; both
                // charges apply when an edge crosses device AND layout.
                if fs.layout() != fd.layout() {
                    gc.time_ms += edge.transpose_ms;
                    gc.energy_j += edge.transpose_mj;
                }
            }
        }
        gc.freq = a.uniform_freq();
        gc
    }

    /// Nominal-clock cost options of one node (the pre-DVFS view; empty
    /// when the table was built at non-nominal states only).
    pub fn node_options(&self, id: NodeId) -> &[(Algorithm, NodeCost)] {
        self.entries[id.0]
            .iter()
            .find(|(f, _)| f.is_nominal())
            .map(|(_, v)| &v[..])
            .unwrap_or(&[])
    }

    /// All frequency slabs of one node (`NOMINAL` first).
    pub fn freq_options(&self, id: NodeId) -> &[FreqSlab] {
        &self.entries[id.0]
    }

    /// Cost options of one node at one DVFS state (empty if unresolved).
    pub fn options_at(&self, id: NodeId, freq: FreqId) -> &[(Algorithm, NodeCost)] {
        self.entries[id.0]
            .iter()
            .find(|(f, _)| *f == freq)
            .map(|(_, v)| &v[..])
            .unwrap_or(&[])
    }

    /// Total (algorithm, frequency) options of a node — the inner search's
    /// per-node decision count.
    pub fn option_count(&self, id: NodeId) -> usize {
        self.entries[id.0].iter().map(|(_, v)| v.len()).sum()
    }

    /// The `k`-th (frequency, algorithm) option of a node, slab-major —
    /// for random starts over the joint space.
    pub fn option_nth(&self, id: NodeId, mut k: usize) -> (FreqId, Algorithm) {
        for (f, slab) in &self.entries[id.0] {
            if k < slab.len() {
                return (*f, slab[k].0);
            }
            k -= slab.len();
        }
        panic!("option index out of range for node {}", id.0);
    }

    /// A copy of the table restricted to one frequency slab per node —
    /// the per-state view the per-graph DVFS search evaluates (cheap:
    /// slabs are `Arc`-shared, so this clones pointers, not options).
    /// Nodes without a slab at `freq` end up empty, exactly like a table
    /// built at `&[freq]` directly.
    pub fn restrict_to_freq(&self, freq: FreqId) -> GraphCostTable {
        self.restrict_states(|f| f == freq)
    }

    /// A copy of the table keeping only the frequency slabs `keep` admits
    /// (cheap: slabs are `Arc`-shared, so this clones pointers, not
    /// options). The fault path uses this to mask a lost device or
    /// thermally-capped clock states out of the search space; nodes whose
    /// every slab is rejected end up empty, exactly like a table built
    /// without those states.
    pub fn restrict_states(&self, mut keep: impl FnMut(FreqId) -> bool) -> GraphCostTable {
        GraphCostTable::from_freq_slabs(
            self.entries
                .iter()
                .map(|slabs| slabs.iter().filter(|(f, _)| keep(*f)).cloned().collect())
                .collect(),
        )
    }

    /// Canonical per-node argmin for an **additive** objective: scan the
    /// node's options slab-major (slabs in table order, options in slab
    /// order) keeping a strict running minimum of
    /// [`CostFunction::node_value`] — the *first* option attaining the
    /// minimum wins. Returns the chosen (frequency, algorithm) and the
    /// number of options scanned.
    ///
    /// This is exactly the choice the reference cold sweep converges to
    /// from the framework-default start (the default is the first option
    /// of the first slab, and the sweep only accepts strict
    /// improvements), which is what makes warm-started and memoized
    /// searches bit-identical to it. The result is independent of any
    /// starting assignment.
    ///
    /// # Panics
    /// On non-additive objectives, and on nodes with no options.
    pub fn scan_argmin(&self, id: NodeId, cf: &CostFunction) -> (FreqId, Algorithm, u64) {
        let mut best: Option<(f64, FreqId, Algorithm)> = None;
        let mut scanned = 0u64;
        for (f, slab) in &self.entries[id.0] {
            for &(algo, cost) in slab.iter() {
                scanned += 1;
                let v = cf.node_value(&cost);
                if best.as_ref().is_none_or(|(bv, _, _)| v < *bv) {
                    best = Some((v, *f, algo));
                }
            }
        }
        let (_, f, algo) = best.unwrap_or_else(|| panic!("argmin over optionless node {}", id.0));
        (f, algo, scanned)
    }

    /// Nodes that actually carry cost choices.
    pub fn costed_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(i, _)| NodeId(i))
    }

    /// Incremental re-evaluation: `base` with node `id` switched from its
    /// current (algorithm, frequency) pair to `(new_algo, new_freq)`.
    /// O(1) through the dense slab index, not O(#options) or O(n).
    ///
    /// Errors (propagated, per the no-panics-on-the-candidate-path
    /// policy) when the node carries no assignment or either pair is not
    /// applicable at the requested state.
    pub fn eval_swap(
        &self,
        base: GraphCost,
        a: &Assignment,
        id: NodeId,
        new_algo: Algorithm,
        new_freq: FreqId,
    ) -> anyhow::Result<GraphCost> {
        let old_algo = a
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("swap on non-runtime node {}", id.0))?;
        let old_freq = a.freq(id);
        let find = |al: Algorithm, f: FreqId| {
            self.option_cost(id, al, f).ok_or_else(|| {
                anyhow::anyhow!(
                    "({}, {}) not applicable to node {}",
                    al.name(),
                    f.describe(),
                    id.0
                )
            })
        };
        let old = find(old_algo, old_freq)?;
        let new = find(new_algo, new_freq)?;
        let mut out = GraphCost {
            time_ms: base.time_ms - old.time_ms + new.time_ms,
            energy_j: base.energy_j - old.energy_j() + new.energy_j(),
            freq: if new_freq == old_freq { base.freq } else { FreqId::NOMINAL },
        };
        // Device migration and layout flips change which incident edges
        // cross a boundary: re-price exactly those, O(degree). The two
        // boundary kinds are independent — a swap can change either or
        // both.
        if let Some(links) = &self.links {
            let dev_changed = old_freq.device() != new_freq.device();
            let lay_changed = old_freq.layout() != new_freq.layout();
            if dev_changed || lay_changed {
                for &ei in &links.incident[id.0] {
                    let edge = &links.edges[ei as usize];
                    let other = if edge.src == id { edge.dst } else { edge.src };
                    let other_freq = a.freq(other);
                    if dev_changed {
                        let was = old_freq.device() != other_freq.device();
                        let is = new_freq.device() != other_freq.device();
                        if was && !is {
                            out.time_ms -= edge.time_ms;
                            out.energy_j -= edge.energy_mj;
                        } else if !was && is {
                            out.time_ms += edge.time_ms;
                            out.energy_j += edge.energy_mj;
                        }
                    }
                    if lay_changed {
                        let was = old_freq.layout() != other_freq.layout();
                        let is = new_freq.layout() != other_freq.layout();
                        if was && !is {
                            out.time_ms -= edge.transpose_ms;
                            out.energy_j -= edge.transpose_mj;
                        } else if !was && is {
                            out.time_ms += edge.transpose_ms;
                            out.energy_j += edge.transpose_mj;
                        }
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_identity() {
        let c = NodeCost { time_ms: 2.0, power_w: 50.0 };
        assert_eq!(c.energy_j(), 100.0);
    }

    #[test]
    fn graph_cost_accumulates() {
        let gc = GraphCost::default()
            .add(&NodeCost { time_ms: 1.0, power_w: 100.0 })
            .add(&NodeCost { time_ms: 3.0, power_w: 50.0 });
        assert_eq!(gc.time_ms, 4.0);
        assert_eq!(gc.energy_j, 250.0);
        assert!((gc.power_w() - 62.5).abs() < 1e-12);
    }

    #[test]
    fn cost_functions_evaluate() {
        let gc = GraphCost { time_ms: 2.0, energy_j: 100.0, ..Default::default() };
        assert_eq!(CostFunction::Time.eval(&gc), 2.0);
        assert_eq!(CostFunction::Energy.eval(&gc), 100.0);
        assert_eq!(CostFunction::Power.eval(&gc), 50.0);
        let lin = CostFunction::linear(0.5);
        assert!((lin.eval(&gc) - (0.5 * 100.0 + 0.5 * 2.0)).abs() < 1e-12);
        let prod = CostFunction::Product { w: 0.5 };
        assert!((prod.eval(&gc) - (100.0f64.sqrt() * 2.0f64.sqrt())).abs() < 1e-9);
    }

    #[test]
    fn normalization_makes_baseline_unit_cost() {
        let baseline = GraphCost { time_ms: 2.0, energy_j: 100.0, ..Default::default() };
        let lin = CostFunction::linear(0.3).normalized(&baseline);
        assert!((lin.eval(&baseline) - 1.0).abs() < 1e-12);
        let pe = CostFunction::power_energy(0.5).normalized(&baseline);
        assert!((pe.eval(&baseline) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn additivity_classification() {
        assert!(CostFunction::Time.is_additive());
        assert!(CostFunction::Energy.is_additive());
        assert!(CostFunction::linear(0.7).is_additive());
        assert!(!CostFunction::Power.is_additive());
        assert!(!CostFunction::Product { w: 0.5 }.is_additive());
        assert_eq!(CostFunction::linear(0.7).recommended_inner_distance(), 1);
        assert_eq!(CostFunction::Power.recommended_inner_distance(), 2);
    }

    #[test]
    #[should_panic(expected = "weight")]
    fn linear_weight_range_checked() {
        CostFunction::linear(1.5);
    }

    fn two_node_table() -> GraphCostTable {
        GraphCostTable::from_entries(vec![
            vec![
                (Algorithm::ConvIm2col, NodeCost { time_ms: 1.0, power_w: 100.0 }),
                (Algorithm::ConvDirect, NodeCost { time_ms: 2.0, power_w: 30.0 }),
            ],
            Vec::new(),
            vec![(Algorithm::Passthrough, NodeCost { time_ms: 0.5, power_w: 10.0 })],
        ])
    }

    #[test]
    fn indexed_option_lookup_matches_linear_find() {
        let t = two_node_table();
        for id in t.costed_ids() {
            for (f, slab) in t.freq_options(id) {
                for &(algo, cost) in slab.iter() {
                    let found = t.option_cost(id, algo, *f).unwrap();
                    assert_eq!(found.time_ms.to_bits(), cost.time_ms.to_bits());
                    assert_eq!(found.power_w.to_bits(), cost.power_w.to_bits());
                }
            }
        }
        // Misses: inapplicable algorithm, unresolved state.
        assert!(t.option_cost(NodeId(0), Algorithm::GemmNaive, FreqId::NOMINAL).is_none());
        assert!(t.option_cost(NodeId(0), Algorithm::ConvIm2col, FreqId(510)).is_none());
    }

    #[test]
    fn eval_swap_errors_instead_of_panicking() {
        let t = two_node_table();
        let entries = vec![
            Some(Algorithm::ConvIm2col),
            None,
            Some(Algorithm::Passthrough),
        ];
        let a = Assignment::from_parts(entries, vec![FreqId::NOMINAL; 3]);
        let base = t.eval(&a);
        // Valid swap works.
        let swapped = t.eval_swap(base, &a, NodeId(0), Algorithm::ConvDirect, FreqId::NOMINAL);
        assert!(swapped.is_ok());
        assert!((swapped.unwrap().time_ms - (base.time_ms + 1.0)).abs() < 1e-12);
        // Swap on a non-runtime node and to an inapplicable pair error.
        assert!(t.eval_swap(base, &a, NodeId(1), Algorithm::ConvDirect, FreqId::NOMINAL).is_err());
        assert!(t.eval_swap(base, &a, NodeId(0), Algorithm::GemmNaive, FreqId::NOMINAL).is_err());
        assert!(t.eval_swap(base, &a, NodeId(0), Algorithm::ConvDirect, FreqId(900)).is_err());
    }

    #[test]
    fn scan_argmin_is_first_strict_minimum() {
        let t = two_node_table();
        // Energy: im2col = 1*100 = 100, direct = 2*30 = 60 -> direct.
        let (f, algo, scanned) = t.scan_argmin(NodeId(0), &CostFunction::Energy);
        assert_eq!((f, algo, scanned), (FreqId::NOMINAL, Algorithm::ConvDirect, 2));
        // Time: im2col (1.0) wins and, being first, survives ties.
        let (_, algo, _) = t.scan_argmin(NodeId(0), &CostFunction::Time);
        assert_eq!(algo, Algorithm::ConvIm2col);
    }

    #[test]
    fn additive_keys_identify_objectives_exactly() {
        assert_eq!(CostFunction::Time.additive_key(), CostFunction::Time.additive_key());
        assert_ne!(CostFunction::Time.additive_key(), CostFunction::Energy.additive_key());
        assert_ne!(
            CostFunction::linear(0.5).additive_key(),
            CostFunction::linear(0.25).additive_key()
        );
        let b = GraphCost { time_ms: 2.0, energy_j: 10.0, ..Default::default() };
        assert_ne!(
            CostFunction::linear(0.5).additive_key(),
            CostFunction::linear(0.5).normalized(&b).additive_key(),
            "normalization is part of the objective identity"
        );
        assert_eq!(CostFunction::Power.additive_key(), None);
        assert_eq!(CostFunction::Product { w: 0.5 }.additive_key(), None);
    }

    fn two_device_table_with_link() -> GraphCostTable {
        use crate::energysim::DeviceId;
        let dla = FreqId::on(DeviceId::DLA, 0);
        let mk = |t_gpu: f64, p_gpu: f64, t_dla: f64, p_dla: f64| {
            vec![
                (
                    FreqId::NOMINAL,
                    Arc::new(vec![(Algorithm::Passthrough, NodeCost { time_ms: t_gpu, power_w: p_gpu })]),
                ),
                (
                    dla,
                    Arc::new(vec![(Algorithm::Passthrough, NodeCost { time_ms: t_dla, power_w: p_dla })]),
                ),
            ]
        };
        let mut t = GraphCostTable::from_freq_slabs(vec![
            mk(1.0, 100.0, 4.0, 10.0),
            Vec::new(),
            mk(0.5, 80.0, 2.0, 8.0),
        ]);
        // One data edge 0 → 2 (node 1 is a weight-like zero-cost node).
        let edges = vec![TransferLink {
            src: NodeId(0),
            dst: NodeId(2),
            bytes: 1024.0,
            time_ms: 0.125,
            energy_mj: 0.75,
            transpose_ms: 0.03,
            transpose_mj: 0.05,
        }];
        let mut incident = vec![Vec::new(); 3];
        incident[0].push(0);
        incident[2].push(0);
        t.attach_links_shared(Arc::new(TransferLinks { edges, incident }));
        t
    }

    #[test]
    fn transfer_charged_iff_edge_crosses_devices() {
        use crate::energysim::DeviceId;
        let t = two_device_table_with_link();
        let dla = FreqId::on(DeviceId::DLA, 0);
        let algos = vec![Some(Algorithm::Passthrough), None, Some(Algorithm::Passthrough)];
        let both_gpu = Assignment::from_parts(algos.clone(), vec![FreqId::NOMINAL; 3]);
        let both_dla = Assignment::from_parts(algos.clone(), vec![dla; 3]);
        let mut split = both_gpu.clone();
        split.set_freq(NodeId(2), dla);

        // Device-uniform: bit-exact conservation (no transfer terms added).
        let gpu_cost = t.eval(&both_gpu);
        assert_eq!(gpu_cost.time_ms.to_bits(), (1.0f64 + 0.5).to_bits());
        assert_eq!(gpu_cost.energy_j.to_bits(), (1.0f64 * 100.0 + 0.5 * 80.0).to_bits());
        let dla_cost = t.eval(&both_dla);
        assert_eq!(dla_cost.time_ms.to_bits(), (4.0f64 + 2.0).to_bits());
        assert_eq!(t.transfer_cost(&both_gpu), (0.0, 0.0));
        assert_eq!(t.transfer_cost(&both_dla), (0.0, 0.0));

        // Split placement: exactly one boundary edge charged.
        let split_cost = t.eval(&split);
        assert!((split_cost.time_ms - (1.0 + 2.0 + 0.125)).abs() < 1e-12);
        assert!((split_cost.energy_j - (100.0 + 16.0 + 0.75)).abs() < 1e-12);
        assert_eq!(t.transfer_cost(&split), (0.125, 0.75));
    }

    #[test]
    fn restrict_states_masks_a_device_out_of_the_table() {
        use crate::energysim::DeviceId;
        let t = two_device_table_with_link();
        let gpu_only = t.restrict_states(|f| f.device() == DeviceId::GPU);
        // The DLA slabs are gone, the GPU slabs untouched.
        assert_eq!(gpu_only.option_count(NodeId(0)), 1);
        assert_eq!(gpu_only.option_count(NodeId(2)), 1);
        let algos = vec![Some(Algorithm::Passthrough), None, Some(Algorithm::Passthrough)];
        let both_gpu = Assignment::from_parts(algos, vec![FreqId::NOMINAL; 3]);
        let full = t.eval(&both_gpu);
        let masked = gpu_only.eval(&both_gpu);
        assert_eq!(full.time_ms.to_bits(), masked.time_ms.to_bits());
        assert_eq!(full.energy_j.to_bits(), masked.energy_j.to_bits());
        // The single-frequency view stays the predicate's special case.
        let a = t.restrict_to_freq(FreqId::NOMINAL);
        let b = t.restrict_states(|f| f == FreqId::NOMINAL);
        for id in [NodeId(0), NodeId(1), NodeId(2)] {
            assert_eq!(a.option_count(id), b.option_count(id));
        }
    }

    #[test]
    fn eval_swap_tracks_boundary_changes() {
        use crate::energysim::DeviceId;
        let t = two_device_table_with_link();
        let dla = FreqId::on(DeviceId::DLA, 0);
        let algos = vec![Some(Algorithm::Passthrough), None, Some(Algorithm::Passthrough)];
        let both_gpu = Assignment::from_parts(algos.clone(), vec![FreqId::NOMINAL; 3]);
        let base = t.eval(&both_gpu);

        // GPU→DLA migration of node 2 opens the boundary…
        let swapped = t.eval_swap(base, &both_gpu, NodeId(2), Algorithm::Passthrough, dla).unwrap();
        let mut split = both_gpu.clone();
        split.set_freq(NodeId(2), dla);
        let full = t.eval(&split);
        assert!((swapped.time_ms - full.time_ms).abs() < 1e-12);
        assert!((swapped.energy_j - full.energy_j).abs() < 1e-12);

        // …and migrating node 0 after it closes the boundary again.
        let closed = t.eval_swap(full, &split, NodeId(0), Algorithm::Passthrough, dla).unwrap();
        let mut both = split.clone();
        both.set_freq(NodeId(0), dla);
        let full_both = t.eval(&both);
        assert!((closed.time_ms - full_both.time_ms).abs() < 1e-12);
        assert!((closed.energy_j - full_both.energy_j).abs() < 1e-12);
        assert!(t.has_links());
    }

    /// As [`two_device_table_with_link`], with every (device, clock) slab
    /// also resolved in NHWC (same costs — this test exercises only the
    /// boundary overlay, not the per-node layout pricing).
    fn two_layout_table_with_link() -> GraphCostTable {
        use crate::energysim::{DeviceId, Layout};
        let dla = FreqId::on(DeviceId::DLA, 0);
        let mk = |t_gpu: f64, p_gpu: f64, t_dla: f64, p_dla: f64| {
            let gpu = Arc::new(vec![(
                Algorithm::Passthrough,
                NodeCost { time_ms: t_gpu, power_w: p_gpu },
            )]);
            let dla_slab = Arc::new(vec![(
                Algorithm::Passthrough,
                NodeCost { time_ms: t_dla, power_w: p_dla },
            )]);
            vec![
                (FreqId::NOMINAL, gpu.clone()),
                (dla, dla_slab.clone()),
                (FreqId::NOMINAL.with_layout(Layout::NHWC), gpu),
                (dla.with_layout(Layout::NHWC), dla_slab),
            ]
        };
        let mut t = GraphCostTable::from_freq_slabs(vec![
            mk(1.0, 100.0, 4.0, 10.0),
            Vec::new(),
            mk(0.5, 80.0, 2.0, 8.0),
        ]);
        let edges = vec![TransferLink {
            src: NodeId(0),
            dst: NodeId(2),
            bytes: 1024.0,
            time_ms: 0.125,
            energy_mj: 0.75,
            transpose_ms: 0.03,
            transpose_mj: 0.05,
        }];
        let mut incident = vec![Vec::new(); 3];
        incident[0].push(0);
        incident[2].push(0);
        t.attach_links_shared(Arc::new(TransferLinks { edges, incident }));
        t
    }

    #[test]
    fn transpose_charged_iff_edge_crosses_layouts() {
        use crate::energysim::{DeviceId, Layout};
        let t = two_layout_table_with_link();
        let nhwc = FreqId::NOMINAL.with_layout(Layout::NHWC);
        let algos = vec![Some(Algorithm::Passthrough), None, Some(Algorithm::Passthrough)];
        let uniform = Assignment::from_parts(algos.clone(), vec![FreqId::NOMINAL; 3]);
        let base = t.eval(&uniform);

        // Layout-uniform plans charge nothing.
        assert_eq!(t.transpose_cost(&uniform), (0.0, 0.0));
        let all_nhwc = Assignment::from_parts(algos.clone(), vec![nhwc; 3]);
        assert_eq!(t.transpose_cost(&all_nhwc), (0.0, 0.0));

        // Flipping one endpoint opens a layout boundary on the 0→2 edge,
        // on the same device: transpose charged, transfer not.
        let mut mixed = uniform.clone();
        mixed.set_freq(NodeId(2), nhwc);
        assert_eq!(t.transpose_cost(&mixed), (0.03, 0.05));
        assert_eq!(t.transfer_cost(&mixed), (0.0, 0.0));
        let full = t.eval(&mixed);
        assert!((full.time_ms - (base.time_ms + 0.03)).abs() < 1e-12);
        assert!((full.energy_j - (base.energy_j + 0.05)).abs() < 1e-12);

        // eval_swap tracks the layout boundary exactly…
        let swapped = t.eval_swap(base, &uniform, NodeId(2), Algorithm::Passthrough, nhwc).unwrap();
        assert!((swapped.time_ms - full.time_ms).abs() < 1e-12);
        assert!((swapped.energy_j - full.energy_j).abs() < 1e-12);
        // …and closing it again recovers the uniform cost.
        let closed =
            t.eval_swap(full, &mixed, NodeId(2), Algorithm::Passthrough, FreqId::NOMINAL).unwrap();
        assert!((closed.time_ms - base.time_ms).abs() < 1e-12);
        assert!((closed.energy_j - base.energy_j).abs() < 1e-12);

        // Crossing device AND layout on one edge charges both overlays.
        let dla_nhwc = FreqId::on(DeviceId::DLA, 0).with_layout(Layout::NHWC);
        let mut both = uniform.clone();
        both.set_freq(NodeId(2), dla_nhwc);
        let cost_both = t.eval(&both);
        let swap_both =
            t.eval_swap(base, &uniform, NodeId(2), Algorithm::Passthrough, dla_nhwc).unwrap();
        assert!((swap_both.time_ms - cost_both.time_ms).abs() < 1e-12);
        assert!((swap_both.energy_j - cost_both.energy_j).abs() < 1e-12);
        assert_eq!(t.transfer_cost(&both), (0.125, 0.75));
        assert_eq!(t.transpose_cost(&both), (0.03, 0.05));
    }

    #[test]
    fn node_value_orders_like_whole_graph_swap() {
        let a = NodeCost { time_ms: 1.0, power_w: 100.0 };
        let b = NodeCost { time_ms: 2.0, power_w: 30.0 };
        assert!(CostFunction::Time.node_value(&a) < CostFunction::Time.node_value(&b));
        assert!(CostFunction::Energy.node_value(&b) < CostFunction::Energy.node_value(&a));
        let lin = CostFunction::Linear { w: 0.5, t_norm: 1.0, e_norm: 1.0 };
        assert!((lin.node_value(&a) - (0.5 * 100.0 + 0.5 * 1.0)).abs() < 1e-12);
    }
}
