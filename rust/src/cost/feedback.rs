//! Measured serving telemetry, overlaying the profile database.
//!
//! The serve loop predicts cost from [`CostDb`] rows that were profiled
//! offline; the rows drift from reality as thermals, clocks, and load
//! move (PolyThrottle's observation). This module is the writeback half
//! of the feedback loop:
//!
//! - [`MeasuredStore`] accumulates EWMA-smoothed **observed** per-row
//!   costs, keyed exactly like the database — `(signature, algorithm,
//!   frequency)` — so an observation is a drop-in replacement for the
//!   prediction it corrects.
//! - [`CostOracle::apply_feedback`] folds a store back into the oracle:
//!   measured rows overwrite their database predecessors (tagged with a
//!   `measured:` provenance), and only the resolve-cache shards and
//!   argmin-memo keys those rows invalidate are evicted — concurrent
//!   readers keep their slab `Arc`s and never observe a torn table.
//!
//! The serve side attributes a whole-plan observation down to rows via
//! [`CostOracle::observe_plan`]: a plan-level observed/predicted ratio
//! scales every node row the plan exercised (per-node attribution under
//! an additive cost model — the plan's cost is the sum of its rows, so a
//! uniform row scale reproduces the observed plan cost exactly).
//!
//! [`CostDb`]: super::CostDb
//! [`CostOracle::apply_feedback`]: super::CostOracle::apply_feedback
//! [`CostOracle::observe_plan`]: super::CostOracle::observe_plan

use super::NodeCost;
use crate::algo::Algorithm;
use crate::energysim::FreqId;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One EWMA-smoothed observed cost row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeasuredRow {
    /// The smoothed observed cost (same units as the profile database:
    /// milliseconds and watts at the row's frequency).
    pub cost: NodeCost,
    /// How many observations the EWMA has absorbed.
    pub samples: u64,
}

/// Thread-safe accumulator of observed `(signature, algorithm, frequency)`
/// costs, keyed like the [`CostDb`](super::CostDb) it overlays.
///
/// Observations blend into an exponentially weighted moving average with
/// the weight given at construction (`new_value = w·obs + (1-w)·old`), so
/// a noisy measurement nudges the row instead of replacing it. The store
/// is internally locked: serve threads observe while a background
/// re-search reads a snapshot.
#[derive(Debug)]
pub struct MeasuredStore {
    ewma: f64,
    rows: Mutex<BTreeMap<(String, Algorithm, FreqId), MeasuredRow>>,
}

impl MeasuredStore {
    /// Create a store whose observations blend with EWMA weight `ewma`
    /// (in `(0, 1]`; 1 means every observation replaces the row).
    ///
    /// # Panics
    /// Panics when `ewma` is outside `(0, 1]` or not finite.
    pub fn new(ewma: f64) -> MeasuredStore {
        assert!(
            ewma.is_finite() && ewma > 0.0 && ewma <= 1.0,
            "MeasuredStore ewma must be in (0, 1], got {ewma}"
        );
        MeasuredStore { ewma, rows: Mutex::new(BTreeMap::new()) }
    }

    /// Record one observed cost for a row. Non-finite or non-positive
    /// times are dropped (a zero-time "observation" is a measurement
    /// artifact, never a real kernel).
    pub fn observe(&self, sig: &str, algo: Algorithm, freq: FreqId, cost: NodeCost) {
        if !(cost.time_ms.is_finite() && cost.time_ms > 0.0 && cost.power_w.is_finite()) {
            return;
        }
        let mut rows = self.rows.lock().unwrap();
        match rows.get_mut(&(sig.to_string(), algo, freq)) {
            Some(row) => {
                row.cost.time_ms = self.ewma * cost.time_ms + (1.0 - self.ewma) * row.cost.time_ms;
                row.cost.power_w = self.ewma * cost.power_w + (1.0 - self.ewma) * row.cost.power_w;
                row.samples += 1;
            }
            None => {
                rows.insert((sig.to_string(), algo, freq), MeasuredRow { cost, samples: 1 });
            }
        }
    }

    /// The smoothed row for a key, if any observation has landed.
    pub fn get(&self, sig: &str, algo: Algorithm, freq: FreqId) -> Option<MeasuredRow> {
        self.rows.lock().unwrap().get(&(sig.to_string(), algo, freq)).copied()
    }

    /// Number of distinct observed rows.
    pub fn len(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// Whether no observation has landed yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A deterministic (key-sorted) snapshot of every smoothed row —
    /// what [`CostOracle::apply_feedback`](super::CostOracle::apply_feedback)
    /// folds into the database.
    pub fn snapshot(&self) -> Vec<(String, Algorithm, FreqId, MeasuredRow)> {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .map(|((s, a, f), row)| (s.clone(), *a, *f, *row))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIG: &str = "relu;in=1x3x8x8";

    fn cost(t: f64, p: f64) -> NodeCost {
        NodeCost { time_ms: t, power_w: p }
    }

    #[test]
    fn observations_blend_as_ewma() {
        let store = MeasuredStore::new(0.5);
        let a = Algorithm::Passthrough;
        store.observe(SIG, a, FreqId::NOMINAL, cost(1.0, 100.0));
        store.observe(SIG, a, FreqId::NOMINAL, cost(3.0, 200.0));
        let row = store.get(SIG, a, FreqId::NOMINAL).unwrap();
        assert_eq!(row.samples, 2);
        assert!((row.cost.time_ms - 2.0).abs() < 1e-12);
        assert!((row.cost.power_w - 150.0).abs() < 1e-12);
    }

    #[test]
    fn keys_are_per_algo_and_per_freq() {
        let store = MeasuredStore::new(1.0);
        let a = Algorithm::Passthrough;
        store.observe(SIG, a, FreqId::NOMINAL, cost(1.0, 100.0));
        store.observe(SIG, a, FreqId(3), cost(2.0, 80.0));
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(SIG, a, FreqId(3)).unwrap().cost.time_ms, 2.0);
        assert!(store.get("other;sig", a, FreqId::NOMINAL).is_none());
    }

    #[test]
    fn junk_observations_are_dropped() {
        let store = MeasuredStore::new(0.5);
        let a = Algorithm::Passthrough;
        store.observe(SIG, a, FreqId::NOMINAL, cost(0.0, 100.0));
        store.observe(SIG, a, FreqId::NOMINAL, cost(f64::NAN, 100.0));
        store.observe(SIG, a, FreqId::NOMINAL, cost(1.0, f64::INFINITY));
        assert!(store.is_empty());
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let store = MeasuredStore::new(1.0);
        let a = Algorithm::Passthrough;
        store.observe("z;sig", a, FreqId::NOMINAL, cost(1.0, 1.0));
        store.observe("a;sig", a, FreqId::NOMINAL, cost(2.0, 2.0));
        let snap = store.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].0 < snap[1].0, "snapshot must be key-sorted");
    }
}
