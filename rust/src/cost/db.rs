//! The profile database: (node signature, algorithm, DVFS state) →
//! measured cost, persisted to JSON on disk (paper §3.2: "The measured
//! values are stored in a database and persisted onto disk for future
//! lookup"; §4.1: "After the first run, each later run finishes in a few
//! minutes since most profile results ... have already been cached into
//! database").
//!
//! Frequency keying: a profile taken at the nominal clock is stored under
//! the bare algorithm name (`"winograd"`), exactly as before the DVFS axis
//! existed — old database files load unchanged and `--dvfs off` reads the
//! same entries it always did. Non-nominal profiles get an `@f<MHz>`
//! suffix (`"winograd@f900"`).

use super::NodeCost;
use crate::algo::Algorithm;
use crate::energysim::FreqId;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// The database key of an (algorithm, frequency) pair. Only the raw zero
/// value (GPU at nominal) maps to the bare pre-DVFS name: other devices'
/// nominal states are distinct measurements and keep their packed `@f`
/// suffix (e.g. `"winograd@f4096"` = DLA nominal), so a DLA profile can
/// never shadow a GPU one.
fn algo_key(algo: Algorithm, freq: FreqId) -> String {
    if freq.0 == 0 {
        algo.name().to_string()
    } else {
        format!("{}@f{}", algo.name(), freq.0)
    }
}

/// Parse a database key back into (algorithm, frequency).
fn parse_algo_key(key: &str) -> Option<(Algorithm, FreqId)> {
    match key.split_once("@f") {
        None => Algorithm::from_name(key).map(|a| (a, FreqId::NOMINAL)),
        Some((name, mhz)) => {
            let algo = Algorithm::from_name(name)?;
            let mhz: u16 = mhz.parse().ok()?;
            Some((algo, FreqId(mhz)))
        }
    }
}

/// Where a profile came from — useful when mixing simulated and real
/// measurements in one database.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance(pub String);

#[derive(Debug, Clone)]
struct Entry {
    cost: NodeCost,
    provenance: String,
}

/// In-memory profile DB with JSON persistence.
#[derive(Debug, Clone, Default)]
pub struct CostDb {
    // signature -> algorithm name -> entry
    map: BTreeMap<String, BTreeMap<String, Entry>>,
    /// Monotone counter of lookups that missed (profiling pressure metric).
    misses: std::cell::Cell<u64>,
}

impl CostDb {
    /// An empty database.
    pub fn new() -> CostDb {
        CostDb::default()
    }

    /// Lookup at the nominal clock (the pre-DVFS entry).
    pub fn get(&self, sig: &str, algo: Algorithm) -> Option<NodeCost> {
        self.get_at(sig, algo, FreqId::NOMINAL)
    }

    /// Lookup at a specific DVFS state (`NOMINAL` = the pre-DVFS entry).
    pub fn get_at(&self, sig: &str, algo: Algorithm, freq: FreqId) -> Option<NodeCost> {
        let hit = self
            .map
            .get(sig)
            .and_then(|algos| algos.get(algo_key(algo, freq).as_str()))
            .map(|e| e.cost);
        if hit.is_none() {
            self.misses.set(self.misses.get() + 1);
        }
        hit
    }

    /// Whether a nominal-clock profile exists for the pair.
    pub fn contains(&self, sig: &str, algo: Algorithm) -> bool {
        self.contains_at(sig, algo, FreqId::NOMINAL)
    }

    /// Whether a profile exists for the pair at a specific DVFS state.
    pub fn contains_at(&self, sig: &str, algo: Algorithm, freq: FreqId) -> bool {
        self.map.get(sig).is_some_and(|a| a.contains_key(algo_key(algo, freq).as_str()))
    }

    /// Insert a nominal-clock profile.
    pub fn insert(&mut self, sig: &str, algo: Algorithm, cost: NodeCost, provenance: &str) {
        self.insert_at(sig, algo, FreqId::NOMINAL, cost, provenance)
    }

    /// Insert a profile at a specific DVFS state.
    pub fn insert_at(
        &mut self,
        sig: &str,
        algo: Algorithm,
        freq: FreqId,
        cost: NodeCost,
        provenance: &str,
    ) {
        self.map
            .entry(sig.to_string())
            .or_default()
            .insert(algo_key(algo, freq), Entry { cost, provenance: provenance.to_string() });
    }

    /// Number of distinct signatures profiled.
    pub fn num_signatures(&self) -> usize {
        self.map.len()
    }

    /// Total number of (signature, algorithm) entries.
    pub fn num_entries(&self) -> usize {
        self.map.values().map(BTreeMap::len).sum()
    }

    /// Lookups that missed since creation (profiling pressure metric).
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// All nominal-clock entries of a signature (reporting / Table 1).
    pub fn entries_for(&self, sig: &str) -> Vec<(Algorithm, NodeCost)> {
        self.map
            .get(sig)
            .map(|algos| {
                algos
                    .iter()
                    .filter_map(|(key, e)| match parse_algo_key(key) {
                        // Raw zero only: GPU nominal, not other devices'.
                        Some((a, f)) if f.0 == 0 => Some((a, e.cost)),
                        _ => None,
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Serialize the whole database (versioned, deterministic order).
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("version", 1i64);
        let mut sigs = Json::obj();
        for (sig, algos) in &self.map {
            let mut a_obj = Json::obj();
            for (name, e) in algos {
                let mut rec = Json::obj();
                rec.set("time_ms", e.cost.time_ms)
                    .set("power_w", e.cost.power_w)
                    .set("provenance", e.provenance.as_str());
                a_obj.set(name, rec);
            }
            sigs.set(sig, a_obj);
        }
        root.set("profiles", sigs);
        root
    }

    /// Parse a database document, validating every entry.
    pub fn from_json(v: &Json) -> anyhow::Result<CostDb> {
        let mut db = CostDb::new();
        let profiles = v
            .get("profiles")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("cost db missing `profiles`"))?;
        for (sig, algos) in profiles {
            let algos = algos
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("profiles[{sig}] not an object"))?;
            for (name, rec) in algos {
                let (algo, freq) = parse_algo_key(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown algorithm key `{name}` in db"))?;
                let cost = NodeCost {
                    time_ms: rec.req_f64("time_ms")?,
                    power_w: rec.req_f64("power_w")?,
                };
                let prov = rec.get("provenance").and_then(Json::as_str).unwrap_or("unknown");
                db.insert_at(sig, algo, freq, cost, prov);
            }
        }
        Ok(db)
    }

    /// Serialize + write to `path`.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::write_file(path, &self.to_json())
    }

    /// Read + parse from `path`.
    pub fn load(path: &Path) -> anyhow::Result<CostDb> {
        CostDb::from_json(&json::read_file(path)?)
    }

    /// Load if present, else empty (the first-run-is-slow behaviour). A
    /// present-but-corrupt file also yields an empty db — silently losing
    /// every profile would masquerade as a cold cache, so the parse error
    /// is reported on stderr (once, with the path) before falling back.
    pub fn load_or_default(path: &Path) -> CostDb {
        let (db, warning) = CostDb::load_or_default_noted(path);
        if let Some(w) = warning {
            eprintln!("warning: {w}");
        }
        db
    }

    /// Testable core of [`CostDb::load_or_default`]: the db plus the
    /// warning line a corrupt file earns (`None` for a missing or healthy
    /// file), instead of printing it.
    pub fn load_or_default_noted(path: &Path) -> (CostDb, Option<String>) {
        if !path.exists() {
            return (CostDb::new(), None);
        }
        match CostDb::load(path) {
            Ok(db) => (db, None),
            Err(e) => (
                CostDb::new(),
                Some(format!(
                    "ignoring corrupt profile db {}: {e} (starting with an empty db)",
                    path.display()
                )),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_contains() {
        let mut db = CostDb::new();
        let c = NodeCost { time_ms: 0.5, power_w: 100.0 };
        db.insert("conv2d;x", Algorithm::ConvDirect, c, "sim-v100");
        assert_eq!(db.get("conv2d;x", Algorithm::ConvDirect), Some(c));
        assert!(db.contains("conv2d;x", Algorithm::ConvDirect));
        assert!(!db.contains("conv2d;x", Algorithm::ConvIm2col));
        assert_eq!(db.get("conv2d;y", Algorithm::ConvDirect), None);
        assert_eq!(db.misses(), 1);
        assert_eq!(db.num_signatures(), 1);
        assert_eq!(db.num_entries(), 1);
    }

    #[test]
    fn corrupt_profile_db_warns_instead_of_silently_resetting() {
        let dir = std::env::temp_dir().join("eadgo_costdb_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();

        // Missing file: cold cache, no warning.
        let missing = dir.join("absent.json");
        let (db, warn) = CostDb::load_or_default_noted(&missing);
        assert_eq!(db.num_entries(), 0);
        assert!(warn.is_none());

        // Healthy file: loads, no warning.
        let good = dir.join("good.json");
        let mut src = CostDb::new();
        src.insert("conv2d;x", Algorithm::ConvDirect, NodeCost { time_ms: 0.5, power_w: 100.0 }, "sim");
        src.save(&good).unwrap();
        let (db, warn) = CostDb::load_or_default_noted(&good);
        assert_eq!(db.num_entries(), 1);
        assert!(warn.is_none());

        // Corrupt file: empty db, and the warning names the path and the
        // parse error so the reset is never mistaken for a cold cache.
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{\"profiles\": 42}").unwrap();
        let (db, warn) = CostDb::load_or_default_noted(&bad);
        assert_eq!(db.num_entries(), 0);
        let warn = warn.expect("corrupt db must produce a warning");
        assert!(warn.contains("bad.json"), "{warn}");
        assert!(warn.contains("profiles"), "{warn}");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_roundtrip() {
        let mut db = CostDb::new();
        db.insert(
            "conv2d;st=1,1;1x3x8x8;4x3x3x3",
            Algorithm::ConvIm2col,
            NodeCost { time_ms: 0.0195, power_w: 144.5 },
            "sim-v100",
        );
        db.insert(
            "conv2d;st=1,1;1x3x8x8;4x3x3x3",
            Algorithm::ConvDirect,
            NodeCost { time_ms: 0.0209, power_w: 84.0 },
            "sim-v100",
        );
        db.insert("matmul;4x8;8x2", Algorithm::GemmBlocked, NodeCost { time_ms: 0.001, power_w: 60.0 }, "cpu");
        let j = db.to_json();
        let back = CostDb::from_json(&j).unwrap();
        assert_eq!(back.num_entries(), 3);
        assert_eq!(
            back.get("conv2d;st=1,1;1x3x8x8;4x3x3x3", Algorithm::ConvDirect),
            Some(NodeCost { time_ms: 0.0209, power_w: 84.0 })
        );
    }

    #[test]
    fn file_roundtrip_and_load_or_default() {
        let dir = std::env::temp_dir().join("eadgo_costdb_test");
        let path = dir.join("profiles.json");
        std::fs::remove_file(&path).ok();
        let empty = CostDb::load_or_default(&path);
        assert_eq!(empty.num_entries(), 0);
        let mut db = CostDb::new();
        db.insert("relu;1x4x8x8", Algorithm::Passthrough, NodeCost { time_ms: 0.001, power_w: 45.0 }, "sim");
        db.save(&path).unwrap();
        let back = CostDb::load_or_default(&path);
        assert_eq!(back.num_entries(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn entries_for_lists_all_algorithms() {
        let mut db = CostDb::new();
        db.insert("s", Algorithm::ConvIm2col, NodeCost { time_ms: 1.0, power_w: 100.0 }, "x");
        db.insert("s", Algorithm::ConvWinograd, NodeCost { time_ms: 0.5, power_w: 90.0 }, "x");
        let mut entries = db.entries_for("s");
        entries.sort_by_key(|(a, _)| *a);
        assert_eq!(entries.len(), 2);
    }

    #[test]
    fn freq_keys_are_separate_and_roundtrip() {
        let mut db = CostDb::new();
        let nom = NodeCost { time_ms: 1.0, power_w: 200.0 };
        let low = NodeCost { time_ms: 1.5, power_w: 110.0 };
        db.insert("conv2d;x", Algorithm::ConvWinograd, nom, "sim-v100");
        db.insert_at("conv2d;x", Algorithm::ConvWinograd, FreqId(900), low, "sim-v100");
        // Distinct entries per state; nominal stays under the bare name.
        assert_eq!(db.get("conv2d;x", Algorithm::ConvWinograd), Some(nom));
        assert_eq!(db.get_at("conv2d;x", Algorithm::ConvWinograd, FreqId(900)), Some(low));
        assert_eq!(db.get_at("conv2d;x", Algorithm::ConvWinograd, FreqId(705)), None);
        assert_eq!(db.num_entries(), 2);
        // Table-1 listing remains nominal-only.
        assert_eq!(db.entries_for("conv2d;x"), vec![(Algorithm::ConvWinograd, nom)]);
        // JSON roundtrip preserves the frequency axis.
        let back = CostDb::from_json(&db.to_json()).unwrap();
        assert_eq!(back.get_at("conv2d;x", Algorithm::ConvWinograd, FreqId(900)), Some(low));
        assert_eq!(back.get("conv2d;x", Algorithm::ConvWinograd), Some(nom));
        assert!(back.contains_at("conv2d;x", Algorithm::ConvWinograd, FreqId(900)));
    }

    #[test]
    fn device_nominal_keys_do_not_shadow_gpu_nominal() {
        use crate::energysim::DeviceId;
        let mut db = CostDb::new();
        let gpu = NodeCost { time_ms: 0.5, power_w: 180.0 };
        let dla = NodeCost { time_ms: 2.5, power_w: 12.0 };
        db.insert("conv2d;x", Algorithm::ConvDirect, gpu, "sim-v100");
        let dla_nom = FreqId::on(DeviceId::DLA, 0);
        assert!(dla_nom.is_nominal(), "DLA nominal is a nominal state");
        db.insert_at("conv2d;x", Algorithm::ConvDirect, dla_nom, dla, "sim-dla");
        // Two distinct entries: the packed DLA state never collides with
        // the bare GPU-nominal key, and Table-1 listings stay GPU-only.
        assert_eq!(db.num_entries(), 2);
        assert_eq!(db.get("conv2d;x", Algorithm::ConvDirect), Some(gpu));
        assert_eq!(db.get_at("conv2d;x", Algorithm::ConvDirect, dla_nom), Some(dla));
        assert_eq!(db.entries_for("conv2d;x"), vec![(Algorithm::ConvDirect, gpu)]);
        let back = CostDb::from_json(&db.to_json()).unwrap();
        assert_eq!(back.get_at("conv2d;x", Algorithm::ConvDirect, dla_nom), Some(dla));
        assert_eq!(back.get("conv2d;x", Algorithm::ConvDirect), Some(gpu));
    }

    #[test]
    fn bad_json_rejected() {
        assert!(CostDb::from_json(&Json::Null).is_err());
        let parsed = crate::util::json::parse(r#"{"profiles": {"s": {"bogus_algo": {"time_ms": 1, "power_w": 2}}}}"#).unwrap();
        assert!(CostDb::from_json(&parsed).is_err());
    }
}
