//! The **cost oracle**: a concurrency-safe evaluation service answering
//! "what does this node cost under each applicable algorithm?" for the
//! search layers.
//!
//! This is the thread-safe half of what used to be the monolithic
//! `OptimizerContext`: the profile database, the signature→options resolve
//! cache, and the measurement provider, all behind interior mutability so
//! the outer search can evaluate candidate graphs from many threads
//! through a shared `&CostOracle`.
//!
//! Design:
//! - Node signatures are **interned** (`String` → [`SigId`], a dense
//!   `u32`) by a [`SigInterner`]. Candidate graphs within one search share
//!   almost all signatures, so the hot path hashes a small integer instead
//!   of re-hashing 60–120 byte strings.
//! - The resolve cache (signature → `Arc<[(Algorithm, NodeCost)]>` options)
//!   is **sharded** across `SHARDS` `RwLock`ed maps keyed by `SigId`, so
//!   concurrent table builds contend only when two threads miss on
//!   signatures in the same shard at the same time.
//! - On a miss the owning shard's write lock is held across the measure,
//!   which guarantees each `(signature, algorithm)` pair is measured
//!   **exactly once** no matter how many threads race to it — the paper's
//!   "nodes with the same parameters only need to be measured once"
//!   invariant, now under parallelism.
//! - The persistent [`CostDb`] sits behind a `Mutex` and is only touched
//!   on resolve misses (first run) — steady-state lookups never reach it.

use super::feedback::MeasuredStore;
use super::{AdditiveKey, CostDb, CostFunction, GraphCostTable, NodeCost, TransferLink, TransferLinks};
use crate::algo::{Algorithm, AlgorithmRegistry, Assignment};
use crate::energysim::{DeviceId, FreqId, LinkModel};
use crate::graph::{DeltaView, Graph, NodeId, OpKind, TensorShape};
use crate::profiler::{CostProvider, ProfileReport};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Interned node-signature id. Dense, starting at 0, stable for the
/// lifetime of the interner that issued it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SigId(pub u32);

#[derive(Default)]
struct InternerInner {
    ids: HashMap<String, SigId>,
    names: Vec<String>,
}

/// Thread-safe signature interner (`String` → [`SigId`]).
#[derive(Default)]
pub struct SigInterner {
    inner: RwLock<InternerInner>,
}

impl SigInterner {
    /// Intern `sig`, returning its stable id (read-lock fast path).
    pub fn intern(&self, sig: &str) -> SigId {
        if let Some(&id) = self.inner.read().unwrap().ids.get(sig) {
            return id;
        }
        let mut w = self.inner.write().unwrap();
        if let Some(&id) = w.ids.get(sig) {
            return id;
        }
        let id = SigId(w.names.len() as u32);
        w.names.push(sig.to_string());
        w.ids.insert(sig.to_string(), id);
        id
    }

    /// The string a [`SigId`] was interned from (diagnostics path).
    pub fn resolve(&self, id: SigId) -> Option<String> {
        self.inner.read().unwrap().names.get(id.0 as usize).cloned()
    }

    /// Number of distinct signatures interned so far.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().names.len()
    }

    /// Whether no signatures have been interned.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Resolve-cache shard count. A small power of two: enough to keep 8–16
/// worker threads off each other's locks, small enough to stay cheap.
const SHARDS: usize = 16;

type ResolveShard = RwLock<HashMap<(SigId, FreqId), Arc<Vec<(Algorithm, NodeCost)>>>>;

/// Most frequency slabs a memoized row set can hold: the nominal clock
/// plus the sim-V100's seven DVFS states fit, as does the GPU+DLA joint
/// state set (7 GPU + 4 DLA slabs); nodes with more slabs (exotic
/// providers) simply scan instead of memoizing. The memo is exact either
/// way — this only trades cache hits for scans.
const MAX_MEMO_SLABS: usize = 16;

/// Key of one per-row argmin memo entry: the additive objective's exact
/// identity plus the node's row identity — its `(freq, slab Arc pointer)`
/// pairs in table order, inlined into a fixed array so building a key
/// never allocates (memo hits stay allocation-free on the hot path).
/// Pointer keying is sound because every slab of an oracle-built table is
/// an `Arc` shared with the resolve cache; entries the cache evicts
/// (feedback writeback is the only eviction path) are pinned in the
/// oracle's `retired` list, so a slab's address is never reused — the
/// pointee outlives every memo entry either way. Unused tail slots stay
/// `(0, 0)` (no real row has a null allocation), and `len` disambiguates
/// anyway.
#[derive(PartialEq, Eq, Hash)]
struct ArgminKey {
    cf: AdditiveKey,
    len: u8,
    rows: [(u16, usize); MAX_MEMO_SLABS],
}

type ArgminShard = RwLock<HashMap<ArgminKey, (FreqId, Algorithm)>>;

/// Per-row argmin memo counters ([`CostOracle::argmin_stats`]): hit rate
/// instrumentation for the incremental inner search. Totals are
/// deterministic for a fixed workload (misses fill exactly once per
/// distinct key); the hit/miss *attribution* to individual candidates can
/// shift under parallel evaluation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArgminStats {
    /// Lookups answered from the memo (no option scan).
    pub hits: u64,
    /// Lookups that scanned the row's options and filled the memo.
    pub misses: u64,
}

impl ArgminStats {
    /// Fraction of lookups served without scanning (0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total > 0 {
            self.hits as f64 / total as f64
        } else {
            0.0
        }
    }
}

/// The thread-safe cost-evaluation layer shared by every search worker
/// (and, downstream, the serving path). See the module docs for the
/// locking design. With the DVFS axis, the resolve cache is keyed by
/// `(SigId, FreqId)` — each frequency state of a signature resolves (and
/// measures) independently, exactly once.
pub struct CostOracle {
    reg: AlgorithmRegistry,
    interner: SigInterner,
    shards: Vec<ResolveShard>,
    db: Mutex<CostDb>,
    provider: Box<dyn CostProvider>,
    provider_name: String,
    /// Non-nominal DVFS states the provider's device exposes, ascending by
    /// clock (the nominal/max state is canonicalized to `FreqId::NOMINAL`
    /// and therefore excluded). Empty = no DVFS support.
    dvfs_freqs: Vec<FreqId>,
    /// Extra (non-GPU) devices the provider can measure, each with its
    /// packed states: the device's nominal first (`FreqId::on(dev, 0)`),
    /// then its sub-nominal DVFS states ascending. Empty for single-device
    /// providers — everything placement-related is gated on this.
    device_freqs: Vec<(DeviceId, Vec<FreqId>)>,
    /// Transfer cost between the provider's devices (`None` = single
    /// device, no transfer ever charged).
    link_model: Option<LinkModel>,
    /// Total (signature, algorithm, frequency) tuples measured through
    /// this oracle.
    profiled: AtomicU64,
    /// Full cost-table builds (one per baseline / expanded wave entry).
    full_tables: AtomicU64,
    /// Delta cost-table builds (one per evaluated candidate).
    delta_tables: AtomicU64,
    /// Candidate-table rows carried over from the parent table untouched.
    carried_rows: AtomicU64,
    /// Candidate-table rows re-resolved because the delta touched them.
    resolved_rows: AtomicU64,
    /// Per-row argmin memo for additive objectives, sharded like the
    /// resolve cache (see [`ArgminKey`] for why pointer keying is sound).
    argmin_shards: Vec<ArgminShard>,
    /// Argmin memo lookups answered without scanning.
    argmin_hits: AtomicU64,
    /// Argmin memo lookups that scanned and filled an entry.
    argmin_misses: AtomicU64,
    /// Resolve-cache slabs evicted by [`CostOracle::apply_feedback`],
    /// pinned for the oracle's lifetime: argmin-memo keys hash slab
    /// allocation addresses, so an evicted slab's address must never be
    /// reused by a future slab (the ABA hazard). Pinning also keeps
    /// tables built before the eviction fully usable — their rows simply
    /// reflect the pre-feedback costs they were built from.
    retired: Mutex<Vec<Arc<Vec<(Algorithm, NodeCost)>>>>,
}

/// Outcome counters of [`CostOracle::apply_feedback`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FeedbackApplied {
    /// Measured rows written into the profile database (provenance
    /// `measured:<provider>`).
    pub rows: usize,
    /// Resolve-cache entries evicted (their slabs pinned as retired).
    pub evicted: usize,
    /// Argmin-memo entries pruned because they referenced evicted slabs.
    pub memo_pruned: usize,
}

/// Cost-table construction counters — instrumentation proving the search
/// takes the delta path (candidate evaluation must not rebuild full
/// [`GraphCostTable`]s; asserted by `rust/tests/delta_engine.rs` and the
/// ablation bench).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TableBuildStats {
    /// Full table builds since oracle creation.
    pub full_tables: u64,
    /// Delta (carry-over) table builds since oracle creation.
    pub delta_tables: u64,
    /// Rows carried over from a parent table without re-resolving.
    pub carried_rows: u64,
    /// Rows re-resolved because the delta touched the node.
    pub resolved_rows: u64,
}

/// The base-graph artifacts a candidate delta evaluates against: the
/// parent's graph, shape table, cost table (built at the search's full
/// frequency set), and default assignment — computed once per expanded
/// wave entry and shared by all of its candidate sites.
pub struct DeltaBase<'a> {
    /// The parent graph the delta applies to.
    pub graph: &'a Graph,
    /// The parent's full shape table.
    pub shapes: &'a [Vec<TensorShape>],
    /// The parent's cost table at the search's frequency set.
    pub table: &'a GraphCostTable,
    /// The parent's framework-default assignment.
    pub assignment: &'a Assignment,
    /// The parent's *converged* inner-search plan, when the caller has
    /// one — the warm start the incremental inner search remaps across
    /// compaction (`None` disables warm starts for this base).
    pub converged: Option<&'a Assignment>,
}

/// Everything [`CostOracle::delta_table_for_freqs`] derives for one
/// candidate: the carry-over cost table, the carried default assignment,
/// the remapped warm start, the dirty cone in compacted ids, and the
/// profile count.
pub struct CandidateTable {
    /// The candidate's cost table (untouched rows carried from the
    /// parent, dirty rows re-resolved), in compaction order.
    pub table: GraphCostTable,
    /// The candidate's framework-default assignment (unchanged choices
    /// carried from the parent's defaults).
    pub assignment: Assignment,
    /// The parent's converged plan remapped across compaction (dirty and
    /// added nodes fall back to their defaults at the nominal clock).
    /// `None` when the base supplied no converged plan.
    pub warm: Option<Assignment>,
    /// Compacted ids of nodes whose rows were re-resolved (the delta's
    /// dirty cone, ascending) — the only nodes an additive warm-started
    /// inner search must re-optimize.
    pub dirty: Vec<NodeId>,
    /// Newly measured (signature, algorithm, frequency) pairs.
    pub measured: usize,
}

impl CostOracle {
    /// Build an oracle from registry + profile DB + measurement provider.
    pub fn new(reg: AlgorithmRegistry, db: CostDb, provider: Box<dyn CostProvider>) -> CostOracle {
        let provider_name = provider.provider_name();
        // Per-device state tables: entry 0 is always the primary GPU, whose
        // states stay device-local (raw MHz, nominal canonicalized to
        // `FreqId::NOMINAL`) — exactly the pre-placement behavior. Extra
        // devices pack their states with their device bits.
        let devices = provider.device_states();
        let states = &devices[0].1;
        debug_assert_eq!(devices[0].0, DeviceId::GPU, "device 0 must be the GPU");
        let nominal = states.iter().map(|s| s.mhz).max().unwrap_or(0);
        let mut dvfs_freqs: Vec<FreqId> =
            states.iter().filter(|s| s.mhz < nominal).map(|s| FreqId(s.mhz)).collect();
        dvfs_freqs.sort();
        dvfs_freqs.dedup();
        let device_freqs: Vec<(DeviceId, Vec<FreqId>)> = devices[1..]
            .iter()
            .map(|(dev, states)| {
                let dev_nominal = states.iter().map(|s| s.mhz).max().unwrap_or(0);
                let mut freqs = vec![FreqId::on(*dev, 0)];
                let mut sub: Vec<u16> =
                    states.iter().filter(|s| s.mhz < dev_nominal).map(|s| s.mhz).collect();
                sub.sort_unstable();
                sub.dedup();
                freqs.extend(sub.into_iter().map(|mhz| FreqId::on(*dev, mhz)));
                (*dev, freqs)
            })
            .collect();
        let link_model = if device_freqs.is_empty() { None } else { provider.link_model() };
        CostOracle {
            reg,
            interner: SigInterner::default(),
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            db: Mutex::new(db),
            provider,
            provider_name,
            dvfs_freqs,
            device_freqs,
            link_model,
            profiled: AtomicU64::new(0),
            full_tables: AtomicU64::new(0),
            delta_tables: AtomicU64::new(0),
            carried_rows: AtomicU64::new(0),
            resolved_rows: AtomicU64::new(0),
            argmin_shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            argmin_hits: AtomicU64::new(0),
            argmin_misses: AtomicU64::new(0),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Default oracle: simulated-V100 profiles (seed 7), empty database.
    pub fn offline_default() -> CostOracle {
        CostOracle::new(
            AlgorithmRegistry::new(),
            CostDb::new(),
            Box::new(crate::profiler::SimV100Provider::new(7)),
        )
    }

    /// The algorithm registry ("which algorithms can run this node?").
    pub fn reg(&self) -> &AlgorithmRegistry {
        &self.reg
    }

    /// The signature interner (exposed for stats and tests).
    pub fn interner(&self) -> &SigInterner {
        &self.interner
    }

    /// The measurement provider's name (provenance).
    pub fn provider_name(&self) -> &str {
        &self.provider_name
    }

    /// The non-nominal DVFS states available for frequency search,
    /// ascending by clock. Empty when the provider's device has no
    /// frequency table (DVFS search then degenerates to nominal-only).
    pub fn dvfs_freqs(&self) -> &[FreqId] {
        &self.dvfs_freqs
    }

    /// Extra (non-GPU) devices available for placement search: each with
    /// its packed states, device nominal first, then sub-nominal DVFS
    /// states ascending. Empty for single-device providers.
    pub fn device_freqs(&self) -> &[(DeviceId, Vec<FreqId>)] {
        &self.device_freqs
    }

    /// Whether placement is a live axis (the provider exposes more than
    /// one device).
    pub fn has_extra_devices(&self) -> bool {
        !self.device_freqs.is_empty()
    }

    /// The inter-device transfer model, when the provider spans devices.
    pub fn link_model(&self) -> Option<&LinkModel> {
        self.link_model.as_ref()
    }

    /// Whether `freqs` spans more than one device — the condition under
    /// which tables get a transfer overlay and the objective stops being
    /// separable at device boundaries.
    fn spans_devices(freqs: &[FreqId]) -> bool {
        freqs.len() > 1 && freqs.iter().any(|f| f.device() != freqs[0].device())
    }

    /// Whether `freqs` spans more than one layout — the condition under
    /// which tables get a re-tiling overlay (possibly with no device link
    /// at all) and the objective stops being separable at layout
    /// boundaries.
    fn spans_layouts(freqs: &[FreqId]) -> bool {
        freqs.len() > 1 && freqs.iter().any(|f| f.layout() != freqs[0].layout())
    }

    /// Total measurements performed through this oracle since creation.
    pub fn profiled_total(&self) -> u64 {
        self.profiled.load(Ordering::Relaxed)
    }

    /// Cost-table construction counters (full vs delta builds, carried vs
    /// re-resolved rows) since oracle creation.
    pub fn table_build_stats(&self) -> TableBuildStats {
        TableBuildStats {
            full_tables: self.full_tables.load(Ordering::Relaxed),
            delta_tables: self.delta_tables.load(Ordering::Relaxed),
            carried_rows: self.carried_rows.load(Ordering::Relaxed),
            resolved_rows: self.resolved_rows.load(Ordering::Relaxed),
        }
    }

    /// Per-row argmin memo counters since oracle creation.
    pub fn argmin_stats(&self) -> ArgminStats {
        ArgminStats {
            hits: self.argmin_hits.load(Ordering::Relaxed),
            misses: self.argmin_misses.load(Ordering::Relaxed),
        }
    }

    /// Memoized per-row argmin of one node under an **additive**
    /// objective: the best (frequency, algorithm) of a node depends only
    /// on its shared rows and the objective, so the answer is cached
    /// keyed by ([`AdditiveKey`], row identity) — carried rows across
    /// thousands of candidates (and all frontier probes at the same
    /// weight) never re-scan their option lists. Returns the chosen pair
    /// plus the options scanned (0 on a memo hit); `None` when `cf` is
    /// not additive.
    ///
    /// **Soundness**: `table` must have been built by this oracle
    /// (`table_for*` / `delta_table_for_freqs` / `restrict_to_freq` of
    /// such a table) so its slabs are `Arc`s pinned by the resolve cache
    /// — that is what makes pointer identity a stable key. The fill
    /// happens under the shard write lock, so each distinct row scans
    /// exactly once.
    pub fn argmin_for(
        &self,
        table: &GraphCostTable,
        id: NodeId,
        cf: &CostFunction,
    ) -> Option<(FreqId, Algorithm, u64)> {
        let cf_key = cf.additive_key()?;
        let slabs = table.freq_options(id);
        if slabs.len() > MAX_MEMO_SLABS {
            // Row set too wide to inline — scan without memoizing (still
            // correct, just uncached; counted as a miss).
            let (f, algo, scanned) = table.scan_argmin(id, cf);
            self.argmin_misses.fetch_add(1, Ordering::Relaxed);
            return Some((f, algo, scanned));
        }
        let mut rows = [(0u16, 0usize); MAX_MEMO_SLABS];
        for (k, (f, slab)) in slabs.iter().enumerate() {
            rows[k] = (f.0, Arc::as_ptr(slab) as *const () as usize);
        }
        let key = ArgminKey { cf: cf_key, len: slabs.len() as u8, rows };
        // Shard by the first row's allocation address (dropping alignment
        // zero bits) — free, unlike an extra whole-key hash on the
        // memo-hit fast path; the map hashes the key exactly once
        // internally.
        let shard_ix = ((rows[0].1 >> 4) ^ rows[0].0 as usize) % SHARDS;
        let shard = &self.argmin_shards[shard_ix];
        if let Some(&(f, algo)) = shard.read().unwrap().get(&key) {
            self.argmin_hits.fetch_add(1, Ordering::Relaxed);
            return Some((f, algo, 0));
        }
        let mut w = shard.write().unwrap();
        if let Some(&(f, algo)) = w.get(&key) {
            self.argmin_hits.fetch_add(1, Ordering::Relaxed);
            return Some((f, algo, 0));
        }
        let (f, algo, scanned) = table.scan_argmin(id, cf);
        w.insert(key, (f, algo));
        self.argmin_misses.fetch_add(1, Ordering::Relaxed);
        Some((f, algo, scanned))
    }

    /// Run `f` against the (locked) profile database.
    pub fn with_db<R>(&self, f: impl FnOnce(&CostDb) -> R) -> R {
        f(&self.db.lock().unwrap())
    }

    /// Total (signature, algorithm, frequency) entries in the DB.
    pub fn db_entries(&self) -> usize {
        self.with_db(|db| db.num_entries())
    }

    /// Distinct signatures in the DB.
    pub fn db_signatures(&self) -> usize {
        self.with_db(|db| db.num_signatures())
    }

    /// Persist the profile database (the paper's on-disk cache).
    pub fn save_db(&self, path: &Path) -> anyhow::Result<()> {
        self.db.lock().unwrap().save(path)
    }

    /// Attribute a whole-plan observation down to the plan's database
    /// rows: every `(signature, algorithm, frequency)` row the plan
    /// `(g, a)` exercises is recorded into `store` at `time_scale` times
    /// its predicted time (power unchanged — energy scales with time
    /// under the constant-power row model). Under the additive cost
    /// model this is exact plan→row attribution: the plan's predicted
    /// cost is the sum of its rows, so scaling every row by the plan's
    /// observed/predicted time ratio reproduces the observed plan cost.
    ///
    /// Rows the database has never priced are skipped (there is no
    /// prediction to scale). Returns the number of rows recorded.
    pub fn observe_plan(
        &self,
        g: &Graph,
        a: &Assignment,
        time_scale: f64,
        store: &MeasuredStore,
    ) -> anyhow::Result<usize> {
        anyhow::ensure!(
            time_scale.is_finite() && time_scale > 0.0,
            "observed/predicted time scale must be positive and finite, got {time_scale}"
        );
        let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        // Collect under the db lock, observe after releasing it — the
        // store has its own lock and holding both invites ordering bugs.
        let mut rows: Vec<(String, Algorithm, FreqId, NodeCost)> = Vec::new();
        {
            let db = self.db.lock().unwrap();
            visit_costed_nodes(g, &shapes, |id, _node, _in_shapes, sig| {
                let Some(algo) = a.get(id) else { return };
                let freq = a.freq(id);
                if let Some(pred) = db.get_at(sig, algo, freq) {
                    let obs =
                        NodeCost { time_ms: pred.time_ms * time_scale, power_w: pred.power_w };
                    rows.push((sig.to_string(), algo, freq, obs));
                }
            });
        }
        let n = rows.len();
        for (sig, algo, freq, cost) in rows {
            store.observe(&sig, algo, freq, cost);
        }
        Ok(n)
    }

    /// Fold a [`MeasuredStore`] back into the oracle: every smoothed
    /// observed row overwrites its database predecessor (provenance
    /// `measured:<provider>`), and exactly the resolve-cache entries and
    /// argmin-memo keys those rows invalidate are evicted. Subsequent
    /// resolves re-read the corrected database rows — untouched
    /// algorithms of an evicted signature are re-read, **not**
    /// re-measured, so feedback never perturbs rows it has no
    /// observation for.
    ///
    /// Safe under concurrent readers: table builders racing this call
    /// keep their slab `Arc`s alive (evicted slabs are pinned in the
    /// oracle's retired list, which also protects the argmin memo's
    /// pointer keys from address reuse), and every map touched is
    /// locked per-shard. A reader observes either the old or the new
    /// rows for a signature, never a torn mixture within one slab.
    pub fn apply_feedback(&self, store: &MeasuredStore) -> FeedbackApplied {
        let snap = store.snapshot();
        if snap.is_empty() {
            return FeedbackApplied::default();
        }
        let provenance = format!("measured:{}", self.provider_name);
        {
            let mut db = self.db.lock().unwrap();
            for (sig, algo, freq, row) in &snap {
                db.insert_at(sig, *algo, *freq, row.cost, &provenance);
            }
        }
        let mut evicted_ptrs: std::collections::HashSet<usize> = std::collections::HashSet::new();
        let mut newly_retired = Vec::new();
        let mut seen: std::collections::HashSet<(SigId, FreqId)> = std::collections::HashSet::new();
        for (sig, _algo, freq, _row) in &snap {
            let id = self.interner.intern(sig);
            if !seen.insert((id, *freq)) {
                continue;
            }
            if let Some(arc) = self.shard(id, *freq).write().unwrap().remove(&(id, *freq)) {
                evicted_ptrs.insert(Arc::as_ptr(&arc) as *const () as usize);
                newly_retired.push(arc);
            }
        }
        let evicted = newly_retired.len();
        let mut memo_pruned = 0usize;
        if !evicted_ptrs.is_empty() {
            self.retired.lock().unwrap().extend(newly_retired);
            for shard in &self.argmin_shards {
                let mut w = shard.write().unwrap();
                let before = w.len();
                w.retain(|key, _| {
                    !key.rows[..key.len as usize].iter().any(|(_, p)| evicted_ptrs.contains(p))
                });
                memo_pruned += before - w.len();
            }
        }
        FeedbackApplied { rows: snap.len(), evicted, memo_pruned }
    }

    fn shard(&self, id: SigId, freq: FreqId) -> &ResolveShard {
        &self.shards[(id.0 as usize ^ freq.0 as usize) % SHARDS]
    }

    /// Resolve one (node signature, frequency) to its (algorithm, cost)
    /// options, measuring through the provider on a true miss. Returns the
    /// options and how many pairs were newly measured.
    fn resolve(
        &self,
        sig: &str,
        op: &OpKind,
        in_shapes: &[TensorShape],
        out_shapes: &[TensorShape],
        freq: FreqId,
    ) -> (Arc<Vec<(Algorithm, NodeCost)>>, usize) {
        let id = self.interner.intern(sig);
        let key = (id, freq);
        let shard = self.shard(id, freq);
        if let Some(v) = shard.read().unwrap().get(&key) {
            return (v.clone(), 0);
        }
        // Miss: fill under the shard write lock so racing threads cannot
        // measure the same signature twice (the loser blocks, re-checks,
        // and takes the winner's entry).
        let mut w = shard.write().unwrap();
        if let Some(v) = w.get(&key) {
            return (v.clone(), 0);
        }
        let mut options = Vec::new();
        let mut measured = 0usize;
        for algo in self.reg.applicable(op, in_shapes) {
            let cached = self.db.lock().unwrap().get_at(sig, algo, freq);
            let cost = match cached {
                Some(c) => c,
                None => {
                    let c = self.provider.measure(sig, op, in_shapes, out_shapes, algo, freq);
                    self.db.lock().unwrap().insert_at(sig, algo, freq, c, &self.provider_name);
                    measured += 1;
                    c
                }
            };
            options.push((algo, cost));
        }
        if measured > 0 {
            self.profiled.fetch_add(measured as u64, Ordering::Relaxed);
        }
        let arc = Arc::new(options);
        w.insert(key, arc.clone());
        (arc, measured)
    }

    /// Profile `g` as needed and build its nominal-clock cost table. Shape
    /// inference is the only fallible step (it doubles as candidate
    /// validation).
    pub fn table_for(&self, g: &Graph) -> anyhow::Result<(GraphCostTable, usize)> {
        let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        Ok(self.table_for_with(g, &shapes))
    }

    /// As [`CostOracle::table_for`] with pre-computed shapes (search hot
    /// path: one inference per candidate, reused everywhere).
    pub fn table_for_with(
        &self,
        g: &Graph,
        shapes: &[Vec<TensorShape>],
    ) -> (GraphCostTable, usize) {
        self.table_for_freqs(g, shapes, &[FreqId::NOMINAL])
    }

    /// Build a cost table with one frequency slab per state in `freqs`
    /// (each resolved — and measured on first touch — independently).
    /// `&[FreqId::NOMINAL]` is exactly the pre-DVFS table.
    pub fn table_for_freqs(
        &self,
        g: &Graph,
        shapes: &[Vec<TensorShape>],
        freqs: &[FreqId],
    ) -> (GraphCostTable, usize) {
        // Zero-copy on cache hits: table slabs share the resolve cache's
        // own Arc'd vectors; zero-cost nodes carry no slabs.
        self.full_tables.fetch_add(1, Ordering::Relaxed);
        let mut entries: Vec<Vec<crate::cost::FreqSlab>> = vec![Vec::new(); g.len()];
        let mut measured = 0usize;
        visit_costed_nodes(g, shapes, |id, node, in_shapes, sig| {
            let mut slabs = Vec::with_capacity(freqs.len());
            for &f in freqs {
                let (options, m) = self.resolve(sig, &node.op, in_shapes, &shapes[id.0], f);
                measured += m;
                slabs.push((f, options));
            }
            entries[id.0] = slabs;
        });
        let mut table = GraphCostTable::from_freq_slabs(entries);
        // The overlay is needed whenever a boundary *could* open: across
        // devices (when the provider has a link model) or across layouts
        // (always — the re-tiling kernel is device-independent).
        if (Self::spans_devices(freqs) && self.link_model.is_some())
            || Self::spans_layouts(freqs)
        {
            table.attach_links(g, shapes, self.link_model.as_ref());
        }
        (table, measured)
    }

    /// Build a **candidate** cost table and default assignment for
    /// `base + delta` without walking the whole graph: rows of nodes the
    /// delta did not touch are carried over from the parent table (an
    /// `Arc` clone per frequency slab — no signature building, interner
    /// traffic, or lock acquisition), and only the delta's dirty nodes
    /// (ops replaced, nodes added, inputs reshaped) resolve through the
    /// cache/provider. The additive cost model makes the carry-over exact:
    /// an untouched node's cost rows are identical at every DVFS state.
    ///
    /// Rows are emitted in the view's compaction order, and carried rows
    /// are the very same `Arc`s a full build would fetch from the resolve
    /// cache, so the resulting table is **bit-identical** to
    /// [`CostOracle::table_for_freqs`] on the materialized product
    /// (property-tested in `rust/tests/delta_engine.rs`) — candidate
    /// evaluation through it reproduces full-rebuild plans exactly.
    ///
    /// When the base carries the parent's **converged** plan
    /// (`DeltaBase::converged`), the result also holds it remapped across
    /// compaction (`CandidateTable::warm`) together with the dirty cone
    /// in compacted ids (`CandidateTable::dirty`) — everything the
    /// incremental inner search needs to re-optimize only what the delta
    /// touched.
    pub fn delta_table_for_freqs(
        &self,
        base: &DeltaBase<'_>,
        view: &DeltaView<'_>,
        freqs: &[FreqId],
    ) -> CandidateTable {
        self.delta_tables.fetch_add(1, Ordering::Relaxed);
        let n_base = base.graph.len();
        let live = view.compact_order();
        let mut entries: Vec<Vec<crate::cost::FreqSlab>> = Vec::with_capacity(live.len());
        let mut choices: Vec<Option<Algorithm>> = Vec::with_capacity(live.len());
        let mut warm_parts: Option<(Vec<Option<Algorithm>>, Vec<FreqId>)> = base
            .converged
            .map(|_| (Vec::with_capacity(live.len()), Vec::with_capacity(live.len())));
        let mut dirty: Vec<NodeId> = Vec::new();
        let mut measured = 0usize;
        let mut carried = 0u64;
        let mut resolved = 0u64;
        let mut sig = String::with_capacity(96);
        // Warm slot for dirty/added nodes: the framework default at the
        // nominal clock — exactly what a cold full rebuild starts at.
        fn warm_default(
            warm_parts: &mut Option<(Vec<Option<Algorithm>>, Vec<FreqId>)>,
            choice: Option<Algorithm>,
        ) {
            if let Some((wc, wf)) = warm_parts {
                wc.push(choice);
                wf.push(FreqId::NOMINAL);
            }
        }
        for (j, &i) in live.iter().enumerate() {
            let op = view.op(i);
            if op.is_constant_space() {
                entries.push(Vec::new());
                choices.push(None);
                warm_default(&mut warm_parts, None);
                continue;
            }
            let is_input = matches!(op, OpKind::Input { .. });
            if i < n_base && !view.is_sig_dirty(i) {
                // Carry-over: same op, same input shapes — the signature
                // is unchanged, so the parent's rows (and its default
                // algorithm) are exactly what a fresh resolve would find.
                // The parent's converged choice carries over for the same
                // reason: its rows (hence its per-row argmin) are
                // unchanged.
                let old = NodeId(i);
                if is_input {
                    entries.push(Vec::new());
                    choices.push(base.assignment.get(old));
                    if let Some((wc, wf)) = &mut warm_parts {
                        let conv = base.converged.expect("warm_parts implies converged");
                        wc.push(conv.get(old));
                        wf.push(conv.freq(old));
                    }
                    carried += 1;
                    continue;
                }
                let base_slabs = base.table.freq_options(old);
                let mut slabs = Vec::with_capacity(freqs.len());
                let mut fell_back = false;
                for &f in freqs {
                    match base_slabs.iter().find(|(bf, _)| *bf == f) {
                        Some(slab) => slabs.push(slab.clone()),
                        None => {
                            // Parent table missing this state (cannot
                            // happen while parent tables and candidate
                            // requests share `search_freqs`) — fall back
                            // to a resolve, counted as such.
                            fell_back = true;
                            let in_shapes = view.in_shapes(i);
                            sig.clear();
                            op.signature_into(&in_shapes, &mut sig);
                            let (options, m) =
                                self.resolve(&sig, op, &in_shapes, view.out_shapes(i), f);
                            measured += m;
                            slabs.push((f, options));
                        }
                    }
                }
                entries.push(slabs);
                choices.push(base.assignment.get(old));
                if fell_back {
                    // The option set differs from the parent's, so its
                    // converged choice is no longer the row argmin — the
                    // node joins the dirty cone and restarts from the
                    // default.
                    warm_default(&mut warm_parts, base.assignment.get(old));
                    dirty.push(NodeId(j));
                    resolved += 1;
                } else {
                    if let Some((wc, wf)) = &mut warm_parts {
                        let conv = base.converged.expect("warm_parts implies converged");
                        wc.push(conv.get(old));
                        wf.push(conv.freq(old));
                    }
                    carried += 1;
                }
                continue;
            }
            // Dirty node: resolve at every requested state, exactly as a
            // full build would.
            let in_shapes = view.in_shapes(i);
            if is_input {
                entries.push(Vec::new());
            } else {
                sig.clear();
                op.signature_into(&in_shapes, &mut sig);
                let mut slabs = Vec::with_capacity(freqs.len());
                for &f in freqs {
                    let (options, m) = self.resolve(&sig, op, &in_shapes, view.out_shapes(i), f);
                    measured += m;
                    slabs.push((f, options));
                }
                entries.push(slabs);
            }
            let choice = Some(self.reg.default_algorithm(op, &in_shapes));
            choices.push(choice);
            warm_default(&mut warm_parts, choice);
            dirty.push(NodeId(j));
            resolved += 1;
        }
        self.carried_rows.fetch_add(carried, Ordering::Relaxed);
        self.resolved_rows.fetch_add(resolved, Ordering::Relaxed);
        let mut table = GraphCostTable::from_freq_slabs(entries);
        // Boundary overlay for multi-device / multi-layout candidates,
        // priced straight off the view in compaction order — edge-for-edge
        // what a full build on the materialized graph produces (same
        // iteration order, same shapes), keeping the delta and full paths
        // bit-identical.
        if (Self::spans_devices(freqs) && self.link_model.is_some())
            || Self::spans_layouts(freqs)
        {
            let transpose = crate::energysim::TransposeModel::on_device();
            let mut edges = Vec::new();
            for (j, &i) in live.iter().enumerate() {
                if table.freq_options(NodeId(j)).is_empty() {
                    continue;
                }
                for p in view.inputs(i) {
                    let Some(src) = view.compact_id(p.node.0) else { continue };
                    if table.freq_options(src).is_empty() {
                        continue;
                    }
                    let bytes =
                        4.0 * view.out_shapes(p.node.0)[p.port].iter().product::<usize>() as f64;
                    let (time_ms, energy_mj) = self
                        .link_model
                        .as_ref()
                        .map(|l| l.transfer_cost(bytes))
                        .unwrap_or((0.0, 0.0));
                    let (transpose_ms, transpose_mj) = transpose.transpose_cost(bytes);
                    edges.push(TransferLink {
                        src,
                        dst: NodeId(j),
                        bytes,
                        time_ms,
                        energy_mj,
                        transpose_ms,
                        transpose_mj,
                    });
                }
            }
            table.attach_links_shared(Arc::new(TransferLinks::from_edges(edges, live.len())));
        }
        let freqs_default = vec![FreqId::NOMINAL; live.len()];
        CandidateTable {
            table,
            assignment: Assignment::from_parts(choices, freqs_default),
            warm: warm_parts.map(|(wc, wf)| Assignment::from_parts(wc, wf)),
            dirty,
            measured,
        }
    }

    /// Ensure every (signature, algorithm) pair of `g` is profiled at the
    /// nominal clock — the `eadgo profile` subcommand's path through the
    /// oracle. (DVFS states are profiled lazily by the search that needs
    /// them; pre-warming all states would multiply first-run cost.)
    pub fn profile_graph(&self, g: &Graph) -> anyhow::Result<ProfileReport> {
        let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        let mut report = ProfileReport::default();
        visit_costed_nodes(g, &shapes, |id, node, in_shapes, sig| {
            let (options, m) =
                self.resolve(sig, &node.op, in_shapes, &shapes[id.0], FreqId::NOMINAL);
            report.measured += m;
            report.cached += options.len() - m;
        });
        Ok(report)
    }

    /// Price `(g, a)` from **already-available** profiles only (the DB,
    /// which backs every resolve) — never triggers a measurement. Returns
    /// `Ok(None)` when any assigned pair is unprofiled. This is the cheap
    /// path for annotating a served plan: free when the oracle is warm
    /// (after an optimize run or a loaded DB), a no-op when it is cold.
    pub fn cached_cost(
        &self,
        g: &Graph,
        a: &crate::algo::Assignment,
    ) -> anyhow::Result<Option<super::GraphCost>> {
        let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        let db = self.db.lock().unwrap();
        let mut total = super::GraphCost::default();
        let mut complete = true;
        visit_costed_nodes(g, &shapes, |id, _node, _in_shapes, sig| {
            if !complete {
                return;
            }
            // A runtime node missing from the assignment means the plan
            // does not match this graph — the estimate would silently
            // undercount, so report it as unavailable instead.
            let Some(algo) = a.get(id) else {
                complete = false;
                return;
            };
            // Priced at the plan's own DVFS state — a per-graph or
            // per-node frequency plan is estimated at its chosen clocks.
            match db.get_at(sig, algo, a.freq(id)) {
                Some(c) => total = total.add(&c),
                None => complete = false,
            }
        });
        if complete {
            total.freq = a.uniform_freq();
        }
        Ok(complete.then_some(total))
    }
}

/// Shared iteration over the cost-bearing (runtime) nodes of a graph:
/// skips constant-space and input nodes, gathers input shapes, builds the
/// signature into a reused scratch buffer, and hands everything to `f`.
/// Single home for the skip rules so the table builder, the profiler path,
/// and the plan pricer cannot drift apart.
fn visit_costed_nodes<F>(g: &Graph, shapes: &[Vec<TensorShape>], mut f: F)
where
    F: FnMut(crate::graph::NodeId, &crate::graph::Node, &[TensorShape], &str),
{
    let mut sig = String::with_capacity(96);
    let mut in_shapes: Vec<TensorShape> = Vec::new();
    for (id, node) in g.nodes() {
        if node.op.is_constant_space() || matches!(node.op, OpKind::Input { .. }) {
            continue;
        }
        in_shapes.clear();
        in_shapes.extend(node.inputs.iter().map(|p| shapes[p.node.0][p.port].clone()));
        sig.clear();
        node.op.signature_into(&in_shapes, &mut sig);
        f(id, node, &in_shapes, &sig);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, PortRef};

    fn conv_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[x, w],
            "c",
        );
        g.outputs = vec![PortRef::of(c)];
        g
    }

    #[test]
    fn interner_is_stable_and_dedups() {
        let i = SigInterner::default();
        let a = i.intern("conv2d;x");
        let b = i.intern("relu;y");
        assert_ne!(a, b);
        assert_eq!(i.intern("conv2d;x"), a);
        assert_eq!(i.len(), 2);
        assert_eq!(i.resolve(a).as_deref(), Some("conv2d;x"));
        assert_eq!(i.resolve(SigId(99)), None);
    }

    #[test]
    fn oracle_measures_each_signature_once() {
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let (_, m1) = oracle.table_for(&g).unwrap();
        assert!(m1 > 0);
        let (_, m2) = oracle.table_for(&g).unwrap();
        assert_eq!(m2, 0, "second build must be fully cached");
        assert_eq!(oracle.profiled_total(), m1 as u64);
        assert!(oracle.db_entries() >= m1);
    }

    #[test]
    fn concurrent_table_builds_agree_and_measure_once() {
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let tables: Vec<GraphCostTable> = std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..8).map(|_| s.spawn(|| oracle.table_for(&g).unwrap().0)).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());
        let costs: Vec<_> = tables.iter().map(|t| t.eval(&a)).collect();
        for c in &costs[1..] {
            assert_eq!(*c, costs[0], "racing builds must agree bit-for-bit");
        }
        // The conv signature resolves once no matter how many threads race.
        let (_, again) = oracle.table_for(&g).unwrap();
        assert_eq!(again, 0);
        let single = CostOracle::offline_default();
        let (_, expect) = single.table_for(&g).unwrap();
        assert_eq!(oracle.profiled_total(), expect as u64);
    }

    #[test]
    fn dvfs_states_resolve_independently_and_once() {
        let oracle = CostOracle::offline_default();
        // The sim-V100 exposes DVFS; the nominal/max state is canonicalized
        // away, so every listed state is strictly below the max clock.
        assert!(!oracle.dvfs_freqs().is_empty());
        assert!(oracle.dvfs_freqs().iter().all(|f| !f.is_nominal() && f.0 < 1380));
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let (t_nom, m_nom) = oracle.table_for_with(&g, &shapes);
        assert!(m_nom > 0);
        // A non-nominal state triggers its own measurements exactly once.
        let low = oracle.dvfs_freqs()[0];
        let (t_dvfs, m_low) = oracle.table_for_freqs(&g, &shapes, &[FreqId::NOMINAL, low]);
        assert_eq!(m_low, m_nom, "each state profiles the same pair set");
        let (_, again) = oracle.table_for_freqs(&g, &shapes, &[FreqId::NOMINAL, low]);
        assert_eq!(again, 0, "second build must be fully cached");
        // Both tables agree at the nominal clock (shared slabs).
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());
        assert_eq!(t_nom.eval(&a), t_dvfs.eval(&a));
        // And the low state is a genuinely different operating point
        // (within measurement noise, never faster than nominal).
        let mut a_low = a.clone();
        a_low.set_uniform_freq(low);
        let c_low = t_dvfs.eval(&a_low);
        assert!(c_low.time_ms >= t_nom.eval(&a).time_ms * 0.96);
        assert_eq!(c_low.freq, low);
    }

    #[test]
    fn argmin_memo_hits_on_shared_rows_and_keys_objectives_apart() {
        use crate::cost::CostFunction;
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let (t1, _) = oracle.table_for_with(&g, &shapes);
        let conv = crate::graph::NodeId(2);
        let cf = CostFunction::Energy;
        let (f1, a1, scanned) = oracle.argmin_for(&t1, conv, &cf).unwrap();
        assert!(scanned > 0, "first lookup scans");
        // A second table over the same graph shares the resolve cache's
        // Arcs, so the lookup is a memo hit (0 options scanned).
        let (t2, m) = oracle.table_for_with(&g, &shapes);
        assert_eq!(m, 0);
        let (f2, a2, rescanned) = oracle.argmin_for(&t2, conv, &cf).unwrap();
        assert_eq!((f1, a1), (f2, a2));
        assert_eq!(rescanned, 0, "shared rows must not re-scan");
        let st = oracle.argmin_stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
        // The memo answer is the canonical scan.
        assert_eq!(t1.scan_argmin(conv, &cf).0, f1);
        assert_eq!(t1.scan_argmin(conv, &cf).1, a1);
        // A different additive objective is a different key (miss), and a
        // non-additive objective has no key at all.
        let (_, _, s3) = oracle.argmin_for(&t1, conv, &CostFunction::Time).unwrap();
        assert!(s3 > 0);
        assert!(oracle.argmin_for(&t1, conv, &CostFunction::Power).is_none());
    }

    #[test]
    fn hetero_oracle_gates_links_on_multi_device_tables() {
        let oracle = CostOracle::new(
            AlgorithmRegistry::new(),
            CostDb::new(),
            Box::new(crate::profiler::SimHeteroProvider::new(7)),
        );
        assert!(oracle.has_extra_devices());
        assert!(oracle.link_model().is_some());
        let device_freqs = oracle.device_freqs().to_vec();
        assert_eq!(device_freqs.len(), 1);
        let (dla, dla_freqs) = &device_freqs[0];
        assert_eq!(*dla, DeviceId::DLA);
        assert!(dla_freqs[0].is_nominal() && dla_freqs[0].device() == DeviceId::DLA);
        assert!(dla_freqs[1..].iter().all(|f| f.device() == DeviceId::DLA && !f.is_nominal()));

        // conv + relu chain: two costed nodes, one data edge.
        let mut g = conv_graph();
        let r = g.add1(OpKind::Relu, &[NodeId(2)], "r");
        g.outputs = vec![PortRef::of(r)];
        let shapes = g.infer_shapes().unwrap();

        // Single-device tables never carry an overlay.
        let (t_gpu, _) = oracle.table_for_with(&g, &shapes);
        assert!(!t_gpu.has_links());
        // Multi-device tables do, with one edge conv→relu.
        let freqs = [FreqId::NOMINAL, dla_freqs[0]];
        let (t_mix, m) = oracle.table_for_freqs(&g, &shapes, &freqs);
        assert!(m > 0, "the DLA slab measures on first touch");
        assert!(t_mix.has_links());
        let links = t_mix.links().unwrap();
        assert_eq!(links.edges().len(), 1);
        assert_eq!((links.edges()[0].src, links.edges()[0].dst), (NodeId(2), r));

        // All-GPU eval through the mixed table matches the GPU-only table
        // bit-for-bit (overlay adds no terms without a boundary).
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());
        let c_gpu = t_gpu.eval(&a);
        let c_mix = t_mix.eval(&a);
        assert_eq!(c_gpu.time_ms.to_bits(), c_mix.time_ms.to_bits());
        assert_eq!(c_gpu.energy_j.to_bits(), c_mix.energy_j.to_bits());

        // Splitting the chain charges exactly the edge's transfer cost.
        let mut split = a.clone();
        split.set_freq(r, dla_freqs[0]);
        let c_split = t_mix.eval(&split);
        let (t_xfer, e_xfer) = t_mix.transfer_cost(&split);
        assert!(t_xfer > 0.0 && e_xfer > 0.0);
        let dla_relu = t_mix.option_cost(r, Algorithm::Passthrough, dla_freqs[0]).unwrap();
        let gpu_relu = t_mix.option_cost(r, Algorithm::Passthrough, FreqId::NOMINAL).unwrap();
        let expect = c_gpu.time_ms - gpu_relu.time_ms + dla_relu.time_ms + t_xfer;
        assert!((c_split.time_ms - expect).abs() < 1e-9);
    }

    #[test]
    fn hetero_gpu_measurements_match_v100_oracle_bitwise() {
        let v100 = CostOracle::offline_default();
        let hetero = CostOracle::new(
            AlgorithmRegistry::new(),
            CostDb::new(),
            Box::new(crate::profiler::SimHeteroProvider::new(7)),
        );
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let a = crate::algo::Assignment::default_for(&g, v100.reg());
        for freqs in [vec![FreqId::NOMINAL], vec![FreqId::NOMINAL, FreqId(900)]] {
            let (ta, _) = v100.table_for_freqs(&g, &shapes, &freqs);
            let (tb, _) = hetero.table_for_freqs(&g, &shapes, &freqs);
            let (ca, cb) = (ta.eval(&a), tb.eval(&a));
            assert_eq!(ca.time_ms.to_bits(), cb.time_ms.to_bits());
            assert_eq!(ca.energy_j.to_bits(), cb.energy_j.to_bits());
        }
    }

    #[test]
    fn profile_graph_reports_warm_cache() {
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let r1 = oracle.profile_graph(&g).unwrap();
        assert!(r1.measured > 0);
        let r2 = oracle.profile_graph(&g).unwrap();
        assert_eq!(r2.measured, 0);
        assert_eq!(r1.measured + r1.cached, r2.cached);
    }

    #[test]
    fn feedback_overrides_rows_without_remeasuring() {
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());
        let (t0, measured) = oracle.table_for(&g).unwrap();
        assert!(measured > 0);
        let c0 = t0.eval(&a);
        // Attribute a 3x-slower whole-plan observation down to rows and
        // fold it back in.
        let store = MeasuredStore::new(1.0);
        let n = oracle.observe_plan(&g, &a, 3.0, &store).unwrap();
        assert!(n > 0);
        assert_eq!(store.len(), n, "conv_graph has no duplicate signatures");
        let applied = oracle.apply_feedback(&store);
        assert_eq!(applied.rows, n);
        assert!(applied.evicted > 0);
        // Rebuilds re-read the corrected db; nothing re-measures.
        let before = oracle.profiled_total();
        let (t1, m1) = oracle.table_for(&g).unwrap();
        assert_eq!(m1, 0, "feedback must never trigger re-measurement");
        assert_eq!(oracle.profiled_total(), before);
        let c1 = t1.eval(&a);
        assert!((c1.time_ms / c0.time_ms - 3.0).abs() < 1e-9, "{} vs {}", c1.time_ms, c0.time_ms);
        assert!((c1.energy_j / c0.energy_j - 3.0).abs() < 1e-9);
        // The serve-side estimate path sees the corrections too.
        let cc = oracle.cached_cost(&g, &a).unwrap().unwrap();
        assert_eq!(cc.time_ms.to_bits(), c1.time_ms.to_bits());
        // Old tables stay valid, still answering from pre-feedback rows.
        assert_eq!(t0.eval(&a).time_ms.to_bits(), c0.time_ms.to_bits());
        // Observed rows are provenance-tagged in the database.
        let j = oracle.with_db(|db| db.to_json()).to_string_compact();
        assert!(j.contains("\"measured:"), "observed rows must carry measured provenance");
    }

    #[test]
    fn feedback_prunes_stale_argmin_memo_entries() {
        use crate::cost::CostFunction;
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let shapes = g.infer_shapes().unwrap();
        let (t0, _) = oracle.table_for_with(&g, &shapes);
        let conv = crate::graph::NodeId(2);
        let cf = CostFunction::Time;
        let (_, algo0, s0) = oracle.argmin_for(&t0, conv, &cf).unwrap();
        assert!(s0 > 0);
        // Observe the winning algorithm as catastrophically slow.
        let mut sig = String::new();
        for (id, node) in g.nodes() {
            if id == conv {
                let in_shapes: Vec<_> =
                    node.inputs.iter().map(|p| shapes[p.node.0][p.port].clone()).collect();
                node.op.signature_into(&in_shapes, &mut sig);
            }
        }
        let store = MeasuredStore::new(1.0);
        store.observe(&sig, algo0, FreqId::NOMINAL, NodeCost { time_ms: 1e6, power_w: 50.0 });
        let applied = oracle.apply_feedback(&store);
        assert_eq!(applied.evicted, 1);
        assert!(applied.memo_pruned >= 1, "the filled memo entry references the evicted slab");
        // A fresh table resolves a new slab (memo miss) and the corrected
        // row dethrones the old argmin.
        let (t1, m) = oracle.table_for_with(&g, &shapes);
        assert_eq!(m, 0);
        let (_, algo1, s1) = oracle.argmin_for(&t1, conv, &cf).unwrap();
        assert!(s1 > 0, "new slab pointers must miss the pruned memo");
        assert_ne!(algo1, algo0, "a 1e6 ms row cannot stay time-optimal");
        // The retired pin keeps the old table's rows intact: its argmin
        // re-scans (its entry was pruned) but still answers from the old
        // slab, consistently with the table's own contents.
        let (_, algo_old, _) = oracle.argmin_for(&t0, conv, &cf).unwrap();
        assert_eq!(algo_old, algo0);
    }

    #[test]
    fn apply_feedback_is_safe_under_concurrent_table_builds() {
        let oracle = CostOracle::offline_default();
        let g = conv_graph();
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());
        oracle.table_for(&g).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let (t, _) = oracle.table_for(&g).unwrap();
                        let c = t.eval(&a);
                        assert!(c.time_ms > 0.0 && c.time_ms.is_finite());
                    }
                });
            }
            s.spawn(|| {
                for i in 1..20u32 {
                    let store = MeasuredStore::new(1.0);
                    oracle.observe_plan(&g, &a, 1.0 + f64::from(i) * 0.01, &store).unwrap();
                    oracle.apply_feedback(&store);
                }
            });
        });
        assert_eq!(oracle.table_for(&g).unwrap().1, 0, "feedback never re-measures");
    }
}
