//! Seeded arrival-trace generation: single-rate and piecewise-rate
//! (bursty) Poisson processes.
//!
//! The serve loop replays a precomputed arrival trace so runs are
//! reproducible: the same seed draws the same inter-arrival sequence
//! regardless of host timing. A trace is either a single-rate Poisson
//! process (the pre-batch-axis behavior, bit-identical here) or a
//! piecewise composition of [`RatePhase`]s — e.g. calm → burst → calm —
//! which is what exposes the difference between fixed batch-1 serving and
//! adaptive (plan, batch) operating-point selection: a fixed loop sized
//! for the calm rate saturates during the burst, while the controller can
//! move to a higher-capacity batched operating point.

use crate::util::rng::Rng;

/// One constant-rate segment of a piecewise-Poisson arrival trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RatePhase {
    /// Mean arrival rate of the phase, requests per second. Must be > 0.
    pub rate_hz: f64,
    /// Number of requests drawn in this phase. Must be > 0.
    pub requests: usize,
}

impl RatePhase {
    /// A phase of `requests` arrivals at `rate_hz`.
    pub fn new(rate_hz: f64, requests: usize) -> RatePhase {
        RatePhase { rate_hz, requests }
    }
}

/// Draw `requests` Poisson arrival times at a single constant rate,
/// starting from `t0`. The draw sequence (`-ln(u)/rate` per arrival, with
/// `u` clamped away from zero) is exactly the pre-batch-axis serve loop's,
/// so single-rate traces are bit-identical to what `run_loop` historically
/// produced from the same RNG state.
pub fn poisson_arrivals(rng: &mut Rng, t0: f64, rate_hz: f64, requests: usize) -> Vec<f64> {
    let mut arrivals = Vec::with_capacity(requests);
    let mut t = t0;
    for _ in 0..requests {
        t += -rng.f64().max(1e-12).ln() / rate_hz;
        arrivals.push(t);
    }
    arrivals
}

/// Draw a piecewise-rate Poisson trace: each phase continues from the last
/// arrival of the previous one, so the trace is globally non-decreasing
/// with locally exponential inter-arrivals at the phase's rate.
pub fn piecewise_arrivals(rng: &mut Rng, phases: &[RatePhase]) -> Vec<f64> {
    let total: usize = phases.iter().map(|p| p.requests).sum();
    let mut arrivals = Vec::with_capacity(total);
    let mut t0 = 0.0;
    for phase in phases {
        let seg = poisson_arrivals(rng, t0, phase.rate_hz, phase.requests);
        t0 = seg.last().copied().unwrap_or(t0);
        arrivals.extend(seg);
    }
    arrivals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_trace_bitwise() {
        let phases =
            [RatePhase::new(100.0, 8), RatePhase::new(2000.0, 32), RatePhase::new(100.0, 8)];
        let a = piecewise_arrivals(&mut Rng::seed_from(7), &phases);
        let b = piecewise_arrivals(&mut Rng::seed_from(7), &phases);
        assert_eq!(a.len(), 48);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
        // A different seed draws a different trace.
        let c = piecewise_arrivals(&mut Rng::seed_from(8), &phases);
        assert_ne!(bits(&a), bits(&c));
    }

    #[test]
    fn arrivals_are_nondecreasing_across_phase_joints() {
        let phases = [RatePhase::new(50.0, 10), RatePhase::new(5000.0, 50)];
        let a = piecewise_arrivals(&mut Rng::seed_from(3), &phases);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "trace went backwards");
        assert!(a[0] > 0.0);
    }

    #[test]
    fn burst_phase_is_denser_than_calm_phase() {
        let phases = [RatePhase::new(10.0, 40), RatePhase::new(10_000.0, 40)];
        let a = piecewise_arrivals(&mut Rng::seed_from(11), &phases);
        let calm_span = a[39] - a[0];
        let burst_span = a[79] - a[40];
        assert!(
            burst_span * 10.0 < calm_span,
            "burst not denser: calm {calm_span}s vs burst {burst_span}s"
        );
    }

    #[test]
    fn single_rate_matches_legacy_draw_sequence() {
        // The contract that keeps `ServeReport`s reproducible across the
        // trace-module refactor: one phase == the historical inline loop.
        let mut rng = Rng::seed_from(2026);
        let a = poisson_arrivals(&mut rng, 0.0, 500.0, 16);
        let mut rng = Rng::seed_from(2026);
        let mut t = 0.0;
        let b: Vec<f64> = (0..16)
            .map(|_| {
                t += -rng.f64().max(1e-12).ln() / 500.0;
                t
            })
            .collect();
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}
