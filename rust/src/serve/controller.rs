//! Load-adaptive frontier control: pick which Pareto-frontier plan serves
//! the next batch, based on the **live** request rate and queue depth.
//!
//! The policy follows PolyThrottle's observation that the energy-optimal
//! operating point shifts with load: under light traffic the controller
//! parks on the energy-optimal plan (rightmost frontier point), and as
//! estimated utilization `ρ = rate × service_time` climbs past
//! [`AdaptiveConfig::high_util`] — or the queue spikes past
//! [`AdaptiveConfig::panic_queue`] — it steps toward the latency-optimal
//! plan (index 0). It steps back toward the energy end only when the
//! *slower neighbor* could absorb the current rate with margin
//! ([`AdaptiveConfig::low_util`]) and the queue is drained. The asymmetric
//! thresholds plus a minimum dwell time between steps are the hysteresis
//! that keeps the controller from thrashing between plans.
//!
//! Utilization is computed from **measured** per-request service times
//! (EWMA per plan, on the serving loop's virtual clock); a plan never yet
//! executed is estimated by scaling a measured neighbor's service time by
//! the cost oracle's time ratio — exactly the pair-wise relative accuracy
//! the paper argues the cost model provides.
//!
//! # Operating-point mode
//!
//! With the batch axis ([`FrontierController::for_operating_points`]) the
//! neighbor-stepping policy above is no longer sound: along a (batch
//! latency, energy/request) frontier, capacity is **not** monotone in the
//! index — a big-batch point of a slow plan can have both lower energy
//! per request *and* higher throughput than a batch-1 point of a fast
//! plan. Stepping "toward index 0 under load" could then step toward
//! *lower* capacity. Operating-point mode therefore decides by explicit
//! feasibility: under panic it jumps to the highest-capacity point; when
//! the active point's utilization exceeds `high_util` it moves to the
//! cheapest point (energy/request) that absorbs the estimated rate with
//! margin; and it relaxes to a strictly cheaper point only when the queue
//! is drained and that point's utilization stays under `low_util`. The
//! same dwell/hysteresis machinery applies. Plan-frontier mode
//! ([`FrontierController::new`]) is untouched — all batches are 1 there
//! and the legacy stepping policy runs bit-identically.

use crate::cost::GraphCost;

/// Tuning knobs of the [`FrontierController`].
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Step toward the latency-optimal plan when the active plan's
    /// estimated utilization exceeds this.
    pub high_util: f64,
    /// Step toward the energy-optimal plan only when the *slower
    /// neighbor's* estimated utilization stays below this (must be <
    /// `high_util` for hysteresis).
    pub low_util: f64,
    /// Queue depth that forces an immediate jump to the latency-optimal
    /// plan, bypassing the dwell timer (overload escape hatch).
    pub panic_queue: usize,
    /// Minimum virtual seconds between plan switches (hysteresis dwell).
    pub min_dwell_s: f64,
    /// EWMA smoothing factor for rate/service estimates, in (0, 1];
    /// larger = more reactive.
    pub ewma: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            high_util: 0.85,
            low_util: 0.55,
            panic_queue: 12,
            min_dwell_s: 0.02,
            ewma: 0.3,
        }
    }
}

/// One plan switch taken by the controller (recorded in
/// [`ServeReport::switches`](crate::serve::ServeReport::switches)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanSwitchEvent {
    /// Virtual time of the switch, seconds.
    pub at_s: f64,
    /// Frontier index served before the switch.
    pub from: usize,
    /// Frontier index served after the switch.
    pub to: usize,
    /// Queue depth observed at the decision.
    pub queue_depth: usize,
    /// Estimated arrival rate at the decision, requests/second.
    pub rate_hz: f64,
}

/// Watches the live request stream and selects the active plan on a
/// [`PlanFrontier`](crate::search::PlanFrontier), fastest-first indexed:
/// index 0 = latency-optimal, last = energy-optimal. Starts on the
/// energy-optimal plan (the right choice under no load) and moves along
/// the frontier as pressure changes; see the module docs for the policy.
#[derive(Debug)]
pub struct FrontierController {
    /// Oracle cost estimates per frontier point, fastest-first. In
    /// operating-point mode these are **full-batch** costs (latency and
    /// energy of one batch at that point's batch size).
    est: Vec<GraphCost>,
    /// Batch size per point (all 1 in plan-frontier mode).
    batch: Vec<usize>,
    /// True when built via [`FrontierController::for_operating_points`]:
    /// decisions use the feasibility policy instead of neighbor stepping.
    ops_mode: bool,
    cfg: AdaptiveConfig,
    active: usize,
    last_switch_s: f64,
    /// EWMA inter-arrival time (seconds) and the last arrival seen.
    ia_ewma_s: Option<f64>,
    last_arrival_s: Option<f64>,
    /// EWMA measured per-request service time per plan (virtual seconds).
    svc_ewma_s: Vec<Option<f64>>,
    switches: Vec<PlanSwitchEvent>,
}

impl FrontierController {
    /// Build a controller over `plan_costs` (fastest-first, as returned by
    /// [`PlanFrontier::costs`](crate::search::PlanFrontier::costs)).
    /// Panics if `plan_costs` is empty.
    pub fn new(plan_costs: Vec<GraphCost>, cfg: AdaptiveConfig) -> FrontierController {
        assert!(!plan_costs.is_empty(), "controller needs at least one plan");
        let n = plan_costs.len();
        FrontierController {
            batch: vec![1; n],
            ops_mode: false,
            est: plan_costs,
            cfg,
            active: n - 1,
            last_switch_s: f64::NEG_INFINITY,
            ia_ewma_s: None,
            last_arrival_s: None,
            svc_ewma_s: vec![None; n],
            switches: Vec::new(),
        }
    }

    /// Build a controller over (plan, batch) operating points. `op_costs`
    /// are **full-batch** oracle estimates (latency / energy of one batch
    /// of `batches[i]` requests at point `i`), fastest-first by batch
    /// latency. Starts on the point with the lowest energy per request —
    /// the right choice under no load — and decides with the feasibility
    /// policy described in the module docs. Panics on empty or
    /// mismatched inputs or a zero batch.
    pub fn for_operating_points(
        op_costs: Vec<GraphCost>,
        batches: Vec<usize>,
        cfg: AdaptiveConfig,
    ) -> FrontierController {
        assert!(!op_costs.is_empty(), "controller needs at least one operating point");
        assert_eq!(op_costs.len(), batches.len(), "one batch size per operating point");
        assert!(batches.iter().all(|&b| b >= 1), "batch sizes must be >= 1");
        let n = op_costs.len();
        let mut c = FrontierController {
            batch: batches,
            ops_mode: true,
            est: op_costs,
            cfg,
            active: 0,
            last_switch_s: f64::NEG_INFINITY,
            ia_ewma_s: None,
            last_arrival_s: None,
            svc_ewma_s: vec![None; n],
            switches: Vec::new(),
        };
        c.active = (0..n)
            .min_by(|&a, &b| {
                c.energy_per_request(a)
                    .partial_cmp(&c.energy_per_request(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(n - 1);
        c
    }

    /// The currently active frontier index.
    pub fn active(&self) -> usize {
        self.active
    }

    /// Estimated live arrival rate, requests/second (0 until two arrivals
    /// have been observed).
    pub fn rate_hz(&self) -> f64 {
        match self.ia_ewma_s {
            Some(ia) if ia > 0.0 => 1.0 / ia,
            _ => 0.0,
        }
    }

    /// Plan switches taken so far, in decision order.
    pub fn switches(&self) -> &[PlanSwitchEvent] {
        &self.switches
    }

    /// Consume the controller, returning its switch log.
    pub fn into_switches(self) -> Vec<PlanSwitchEvent> {
        self.switches
    }

    /// Feed one request arrival (virtual timestamp, seconds). Arrivals
    /// must be fed in nondecreasing time order.
    pub fn observe_arrival(&mut self, at_s: f64) {
        if let Some(prev) = self.last_arrival_s {
            let ia = (at_s - prev).max(0.0);
            self.ia_ewma_s = Some(match self.ia_ewma_s {
                Some(e) => self.cfg.ewma * ia + (1.0 - self.cfg.ewma) * e,
                None => ia,
            });
        }
        self.last_arrival_s = Some(at_s);
    }

    /// Feed one measured batch execution: the plan that served it and the
    /// per-request service time (batch wallclock / batch size).
    pub fn observe_service(&mut self, plan: usize, per_request_s: f64) {
        let slot = &mut self.svc_ewma_s[plan];
        *slot = Some(match *slot {
            Some(e) => self.cfg.ewma * per_request_s + (1.0 - self.cfg.ewma) * e,
            None => per_request_s,
        });
    }

    /// Oracle-estimated per-request latency of point `i`, milliseconds
    /// (full-batch latency amortized over the batch; identity at batch 1).
    fn per_request_ms(&self, i: usize) -> f64 {
        self.est[i].time_ms / self.batch[i] as f64
    }

    /// Oracle-estimated energy per request of point `i`, joules (identity
    /// at batch 1).
    fn energy_per_request(&self, i: usize) -> f64 {
        self.est[i].energy_j / self.batch[i] as f64
    }

    /// Estimated per-request service time of `plan`: measured EWMA when
    /// available, else the nearest measured plan scaled by the oracle's
    /// **per-request** time ratio (pair-wise relative accuracy; dividing
    /// by a batch of 1 is exact, so plan-frontier mode is unchanged),
    /// else unknown.
    fn service_s(&self, plan: usize) -> Option<f64> {
        if let Some(s) = self.svc_ewma_s[plan] {
            return Some(s);
        }
        let nearest = (0..self.est.len())
            .filter(|&q| self.svc_ewma_s[q].is_some())
            .min_by_key(|&q| (q.abs_diff(plan), q))?;
        let measured = self.svc_ewma_s[nearest]?;
        let ref_ms = self.per_request_ms(nearest);
        if ref_ms <= 0.0 || self.per_request_ms(plan) <= 0.0 {
            return Some(measured);
        }
        Some(measured * self.per_request_ms(plan) / ref_ms)
    }

    /// Estimated utilization `ρ = rate × service` of `plan` (None until
    /// both a rate and a service estimate exist).
    fn util(&self, rate_hz: f64, plan: usize) -> Option<f64> {
        if rate_hz <= 0.0 {
            return None;
        }
        self.service_s(plan).map(|s| rate_hz * s)
    }

    /// The operating point with the highest estimated capacity (lowest
    /// per-request service time), ties broken toward lower energy per
    /// request then lower index. Ranks by measured service when any point
    /// has been measured (then `service_s` is Some for all), else by the
    /// oracle's per-request latency — never a mix.
    fn max_capacity_op(&self) -> usize {
        let rank = |i: usize| self.service_s(i).unwrap_or_else(|| self.per_request_ms(i));
        let mut best = 0;
        for i in 1..self.est.len() {
            let (ri, ei) = (rank(i), self.energy_per_request(i));
            let (rb, eb) = (rank(best), self.energy_per_request(best));
            if ri < rb || (ri == rb && ei < eb) {
                best = i;
            }
        }
        best
    }

    /// The lowest energy-per-request operating point whose estimated
    /// utilization at `rate_hz` stays at or below `margin` (None when no
    /// point is feasible or no service estimate exists yet).
    fn cheapest_feasible(&self, rate_hz: f64, margin: f64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.est.len() {
            match self.util(rate_hz, i) {
                Some(u) if u <= margin => {}
                _ => continue,
            }
            best = match best {
                Some(b) if self.energy_per_request(b) <= self.energy_per_request(i) => Some(b),
                _ => Some(i),
            };
        }
        best
    }

    /// Operating-point decision: explicit feasibility instead of neighbor
    /// stepping (capacity is not monotone in the index once batch varies).
    fn decide_ops(&mut self, now_s: f64, queue_depth: usize) -> usize {
        let rate = self.rate_hz();
        if queue_depth >= self.cfg.panic_queue {
            // Overload escape hatch: jump to the highest-capacity point,
            // dwell timer notwithstanding.
            let target = self.max_capacity_op();
            if target != self.active {
                self.switch(target, now_s, queue_depth, rate);
            }
            return self.active;
        }
        let dwell_ok = now_s - self.last_switch_s >= self.cfg.min_dwell_s;
        if !dwell_ok || rate <= 0.0 {
            return self.active;
        }
        let Some(util_active) = self.util(rate, self.active) else {
            return self.active;
        };
        if util_active > self.cfg.high_util {
            // Saturating: cheapest point that absorbs the rate with
            // margin, or the highest-capacity point if none does.
            let target =
                self.cheapest_feasible(rate, self.cfg.high_util).unwrap_or_else(|| self.max_capacity_op());
            if target != self.active {
                self.switch(target, now_s, queue_depth, rate);
            }
        } else if queue_depth <= 1 {
            // Drained: relax to a strictly cheaper point only when it
            // holds utilization under the low-water mark (hysteresis).
            if let Some(target) = self.cheapest_feasible(rate, self.cfg.low_util) {
                if target != self.active
                    && self.energy_per_request(target) < self.energy_per_request(self.active)
                {
                    self.switch(target, now_s, queue_depth, rate);
                }
            }
        }
        self.active
    }

    /// Decide which plan serves the next batch, given the virtual clock
    /// and the queue depth at the decision point. May record a switch.
    pub fn decide(&mut self, now_s: f64, queue_depth: usize) -> usize {
        if self.est.len() <= 1 {
            return self.active;
        }
        if self.ops_mode {
            return self.decide_ops(now_s, queue_depth);
        }
        let rate = self.rate_hz();
        let util_active = self.util(rate, self.active);
        let util_slower = if self.active + 1 < self.est.len() {
            self.util(rate, self.active + 1)
        } else {
            None
        };
        let dwell_ok = now_s - self.last_switch_s >= self.cfg.min_dwell_s;
        if queue_depth >= self.cfg.panic_queue && self.active > 0 {
            // Overload escape hatch: jump straight to the latency-optimal
            // plan, dwell timer notwithstanding.
            self.switch(0, now_s, queue_depth, rate);
        } else if dwell_ok
            && self.active > 0
            && util_active.is_some_and(|u| u > self.cfg.high_util)
        {
            self.switch(self.active - 1, now_s, queue_depth, rate);
        } else if dwell_ok
            && queue_depth <= 1
            && util_slower.is_some_and(|u| u < self.cfg.low_util)
        {
            self.switch(self.active + 1, now_s, queue_depth, rate);
        }
        self.active
    }

    /// Carry the live load estimates of `prev` into this controller after
    /// a feedback hot-swap, so the new surface does not restart cold: the
    /// arrival-rate EWMA, last-arrival timestamp, dwell timer, and switch
    /// log all carry over. Measured per-plan service EWMAs carry only when
    /// `carry_service` is set **and** the surfaces have the same plan
    /// count (a re-priced surface keeps its measurements; a re-searched
    /// surface's plans are new graphs, so theirs must restart).
    pub fn rebase_from(&mut self, prev: &FrontierController, carry_service: bool) {
        self.ia_ewma_s = prev.ia_ewma_s;
        self.last_arrival_s = prev.last_arrival_s;
        self.last_switch_s = prev.last_switch_s;
        self.switches = prev.switches.clone();
        if carry_service && self.svc_ewma_s.len() == prev.svc_ewma_s.len() {
            self.svc_ewma_s.clone_from(&prev.svc_ewma_s);
        }
    }

    /// [`rebase_from`](Self::rebase_from) for a surface that *shrank or
    /// reshuffled* under a fault: `map[new]` names the previous index whose
    /// measured service EWMA the new point `new` inherits (`None` for a
    /// freshly activated contingency plan, which must re-measure). Load
    /// estimates and the switch log carry over as in `rebase_from`.
    pub fn rebase_from_masked(&mut self, prev: &FrontierController, map: &[Option<usize>]) {
        self.rebase_from(prev, false);
        for (new, old) in map.iter().enumerate().take(self.svc_ewma_s.len()) {
            if let Some(old) = old {
                if let Some(e) = prev.svc_ewma_s.get(*old) {
                    self.svc_ewma_s[new] = *e;
                }
            }
        }
    }

    fn switch(&mut self, to: usize, now_s: f64, queue_depth: usize, rate_hz: f64) {
        self.switches.push(PlanSwitchEvent {
            at_s: now_s,
            from: self.active,
            to,
            queue_depth,
            rate_hz,
        });
        self.active = to;
        self.last_switch_s = now_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn cost(time_ms: f64, energy_j: f64) -> GraphCost {
        GraphCost { time_ms, energy_j, freq: FreqId::NOMINAL }
    }

    /// A 3-point frontier: fast/hungry, middle, slow/frugal.
    fn frontier() -> Vec<GraphCost> {
        vec![cost(1.0, 300.0), cost(2.0, 200.0), cost(4.0, 100.0)]
    }

    #[test]
    fn starts_energy_optimal() {
        let c = FrontierController::new(frontier(), AdaptiveConfig::default());
        assert_eq!(c.active(), 2);
        assert_eq!(c.rate_hz(), 0.0);
    }

    #[test]
    fn light_load_stays_on_energy_plan() {
        let mut c = FrontierController::new(frontier(), AdaptiveConfig::default());
        // 10 req/s against a 4 ms plan: utilization 0.04.
        let mut t = 0.0;
        for _ in 0..50 {
            c.observe_arrival(t);
            t += 0.1;
            c.observe_service(c.active(), 0.004);
            assert_eq!(c.decide(t, 0), 2);
        }
        assert!(c.switches().is_empty());
    }

    #[test]
    fn overload_steps_toward_latency_plan() {
        let mut c = FrontierController::new(frontier(), AdaptiveConfig::default());
        // 600 req/s against a 4 ms plan: utilization 2.4 — must step down.
        let mut t = 0.0;
        for _ in 0..200 {
            c.observe_arrival(t);
            t += 1.0 / 600.0;
            c.observe_service(c.active(), 0.004 * frontier()[c.active()].time_ms / 4.0);
            c.decide(t, 2);
        }
        assert_eq!(c.active(), 0, "controller must reach the latency plan");
        assert!(!c.switches().is_empty());
        for w in c.switches().windows(2) {
            assert!(w[1].at_s - w[0].at_s >= AdaptiveConfig::default().min_dwell_s - 1e-12);
        }
    }

    #[test]
    fn panic_queue_jumps_to_latency_plan() {
        let mut c = FrontierController::new(frontier(), AdaptiveConfig::default());
        c.observe_arrival(0.0);
        c.observe_arrival(0.001);
        assert_eq!(c.decide(0.001, 50), 0, "deep queue jumps to index 0");
        assert_eq!(c.switches().len(), 1);
        assert_eq!(c.switches()[0].from, 2);
        assert_eq!(c.switches()[0].to, 0);
    }

    #[test]
    fn recovers_to_energy_plan_with_hysteresis() {
        let cfg = AdaptiveConfig::default();
        let mut c = FrontierController::new(frontier(), cfg.clone());
        // Burst pushes it to the latency plan...
        c.observe_arrival(0.0);
        c.observe_arrival(0.0005);
        c.decide(0.0005, 50);
        assert_eq!(c.active(), 0);
        // ...then a long quiet stretch at 10 req/s brings it back, one
        // dwell-separated step at a time.
        let mut t = 0.1;
        for _ in 0..100 {
            c.observe_arrival(t);
            t += 0.1;
            c.observe_service(c.active(), 0.001 * frontier()[c.active()].time_ms);
            c.decide(t, 0);
        }
        assert_eq!(c.active(), 2, "quiet traffic must drift back to the energy plan");
        // Hysteresis: never more than one switch inside a dwell window.
        for w in c.switches().windows(2) {
            assert!(w[1].at_s - w[0].at_s >= cfg.min_dwell_s - 1e-12);
        }
    }

    #[test]
    fn unmeasured_plan_scales_from_neighbor() {
        let mut c = FrontierController::new(frontier(), AdaptiveConfig::default());
        c.observe_service(2, 0.004);
        // Plan 0 never ran: estimate = 0.004 * (1.0 / 4.0).
        let s = c.service_s(0).unwrap();
        assert!((s - 0.001).abs() < 1e-12, "{s}");
    }

    #[test]
    fn single_plan_never_switches() {
        let mut c = FrontierController::new(vec![cost(1.0, 1.0)], AdaptiveConfig::default());
        c.observe_arrival(0.0);
        c.observe_arrival(0.0001);
        assert_eq!(c.decide(0.001, 1000), 0);
        assert!(c.switches().is_empty());
    }

    /// Three (plan, batch) operating points, fastest-first by batch
    /// latency. Per-request (ms, J): op0 (1.0, 0.30), op1 (1.5, 0.15),
    /// op2 (2.0, 0.10) — capacity falls with index, energy improves.
    fn ops_frontier() -> (Vec<GraphCost>, Vec<usize>) {
        (vec![cost(1.0, 0.3), cost(6.0, 0.6), cost(16.0, 0.8)], vec![1, 4, 8])
    }

    /// Per-request service time of operating point `i` in `ops_frontier`,
    /// virtual seconds, matching the oracle estimates exactly.
    fn ops_svc_s(i: usize) -> f64 {
        1e-3 * [1.0, 1.5, 2.0][i]
    }

    #[test]
    fn ops_starts_on_cheapest_per_request_point() {
        let (est, batches) = ops_frontier();
        let c = FrontierController::for_operating_points(est, batches, AdaptiveConfig::default());
        assert_eq!(c.active(), 2, "start = lowest energy/request, not last index by luck");
    }

    #[test]
    fn ops_panic_jumps_to_max_capacity_point() {
        let (est, batches) = ops_frontier();
        let mut c = FrontierController::for_operating_points(est, batches, AdaptiveConfig::default());
        c.observe_arrival(0.0);
        c.observe_arrival(0.001);
        assert_eq!(c.decide(0.001, 50), 0, "deep queue jumps to the highest-capacity point");
        assert_eq!(c.switches().len(), 1);
        assert_eq!((c.switches()[0].from, c.switches()[0].to), (2, 0));
    }

    #[test]
    fn ops_panic_keeps_batched_point_when_it_has_max_capacity() {
        // Capacity is NOT monotone in the index here: the last point is a
        // big-batch op with the *highest* capacity (0.5 ms/request). The
        // legacy stepping policy would have fled toward index 0; the ops
        // policy must stay put.
        let est = vec![cost(1.0, 0.3), cost(4.0, 0.1), cost(8.0, 0.4)];
        let batches = vec![1, 1, 16];
        let mut c = FrontierController::for_operating_points(est, batches, AdaptiveConfig::default());
        assert_eq!(c.active(), 2, "0.4/16 J is the cheapest per request");
        c.observe_arrival(0.0);
        c.observe_arrival(0.001);
        assert_eq!(c.decide(0.001, 50), 2, "batched point is also the capacity max");
        assert!(c.switches().is_empty());
    }

    #[test]
    fn ops_overload_moves_to_cheapest_feasible_point() {
        let (est, batches) = ops_frontier();
        let mut c = FrontierController::for_operating_points(est, batches, AdaptiveConfig::default());
        // 480 req/s: active op2 runs at util 0.96 > 0.85; op1 (0.72) and
        // op0 (0.48) are both feasible — the cheaper op1 must win.
        let mut t = 0.0;
        for _ in 0..200 {
            c.observe_arrival(t);
            t += 1.0 / 480.0;
            c.observe_service(c.active(), ops_svc_s(c.active()));
            c.decide(t, 2);
        }
        assert_eq!(c.active(), 1, "cheapest feasible point, not a blind step to index 0");
        assert_eq!(c.switches().len(), 1);
    }

    #[test]
    fn rebase_carries_load_state_and_gates_service_ewmas() {
        let mut prev = FrontierController::new(frontier(), AdaptiveConfig::default());
        prev.observe_arrival(0.0);
        prev.observe_arrival(0.01);
        prev.observe_service(2, 0.004);
        prev.decide(0.02, 50); // records a panic switch to plan 0
        assert_eq!(prev.switches().len(), 1);

        // Same plan count + carry_service: everything carries.
        let mut same = FrontierController::new(frontier(), AdaptiveConfig::default());
        same.rebase_from(&prev, true);
        assert_eq!(same.rate_hz(), prev.rate_hz());
        assert_eq!(same.switches().len(), 1);
        assert_eq!(same.svc_ewma_s, prev.svc_ewma_s);

        // carry_service = false: rate survives, measurements restart.
        let mut fresh = FrontierController::new(frontier(), AdaptiveConfig::default());
        fresh.rebase_from(&prev, false);
        assert_eq!(fresh.rate_hz(), prev.rate_hz());
        assert!(fresh.svc_ewma_s.iter().all(Option::is_none));

        // Mismatched plan count: service EWMAs restart even when asked.
        let mut shrunk =
            FrontierController::new(vec![cost(1.0, 1.0)], AdaptiveConfig::default());
        shrunk.rebase_from(&prev, true);
        assert_eq!(shrunk.rate_hz(), prev.rate_hz());
        assert!(shrunk.svc_ewma_s.iter().all(Option::is_none));
    }

    #[test]
    fn masked_rebase_maps_surviving_service_ewmas() {
        let mut prev = FrontierController::new(frontier(), AdaptiveConfig::default());
        prev.observe_arrival(0.0);
        prev.observe_arrival(0.01);
        prev.observe_service(0, 0.001);
        prev.observe_service(2, 0.004);

        // A device loss dropped plan 1 and replaced plan 2 with a
        // contingency: the new surface is [old 0, fresh contingency].
        let mut next = FrontierController::new(
            vec![cost(1.0, 300.0), cost(4.0, 100.0)],
            AdaptiveConfig::default(),
        );
        next.rebase_from_masked(&prev, &[Some(0), None]);
        assert_eq!(next.rate_hz(), prev.rate_hz(), "load estimates carry over");
        assert_eq!(next.svc_ewma_s[0], prev.svc_ewma_s[0], "survivor keeps its measurement");
        assert_eq!(next.svc_ewma_s[1], None, "contingency plan re-measures");
    }

    #[test]
    fn ops_recovers_to_cheapest_point_with_hysteresis() {
        let (est, batches) = ops_frontier();
        let cfg = AdaptiveConfig::default();
        let mut c = FrontierController::for_operating_points(est, batches, cfg.clone());
        // Panic pushes it to the capacity point...
        c.observe_arrival(0.0);
        c.observe_arrival(0.0005);
        c.decide(0.0005, 50);
        assert_eq!(c.active(), 0);
        // ...then quiet 50 req/s traffic relaxes it back to the cheapest
        // point (util 0.1 < low_util), respecting the dwell timer.
        let mut t = 0.1;
        for _ in 0..100 {
            c.observe_arrival(t);
            t += 0.02;
            c.observe_service(c.active(), ops_svc_s(c.active()));
            c.decide(t, 0);
        }
        assert_eq!(c.active(), 2, "quiet traffic must return to the cheapest point");
        for w in c.switches().windows(2) {
            assert!(w[1].at_s - w[0].at_s >= cfg.min_dwell_s - 1e-12);
        }
    }
}
