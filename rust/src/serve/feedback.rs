//! Drift detection for the self-tuning serve loop: compare what the cost
//! model *predicted* a batch would cost against what serving *observed*,
//! and decide when the gap is real.
//!
//! This is the detection half of the feedback loop (the writeback half
//! lives in [`crate::cost::feedback`]):
//!
//! - Every executed batch feeds [`DriftDetector::observe`] with the
//!   oracle's predicted batch latency and the measured service time.
//! - A **calibration constant** κ maps predicted milliseconds to observed
//!   seconds. Under a virtual service model
//!   ([`ServiceModel::Virtual`](super::ServiceModel::Virtual)) κ is the
//!   model's exact scale; under wallclock service it is learned from the
//!   first [`FeedbackConfig::calibration_batches`] batches and then
//!   frozen. Warmup calibration deliberately absorbs any *uniform*
//!   mis-scale of the database (a constant factor on every row is
//!   indistinguishable from a slower host); only *relative* drift — some
//!   rows wrong by a different factor than others, or drift that starts
//!   after calibration — is observable there.
//! - The relative error `|observed / (κ · predicted) − 1|` is EWMA-smoothed
//!   and run through a hysteresis state machine: drift **arms** only after
//!   [`FeedbackConfig::drift_batches`] consecutive over-threshold batches
//!   and **clears** only once the smoothed error falls below the (lower)
//!   [`FeedbackConfig::drift_clear`] mark, so a single noisy batch neither
//!   raises nor silences the alarm.
//! - Per-plan observed/predicted ratio EWMAs ([`DriftDetector::plan_scale`])
//!   feed the telemetry writeback: the serve loop scales the active plan's
//!   database rows by its ratio via
//!   [`CostOracle::observe_plan`](crate::cost::CostOracle::observe_plan).
//!
//! State transitions are reported as typed [`DriftEvent`]s in
//! [`ServeReport::drift_events`](super::ServeReport::drift_events); a
//! completed re-search lands as a [`HotSwapEvent`] in
//! [`ServeReport::swaps`](super::ServeReport::swaps).

/// Tuning knobs of the serve-time feedback loop (telemetry writeback,
/// drift detection, and background re-search).
#[derive(Debug, Clone)]
pub struct FeedbackConfig {
    /// EWMA weight of the measured-row store (how fast observed rows track
    /// new observations), in `(0, 1]`.
    pub store_ewma: f64,
    /// EWMA weight of the drift detector's error and per-plan ratio
    /// estimates, in `(0, 1]`.
    pub drift_ewma: f64,
    /// Smoothed relative prediction error that arms drift detection
    /// (0.25 = the model is off by 25%).
    pub drift_threshold: f64,
    /// Smoothed relative error below which an armed drift clears; must be
    /// below `drift_threshold` (hysteresis gap).
    pub drift_clear: f64,
    /// Consecutive over-threshold batches required before drift arms
    /// (debounce against one-off stragglers).
    pub drift_batches: usize,
    /// Batches used to learn the calibration constant κ under wallclock
    /// service (ignored when the service model fixes κ exactly).
    pub calibration_batches: usize,
    /// Minimum virtual seconds between re-search launches while drift
    /// stays armed.
    pub research_interval_s: f64,
    /// Maximum re-searches per serve run; 0 = detection and writeback
    /// only, never re-search.
    pub max_researches: usize,
    /// Run re-searches on a background thread (requests keep flowing; the
    /// corrected surface hot-swaps in when ready) instead of inline on the
    /// serving thread (deterministic, used by tests and the CLI).
    pub background: bool,
    /// Chaos hook: make every re-search job panic instead of searching.
    /// Exercises the serve loop's research-failure containment (the panic
    /// must surface as a `DegradeEvent`, never poison the session); only
    /// ever set by tests.
    pub inject_research_panic: bool,
}

impl Default for FeedbackConfig {
    fn default() -> Self {
        FeedbackConfig {
            store_ewma: 0.3,
            drift_ewma: 0.3,
            drift_threshold: 0.25,
            drift_clear: 0.10,
            drift_batches: 3,
            calibration_batches: 8,
            research_interval_s: 0.5,
            max_researches: 4,
            background: false,
            inject_research_panic: false,
        }
    }
}

impl FeedbackConfig {
    /// Validate the knobs (EWMA ranges, hysteresis ordering, counters).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, w) in [("store_ewma", self.store_ewma), ("drift_ewma", self.drift_ewma)] {
            anyhow::ensure!(
                w.is_finite() && w > 0.0 && w <= 1.0,
                "{name} must be in (0, 1], got {w}"
            );
        }
        anyhow::ensure!(
            self.drift_threshold.is_finite() && self.drift_threshold > 0.0,
            "drift_threshold must be a positive finite ratio, got {}",
            self.drift_threshold
        );
        anyhow::ensure!(
            self.drift_clear.is_finite()
                && self.drift_clear >= 0.0
                && self.drift_clear < self.drift_threshold,
            "drift_clear must be in [0, drift_threshold), got {} vs {}",
            self.drift_clear,
            self.drift_threshold
        );
        anyhow::ensure!(self.drift_batches >= 1, "drift_batches must be >= 1");
        anyhow::ensure!(self.calibration_batches >= 1, "calibration_batches must be >= 1");
        anyhow::ensure!(
            self.research_interval_s.is_finite() && self.research_interval_s >= 0.0,
            "research_interval_s must be finite and >= 0, got {}",
            self.research_interval_s
        );
        Ok(())
    }
}

/// What a [`DriftEvent`] reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriftKind {
    /// Sustained predicted-vs-observed divergence armed the detector.
    Detected,
    /// The smoothed error fell back below the clear mark.
    Cleared,
}

/// One drift state transition, recorded in
/// [`ServeReport::drift_events`](super::ServeReport::drift_events).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftEvent {
    /// Virtual time of the transition, seconds.
    pub at_s: f64,
    /// Plan index whose batch triggered the transition.
    pub plan: usize,
    /// Smoothed relative prediction error at the transition.
    pub rel_err: f64,
    /// Raw observed/predicted ratio of the triggering batch.
    pub ratio: f64,
    /// Armed or cleared.
    pub kind: DriftKind,
}

/// One hot-swap of the serving surface (recorded in
/// [`ServeReport::swaps`](super::ServeReport::swaps)): the controller was
/// rebuilt over a corrected cost surface without pausing the request loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HotSwapEvent {
    /// Virtual time the corrected surface took effect, seconds.
    pub at_s: f64,
    /// Surface epoch after the swap (requests record the epoch that
    /// served them; epoch 0 is the initial surface).
    pub epoch: usize,
    /// True when a full frontier re-search produced new plans; false when
    /// the existing plans were merely re-priced against corrected rows.
    pub researched: bool,
    /// Energy per request (mJ) of the previously active operating point,
    /// priced under the *corrected* surface.
    pub energy_mj_before: f64,
    /// Energy per request (mJ) of the corrected surface's cheapest
    /// operating point — what the controller can now relax to.
    pub energy_mj_after: f64,
}

/// Compares predicted vs observed per-batch cost and decides, with
/// calibration and hysteresis, when the cost model has drifted from
/// reality. See the module docs for the algorithm.
#[derive(Debug)]
pub struct DriftDetector {
    ewma: f64,
    threshold: f64,
    clear: f64,
    arm_batches: usize,
    calibration_batches: usize,
    /// Seconds of observed service per predicted millisecond; `None`
    /// while warmup calibration is still accumulating.
    kappa: Option<f64>,
    calib_sum: f64,
    calib_n: usize,
    /// EWMA of `|ratio - 1|` across all observed batches.
    err_ewma: Option<f64>,
    /// Consecutive over-threshold batches while disarmed.
    over_run: usize,
    in_drift: bool,
    /// Per-plan EWMA of the observed/predicted ratio — the writeback
    /// scale for that plan's database rows.
    plan_ratio: Vec<Option<f64>>,
    /// Batches still to ignore after a fault epoch
    /// ([`DriftDetector::suppress_for`]): fault-induced slowdowns must not
    /// arm drift.
    suppress_left: usize,
}

impl DriftDetector {
    /// Build a detector for `n_plans` plans. `fixed_kappa` pins the
    /// calibration constant exactly (virtual service models know their
    /// own scale); `None` learns it from the first
    /// [`FeedbackConfig::calibration_batches`] observations.
    pub fn new(cfg: &FeedbackConfig, n_plans: usize, fixed_kappa: Option<f64>) -> DriftDetector {
        DriftDetector {
            ewma: cfg.drift_ewma,
            threshold: cfg.drift_threshold,
            clear: cfg.drift_clear,
            arm_batches: cfg.drift_batches,
            calibration_batches: cfg.calibration_batches,
            kappa: fixed_kappa,
            calib_sum: 0.0,
            calib_n: 0,
            err_ewma: None,
            over_run: 0,
            in_drift: false,
            plan_ratio: vec![None; n_plans],
            suppress_left: 0,
        }
    }

    /// Ignore the next `batches` observations entirely (no calibration, no
    /// ratio update, no arming). Called when a fault degrades the surface:
    /// the slowdown is a known hardware event, not cost-model drift, and
    /// must not arm the detector or pollute the writeback ratios.
    pub fn suppress_for(&mut self, batches: usize) {
        self.suppress_left = self.suppress_left.max(batches);
    }

    /// Feed one executed batch: the serving plan, the oracle's predicted
    /// **batch** latency (ms) and the observed service time (s). Returns a
    /// [`DriftEvent`] when the drift state transitions. Non-finite or
    /// non-positive inputs and unknown plan indices are ignored.
    pub fn observe(
        &mut self,
        at_s: f64,
        plan: usize,
        predicted_ms: f64,
        observed_s: f64,
    ) -> Option<DriftEvent> {
        if !(predicted_ms.is_finite() && predicted_ms > 0.0)
            || !(observed_s.is_finite() && observed_s > 0.0)
            || plan >= self.plan_ratio.len()
        {
            return None;
        }
        if self.suppress_left > 0 {
            self.suppress_left -= 1;
            return None;
        }
        let Some(kappa) = self.kappa else {
            // Warmup calibration: learn κ, observe nothing yet.
            self.calib_sum += observed_s / predicted_ms;
            self.calib_n += 1;
            if self.calib_n >= self.calibration_batches {
                self.kappa = Some(self.calib_sum / self.calib_n as f64);
            }
            return None;
        };
        let ratio = observed_s / (kappa * predicted_ms);
        let slot = &mut self.plan_ratio[plan];
        *slot = Some(match *slot {
            Some(e) => self.ewma * ratio + (1.0 - self.ewma) * e,
            None => ratio,
        });
        let rel = (ratio - 1.0).abs();
        let err = match self.err_ewma {
            Some(e) => self.ewma * rel + (1.0 - self.ewma) * e,
            None => rel,
        };
        self.err_ewma = Some(err);
        if self.in_drift {
            if err < self.clear {
                self.in_drift = false;
                self.over_run = 0;
                return Some(DriftEvent {
                    at_s,
                    plan,
                    rel_err: err,
                    ratio,
                    kind: DriftKind::Cleared,
                });
            }
        } else if err > self.threshold {
            self.over_run += 1;
            if self.over_run >= self.arm_batches {
                self.in_drift = true;
                self.over_run = 0;
                return Some(DriftEvent {
                    at_s,
                    plan,
                    rel_err: err,
                    ratio,
                    kind: DriftKind::Detected,
                });
            }
        } else {
            self.over_run = 0;
        }
        None
    }

    /// The EWMA observed/predicted ratio of `plan` — the scale to apply
    /// to that plan's database rows (`None` before any post-calibration
    /// batch served it, or for unknown indices).
    pub fn plan_scale(&self, plan: usize) -> Option<f64> {
        self.plan_ratio.get(plan).copied().flatten()
    }

    /// Whether drift is currently armed.
    pub fn in_drift(&self) -> bool {
        self.in_drift
    }

    /// The calibration constant (s of observed service per predicted ms),
    /// `None` while warmup calibration is still accumulating.
    pub fn kappa(&self) -> Option<f64> {
        self.kappa
    }

    /// Reset the error state for a new `n_plans`-plan surface after a
    /// hot-swap: ratios, smoothed error, and the armed state clear (the
    /// corrected surface must re-earn any drift verdict), while κ — a
    /// property of the host, not the surface — is kept.
    pub fn rebase(&mut self, n_plans: usize) {
        self.plan_ratio = vec![None; n_plans];
        self.err_ewma = None;
        self.over_run = 0;
        self.in_drift = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FeedbackConfig {
        FeedbackConfig {
            drift_ewma: 0.5,
            drift_threshold: 0.25,
            drift_clear: 0.10,
            drift_batches: 3,
            calibration_batches: 4,
            ..Default::default()
        }
    }

    #[test]
    fn validate_enforces_hysteresis_ordering() {
        assert!(FeedbackConfig::default().validate().is_ok());
        let bad = FeedbackConfig { drift_clear: 0.5, drift_threshold: 0.25, ..cfg() };
        assert!(bad.validate().is_err(), "clear above threshold must be rejected");
        assert!(FeedbackConfig { drift_ewma: 0.0, ..cfg() }.validate().is_err());
        assert!(FeedbackConfig { store_ewma: 1.5, ..cfg() }.validate().is_err());
        assert!(FeedbackConfig { drift_batches: 0, ..cfg() }.validate().is_err());
        assert!(FeedbackConfig { research_interval_s: f64::NAN, ..cfg() }.validate().is_err());
    }

    #[test]
    fn fixed_kappa_detects_and_clears_with_hysteresis() {
        // κ pinned at 1e-3 s/ms: an accurate model observes exactly
        // κ·predicted seconds.
        let mut d = DriftDetector::new(&cfg(), 1, Some(1e-3));
        let mut t = 0.0;
        for _ in 0..10 {
            t += 0.01;
            assert_eq!(d.observe(t, 0, 1.0, 1e-3), None, "accurate batches never arm");
        }
        assert!(!d.in_drift());
        // The host now runs 2x slower than predicted: rel error 1.0 per
        // batch. The EWMA crosses 0.25 immediately, but the debounce holds
        // the alarm until the 3rd consecutive over-threshold batch.
        let mut events = Vec::new();
        for i in 0..3 {
            t += 0.01;
            let e = d.observe(t, 0, 1.0, 2e-3);
            if i < 2 {
                assert_eq!(e, None, "debounce must hold batch {i}");
            } else {
                events.push(e.expect("third over-threshold batch arms"));
            }
        }
        assert_eq!(events[0].kind, DriftKind::Detected);
        assert!(d.in_drift());
        assert!((d.plan_scale(0).unwrap() - 2.0).abs() < 0.2, "ratio EWMA tracks the 2x drift");
        // Accuracy restored: the error EWMA decays; the alarm clears only
        // below the lower clear mark, and exactly once.
        let mut cleared = 0;
        for _ in 0..10 {
            t += 0.01;
            if let Some(e) = d.observe(t, 0, 1.0, 1e-3) {
                assert_eq!(e.kind, DriftKind::Cleared);
                cleared += 1;
            }
        }
        assert_eq!(cleared, 1, "hysteresis clears once, not repeatedly");
        assert!(!d.in_drift());
    }

    #[test]
    fn one_off_straggler_does_not_arm() {
        let mut d = DriftDetector::new(&cfg(), 1, Some(1e-3));
        for i in 0..20 {
            let obs = if i == 10 { 5e-3 } else { 1e-3 };
            assert_eq!(d.observe(i as f64, 0, 1.0, obs), None);
        }
        assert!(!d.in_drift(), "a single straggler must not arm drift");
    }

    #[test]
    fn warmup_calibration_absorbs_uniform_scale() {
        // No fixed κ: the first 4 batches calibrate. A host uniformly 2x
        // slower than the database is absorbed into κ — no drift.
        let mut d = DriftDetector::new(&cfg(), 1, None);
        for i in 0..4 {
            assert_eq!(d.observe(i as f64, 0, 1.0, 2e-3), None);
            assert_eq!(d.plan_scale(0), None, "calibration batches observe nothing");
        }
        assert!((d.kappa().unwrap() - 2e-3).abs() < 1e-15);
        for i in 4..10 {
            assert_eq!(d.observe(i as f64, 0, 1.0, 2e-3), None);
        }
        assert!(!d.in_drift(), "uniform mis-scale is calibrated away");
        // Drift *after* calibration is observable: service doubles again.
        let mut armed = false;
        for i in 10..20 {
            if let Some(e) = d.observe(i as f64, 0, 1.0, 4e-3) {
                assert_eq!(e.kind, DriftKind::Detected);
                armed = true;
            }
        }
        assert!(armed, "post-calibration drift must arm");
    }

    #[test]
    fn rebase_clears_state_but_keeps_kappa() {
        let mut d = DriftDetector::new(&cfg(), 1, Some(1e-3));
        for i in 0..10 {
            d.observe(i as f64, 0, 1.0, 3e-3);
        }
        assert!(d.in_drift());
        d.rebase(3);
        assert!(!d.in_drift());
        assert_eq!(d.plan_scale(0), None);
        assert_eq!(d.plan_scale(2), None);
        assert_eq!(d.kappa(), Some(1e-3), "κ is a host property, kept across swaps");
        // The new surface re-earns its own verdict.
        assert_eq!(d.observe(100.0, 2, 1.0, 1e-3).map(|e| e.kind), None);
    }

    #[test]
    fn suppressed_batches_never_arm_or_calibrate() {
        let mut d = DriftDetector::new(&cfg(), 1, Some(1e-3));
        // A fault epoch: the next 5 batches run 3x slow for a known
        // hardware reason. Suppression swallows them without arming or
        // touching the ratio EWMAs.
        d.suppress_for(5);
        for i in 0..5 {
            assert_eq!(d.observe(i as f64, 0, 1.0, 3e-3), None);
        }
        assert!(!d.in_drift(), "suppressed slowdown must not arm drift");
        assert_eq!(d.plan_scale(0), None, "suppressed batches must not pollute writeback");
        // Observation resumes once the window is spent.
        for i in 5..15 {
            d.observe(i as f64, 0, 1.0, 3e-3);
        }
        assert!(d.in_drift(), "post-suppression drift must still arm");
    }

    #[test]
    fn junk_observations_are_ignored() {
        let mut d = DriftDetector::new(&cfg(), 1, Some(1e-3));
        assert_eq!(d.observe(0.0, 0, 0.0, 1e-3), None);
        assert_eq!(d.observe(0.0, 0, f64::NAN, 1e-3), None);
        assert_eq!(d.observe(0.0, 0, 1.0, -1.0), None);
        assert_eq!(d.observe(0.0, 7, 1.0, 1e-3), None, "unknown plan index");
        assert_eq!(d.plan_scale(0), None);
    }
}
