//! The serve-session builder: one entry point composing a plan source, an
//! adaptive policy, and the self-tuning feedback loop behind a single
//! serving loop.
//!
//! [`ServeSession`] replaces the four pre-0.2 entry points (`serve`,
//! `serve_plan`, `serve_frontier`, `serve_operating_points`, kept as
//! deprecated shims over this builder). One loop serves every mode; with
//! feedback off it reproduces the legacy loops exactly — bit-identically
//! under [`ServiceModel::Virtual`](super::ServiceModel::Virtual), where no
//! wallclock enters the simulation.
//!
//! With [`ServeSession::feedback`] the session closes the optimize→serve
//! loop per executed batch:
//!
//! 1. **Observe** — the measured service time feeds a
//!    [`DriftDetector`](super::DriftDetector) against the oracle's
//!    predicted batch cost; state transitions land in
//!    [`ServeReport::drift_events`](super::ServeReport::drift_events).
//! 2. **Write back** — the active plan's observed/predicted ratio scales
//!    its database rows into a [`MeasuredStore`](crate::cost::MeasuredStore)
//!    via [`CostOracle::observe_plan`](crate::cost::CostOracle::observe_plan).
//! 3. **Re-search** — on sustained drift the measured rows are folded into
//!    the oracle ([`CostOracle::apply_feedback`](crate::cost::CostOracle::apply_feedback))
//!    and the surface is re-priced against the corrected costs — or fully
//!    re-searched ([`ServeSession::research`]) with
//!    [`optimize_frontier_batched_warm`] warm-started from the active
//!    plan's assignment. Background mode runs this on a scoped thread while
//!    requests keep flowing.
//! 4. **Hot-swap** — the corrected surface replaces the controller's
//!    frontier atomically between batches
//!    ([`FrontierController::rebase_from`](super::FrontierController::rebase_from)
//!    carries the live load estimates), recorded as a
//!    [`HotSwapEvent`](super::HotSwapEvent); subsequent requests serve
//!    under the next epoch.

use super::controller::{AdaptiveConfig, FrontierController};
use super::faults::{
    DegradeCause, DegradeEvent, FaultEvent, FaultKind, FaultPlan, FaultState, ShedEvent,
};
use super::feedback::{DriftDetector, DriftEvent, FeedbackConfig, HotSwapEvent};
use super::trace::RatePhase;
use super::{OperatingPoint, RequestRecord, ServeConfig, ServeReport, ServiceModel};
use crate::algo::Assignment;
use crate::cost::{CostOracle, GraphCost};
use crate::energysim::{DeviceId, FreqId, GpuSpec};
use crate::graph::Graph;
use crate::search::{
    optimize_frontier_batched_warm, price_plan_at_batch, OptimizerContext, PlanFrontier,
    PlanPoint, SearchConfig,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use std::sync::mpsc;

/// How the feedback loop re-searches on sustained drift: a full two-level
/// frontier search ([`optimize_frontier_batched_warm`]) against the
/// feedback-corrected oracle, warm-started from the active plan's
/// assignment. Without this config the loop re-*prices* the existing
/// plans instead (same graphs, corrected rows).
///
/// Requires [`ServeSession::run_with_adopt`]: a full search can yield
/// *new* graphs the executor has never seen, and the adopt callback is
/// how it compiles them before they serve traffic.
pub struct ResearchConfig<'a> {
    /// Optimizer context (rules + the shared oracle) to search with. Use
    /// the same context whose oracle the session serves so feedback
    /// corrections are visible to the search.
    pub ctx: &'a OptimizerContext,
    /// The origin graph to search from (typically the model the surface
    /// was originally optimized from).
    pub origin: Graph,
    /// Two-level search configuration.
    pub search: SearchConfig,
    /// Frontier probe count (`n` of the weight sweep).
    pub points: usize,
    /// Batch sizes to sweep, strictly increasing.
    pub batches: Vec<usize>,
}

/// What a completed re-search produced.
enum ResearchOutcome {
    /// Existing plans re-priced against corrected rows: a new price grid,
    /// same graphs and operating points.
    Reprice(Vec<Vec<GraphCost>>),
    /// A full frontier re-search: new plan points (new graphs possible).
    Full(Vec<PlanPoint>),
}

/// Which serving mode the session resolved to (mirrors the three legacy
/// loops; one unified loop serves all of them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// Single plan, no controller.
    Fixed,
    /// Plan frontier with neighbor-stepping adaptive control, batch via
    /// the greedy `batch_max` window.
    Frontier,
    /// (plan, batch) operating points with feasibility-based control,
    /// deadline-aware batch formation, honest partial-batch pricing.
    Ops,
}

/// Everything `prepare` resolved for the loop to run on.
struct SessionState<'a> {
    cfg: ServeConfig,
    mode: Mode,
    oracle: Option<&'a CostOracle>,
    policy: Option<AdaptiveConfig>,
    controller: Option<FrontierController>,
    /// Frontier mode: per-plan cost estimates, fastest-first.
    costs: Vec<GraphCost>,
    /// Ops mode: `grid[p][m - 1]` = full-batch cost of plan `p` at batch `m`.
    grid: Vec<Vec<GraphCost>>,
    /// Ops mode: the operating points (indices into `grid`).
    ops: Vec<OperatingPoint>,
    /// Ops mode: effective target batch per point (capped by `batch_max`).
    batches: Vec<usize>,
    /// Fixed mode: the served plan's estimate, when an oracle priced it.
    plan_cost: Option<GraphCost>,
    /// Full plan points (graphs + assignments), when the source carried
    /// them — required for feedback writeback and re-search.
    points: Vec<PlanPoint>,
    feedback: Option<FeedbackConfig>,
    detector: Option<DriftDetector>,
    store: Option<crate::cost::MeasuredStore>,
    research: Option<ResearchConfig<'a>>,
    /// Seeded fault-injection plan, consumed by the loop's [`FaultState`].
    faults: Option<FaultPlan>,
    /// Per-plan device-loss fallbacks, aligned with `points` (`None` =
    /// the plan has no contingency and is dropped if its device dies).
    contingencies: Vec<Option<PlanPoint>>,
}

/// Builder for one serving run: compose a plan source, an adaptive policy,
/// and optionally the self-tuning feedback loop, then [`run`](Self::run).
///
/// Exactly one plan source may be set: [`plan`](Self::plan) (fixed plan),
/// [`frontier_costs`](Self::frontier_costs) (adaptive over bare cost
/// estimates), [`surface`](Self::surface) / [`plan_points`](Self::plan_points)
/// (adaptive over full plan points), or
/// [`operating_points`](Self::operating_points) (explicit (plan, batch)
/// grid). No source = a single anonymous plan, as the legacy `serve`.
///
/// ```
/// use eadgo::algo::Assignment;
/// use eadgo::cost::CostOracle;
/// use eadgo::graph::{Graph, OpKind, PortRef};
/// use eadgo::serve::{ServeConfig, ServeSession};
///
/// let oracle = CostOracle::offline_default();
/// let mut g = Graph::new();
/// let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
/// let r = g.add1(OpKind::Relu, &[x], "r");
/// g.outputs = vec![PortRef::of(r)];
/// let a = Assignment::default_for(&g, oracle.reg());
/// oracle.table_for(&g).unwrap(); // warm profiles => estimate attached
///
/// let cfg = ServeConfig { requests: 8, input_shape: vec![1, 3, 8, 8], ..Default::default() };
/// let report = ServeSession::new(&cfg)
///     .oracle(&oracle)
///     .plan(&g, &a)
///     .run(|_, batch| Ok(batch.iter().map(eadgo::tensor::ops::relu).collect()))
///     .unwrap();
/// assert_eq!(report.records.len(), 8);
/// let est = report.plan_cost.expect("oracle is warm");
/// assert_eq!(report.energy_mj_per_request, Some(est.energy_j));
/// ```
pub struct ServeSession<'a> {
    cfg: &'a ServeConfig,
    oracle: Option<&'a CostOracle>,
    plan: Option<(&'a Graph, &'a Assignment)>,
    costs: Option<Vec<GraphCost>>,
    points: Option<Vec<PlanPoint>>,
    ops: Option<(Vec<Vec<GraphCost>>, Vec<OperatingPoint>)>,
    policy: Option<AdaptiveConfig>,
    feedback: Option<FeedbackConfig>,
    research: Option<ResearchConfig<'a>>,
    phases: Option<Vec<RatePhase>>,
    service: Option<ServiceModel>,
    faults: Option<FaultPlan>,
    contingencies: Option<Vec<Option<PlanPoint>>>,
}

impl<'a> ServeSession<'a> {
    /// Start a session over `cfg`.
    pub fn new(cfg: &'a ServeConfig) -> ServeSession<'a> {
        ServeSession {
            cfg,
            oracle: None,
            plan: None,
            costs: None,
            points: None,
            ops: None,
            policy: None,
            feedback: None,
            research: None,
            phases: None,
            service: None,
            faults: None,
            contingencies: None,
        }
    }

    /// Share the cost oracle: prices fixed plans, builds ops grids from
    /// plan points, and receives feedback writeback. Required for
    /// [`feedback`](Self::feedback).
    pub fn oracle(mut self, oracle: &'a CostOracle) -> Self {
        self.oracle = Some(oracle);
        self
    }

    /// Serve one fixed `(graph, assignment)` plan. With an
    /// [`oracle`](Self::oracle) the report carries its cost estimate
    /// (priced from already-available profiles only — a cold oracle yields
    /// `plan_cost: None` rather than blocking startup on measurements).
    pub fn plan(mut self, g: &'a Graph, a: &'a Assignment) -> Self {
        self.plan = Some((g, a));
        self
    }

    /// Serve a plan frontier adaptively from bare cost estimates,
    /// fastest-first (as returned by
    /// [`PlanFrontier::costs`](crate::search::PlanFrontier::costs)).
    /// Needs [`adaptive`](Self::adaptive); incompatible with feedback
    /// (writeback needs the plan graphs — use [`surface`](Self::surface)).
    pub fn frontier_costs(mut self, plan_costs: &[GraphCost]) -> Self {
        self.costs = Some(plan_costs.to_vec());
        self
    }

    /// Serve a Pareto [`PlanFrontier`] adaptively (full plan points:
    /// graphs, assignments, and costs). With feedback on, the points are
    /// ops-ified — priced per batch size and served as operating points —
    /// so the surface can be re-priced and hot-swapped.
    pub fn surface(self, frontier: &PlanFrontier) -> Self {
        self.plan_points(frontier.points())
    }

    /// Like [`surface`](Self::surface), from raw plan points (no
    /// dominance pruning — crafted surfaces serve as given).
    pub fn plan_points(mut self, points: &[PlanPoint]) -> Self {
        self.points = Some(points.to_vec());
        self
    }

    /// Serve explicit (plan, batch) operating points over a price grid
    /// (`grid[p][m - 1]` = full-batch cost of plan `p` at batch `m`).
    /// Needs [`adaptive`](Self::adaptive); incompatible with feedback
    /// (writeback needs the plan graphs — use [`surface`](Self::surface)).
    pub fn operating_points(mut self, grid: &[Vec<GraphCost>], ops: &[OperatingPoint]) -> Self {
        self.ops = Some((grid.to_vec(), ops.to_vec()));
        self
    }

    /// Adaptive policy for multi-plan sources (required by
    /// [`frontier_costs`](Self::frontier_costs) and
    /// [`operating_points`](Self::operating_points); defaulted when
    /// feedback ops-ifies a surface).
    pub fn adaptive(mut self, policy: AdaptiveConfig) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Enable the self-tuning feedback loop: telemetry writeback, drift
    /// detection, and (over a plan-point surface) drift-triggered
    /// re-search with hot-swap. Needs an [`oracle`](Self::oracle) and a
    /// source carrying plan graphs ([`plan`](Self::plan),
    /// [`surface`](Self::surface), or [`plan_points`](Self::plan_points)).
    pub fn feedback(mut self, fb: FeedbackConfig) -> Self {
        self.feedback = Some(fb);
        self
    }

    /// Upgrade drift-triggered re-search from re-pricing to a full
    /// frontier search (see [`ResearchConfig`]). Requires
    /// [`run_with_adopt`](Self::run_with_adopt).
    pub fn research(mut self, rc: ResearchConfig<'a>) -> Self {
        self.research = Some(rc);
        self
    }

    /// Override the arrival trace with piecewise-rate phases (equivalent
    /// to setting [`ServeConfig::phases`]).
    pub fn trace(mut self, phases: Vec<RatePhase>) -> Self {
        self.phases = Some(phases);
        self
    }

    /// Override the service model (equivalent to setting
    /// [`ServeConfig::service`]).
    pub fn service(mut self, service: ServiceModel) -> Self {
        self.service = Some(service);
        self
    }

    /// Inject a deterministic fault plan: timestamped device-loss,
    /// clock-cap, and transient-error events applied on the virtual clock
    /// (see [`FaultPlan`]). Device-loss and clock-cap events need a
    /// plan-point surface plus an [`oracle`](Self::oracle) to re-price it;
    /// plans with device-loss events additionally require
    /// [`run_with_adopt`](Self::run_with_adopt), since a contingency swap
    /// can activate plans the executor has never compiled.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Per-plan contingency fallbacks, aligned with the surface's plan
    /// points: on `DeviceLost`, a plan using the lost device is replaced
    /// by its contingency (synthesized at `--save-frontier` time and
    /// persisted in v6 manifests) instead of being dropped outright.
    pub fn contingencies(mut self, plans: Vec<Option<PlanPoint>>) -> Self {
        self.contingencies = Some(plans);
        self
    }

    /// Run the session. `exec` executes one batch under the given plan
    /// index (always 0 for fixed-plan serving; the *grid* plan index for
    /// operating-point serving) and returns one output per request.
    ///
    /// Errors if a [`research`](Self::research) config is set — a full
    /// re-search can produce new graphs the executor has never compiled,
    /// so it requires [`run_with_adopt`](Self::run_with_adopt).
    pub fn run<F>(self, exec: F) -> anyhow::Result<ServeReport>
    where
        F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
    {
        anyhow::ensure!(
            self.research.is_none(),
            "a full re-search can adopt new plans the executor has never seen: use run_with_adopt"
        );
        anyhow::ensure!(
            !self.faults.as_ref().map_or(false, FaultPlan::loses_device),
            "a device-loss fault plan can activate contingency plans the executor has never \
             seen: use run_with_adopt"
        );
        self.run_with_adopt(exec, |_: &[PlanPoint]| Ok(()))
    }

    /// Run the session with an adopt callback: before a fully re-searched
    /// surface serves traffic, `adopt` receives its plan points (in grid
    /// order) so the executor can compile them; an adopt error aborts the
    /// swap and the serve run. Re-pricing swaps (same graphs) do not call
    /// `adopt`.
    pub fn run_with_adopt<F, G>(self, mut exec: F, mut adopt: G) -> anyhow::Result<ServeReport>
    where
        F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
        G: FnMut(&[PlanPoint]) -> anyhow::Result<()>,
    {
        let mut st = self.prepare()?;
        let needs_bg = st.mode == Mode::Ops
            && st.feedback.as_ref().is_some_and(|f| f.background && f.max_researches > 0);
        if needs_bg {
            std::thread::scope(|scope| serve_loop(&mut st, &mut exec, &mut adopt, Some(scope)))
        } else {
            serve_loop(&mut st, &mut exec, &mut adopt, None)
        }
    }

    /// Resolve the builder into a validated [`SessionState`], preserving
    /// the legacy entry points' validation messages exactly.
    fn prepare(self) -> anyhow::Result<SessionState<'a>> {
        let mut cfg = self.cfg.clone();
        if let Some(phases) = self.phases {
            cfg.phases = phases;
        }
        if let Some(service) = self.service {
            cfg.service = service;
        }
        anyhow::ensure!(cfg.batch_max > 0, "batch_max must be > 0");

        let sources = usize::from(self.plan.is_some())
            + usize::from(self.costs.is_some())
            + usize::from(self.points.is_some())
            + usize::from(self.ops.is_some());
        anyhow::ensure!(
            sources <= 1,
            "ServeSession takes at most one plan source (plan / frontier_costs / \
             surface / plan_points / operating_points), got {sources}"
        );

        let feedback_on = self.feedback.is_some();
        if let Some(fb) = &self.feedback {
            fb.validate()?;
            anyhow::ensure!(
                self.oracle.is_some(),
                "feedback needs a cost oracle (ServeSession::oracle)"
            );
        }
        // Device-loss and clock-cap events degrade the *surface*: they
        // need plan points to mask and an oracle to re-price them, so a
        // plan-point source ops-ifies even without feedback.
        let structural_faults = self.faults.as_ref().map_or(false, |f| {
            f.events.iter().any(|e| !matches!(e.kind, FaultKind::TransientError { .. }))
        });

        let mut st = SessionState {
            cfg,
            mode: Mode::Fixed,
            oracle: self.oracle,
            policy: self.policy,
            controller: None,
            costs: Vec::new(),
            grid: Vec::new(),
            ops: Vec::new(),
            batches: Vec::new(),
            plan_cost: None,
            points: Vec::new(),
            feedback: self.feedback,
            detector: None,
            store: None,
            research: self.research,
            faults: self.faults,
            contingencies: Vec::new(),
        };

        if let Some((grid, ops)) = self.ops {
            validate_ops(&st.cfg, &grid, &ops)?;
            st.batches = ops.iter().map(|o| o.batch.min(st.cfg.batch_max)).collect();
            st.grid = grid;
            st.ops = ops;
            st.mode = Mode::Ops;
        } else if let Some(points) = self.points {
            anyhow::ensure!(!points.is_empty(), "serve_frontier needs at least one plan");
            if feedback_on || structural_faults {
                // Ops-ify: price every plan across 1..=batch_max and serve
                // the surface as operating points, so corrected rows can
                // re-price it and the controller can hot-swap.
                let oracle = match st.oracle {
                    Some(o) => o,
                    None => anyhow::bail!(
                        "fault plans with device-loss or clock-cap events need a cost \
                         oracle (ServeSession::oracle) to re-price the surface"
                    ),
                };
                let bmax = st.cfg.batch_max;
                let mut grid = Vec::with_capacity(points.len());
                for p in &points {
                    let row: anyhow::Result<Vec<GraphCost>> = (1..=bmax)
                        .map(|m| price_plan_at_batch(oracle, &p.graph, &p.assignment, m))
                        .collect();
                    grid.push(row?);
                }
                st.ops =
                    (0..points.len()).map(|i| OperatingPoint { plan: i, batch: bmax }).collect();
                st.batches = vec![bmax; points.len()];
                st.grid = grid;
                st.points = points;
                st.mode = Mode::Ops;
            } else {
                st.costs = points.iter().map(|p| p.cost).collect();
                st.points = points;
                st.mode = Mode::Frontier;
            }
        } else if let Some(costs) = self.costs {
            anyhow::ensure!(!costs.is_empty(), "serve_frontier needs at least one plan");
            anyhow::ensure!(
                !feedback_on,
                "feedback needs the plan graphs for writeback, not bare cost estimates: \
                 use ServeSession::surface or plan_points"
            );
            st.costs = costs;
            st.mode = Mode::Frontier;
        } else if let Some((g, a)) = self.plan {
            st.plan_cost = match st.oracle {
                Some(oracle) => oracle.cached_cost(g, a)?,
                None => None,
            };
            if let Some(cost) = st.plan_cost {
                st.points = vec![PlanPoint {
                    graph: g.clone(),
                    assignment: a.clone(),
                    cost,
                    weight: 1.0,
                    batch: 1,
                }];
            }
            anyhow::ensure!(
                !feedback_on || st.plan_cost.is_some(),
                "feedback needs a priced plan: warm the oracle (or load a cost DB) first"
            );
            st.mode = Mode::Fixed;
        } else {
            anyhow::ensure!(
                !feedback_on,
                "feedback needs a plan source carrying graphs (plan / surface / plan_points)"
            );
            st.mode = Mode::Fixed;
        }

        // Fault-tolerance wiring: contingencies align 1:1 with the plan
        // points, and structural faults need an ops-ified surface to mask
        // and re-price.
        if let Some(conts) = self.contingencies {
            anyhow::ensure!(
                !st.points.is_empty() && st.mode != Mode::Fixed,
                "contingency plans need a plan-point surface (ServeSession::surface or \
                 plan_points)"
            );
            anyhow::ensure!(
                conts.len() == st.points.len(),
                "got {} contingency slots for a {}-plan surface",
                conts.len(),
                st.points.len()
            );
            st.contingencies = conts;
        }
        if structural_faults {
            anyhow::ensure!(
                st.mode == Mode::Ops && st.points.len() == st.grid.len(),
                "fault plans with device-loss or clock-cap events need a plan-point surface \
                 (ServeSession::surface or plan_points)"
            );
        }

        // Controllers for the multi-plan modes.
        match st.mode {
            Mode::Fixed => {}
            Mode::Frontier => {
                let policy = st.policy.clone().ok_or_else(|| {
                    anyhow::anyhow!("frontier serving needs an adaptive policy (ServeSession::adaptive)")
                })?;
                st.controller = Some(FrontierController::new(st.costs.clone(), policy));
            }
            Mode::Ops => {
                // Feedback's (and structural faults') ops-ified surfaces
                // default the policy; explicit operating points require it
                // (as the legacy loop did).
                let policy = match (st.policy.clone(), feedback_on || structural_faults) {
                    (Some(p), _) => p,
                    (None, true) => AdaptiveConfig::default(),
                    (None, false) => anyhow::bail!(
                        "operating-point serving needs an adaptive policy (ServeSession::adaptive)"
                    ),
                };
                st.policy = Some(policy.clone());
                let est: Vec<GraphCost> = st
                    .ops
                    .iter()
                    .zip(&st.batches)
                    .map(|(o, &b)| st.grid[o.plan][b - 1])
                    .collect();
                st.controller =
                    Some(FrontierController::for_operating_points(est, st.batches.clone(), policy));
            }
        }

        // Feedback over ops mode needs one plan point per grid plan.
        if feedback_on && st.mode == Mode::Ops {
            anyhow::ensure!(
                st.points.len() == st.grid.len(),
                "feedback over operating points needs the plan graphs for writeback: \
                 use ServeSession::surface or plan_points"
            );
        }
        if st.research.is_some() {
            anyhow::ensure!(
                st.feedback.is_some(),
                "research needs feedback enabled (ServeSession::feedback)"
            );
            anyhow::ensure!(
                st.mode == Mode::Ops,
                "research needs a plan-point surface (ServeSession::surface or plan_points)"
            );
        }

        // Virtual service models must price every plan the session can run.
        if let ServiceModel::Virtual { per_batch_ms, scale_s_per_ms } = &st.cfg.service {
            anyhow::ensure!(
                scale_s_per_ms.is_finite() && *scale_s_per_ms > 0.0,
                "virtual service scale must be positive and finite, got {scale_s_per_ms}"
            );
            let plans = match st.mode {
                Mode::Fixed => 1,
                Mode::Frontier => st.costs.len(),
                Mode::Ops => st.grid.len(),
            };
            anyhow::ensure!(
                per_batch_ms.len() >= plans,
                "virtual service model prices {} plans but serving uses {plans}",
                per_batch_ms.len()
            );
            anyhow::ensure!(
                per_batch_ms.iter().all(|row| !row.is_empty()),
                "virtual service rows must be non-empty"
            );
        }

        // Arm the feedback machinery.
        if let Some(fb) = &st.feedback {
            let n_plans = match st.mode {
                Mode::Fixed => 1,
                Mode::Frontier => st.costs.len(),
                Mode::Ops => st.grid.len(),
            };
            let fixed_kappa = match &st.cfg.service {
                ServiceModel::Virtual { scale_s_per_ms, .. } => Some(*scale_s_per_ms),
                ServiceModel::Wallclock => None,
            };
            st.detector = Some(DriftDetector::new(fb, n_plans, fixed_kappa));
            st.store = Some(crate::cost::MeasuredStore::new(fb.store_ewma));
        }

        Ok(st)
    }
}

/// The legacy operating-point validations, verbatim.
fn validate_ops(
    cfg: &ServeConfig,
    grid: &[Vec<GraphCost>],
    ops: &[OperatingPoint],
) -> anyhow::Result<()> {
    anyhow::ensure!(!ops.is_empty(), "serve_operating_points needs at least one operating point");
    for op in ops {
        anyhow::ensure!(op.batch >= 1, "operating-point batch must be >= 1");
        anyhow::ensure!(
            op.plan < grid.len(),
            "operating point references plan {} but the grid prices {} plans",
            op.plan,
            grid.len()
        );
        let have = grid[op.plan].len();
        anyhow::ensure!(
            op.batch.min(cfg.batch_max) <= have,
            "plan {} is priced for batches 1..={have}, operating point targets batch {}",
            op.plan,
            op.batch.min(cfg.batch_max)
        );
    }
    Ok(())
}

/// Build the re-search job to run (inline or on a background thread):
/// a self-contained closure over clones + the shared `'env` references.
fn build_research_job<'env>(
    st: &SessionState<'env>,
) -> Box<dyn FnOnce() -> anyhow::Result<ResearchOutcome> + Send + 'env> {
    let oracle: &'env CostOracle = st.oracle.expect("feedback mode has an oracle");
    if st.feedback.as_ref().is_some_and(|f| f.inject_research_panic) {
        // Chaos hook: exercises the serve loop's panic containment.
        return Box::new(|| panic!("injected research panic (FeedbackConfig::inject_research_panic)"));
    }
    match &st.research {
        None => {
            // Reprice: same plans, corrected rows, existing grid depths.
            let plans: Vec<(Graph, Assignment, usize)> = st
                .points
                .iter()
                .zip(&st.grid)
                .map(|(p, row)| (p.graph.clone(), p.assignment.clone(), row.len()))
                .collect();
            Box::new(move || {
                let mut grid = Vec::with_capacity(plans.len());
                for (g, a, depth) in &plans {
                    let row: anyhow::Result<Vec<GraphCost>> =
                        (1..=*depth).map(|m| price_plan_at_batch(oracle, g, a, m)).collect();
                    grid.push(row?);
                }
                Ok(ResearchOutcome::Reprice(grid))
            })
        }
        Some(rc) => {
            let ctx: &'env OptimizerContext = rc.ctx;
            let origin = rc.origin.clone();
            let search = rc.search.clone();
            let n = rc.points;
            let batches = rc.batches.clone();
            // Warm-start from the currently active plan's assignment.
            let active = st.controller.as_ref().map(|c| c.active()).unwrap_or(0);
            let warm = st.points[st.ops[active].plan].assignment.clone();
            Box::new(move || {
                let res =
                    optimize_frontier_batched_warm(&origin, ctx, &search, n, &batches, Some(&warm))?;
                anyhow::ensure!(
                    !res.frontier.is_empty(),
                    "re-search produced an empty frontier"
                );
                Ok(ResearchOutcome::Full(res.frontier.points().to_vec()))
            })
        }
    }
}

/// Install a completed re-search: rebuild the surface, rebase the
/// controller (carrying live load estimates), bump the epoch, and record
/// the [`HotSwapEvent`]. Runs between batches on the serving thread — the
/// request loop never pauses for it.
fn apply_swap<G>(
    st: &mut SessionState<'_>,
    outcome: ResearchOutcome,
    clock: f64,
    adopt: &mut G,
    epoch: &mut usize,
    swaps: &mut Vec<HotSwapEvent>,
) -> anyhow::Result<()>
where
    G: FnMut(&[PlanPoint]) -> anyhow::Result<()>,
{
    let researched = matches!(outcome, ResearchOutcome::Full(_));
    match outcome {
        ResearchOutcome::Reprice(grid) => {
            st.grid = grid;
        }
        ResearchOutcome::Full(points) => {
            // The executor must compile the new plans before they serve.
            adopt(&points)?;
            let oracle = st.oracle.expect("feedback mode has an oracle");
            let bmax = st.cfg.batch_max;
            let mut grid = Vec::with_capacity(points.len());
            for p in &points {
                let row: anyhow::Result<Vec<GraphCost>> = (1..=bmax)
                    .map(|m| price_plan_at_batch(oracle, &p.graph, &p.assignment, m))
                    .collect();
                grid.push(row?);
            }
            st.ops = (0..points.len()).map(|i| OperatingPoint { plan: i, batch: bmax }).collect();
            st.batches = vec![bmax; points.len()];
            st.grid = grid;
            st.points = points;
        }
    }

    let per_request_mj =
        |st: &SessionState, i: usize| st.grid[st.ops[i].plan][st.batches[i] - 1].energy_j
            / st.batches[i] as f64;
    // The previously active point, clamped: a re-searched surface may be
    // smaller than the one it replaces.
    let prev_active =
        st.controller.as_ref().map(|c| c.active()).unwrap_or(0).min(st.ops.len() - 1);
    let energy_mj_before = per_request_mj(st, prev_active);
    let energy_mj_after = (0..st.ops.len())
        .map(|i| per_request_mj(st, i))
        .fold(f64::INFINITY, f64::min);

    let est: Vec<GraphCost> =
        st.ops.iter().zip(&st.batches).map(|(o, &b)| st.grid[o.plan][b - 1]).collect();
    let policy = st.policy.clone().unwrap_or_default();
    let mut next = FrontierController::for_operating_points(est, st.batches.clone(), policy);
    if let Some(prev) = st.controller.as_ref() {
        // Re-priced surfaces keep their measured service EWMAs (same
        // graphs); re-searched ones must re-measure.
        next.rebase_from(prev, !researched);
    }
    st.controller = Some(next);
    if let Some(det) = st.detector.as_mut() {
        det.rebase(st.grid.len());
    }
    *epoch += 1;
    swaps.push(HotSwapEvent {
        at_s: clock,
        epoch: *epoch,
        researched,
        energy_mj_before,
        energy_mj_after,
    });
    Ok(())
}

/// Salt of the dedicated transient-error RNG stream: fault draws must not
/// perturb the arrival/payload stream, so they come from their own
/// deterministic generator seeded off the session seed.
const FAULT_RNG_SALT: u64 = 0xFA17_5EED_0000_0001;

/// Whether any node of `p`'s assignment runs on `d` (nodes left at the
/// nominal state count as GPU).
fn uses_device(p: &PlanPoint, d: DeviceId) -> bool {
    p.assignment.assigned_ids().any(|id| p.assignment.freq(id).device() == d)
}

/// Clamp every per-node frequency the current fault set disallows to the
/// fastest surviving state on the same device (layout preserved). Lost
/// devices are not remapped here — device loss replaces whole plans via
/// contingencies instead.
fn capped_assignment(fs: &FaultState, a: &Assignment) -> Assignment {
    let mut out = a.clone();
    let ids: Vec<_> = out.assigned_ids().collect();
    for id in ids {
        let f = out.freq(id);
        let d = f.device();
        if fs.allows(f) || fs.is_lost(d) {
            continue;
        }
        let (Some(cap), Some(spec)) = (fs.cap_mhz(d), GpuSpec::for_device(d)) else {
            continue;
        };
        // The fastest state under the cap; a cap below the whole table
        // clamps to the slowest state (best effort beats a dead clock).
        let states = spec.capped_states(cap);
        let mhz = states.last().or(spec.freq_states.first()).map(|s| s.mhz);
        if let Some(mhz) = mhz {
            out.set_freq(id, FreqId::on(d, mhz).with_layout(f.layout()));
        }
    }
    out
}

/// Re-price an ops-ified surface row by row against the oracle (each plan
/// across the given batch depth).
fn reprice_grid(
    oracle: &CostOracle,
    points: &[PlanPoint],
    depths: &[usize],
) -> anyhow::Result<Vec<Vec<GraphCost>>> {
    let mut grid = Vec::with_capacity(points.len());
    for (p, &depth) in points.iter().zip(depths) {
        let row: anyhow::Result<Vec<GraphCost>> =
            (1..=depth).map(|m| price_plan_at_batch(oracle, &p.graph, &p.assignment, m)).collect();
        grid.push(row?);
    }
    Ok(grid)
}

/// Rebuild the controller over the current (degraded) grid, carrying live
/// load state from the previous one: `map` carries surviving service
/// EWMAs by index (device loss); `None` restarts all measurements (clock
/// caps make them stale). Also rebases the drift detector and suppresses
/// it for one debounce window — the fault-induced slowdown is a known
/// hardware event, not cost-model drift.
fn rebuild_degraded_controller(st: &mut SessionState<'_>, map: Option<&[Option<usize>]>) {
    let est: Vec<GraphCost> =
        st.ops.iter().zip(&st.batches).map(|(o, &b)| st.grid[o.plan][b - 1]).collect();
    let policy = st.policy.clone().unwrap_or_default();
    let mut next = FrontierController::for_operating_points(est, st.batches.clone(), policy);
    if let Some(prev) = st.controller.as_ref() {
        match map {
            Some(map) => next.rebase_from_masked(prev, map),
            None => next.rebase_from(prev, false),
        }
    }
    st.controller = Some(next);
    if let Some(det) = st.detector.as_mut() {
        det.rebase(st.grid.len());
        let batches = st.feedback.as_ref().map(|f| f.drift_batches).unwrap_or(0);
        det.suppress_for(batches);
    }
}

/// Degrade the surface on `DeviceLost`: plans that use the lost device
/// are replaced by their contingency (or dropped when none avoids it),
/// the executor adopts the new surface before it serves traffic, the grid
/// is re-priced, and the controller rebases with surviving measurements.
/// Errors only when *nothing* survives — admitted requests are never
/// dropped by the swap itself.
#[allow(clippy::too_many_arguments)]
fn apply_device_loss<G>(
    st: &mut SessionState<'_>,
    fs: &FaultState,
    lost: DeviceId,
    clock: f64,
    adopt: &mut G,
    epoch: &mut usize,
    degrades: &mut Vec<DegradeEvent>,
    svc_scale: &mut Vec<f64>,
) -> anyhow::Result<()>
where
    G: FnMut(&[PlanPoint]) -> anyhow::Result<()>,
{
    let oracle = st.oracle.expect("structural faults validated an oracle");
    let n_before = st.points.len();
    let mut new_points: Vec<PlanPoint> = Vec::new();
    let mut new_conts: Vec<Option<PlanPoint>> = Vec::new();
    // map[new] = Some(old index) for survivors (their measurements carry).
    let mut map: Vec<Option<usize>> = Vec::new();
    let mut used = 0usize;
    for (i, p) in st.points.iter().enumerate() {
        if !uses_device(p, lost) {
            new_points.push(p.clone());
            new_conts.push(st.contingencies.get(i).cloned().flatten());
            map.push(Some(i));
        } else if let Some(c) = st.contingencies.get(i).cloned().flatten() {
            if !uses_device(&c, lost) {
                new_points.push(c);
                new_conts.push(None);
                map.push(None);
                used += 1;
            }
        }
    }
    anyhow::ensure!(
        !new_points.is_empty(),
        "device '{}' lost: every plan uses it and no contingency avoids it",
        lost.name()
    );
    // The executor compiles the degraded surface before it serves traffic
    // (a contingency graph may never have been compiled).
    adopt(&new_points)?;
    for p in &mut new_points {
        p.assignment = capped_assignment(fs, &p.assignment);
    }
    let bmax = st.cfg.batch_max;
    let grid = reprice_grid(oracle, &new_points, &vec![bmax; new_points.len()])?;
    st.ops = (0..new_points.len()).map(|i| OperatingPoint { plan: i, batch: bmax }).collect();
    st.batches = vec![bmax; new_points.len()];
    st.grid = grid;
    let carried: Vec<f64> = map
        .iter()
        .map(|m| m.and_then(|i| svc_scale.get(i).copied()).unwrap_or(1.0))
        .collect();
    *svc_scale = carried;
    st.points = new_points;
    st.contingencies = new_conts;
    rebuild_degraded_controller(st, Some(&map));
    *epoch += 1;
    degrades.push(DegradeEvent {
        at_s: clock,
        epoch: *epoch,
        cause: DegradeCause::DeviceLost(lost),
        points_before: n_before,
        points_after: st.points.len(),
        contingencies_used: used,
        detail: format!("{} of {n_before} plans survived", st.points.len()),
    });
    Ok(())
}

/// Degrade the surface under a clock cap: clamp every plan's disallowed
/// states, re-price the grid, rebuild the controller (measured service
/// EWMAs are stale under new clocks), and record the `DegradeEvent`. The
/// capped/uncapped predicted-time ratio at each plan's target batch folds
/// into `svc_scale`, so the modeled slowdown reaches the service clock
/// deterministically.
#[allow(clippy::too_many_arguments)]
fn apply_clock_cap(
    st: &mut SessionState<'_>,
    fs: &FaultState,
    device: DeviceId,
    cap_mhz: u16,
    clock: f64,
    epoch: &mut usize,
    degrades: &mut Vec<DegradeEvent>,
    svc_scale: &mut [f64],
) -> anyhow::Result<()> {
    let oracle = st.oracle.expect("structural faults validated an oracle");
    for p in st.points.iter_mut() {
        p.assignment = capped_assignment(fs, &p.assignment);
    }
    let depths: Vec<usize> = st.grid.iter().map(Vec::len).collect();
    let grid = reprice_grid(oracle, &st.points, &depths)?;
    for i in 0..grid.len().min(svc_scale.len()) {
        let b = st.batches.get(i).copied().unwrap_or(1).clamp(1, depths[i]);
        let old = st.grid[i][b - 1].time_ms;
        let new = grid[i][b - 1].time_ms;
        if old > 0.0 && new.is_finite() && new > 0.0 {
            svc_scale[i] *= new / old;
        }
    }
    st.grid = grid;
    rebuild_degraded_controller(st, None);
    *epoch += 1;
    degrades.push(DegradeEvent {
        at_s: clock,
        epoch: *epoch,
        cause: DegradeCause::ClockCap(device, cap_mhz),
        points_before: st.points.len(),
        points_after: st.points.len(),
        contingencies_used: 0,
        detail: String::new(),
    });
    Ok(())
}

/// The unified serving loop. With no controller and no feedback this is
/// the legacy fixed-plan loop statement for statement; the frontier and
/// operating-point behaviours differ only where the legacy loops did
/// (batch-fill horizon and energy accounting).
fn serve_loop<'env, 'scope, F, G>(
    st: &mut SessionState<'env>,
    exec: &mut F,
    adopt: &mut G,
    scope: Option<&'scope std::thread::Scope<'scope, 'env>>,
) -> anyhow::Result<ServeReport>
where
    'env: 'scope,
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
    G: FnMut(&[PlanPoint]) -> anyhow::Result<()>,
{
    let mut rng = Rng::seed_from(st.cfg.seed);
    // Poisson arrivals (single- or piecewise-rate), drawn before any
    // payload so the RNG stream matches the historical inline draw.
    let arrivals = st.cfg.arrival_trace(&mut rng)?;
    let total = arrivals.len();

    let mut records: Vec<RequestRecord> = Vec::with_capacity(total);
    let mut clock = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut n_batches = 0usize;
    let mut energy_mj = 0.0f64;
    let mut next = 0usize; // next unserved request index
    let mut epoch = 0usize;
    let mut drift_events: Vec<DriftEvent> = Vec::new();
    let mut swaps: Vec<HotSwapEvent> = Vec::new();

    // Fault machinery: the plan's event cursor, a dedicated RNG for
    // transient-error draws (drawn only inside active windows, so
    // fault-free runs replay the exact historical payload stream), and the
    // typed event logs for the report.
    let mut fstate = st.faults.take().map(FaultState::new);
    let mut frng = Rng::seed_from(st.cfg.seed ^ FAULT_RNG_SALT);
    let mut faults: Vec<FaultEvent> = Vec::new();
    let mut degrades: Vec<DegradeEvent> = Vec::new();
    let mut sheds: Vec<ShedEvent> = Vec::new();
    // Per-plan service-time multiplier under clock caps: the capped /
    // uncapped predicted-time ratio at the plan's target batch, folded
    // into every service observation so virtual replays slow down too.
    let mut svc_scale: Vec<f64> = match st.mode {
        Mode::Ops => vec![1.0; st.grid.len()],
        Mode::Frontier => vec![1.0; st.costs.len()],
        Mode::Fixed => vec![1.0],
    };

    // Background re-search plumbing: at most one in flight; results are
    // polled between batches and installed atomically from the serving
    // thread (the hot-swap itself never races the loop).
    let (tx, rx) = mpsc::channel::<anyhow::Result<ResearchOutcome>>();
    let mut in_flight = false;
    let mut researches = 0usize;
    let mut last_research_s = f64::NEG_INFINITY;

    while next < total {
        if in_flight {
            match rx.try_recv() {
                Ok(result) => {
                    in_flight = false;
                    match result {
                        Ok(outcome) => {
                            apply_swap(st, outcome, clock, adopt, &mut epoch, &mut swaps)?;
                        }
                        // A failed (or panicked) background re-search must
                        // not poison the session: log the degradation and
                        // keep serving on the current surface.
                        Err(e) => degrades.push(DegradeEvent {
                            at_s: clock,
                            epoch,
                            cause: DegradeCause::ResearchFailed,
                            points_before: st.grid.len(),
                            points_after: st.grid.len(),
                            contingencies_used: 0,
                            detail: e.to_string(),
                        }),
                    }
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => in_flight = false,
            }
        }

        // Advance to the first pending arrival if idle.
        clock = clock.max(arrivals[next]);
        // Activate every fault due by now, in timestamp order. Structural
        // faults (device loss, clock caps) degrade the surface *between*
        // batches: admitted requests are never dropped by the swap itself.
        if let Some(fs) = fstate.as_mut() {
            for evt in fs.advance(clock) {
                faults.push(evt);
                match evt.kind {
                    FaultKind::DeviceLost { device } => apply_device_loss(
                        st, fs, device, clock, adopt, &mut epoch, &mut degrades, &mut svc_scale,
                    )?,
                    FaultKind::ThermalCap { device, .. } | FaultKind::PowerCap { device, .. } => {
                        // A power cap above the device's nominal draw
                        // resolves to no clock cap at all.
                        if let Some(cap) = fs.cap_mhz(device) {
                            apply_clock_cap(
                                st, fs, device, cap, clock, &mut epoch, &mut degrades,
                                &mut svc_scale,
                            )?;
                        }
                    }
                    FaultKind::TransientError { .. } => {}
                }
            }
        }
        // The controller decides on the live queue depth at this instant:
        // every request that has arrived but not been served.
        let sel = match st.controller.as_mut() {
            Some(c) => {
                let mut depth = 1usize;
                while next + depth < total && arrivals[next + depth] <= clock {
                    depth += 1;
                }
                c.decide(clock, depth)
            }
            None => 0,
        };
        // Batch formation: the ops loop targets the active point's batch
        // and anchors the fill horizon at the oldest pending request's
        // arrival (admission control); the legacy loops fill greedily to
        // batch_max within a window starting now.
        let (exec_plan, target, horizon) = match st.mode {
            Mode::Ops => (
                st.ops[sel].plan,
                st.batches[sel],
                (arrivals[next] + st.cfg.max_wait_s).max(clock),
            ),
            _ => (sel, st.cfg.batch_max, clock + st.cfg.max_wait_s),
        };
        let mut end = next + 1;
        while end < total && end - next < target && arrivals[end] <= horizon {
            end += 1;
        }
        // If we waited for later arrivals, the batch starts at the later of
        // (deadline reached, last included arrival).
        if end - next > 1 {
            clock = clock.max(arrivals[end - 1]);
        }
        let batch_ids: Vec<usize> = (next..end).collect();
        if let Some(c) = st.controller.as_mut() {
            for &id in &batch_ids {
                c.observe_arrival(arrivals[id]);
            }
        }
        let inputs: Vec<Tensor> = batch_ids
            .iter()
            .map(|_| Tensor::rand(&st.cfg.input_shape, &mut rng, -1.0, 1.0))
            .collect();

        // Execute, retrying under an active transient-error window with
        // deterministic exponential backoff. Every attempt burns service
        // time and energy; when retries exhaust — or waiting out the next
        // backoff would blow the retry budget — the whole batch is shed.
        let m = inputs.len();
        let mut retries = 0usize;
        let mut shed = false;
        let service = loop {
            let t0 = std::time::Instant::now();
            let outputs = exec(exec_plan, &inputs)?;
            let wall_s = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                outputs.len() == inputs.len(),
                "exec_batch returned {} outputs for {} requests",
                outputs.len(),
                inputs.len()
            );
            let service = st.cfg.service.service_s(exec_plan, m, wall_s) * svc_scale[exec_plan];
            busy_s += service;
            n_batches += 1;
            if st.mode == Mode::Ops {
                // Honest partial-batch pricing: charge the plan at the
                // batch size actually formed (a failed attempt burns the
                // same energy as a successful one).
                energy_mj += st.grid[st.ops[sel].plan][m - 1].energy_j;
            }
            let rate = fstate.as_ref().map_or(0.0, |fs| fs.transient_rate(clock));
            if !(rate > 0.0 && frng.f64() < rate) {
                break service;
            }
            // The attempt failed: its service time passed, nothing was
            // delivered.
            clock += service;
            let fp = fstate.as_ref().expect("an active window implies a fault plan").plan();
            if retries >= fp.max_retries {
                shed = true;
                break service;
            }
            let backoff = fp.backoff_s(retries);
            if clock + backoff > arrivals[next] + fp.retry_budget_s {
                // Deadline-aware shedding: the oldest admitted request's
                // retry budget cannot absorb another backoff.
                shed = true;
                break service;
            }
            clock += backoff;
            retries += 1;
        };
        if shed {
            for &id in &batch_ids {
                sheds.push(ShedEvent {
                    at_s: clock,
                    id,
                    retries,
                    waited_s: clock - arrivals[id],
                });
            }
            next = end;
            continue;
        }
        if let Some(c) = st.controller.as_mut() {
            c.observe_service(sel, service / m as f64);
        }
        let start = clock;
        clock += service;
        for &id in &batch_ids {
            records.push(RequestRecord {
                id,
                arrival_s: arrivals[id],
                start_s: start,
                done_s: clock,
                batch_size: m,
                plan: sel,
                epoch,
            });
        }

        // The feedback loop: observe → write back → (maybe) re-search.
        if st.detector.is_some() {
            let plan_idx = if st.mode == Mode::Ops { st.ops[sel].plan } else { 0 };
            let predicted_ms = match st.mode {
                Mode::Ops => st.grid[plan_idx][m - 1].time_ms,
                Mode::Frontier => st.costs[sel].time_ms * m as f64,
                Mode::Fixed => st.plan_cost.map(|c| c.time_ms * m as f64).unwrap_or(0.0),
            };
            let (evt, ratio, in_drift) = {
                let det = st.detector.as_mut().expect("checked above");
                let evt = det.observe(clock, plan_idx, predicted_ms, service);
                (evt, det.plan_scale(plan_idx), det.in_drift())
            };
            if let Some(evt) = evt {
                drift_events.push(evt);
            }
            if let (Some(scale), Some(oracle), Some(store)) = (ratio, st.oracle, st.store.as_ref())
            {
                if let Some(p) = st.points.get(plan_idx) {
                    oracle.observe_plan(&p.graph, &p.assignment, scale, store)?;
                }
            }
            let fb = st.feedback.as_ref().expect("detector implies feedback");
            if in_drift
                && !in_flight
                && st.mode == Mode::Ops
                && researches < fb.max_researches
                && clock - last_research_s >= fb.research_interval_s
            {
                researches += 1;
                last_research_s = clock;
                let oracle = st.oracle.expect("feedback mode has an oracle");
                let store = st.store.as_ref().expect("feedback mode has a store");
                // Fold the measured rows into the oracle so the re-search
                // (and all later pricing) sees corrected costs.
                oracle.apply_feedback(store);
                let job = build_research_job(st);
                match scope {
                    Some(scope) => {
                        let tx = tx.clone();
                        scope.spawn(move || {
                            // A panic inside the research job must not
                            // poison the session: surface it as an error
                            // and let the receive site degrade gracefully.
                            let out =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
                                    .unwrap_or_else(|p| {
                                        let msg = p
                                            .downcast_ref::<&str>()
                                            .map(|s| s.to_string())
                                            .or_else(|| p.downcast_ref::<String>().cloned())
                                            .unwrap_or_else(|| "non-string panic payload".into());
                                        Err(anyhow::anyhow!("re-search panicked: {msg}"))
                                    });
                            let _ = tx.send(out);
                        });
                        in_flight = true;
                    }
                    None => {
                        apply_swap(st, job()?, clock, adopt, &mut epoch, &mut swaps)?;
                    }
                }
            }
        }

        next = end;
    }
    // A still-running background re-search is abandoned: its result has no
    // traffic left to serve (the scope joins the thread on exit).

    let first = arrivals.first().copied().unwrap_or(0.0);
    let switches =
        st.controller.take().map(FrontierController::into_switches).unwrap_or_default();
    let energy_mj_per_request = match st.mode {
        Mode::Fixed => st.plan_cost.map(|c| c.energy_j),
        Mode::Frontier => {
            if st.costs.iter().all(|c| c.energy_j > 0.0) && !records.is_empty() {
                let total_mj: f64 = records.iter().map(|r| st.costs[r.plan].energy_j).sum();
                Some(total_mj / records.len() as f64)
            } else {
                None
            }
        }
        Mode::Ops => {
            if energy_mj > 0.0 && total > 0 {
                Some(energy_mj / total as f64)
            } else {
                None
            }
        }
    };
    Ok(ServeReport {
        span_s: clock - first,
        busy_s,
        batches: n_batches,
        records,
        plan_cost: st.plan_cost,
        switches,
        energy_mj_per_request,
        drift_events,
        swaps,
        feedback_rows: st.store.as_ref().map(crate::cost::MeasuredStore::len).unwrap_or(0),
        faults,
        degrades,
        sheds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn cfg() -> ServeConfig {
        ServeConfig {
            requests: 8,
            batch_max: 2,
            arrival_rate_hz: 10_000.0,
            max_wait_s: 0.001,
            seed: 1,
            input_shape: vec![1, 3, 8, 8],
            phases: Vec::new(),
            service: ServiceModel::Wallclock,
        }
    }

    fn relu_exec(_plan: usize, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        Ok(inputs.iter().map(crate::tensor::ops::relu).collect())
    }

    fn cost(time_ms: f64, energy_j: f64) -> GraphCost {
        GraphCost { time_ms, energy_j, freq: FreqId::NOMINAL }
    }

    #[test]
    fn rejects_conflicting_plan_sources() {
        let c = cfg();
        let err = ServeSession::new(&c)
            .frontier_costs(&[cost(1.0, 1.0)])
            .operating_points(&[vec![cost(1.0, 1.0)]], &[OperatingPoint { plan: 0, batch: 1 }])
            .adaptive(AdaptiveConfig::default())
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("at most one plan source"), "{err}");
    }

    #[test]
    fn multi_plan_sources_require_policy() {
        let c = cfg();
        let err = ServeSession::new(&c)
            .frontier_costs(&[cost(1.0, 1.0), cost(2.0, 0.5)])
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("adaptive policy"), "{err}");
        let err = ServeSession::new(&c)
            .operating_points(&[vec![cost(1.0, 1.0)]], &[OperatingPoint { plan: 0, batch: 1 }])
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("adaptive policy"), "{err}");
    }

    #[test]
    fn feedback_requires_oracle_and_graphs() {
        let c = cfg();
        // No oracle.
        let err = ServeSession::new(&c)
            .feedback(FeedbackConfig::default())
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("cost oracle"), "{err}");
        // Oracle but no plan source carrying graphs.
        let oracle = CostOracle::offline_default();
        let err = ServeSession::new(&c)
            .oracle(&oracle)
            .feedback(FeedbackConfig::default())
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("plan source"), "{err}");
        // Bare costs cannot host writeback.
        let err = ServeSession::new(&c)
            .oracle(&oracle)
            .frontier_costs(&[cost(1.0, 1.0)])
            .adaptive(AdaptiveConfig::default())
            .feedback(FeedbackConfig::default())
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("bare cost estimates"), "{err}");
    }

    #[test]
    fn research_requires_run_with_adopt_and_feedback() {
        let c = cfg();
        let ctx = crate::search::OptimizerContext::offline_default();
        let rc = || ResearchConfig {
            ctx: &ctx,
            origin: Graph::new(),
            search: SearchConfig::default(),
            points: 2,
            batches: vec![1, 2],
        };
        let err = ServeSession::new(&c).research(rc()).run(relu_exec).unwrap_err();
        assert!(err.to_string().contains("run_with_adopt"), "{err}");
        let err = ServeSession::new(&c)
            .research(rc())
            .run_with_adopt(relu_exec, |_| Ok(()))
            .unwrap_err();
        assert!(err.to_string().contains("feedback"), "{err}");
    }

    #[test]
    fn virtual_model_must_cover_every_plan() {
        let costs = vec![cost(1.0, 1.0), cost(2.0, 0.5)];
        let c = ServeConfig {
            service: ServiceModel::Virtual {
                per_batch_ms: vec![vec![1.0]],
                scale_s_per_ms: 1e-3,
            },
            ..cfg()
        };
        let err = ServeSession::new(&c)
            .frontier_costs(&costs)
            .adaptive(AdaptiveConfig::default())
            .run(relu_exec)
            .unwrap_err();
        assert!(err.to_string().contains("prices 1 plans but serving uses 2"), "{err}");
        let bad_scale = ServeConfig {
            service: ServiceModel::Virtual { per_batch_ms: vec![vec![1.0]], scale_s_per_ms: 0.0 },
            ..cfg()
        };
        let err = ServeSession::new(&bad_scale).run(relu_exec).unwrap_err();
        assert!(err.to_string().contains("scale"), "{err}");
    }
}
