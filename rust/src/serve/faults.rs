//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a typed, timestamped script of hardware misbehavior —
//! device loss, thermal/power clock caps, transient execution errors —
//! loaded from JSON (`eadgo serve --fault-plan faults.json`, mirroring the
//! `--truth-db` drift-injection harness) and applied on the serve loop's
//! **virtual clock**. Replays are bitwise reproducible: the only randomness
//! is a dedicated fault RNG seeded from the serve seed, drawn only while a
//! transient-error window is active, so a fault-free run never touches it
//! and stays byte-identical to a run without a plan.
//!
//! The session reacts to activated events with typed records that land in
//! [`ServeReport`](super::ServeReport) next to the drift/swap events:
//!
//! - [`FaultEvent`] — an injected event became active.
//! - [`DegradeEvent`] — the serving surface degraded (lost-device points
//!   masked, a contingency plan activated, clock-capped re-pricing, or a
//!   background re-search that died without poisoning the session).
//! - [`ShedEvent`] — a request was shed because transient-error retries
//!   would have blown its deadline budget.
//!
//! The event JSON arrays are emitted only when non-empty, so fault-free
//! reports serialize byte-identically to the pre-fault format.

use crate::energysim::{DeviceId, FreqId, GpuSpec};
use crate::util::json::{self, Json};
use std::path::Path;

/// One kind of injected hardware fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The device drops off the bus: every plan state placed on it becomes
    /// unservable and the session must fail over to surviving plans or a
    /// manifest contingency plan.
    DeviceLost {
        /// The device that disappears.
        device: DeviceId,
    },
    /// Thermal throttling clamps the device's core clock: states above
    /// `max_mhz` become unreachable and the surface re-prices against the
    /// capped clock table.
    ThermalCap {
        /// The throttled device.
        device: DeviceId,
        /// Highest core clock still reachable, MHz.
        max_mhz: u16,
    },
    /// A board power cap: resolved against the device's modeled power curve
    /// ([`GpuSpec::max_mhz_under_power`]) to the highest clock whose draw
    /// fits the budget, then applied exactly like a thermal cap.
    PowerCap {
        /// The capped device.
        device: DeviceId,
        /// Board power budget, watts.
        watts: f64,
    },
    /// A window of transient execution errors: each batch executed while
    /// the window is active fails independently with probability `rate`
    /// (drawn from the dedicated fault RNG), triggering bounded retry with
    /// exponential backoff and deadline-aware shedding.
    TransientError {
        /// Per-attempt failure probability in [0, 1].
        rate: f64,
        /// Window length from the event timestamp, virtual seconds.
        duration_s: f64,
    },
}

impl FaultKind {
    /// Canonical kind tag used in JSON (`device_lost`, `thermal_cap`,
    /// `power_cap`, `transient_error`).
    pub fn tag(&self) -> &'static str {
        match self {
            FaultKind::DeviceLost { .. } => "device_lost",
            FaultKind::ThermalCap { .. } => "thermal_cap",
            FaultKind::PowerCap { .. } => "power_cap",
            FaultKind::TransientError { .. } => "transient_error",
        }
    }
}

/// One timestamped fault injection, recorded in
/// [`ServeReport::faults`](super::ServeReport::faults) when it activates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Virtual time the fault activates, seconds.
    pub at_s: f64,
    /// What happens.
    pub kind: FaultKind,
}

impl FaultEvent {
    /// JSON form (report serialization; deterministic field set per kind).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("at_s", self.at_s).set("kind", self.kind.tag());
        match self.kind {
            FaultKind::DeviceLost { device } => {
                o.set("device", device.name());
            }
            FaultKind::ThermalCap { device, max_mhz } => {
                o.set("device", device.name()).set("max_mhz", max_mhz as f64);
            }
            FaultKind::PowerCap { device, watts } => {
                o.set("device", device.name()).set("watts", watts);
            }
            FaultKind::TransientError { rate, duration_s } => {
                o.set("rate", rate).set("duration_s", duration_s);
            }
        }
        o
    }
}

/// Why the serving surface degraded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradeCause {
    /// A [`FaultKind::DeviceLost`] masked plans and (possibly) activated a
    /// manifest contingency plan.
    DeviceLost(DeviceId),
    /// A thermal or power cap clamped the device to this clock and the
    /// surface was re-priced against the capped table.
    ClockCap(DeviceId, u16),
    /// A background re-search panicked or failed; the session kept serving
    /// on the current surface instead of propagating the error.
    ResearchFailed,
}

impl DegradeCause {
    /// Canonical string form used in JSON and log lines.
    pub fn describe(&self) -> String {
        match self {
            DegradeCause::DeviceLost(d) => format!("device_lost:{}", d.name()),
            DegradeCause::ClockCap(d, mhz) => format!("clock_cap:{}@{mhz}MHz", d.name()),
            DegradeCause::ResearchFailed => "research_failed".to_string(),
        }
    }
}

/// One graceful-degradation action taken by the session, recorded in
/// [`ServeReport::degrades`](super::ServeReport::degrades).
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeEvent {
    /// Virtual time of the action, seconds.
    pub at_s: f64,
    /// Surface epoch after the action (device loss and clock caps bump the
    /// epoch like a feedback hot-swap; a failed re-search does not).
    pub epoch: usize,
    /// What triggered the degradation.
    pub cause: DegradeCause,
    /// Serving points before the action.
    pub points_before: usize,
    /// Serving points after the action.
    pub points_after: usize,
    /// Manifest contingency plans activated by the action.
    pub contingencies_used: usize,
    /// Free-form diagnostic (the error text of a failed re-search; empty
    /// otherwise).
    pub detail: String,
}

impl DegradeEvent {
    /// JSON form (report serialization).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("at_s", self.at_s)
            .set("epoch", self.epoch as f64)
            .set("cause", self.cause.describe().as_str())
            .set("points_before", self.points_before as f64)
            .set("points_after", self.points_after as f64)
            .set("contingencies_used", self.contingencies_used as f64);
        if !self.detail.is_empty() {
            o.set("detail", self.detail.as_str());
        }
        o
    }
}

/// One admitted request shed because transient-error retries would have
/// blown its deadline budget, recorded in
/// [`ServeReport::sheds`](super::ServeReport::sheds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShedEvent {
    /// Virtual time of the shed decision, seconds.
    pub at_s: f64,
    /// Request id (arrival order, same id space as request records).
    pub id: usize,
    /// Execution attempts made before shedding.
    pub retries: usize,
    /// Seconds the request had waited since arrival.
    pub waited_s: f64,
}

impl ShedEvent {
    /// JSON form (report serialization).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("at_s", self.at_s)
            .set("id", self.id as f64)
            .set("retries", self.retries as f64)
            .set("waited_s", self.waited_s);
        o
    }
}

/// A typed, validated fault-injection script: timestamped events plus the
/// retry policy for transient errors. Load from JSON with
/// [`FaultPlan::load`]; the serve loop consumes it through [`FaultState`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Events sorted by activation time (stable on ties: file order).
    pub events: Vec<FaultEvent>,
    /// Maximum retry attempts per batch under a transient-error window
    /// before the batch's requests are shed.
    pub max_retries: usize,
    /// Exponential-backoff base: attempt `k` waits `backoff_ms · 2^k`
    /// milliseconds of virtual time before re-executing.
    pub backoff_ms: f64,
    /// Deadline budget for retries, seconds past the oldest admitted
    /// request's arrival: a retry whose backoff would end later than this
    /// sheds the batch instead (infinite = shed only on retry exhaustion).
    pub retry_budget_s: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            events: Vec::new(),
            max_retries: 3,
            backoff_ms: 2.0,
            retry_budget_s: f64::INFINITY,
        }
    }
}

impl FaultPlan {
    /// Backoff before retry attempt `attempt` (0-based), virtual seconds.
    pub fn backoff_s(&self, attempt: usize) -> f64 {
        self.backoff_ms * 2f64.powi(attempt.min(32) as i32) / 1e3
    }

    /// Whether any event names this device as lost.
    pub fn loses_device(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, FaultKind::DeviceLost { .. }))
    }

    /// Parse and validate a plan from its JSON form:
    ///
    /// ```json
    /// {"max_retries": 3, "backoff_ms": 2.0,
    ///  "events": [
    ///    {"at_s": 0.5, "kind": "device_lost", "device": "dla"},
    ///    {"at_s": 1.0, "kind": "thermal_cap", "device": "gpu", "max_mhz": 900},
    ///    {"at_s": 1.5, "kind": "power_cap", "device": "gpu", "watts": 120.0},
    ///    {"at_s": 2.0, "kind": "transient_error", "rate": 0.25, "duration_s": 1.0}]}
    /// ```
    ///
    /// Every malformed field is a typed error naming the offending event;
    /// events are sorted by `at_s` (stable, so same-time events keep file
    /// order).
    pub fn from_json(v: &Json) -> anyhow::Result<FaultPlan> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("fault plan: expected a JSON object at top level"))?;
        let mut plan = FaultPlan::default();
        if let Some(mr) = obj.get("max_retries") {
            let n = mr
                .as_i64()
                .filter(|&n| (0..=16).contains(&n))
                .ok_or_else(|| anyhow::anyhow!("fault plan: max_retries must be an integer in 0..=16"))?;
            plan.max_retries = n as usize;
        }
        if let Some(bo) = obj.get("backoff_ms") {
            let b = bo
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 0.0)
                .ok_or_else(|| anyhow::anyhow!("fault plan: backoff_ms must be finite and >= 0"))?;
            plan.backoff_ms = b;
        }
        if let Some(rb) = obj.get("retry_budget_s") {
            let b = rb
                .as_f64()
                .filter(|b| *b > 0.0)
                .ok_or_else(|| anyhow::anyhow!("fault plan: retry_budget_s must be > 0"))?;
            plan.retry_budget_s = b;
        }
        let events = match obj.get("events") {
            Some(e) => e
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("fault plan: \"events\" must be an array"))?,
            None => &[] as &[Json],
        };
        for (i, e) in events.iter().enumerate() {
            plan.events
                .push(event_from_json(e).map_err(|err| anyhow::anyhow!("fault plan event {i}: {err}"))?);
        }
        plan.events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap_or(std::cmp::Ordering::Equal));
        Ok(plan)
    }

    /// Read and parse a plan file.
    pub fn load(path: &Path) -> anyhow::Result<FaultPlan> {
        let v = json::read_file(path)
            .map_err(|e| anyhow::anyhow!("fault plan {}: {e}", path.display()))?;
        FaultPlan::from_json(&v)
            .map_err(|e| anyhow::anyhow!("fault plan {}: {e}", path.display()))
    }
}

/// Parse one fault event (see [`FaultPlan::from_json`] for the format).
fn event_from_json(v: &Json) -> anyhow::Result<FaultEvent> {
    let at_s = v.req_f64("at_s")?;
    anyhow::ensure!(at_s.is_finite() && at_s >= 0.0, "at_s must be finite and >= 0, got {at_s}");
    let kind = v.req_str("kind")?;
    let device = || -> anyhow::Result<DeviceId> {
        let name = v.req_str("device")?;
        DeviceId::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown device \"{name}\" (known: {})",
                crate::energysim::DEVICE_NAMES.join(", ")
            )
        })
    };
    let kind = match kind {
        "device_lost" => FaultKind::DeviceLost { device: device()? },
        "thermal_cap" => {
            let mhz = v.req_f64("max_mhz")?;
            anyhow::ensure!(
                mhz.is_finite() && mhz >= 1.0 && mhz <= 4095.0,
                "max_mhz must be in 1..=4095, got {mhz}"
            );
            FaultKind::ThermalCap { device: device()?, max_mhz: mhz as u16 }
        }
        "power_cap" => {
            let watts = v.req_f64("watts")?;
            anyhow::ensure!(watts.is_finite() && watts > 0.0, "watts must be finite and > 0, got {watts}");
            FaultKind::PowerCap { device: device()?, watts }
        }
        "transient_error" => {
            let rate = v.req_f64("rate")?;
            anyhow::ensure!((0.0..=1.0).contains(&rate), "rate must be in [0, 1], got {rate}");
            let duration_s = v.req_f64("duration_s")?;
            anyhow::ensure!(
                duration_s.is_finite() && duration_s > 0.0,
                "duration_s must be finite and > 0, got {duration_s}"
            );
            FaultKind::TransientError { rate, duration_s }
        }
        other => anyhow::bail!(
            "unknown fault kind \"{other}\" (known: device_lost, thermal_cap, power_cap, transient_error)"
        ),
    };
    Ok(FaultEvent { at_s, kind })
}

/// Live fault tracker the serve loop advances on its virtual clock: which
/// devices are lost, which are clock-capped (thermal and power caps both
/// resolve to a max clock; the tightest wins), and whether a
/// transient-error window is active.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    next: usize,
    lost: Vec<DeviceId>,
    /// Effective clock cap per device (tightest of all applied caps), MHz.
    caps: Vec<(DeviceId, u16)>,
    /// Transient windows as (start_s, end_s, rate).
    windows: Vec<(f64, f64, f64)>,
}

impl FaultState {
    /// Track `plan` from time zero with no fault active.
    pub fn new(plan: FaultPlan) -> FaultState {
        FaultState { plan, next: 0, lost: Vec::new(), caps: Vec::new(), windows: Vec::new() }
    }

    /// The retry policy of the underlying plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Activate every event due at or before `clock`, in timestamp order,
    /// and return them (for the report's fault log). Power caps are
    /// resolved to clock caps against the device's modeled power curve
    /// here, so downstream only ever sees a max-MHz constraint.
    pub fn advance(&mut self, clock: f64) -> Vec<FaultEvent> {
        let mut fired = Vec::new();
        while let Some(e) = self.plan.events.get(self.next) {
            if e.at_s > clock {
                break;
            }
            let e = *e;
            self.next += 1;
            match e.kind {
                FaultKind::DeviceLost { device } => {
                    if !self.lost.contains(&device) {
                        self.lost.push(device);
                    }
                }
                FaultKind::ThermalCap { device, max_mhz } => self.tighten_cap(device, max_mhz),
                FaultKind::PowerCap { device, watts } => {
                    if let Some(spec) = GpuSpec::for_device(device) {
                        if let Some(mhz) = spec.max_mhz_under_power(watts) {
                            self.tighten_cap(device, mhz);
                        }
                    }
                }
                FaultKind::TransientError { rate, duration_s } => {
                    self.windows.push((e.at_s, e.at_s + duration_s, rate));
                }
            }
            fired.push(e);
        }
        fired
    }

    fn tighten_cap(&mut self, device: DeviceId, max_mhz: u16) {
        match self.caps.iter_mut().find(|(d, _)| *d == device) {
            Some((_, cap)) => *cap = (*cap).min(max_mhz),
            None => self.caps.push((device, max_mhz)),
        }
    }

    /// Whether `device` has been lost.
    pub fn is_lost(&self, device: DeviceId) -> bool {
        self.lost.contains(&device)
    }

    /// Whether any device has been lost.
    pub fn any_lost(&self) -> bool {
        !self.lost.is_empty()
    }

    /// The effective clock cap on `device`, MHz (`None` = uncapped).
    pub fn cap_mhz(&self, device: DeviceId) -> Option<u16> {
        self.caps.iter().find(|(d, _)| *d == device).map(|&(_, c)| c)
    }

    /// The transient-error failure probability at `clock`: the maximum
    /// rate over all windows containing it, 0 outside every window.
    pub fn transient_rate(&self, clock: f64) -> f64 {
        self.windows
            .iter()
            .filter(|(s, e, _)| *s <= clock && clock < *e)
            .map(|&(_, _, r)| r)
            .fold(0.0, f64::max)
    }

    /// Whether a packed frequency state survives the current fault set:
    /// its device is not lost and its effective clock fits any cap.
    pub fn allows(&self, f: FreqId) -> bool {
        let d = f.device();
        if self.is_lost(d) {
            return false;
        }
        match self.cap_mhz(d) {
            None => true,
            Some(cap) => {
                let mhz = match f.mhz() {
                    0 => GpuSpec::for_device(d).map(|s| s.nominal_mhz()).unwrap_or(0),
                    m => m,
                };
                mhz <= cap
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> anyhow::Result<FaultPlan> {
        FaultPlan::from_json(&json::parse(s).expect("test JSON parses"))
    }

    #[test]
    fn parses_every_kind_and_sorts_by_time() {
        let p = parse(
            r#"{"max_retries": 2, "backoff_ms": 4.0, "events": [
                {"at_s": 2.0, "kind": "transient_error", "rate": 0.25, "duration_s": 1.0},
                {"at_s": 0.5, "kind": "device_lost", "device": "dla"},
                {"at_s": 1.0, "kind": "thermal_cap", "device": "gpu", "max_mhz": 900},
                {"at_s": 1.5, "kind": "power_cap", "device": "gpu", "watts": 120.0}]}"#,
        )
        .expect("valid plan");
        assert_eq!(p.max_retries, 2);
        assert_eq!(p.backoff_ms, 4.0);
        let times: Vec<f64> = p.events.iter().map(|e| e.at_s).collect();
        assert_eq!(times, vec![0.5, 1.0, 1.5, 2.0], "events sort by at_s");
        assert!(p.loses_device());
        assert_eq!(p.events[0].kind, FaultKind::DeviceLost { device: DeviceId::DLA });
    }

    #[test]
    fn empty_plan_defaults() {
        let p = parse("{}").expect("empty plan is valid");
        assert!(p.events.is_empty());
        assert_eq!(p.max_retries, 3);
        assert!(p.retry_budget_s.is_infinite());
        assert!(!p.loses_device());
    }

    #[test]
    fn malformed_events_are_typed_errors() {
        for (js, needle) in [
            (r#"{"events": [{"at_s": -1.0, "kind": "device_lost", "device": "dla"}]}"#, "at_s"),
            (r#"{"events": [{"at_s": 0.0, "kind": "device_lost", "device": "tpu"}]}"#, "unknown device"),
            (r#"{"events": [{"at_s": 0.0, "kind": "meteor_strike"}]}"#, "unknown fault kind"),
            (
                r#"{"events": [{"at_s": 0.0, "kind": "transient_error", "rate": 1.5, "duration_s": 1.0}]}"#,
                "rate",
            ),
            (
                r#"{"events": [{"at_s": 0.0, "kind": "transient_error", "rate": 0.5, "duration_s": 0.0}]}"#,
                "duration_s",
            ),
            (r#"{"events": [{"at_s": 0.0, "kind": "thermal_cap", "device": "gpu", "max_mhz": 0}]}"#, "max_mhz"),
            (r#"{"events": [{"at_s": 0.0, "kind": "power_cap", "device": "gpu", "watts": -5}]}"#, "watts"),
            (r#"{"max_retries": 99}"#, "max_retries"),
            (r#"{"backoff_ms": -1}"#, "backoff_ms"),
            (r#"[1, 2]"#, "object"),
        ] {
            let err = parse(js).expect_err(js).to_string();
            assert!(err.contains(needle), "error for {js} must mention {needle}, got: {err}");
        }
    }

    #[test]
    fn state_advances_in_order_and_tracks_loss_and_caps() {
        let p = parse(
            r#"{"events": [
                {"at_s": 0.5, "kind": "device_lost", "device": "dla"},
                {"at_s": 1.0, "kind": "thermal_cap", "device": "gpu", "max_mhz": 1100},
                {"at_s": 2.0, "kind": "thermal_cap", "device": "gpu", "max_mhz": 900}]}"#,
        )
        .unwrap();
        let mut st = FaultState::new(p);
        assert!(st.advance(0.4).is_empty());
        assert!(!st.is_lost(DeviceId::DLA));

        let fired = st.advance(1.2);
        assert_eq!(fired.len(), 2, "both due events fire, in order");
        assert_eq!(fired[0].at_s, 0.5);
        assert!(st.is_lost(DeviceId::DLA));
        assert!(!st.is_lost(DeviceId::GPU));
        assert_eq!(st.cap_mhz(DeviceId::GPU), Some(1100));

        st.advance(5.0);
        assert_eq!(st.cap_mhz(DeviceId::GPU), Some(900), "tightest cap wins");
        assert!(st.advance(100.0).is_empty(), "events fire once");
    }

    #[test]
    fn allows_masks_lost_devices_and_capped_clocks() {
        let p = parse(
            r#"{"events": [
                {"at_s": 0.0, "kind": "device_lost", "device": "dla"},
                {"at_s": 0.0, "kind": "thermal_cap", "device": "gpu", "max_mhz": 1000}]}"#,
        )
        .unwrap();
        let mut st = FaultState::new(p);
        st.advance(0.0);
        assert!(!st.allows(FreqId::on(DeviceId::DLA, 0)), "lost device masks every state");
        assert!(!st.allows(FreqId::on(DeviceId::DLA, 640)));
        assert!(st.allows(FreqId::on(DeviceId::GPU, 900)), "below the cap");
        assert!(!st.allows(FreqId::on(DeviceId::GPU, 1095)), "above the cap");
        assert!(
            !st.allows(FreqId::NOMINAL),
            "GPU nominal means 1380 MHz, which exceeds a 1000 MHz cap"
        );
    }

    #[test]
    fn transient_windows_bound_the_rate() {
        let p = parse(
            r#"{"events": [
                {"at_s": 1.0, "kind": "transient_error", "rate": 0.25, "duration_s": 1.0},
                {"at_s": 1.5, "kind": "transient_error", "rate": 0.5, "duration_s": 0.2}]}"#,
        )
        .unwrap();
        let mut st = FaultState::new(p);
        st.advance(10.0);
        assert_eq!(st.transient_rate(0.5), 0.0, "before the window");
        assert_eq!(st.transient_rate(1.2), 0.25);
        assert_eq!(st.transient_rate(1.6), 0.5, "overlap takes the max rate");
        assert_eq!(st.transient_rate(1.9), 0.25);
        assert_eq!(st.transient_rate(2.5), 0.0, "after the window");
    }

    #[test]
    fn power_cap_resolves_to_a_clock_cap() {
        // 120 W on a 300 W-TDP V100 must cap well below nominal but above
        // the lowest state; the exact clock comes from the power model.
        let p = parse(
            r#"{"events": [{"at_s": 0.0, "kind": "power_cap", "device": "gpu", "watts": 120.0}]}"#,
        )
        .unwrap();
        let mut st = FaultState::new(p);
        st.advance(0.0);
        let cap = st.cap_mhz(DeviceId::GPU).expect("a 120 W cap must clamp the clock");
        assert!(cap < 1380, "cap {cap} must be below nominal");
        assert!(cap >= 510, "cap {cap} cannot fall below the lowest state");
        // A generous cap above TDP changes nothing.
        let p2 = parse(
            r#"{"events": [{"at_s": 0.0, "kind": "power_cap", "device": "gpu", "watts": 400.0}]}"#,
        )
        .unwrap();
        let mut st2 = FaultState::new(p2);
        st2.advance(0.0);
        assert_eq!(st2.cap_mhz(DeviceId::GPU), None, "a cap above TDP is a no-op");
    }

    #[test]
    fn backoff_doubles_deterministically() {
        let p = FaultPlan { backoff_ms: 2.0, ..FaultPlan::default() };
        assert_eq!(p.backoff_s(0), 0.002);
        assert_eq!(p.backoff_s(1), 0.004);
        assert_eq!(p.backoff_s(2), 0.008);
    }

    #[test]
    fn event_json_roundtrips_through_report_form() {
        let e = FaultEvent {
            at_s: 0.5,
            kind: FaultKind::ThermalCap { device: DeviceId::GPU, max_mhz: 900 },
        };
        let j = e.to_json();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("thermal_cap"));
        assert_eq!(j.get("device").and_then(Json::as_str), Some("gpu"));
        assert_eq!(j.get("max_mhz").and_then(Json::as_f64), Some(900.0));
        let d = DegradeEvent {
            at_s: 1.0,
            epoch: 1,
            cause: DegradeCause::DeviceLost(DeviceId::DLA),
            points_before: 4,
            points_after: 3,
            contingencies_used: 1,
            detail: String::new(),
        };
        let dj = d.to_json();
        assert_eq!(dj.get("cause").and_then(Json::as_str), Some("device_lost:dla"));
        assert!(dj.get("detail").is_none(), "empty detail is omitted");
        let s = ShedEvent { at_s: 2.0, id: 7, retries: 3, waited_s: 0.4 };
        assert_eq!(s.to_json().get("id").and_then(Json::as_f64), Some(7.0));
    }
}
