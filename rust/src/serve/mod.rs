//! Serving coordinator: a single-node request loop with Poisson arrivals,
//! FIFO queueing, and dynamic batching — the L3 "thin driver" that puts the
//! optimized `(G, A)` behind a request interface (`eadgo serve`).
//!
//! The loop is a discrete-event simulation driven by *real* service times:
//! request arrivals follow a seeded Poisson process on a virtual clock,
//! while every batch execution is a real engine call whose measured
//! wallclock advances that clock. Latency percentiles therefore reflect
//! genuine compute + queueing behaviour, reproducibly.

use crate::algo::Assignment;
use crate::cost::{CostOracle, GraphCost};
use crate::graph::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Total requests to serve.
    pub requests: usize,
    /// Maximum batch size the dispatcher may form.
    pub batch_max: usize,
    /// Mean arrival rate (requests/second) of the Poisson process.
    pub arrival_rate_hz: f64,
    /// How long the dispatcher waits to fill a batch once one request is
    /// pending, seconds (0 = greedy: serve whatever is queued).
    pub max_wait_s: f64,
    /// RNG seed for arrivals and request payloads.
    pub seed: u64,
    /// Input tensor shape per request.
    pub input_shape: Vec<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 64,
            batch_max: 4,
            arrival_rate_hz: 500.0,
            max_wait_s: 0.002,
            seed: 2026,
            input_shape: vec![1, 3, 32, 32],
        }
    }
}

/// Per-request accounting (times on the virtual clock, seconds).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub id: usize,
    pub arrival_s: f64,
    pub start_s: f64,
    pub done_s: f64,
    pub batch_size: usize,
}

impl RequestRecord {
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }

    pub fn queue_delay_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// Aggregated serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub records: Vec<RequestRecord>,
    /// Total virtual time from first arrival to last completion.
    pub span_s: f64,
    /// Real wallclock spent inside the engine.
    pub busy_s: f64,
    pub batches: usize,
    /// The cost oracle's estimate for the served plan (per inference),
    /// when serving went through [`serve_plan`] with a shared oracle.
    pub plan_cost: Option<GraphCost>,
}

impl ServeReport {
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.records.iter().map(RequestRecord::latency_s).collect::<Vec<_>>())
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.span_s > 0.0 {
            self.records.len() as f64 / self.span_s
        } else {
            0.0
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches > 0 {
            self.records.len() as f64 / self.batches as f64
        } else {
            0.0
        }
    }
}

/// Run the serving loop. `exec_batch` performs one real inference batch
/// (one tensor per request) and returns one output per request; its
/// measured wallclock is the service time on the virtual clock.
pub fn serve<F>(cfg: &ServeConfig, mut exec_batch: F) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    anyhow::ensure!(cfg.requests > 0, "requests must be > 0");
    anyhow::ensure!(cfg.batch_max > 0, "batch_max must be > 0");
    anyhow::ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be > 0");

    let mut rng = Rng::seed_from(cfg.seed);
    // Poisson arrivals: exponential inter-arrival times.
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for _ in 0..cfg.requests {
        t += -rng.f64().max(1e-12).ln() / cfg.arrival_rate_hz;
        arrivals.push(t);
    }

    let mut records: Vec<RequestRecord> = Vec::with_capacity(cfg.requests);
    let mut clock = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut batches = 0usize;
    let mut next = 0usize; // next unserved request index

    while next < cfg.requests {
        // Advance to the first pending arrival if idle.
        clock = clock.max(arrivals[next]);
        // Optional batching wait: let the window fill.
        let deadline = clock + cfg.max_wait_s;
        let mut end = next + 1;
        while end < cfg.requests && end - next < cfg.batch_max && arrivals[end] <= deadline {
            end += 1;
        }
        // If we waited for later arrivals, the batch starts at the later of
        // (deadline reached, last included arrival).
        if end - next > 1 {
            clock = clock.max(arrivals[end - 1]);
        }
        let batch_ids: Vec<usize> = (next..end).collect();
        let inputs: Vec<Tensor> = batch_ids
            .iter()
            .map(|_| Tensor::rand(&cfg.input_shape, &mut rng, -1.0, 1.0))
            .collect();

        let t0 = std::time::Instant::now();
        let outputs = exec_batch(&inputs)?;
        let service = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            outputs.len() == inputs.len(),
            "exec_batch returned {} outputs for {} requests",
            outputs.len(),
            inputs.len()
        );
        busy_s += service;
        batches += 1;
        let start = clock;
        clock += service;
        for &id in &batch_ids {
            records.push(RequestRecord {
                id,
                arrival_s: arrivals[id],
                start_s: start,
                done_s: clock,
                batch_size: batch_ids.len(),
            });
        }
        next = end;
    }

    let first = arrivals.first().copied().unwrap_or(0.0);
    Ok(ServeReport { span_s: clock - first, busy_s, batches, records, plan_cost: None })
}

/// Serve an optimized `(graph, assignment)` plan, annotating the report
/// with the shared [`CostOracle`]'s cost estimate for that plan.
///
/// This is the optimize→serve composition point: the caller hands in the
/// *same* oracle the optimizer searched with (warm profile DB), so the
/// estimate is exactly what the search minimized. Pricing uses only
/// already-available profiles — a cold oracle yields `plan_cost: None`
/// rather than blocking serving startup on measurements.
pub fn serve_plan<F>(
    cfg: &ServeConfig,
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    exec_batch: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    let plan_cost = oracle.cached_cost(g, a)?;
    let mut report = serve(cfg, exec_batch)?;
    report.plan_cost = plan_cost;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_exec(inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        // trivial real work: elementwise relu per request
        Ok(inputs.iter().map(crate::tensor::ops::relu).collect())
    }

    fn cfg(requests: usize, batch: usize) -> ServeConfig {
        ServeConfig {
            requests,
            batch_max: batch,
            arrival_rate_hz: 10_000.0,
            max_wait_s: 0.001,
            seed: 1,
            input_shape: vec![1, 3, 8, 8],
        }
    }

    #[test]
    fn serves_all_requests_in_order() {
        let report = serve(&cfg(50, 4), fast_exec).unwrap();
        assert_eq!(report.records.len(), 50);
        let ids: Vec<usize> = report.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn time_accounting_consistent() {
        let report = serve(&cfg(40, 4), fast_exec).unwrap();
        for r in &report.records {
            assert!(r.start_s >= r.arrival_s - 1e-12, "start before arrival");
            assert!(r.done_s > r.start_s, "done before start");
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
        }
        assert!(report.throughput_rps() > 0.0);
        assert!(report.latency_summary().p95 >= report.latency_summary().p50);
    }

    #[test]
    fn batching_kicks_in_under_load() {
        // arrival rate far above service rate + generous window -> batches form
        let report = serve(&cfg(64, 8), fast_exec).unwrap();
        assert!(report.mean_batch_size() > 1.0, "mean batch {}", report.mean_batch_size());
        assert!(report.batches < 64);
    }

    #[test]
    fn batch_max_one_disables_batching() {
        let report = serve(&cfg(30, 1), fast_exec).unwrap();
        assert_eq!(report.batches, 30);
        assert!(report.records.iter().all(|r| r.batch_size == 1));
    }

    #[test]
    fn deterministic_arrivals() {
        let a = serve(&cfg(20, 4), fast_exec).unwrap();
        let b = serve(&cfg(20, 4), fast_exec).unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }

    #[test]
    fn serve_plan_shares_oracle_estimate() {
        use crate::graph::{OpKind, PortRef};
        let oracle = crate::cost::CostOracle::offline_default();
        let mut g = crate::graph::Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let r = g.add1(OpKind::Relu, &[x], "r");
        g.outputs = vec![PortRef::of(r)];
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());

        // Cold oracle: serving must not trigger any profiling; no estimate.
        let cold = serve_plan(&cfg(10, 2), &oracle, &g, &a, fast_exec).unwrap();
        assert_eq!(cold.plan_cost, None);
        assert_eq!(oracle.profiled_total(), 0);

        // Warm the oracle (as `serve --optimize` or a loaded DB would).
        oracle.table_for(&g).unwrap();
        let before = oracle.profiled_total();
        let report = serve_plan(&cfg(10, 2), &oracle, &g, &a, fast_exec).unwrap();
        let est = report.plan_cost.expect("estimate attached once warm");
        assert!(est.time_ms > 0.0 && est.energy_j > 0.0);
        // Pricing the plan measured nothing new.
        assert_eq!(oracle.profiled_total(), before);
    }

    #[test]
    fn exec_errors_propagate() {
        let r = serve(&cfg(5, 2), |_| anyhow::bail!("backend down"));
        assert!(r.is_err());
    }

    #[test]
    fn output_arity_checked() {
        let r = serve(&cfg(5, 2), |_| Ok(vec![]));
        assert!(r.is_err());
    }
}
