//! Serving coordinator: a single-node request loop with Poisson arrivals,
//! FIFO queueing, dynamic batching, and a self-tuning feedback loop — the
//! L3 driver that puts optimized `(G, A)` plans behind a request interface
//! (`eadgo serve`).
//!
//! The loop is a discrete-event simulation driven by *real* service times:
//! request arrivals follow a seeded Poisson process on a virtual clock,
//! while every batch execution is a real engine call whose measured
//! wallclock advances that clock (or, under [`ServiceModel::Virtual`], a
//! deterministic modeled service time). Latency percentiles therefore
//! reflect genuine compute + queueing behaviour, reproducibly.
//!
//! **The entry point is [`ServeSession`]**: one builder that composes a
//! plan source (a fixed plan, a Pareto frontier, or explicit operating
//! points), an adaptive policy, and — the feedback loop — serve-time
//! telemetry writeback, drift detection, and background re-search:
//!
//! ```text
//! ServeSession::new(&cfg)
//!     .oracle(&oracle)          // cost estimates + feedback writeback
//!     .surface(&frontier)       // or .plan(..) / .operating_points(..)
//!     .adaptive(policy)         // load-adaptive plan selection
//!     .feedback(fb)             // telemetry -> drift -> re-search -> swap
//!     .run(exec)?
//! ```
//!
//! With feedback enabled the session closes the optimize→serve loop:
//! measured batch times are attributed back onto the cost-database rows
//! the active plan exercised
//! ([`CostOracle::observe_plan`](crate::cost::CostOracle::observe_plan)),
//! a [`DriftDetector`] watches the predicted-vs-observed gap with
//! hysteresis, and on sustained drift the session re-prices (or fully
//! re-searches, via [`ResearchConfig`]) the surface against the corrected
//! oracle and **hot-swaps** the controller's frontier without pausing the
//! request loop. Every drift transition and swap is recorded in the
//! [`ServeReport`].
//!
//! The four pre-session entry points — [`serve`], [`serve_plan`],
//! [`serve_frontier`], [`serve_operating_points`] — remain as deprecated
//! thin shims over [`ServeSession`]; with feedback off the session loop
//! is behaviourally identical to them (bit-identical under
//! [`ServiceModel::Virtual`], where no wallclock enters the simulation).
//!
//! Arrival traces are single-rate Poisson by default, or piecewise-rate
//! (bursty) when [`ServeConfig::phases`] is set — see [`trace`].
//!
//! [`PlanFrontier`]: crate::search::PlanFrontier

/// Load-adaptive plan selection over a Pareto frontier.
pub mod controller;
/// Deterministic fault injection: typed fault plans and degradation events.
pub mod faults;
/// Drift detection for the serve-time feedback loop.
pub mod feedback;
/// The serve-session builder and its unified serving loop.
pub mod session;
/// Seeded single-rate and piecewise-rate (bursty) Poisson arrival traces.
pub mod trace;

pub use controller::{AdaptiveConfig, FrontierController, PlanSwitchEvent};
pub use faults::{DegradeCause, DegradeEvent, FaultEvent, FaultKind, FaultPlan, ShedEvent};
pub use feedback::{DriftDetector, DriftEvent, DriftKind, FeedbackConfig, HotSwapEvent};
pub use session::{ResearchConfig, ServeSession};
pub use trace::RatePhase;

use crate::algo::Assignment;
use crate::cost::{CostOracle, GraphCost};
use crate::graph::Graph;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// How a batch's service time on the virtual clock is determined.
///
/// The wallclock model is the historical behaviour: real engine time
/// drives the simulation, so latency numbers reflect the host. The
/// virtual model makes the whole serve run a deterministic function of
/// the configuration — the byte-identity contract between [`ServeSession`]
/// and the legacy entry points is stated (and tested) under it, and the
/// CLI's `--truth-db` drift ablation uses it to play back a known ground
/// truth against a mis-calibrated cost database.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ServiceModel {
    /// Measured engine wallclock is the service time (historical
    /// behaviour; non-deterministic across runs).
    #[default]
    Wallclock,
    /// Deterministic service: a batch of `m` requests on plan `p` takes
    /// `per_batch_ms[p][min(m, len) - 1] * scale_s_per_ms` seconds of
    /// virtual time regardless of engine wallclock (the engine still
    /// runs; its wallclock is ignored). Plan indices past the table are
    /// clamped to the last row, so plans adopted by a full re-search
    /// reuse the nearest priced row instead of panicking.
    Virtual {
        /// Ground-truth batch latency per plan: `per_batch_ms[p][m - 1]`
        /// is the whole-batch latency of plan `p` at batch size `m`, ms.
        per_batch_ms: Vec<Vec<f64>>,
        /// Seconds of virtual service per modeled millisecond.
        scale_s_per_ms: f64,
    },
}

impl ServiceModel {
    /// Service time (seconds) of a batch of `m` requests executed on plan
    /// `plan`, given the measured engine wallclock `wall_s`.
    pub fn service_s(&self, plan: usize, m: usize, wall_s: f64) -> f64 {
        match self {
            ServiceModel::Wallclock => wall_s,
            ServiceModel::Virtual { per_batch_ms, scale_s_per_ms } => {
                let row = &per_batch_ms[plan.min(per_batch_ms.len() - 1)];
                row[m.min(row.len()) - 1] * scale_s_per_ms
            }
        }
    }
}

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Total requests to serve.
    pub requests: usize,
    /// Maximum batch size the dispatcher may form.
    pub batch_max: usize,
    /// Mean arrival rate (requests/second) of the Poisson process.
    pub arrival_rate_hz: f64,
    /// How long the dispatcher waits to fill a batch once one request is
    /// pending, seconds (0 = greedy: serve whatever is queued).
    pub max_wait_s: f64,
    /// RNG seed for arrivals and request payloads.
    pub seed: u64,
    /// Input tensor shape per request.
    pub input_shape: Vec<usize>,
    /// Piecewise-rate arrival phases for bursty traces. Empty = the
    /// single-rate Poisson process (`arrival_rate_hz` × `requests`,
    /// bit-identical to the pre-trace behavior); non-empty = the phases
    /// define both the rates and the total request count, and
    /// `requests`/`arrival_rate_hz` are ignored.
    pub phases: Vec<RatePhase>,
    /// How batch service time on the virtual clock is determined
    /// (measured engine wallclock by default).
    pub service: ServiceModel,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 64,
            batch_max: 4,
            arrival_rate_hz: 500.0,
            max_wait_s: 0.002,
            seed: 2026,
            input_shape: vec![1, 3, 32, 32],
            phases: Vec::new(),
            service: ServiceModel::Wallclock,
        }
    }
}

impl ServeConfig {
    /// Total requests this config serves: the sum of phase sizes when a
    /// bursty trace is configured, else `requests`.
    pub fn effective_requests(&self) -> usize {
        if self.phases.is_empty() {
            self.requests
        } else {
            self.phases.iter().map(|p| p.requests).sum()
        }
    }

    /// Draw the arrival trace for this config from `rng`. Single-rate
    /// configs reproduce the historical inline draw bit-for-bit.
    pub(crate) fn arrival_trace(&self, rng: &mut Rng) -> anyhow::Result<Vec<f64>> {
        if self.phases.is_empty() {
            anyhow::ensure!(self.requests > 0, "requests must be > 0");
            anyhow::ensure!(self.arrival_rate_hz > 0.0, "arrival rate must be > 0");
            Ok(trace::poisson_arrivals(rng, 0.0, self.arrival_rate_hz, self.requests))
        } else {
            for p in &self.phases {
                anyhow::ensure!(
                    p.rate_hz > 0.0 && p.rate_hz.is_finite(),
                    "phase rate must be a positive finite rate, got {}",
                    p.rate_hz
                );
                anyhow::ensure!(p.requests > 0, "phase request count must be > 0");
            }
            Ok(trace::piecewise_arrivals(rng, &self.phases))
        }
    }
}

/// Per-request accounting (times on the virtual clock, seconds).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    /// Request index in arrival order.
    pub id: usize,
    /// Arrival time on the virtual clock.
    pub arrival_s: f64,
    /// When the batch containing this request started executing.
    pub start_s: f64,
    /// When the batch completed.
    pub done_s: f64,
    /// Size of the batch that served this request.
    pub batch_size: usize,
    /// Frontier index of the plan that served this request (0 for
    /// single-plan serving; the *operating-point* index under
    /// operating-point serving).
    pub plan: usize,
    /// Surface epoch that served this request: 0 until the feedback
    /// loop's first hot-swap, then incremented per swap (always 0 with
    /// feedback off).
    pub epoch: usize,
}

impl RequestRecord {
    /// End-to-end latency: completion minus arrival.
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }

    /// Time spent queued before execution started.
    pub fn queue_delay_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// Aggregated serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request accounting, in arrival order.
    pub records: Vec<RequestRecord>,
    /// Total virtual time from first arrival to last completion.
    pub span_s: f64,
    /// Virtual time spent in service (equals real engine wallclock under
    /// [`ServiceModel::Wallclock`]).
    pub busy_s: f64,
    /// Number of batches executed.
    pub batches: usize,
    /// The cost oracle's estimate for the served plan (per inference),
    /// when serving a single plan with a shared oracle.
    pub plan_cost: Option<GraphCost>,
    /// Plan switches taken by the [`FrontierController`] (empty for
    /// fixed-plan serving).
    pub switches: Vec<PlanSwitchEvent>,
    /// Oracle-estimated energy per request in mJ, averaged over the plans
    /// that actually served each request (`None` when no estimate is
    /// available).
    pub energy_mj_per_request: Option<f64>,
    /// Drift state transitions observed by the feedback loop (empty with
    /// feedback off).
    pub drift_events: Vec<DriftEvent>,
    /// Hot-swaps of the serving surface taken by the feedback loop
    /// (empty with feedback off).
    pub swaps: Vec<HotSwapEvent>,
    /// Distinct measured cost rows accumulated by telemetry writeback
    /// (0 with feedback off).
    pub feedback_rows: usize,
    /// Injected faults that activated during the run (empty without a
    /// fault plan; serialized only when non-empty, so fault-free reports
    /// stay byte-identical to the pre-fault format).
    pub faults: Vec<FaultEvent>,
    /// Graceful-degradation actions taken by the session (device-loss
    /// masking, contingency activation, clock-cap re-pricing, survived
    /// re-search failures). Serialized only when non-empty.
    pub degrades: Vec<DegradeEvent>,
    /// Admitted requests shed because transient-error retries would have
    /// blown their deadline budget. Serialized only when non-empty.
    pub sheds: Vec<ShedEvent>,
}

impl ServeReport {
    /// Latency summary (p50/p95/p99/mean) over all requests.
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.records.iter().map(RequestRecord::latency_s).collect::<Vec<_>>())
    }

    /// Served throughput over the serving span (first arrival to last
    /// completion), requests/second.
    pub fn throughput_rps(&self) -> f64 {
        if self.span_s > 0.0 {
            self.records.len() as f64 / self.span_s
        } else {
            0.0
        }
    }

    /// Oracle-estimated served requests per joule (the ablation's energy
    /// efficiency metric; `None` without an energy estimate).
    pub fn requests_per_joule(&self) -> Option<f64> {
        match self.energy_mj_per_request {
            Some(mj) if mj > 0.0 => Some(1000.0 / mj),
            _ => None,
        }
    }

    /// Average formed batch size.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches > 0 {
            self.records.len() as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    /// Requests served per frontier plan index (length = max plan + 1).
    pub fn plan_histogram(&self) -> Vec<usize> {
        let n = self.records.iter().map(|r| r.plan + 1).max().unwrap_or(0);
        let mut counts = vec![0usize; n];
        for r in &self.records {
            counts[r.plan] += 1;
        }
        counts
    }

    /// Human-readable plan distribution, e.g. `"p0×12 p2×52"` (plans that
    /// served no request are omitted).
    pub fn plan_distribution(&self) -> String {
        self.plan_histogram()
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(i, n)| format!("p{i}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Deterministic JSON rendering of the complete report (sorted keys,
    /// shortest-round-trip floats). Under [`ServiceModel::Virtual`] two
    /// identical configurations produce byte-identical renderings — the
    /// byte-identity contract between [`ServeSession`] and the legacy
    /// entry points compares these.
    pub fn to_json(&self) -> Json {
        let cost_json = |c: &GraphCost| {
            let mut j = Json::obj();
            j.set("time_ms", c.time_ms).set("energy_j", c.energy_j).set("freq", c.freq.0 as usize);
            j
        };
        let mut j = Json::obj();
        j.set("span_s", self.span_s)
            .set("busy_s", self.busy_s)
            .set("batches", self.batches)
            .set("feedback_rows", self.feedback_rows)
            .set(
                "energy_mj_per_request",
                self.energy_mj_per_request.map(Json::Num).unwrap_or(Json::Null),
            )
            .set("plan_cost", self.plan_cost.as_ref().map(cost_json).unwrap_or(Json::Null))
            .set(
                "records",
                self.records
                    .iter()
                    .map(|r| {
                        let mut o = Json::obj();
                        o.set("id", r.id)
                            .set("arrival_s", r.arrival_s)
                            .set("start_s", r.start_s)
                            .set("done_s", r.done_s)
                            .set("batch_size", r.batch_size)
                            .set("plan", r.plan)
                            .set("epoch", r.epoch);
                        o
                    })
                    .collect::<Vec<_>>(),
            )
            .set(
                "switches",
                self.switches
                    .iter()
                    .map(|s| {
                        let mut o = Json::obj();
                        o.set("at_s", s.at_s)
                            .set("from", s.from)
                            .set("to", s.to)
                            .set("queue_depth", s.queue_depth)
                            .set("rate_hz", s.rate_hz);
                        o
                    })
                    .collect::<Vec<_>>(),
            )
            .set(
                "drift_events",
                self.drift_events
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("at_s", e.at_s)
                            .set("plan", e.plan)
                            .set("rel_err", e.rel_err)
                            .set("ratio", e.ratio)
                            .set(
                                "kind",
                                match e.kind {
                                    DriftKind::Detected => "detected",
                                    DriftKind::Cleared => "cleared",
                                },
                            );
                        o
                    })
                    .collect::<Vec<_>>(),
            )
            .set(
                "swaps",
                self.swaps
                    .iter()
                    .map(|s| {
                        let mut o = Json::obj();
                        o.set("at_s", s.at_s)
                            .set("epoch", s.epoch)
                            .set("researched", s.researched)
                            .set("energy_mj_before", s.energy_mj_before)
                            .set("energy_mj_after", s.energy_mj_after);
                        o
                    })
                    .collect::<Vec<_>>(),
            );
        // Fault-era arrays appear only when something happened: a run with
        // no fault plan (and no surviving-failure degrades) serializes
        // byte-identically to the pre-fault report format.
        if !self.faults.is_empty() {
            j.set("faults", self.faults.iter().map(FaultEvent::to_json).collect::<Vec<_>>());
        }
        if !self.degrades.is_empty() {
            j.set("degrades", self.degrades.iter().map(DegradeEvent::to_json).collect::<Vec<_>>());
        }
        if !self.sheds.is_empty() {
            j.set("sheds", self.sheds.iter().map(ShedEvent::to_json).collect::<Vec<_>>());
        }
        j
    }

    /// Fraction of admitted requests actually served: `served / (served +
    /// shed)`. 1.0 for a run that shed nothing (including every fault-free
    /// run); this is the bench payload's `serve.availability_under_faults`.
    pub fn availability(&self) -> f64 {
        let total = self.records.len() + self.sheds.len();
        if total == 0 {
            return 1.0;
        }
        self.records.len() as f64 / total as f64
    }
}

/// Run the serving loop over a single plan. `exec_batch` performs one
/// real inference batch (one tensor per request) and returns one output
/// per request; its measured wallclock is the service time on the
/// virtual clock.
#[deprecated(since = "0.2.0", note = "use serve::ServeSession::new(cfg).run(..)")]
pub fn serve<F>(cfg: &ServeConfig, mut exec_batch: F) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    ServeSession::new(cfg).run(move |_, batch| exec_batch(batch))
}

/// Serve an optimized `(graph, assignment)` plan, annotating the report
/// with the shared [`CostOracle`]'s cost estimate for that plan.
///
/// This is the optimize→serve composition point: the caller hands in the
/// *same* oracle the optimizer searched with (warm profile DB), so the
/// estimate is exactly what the search minimized. Pricing uses only
/// already-available profiles — a cold oracle yields `plan_cost: None`
/// rather than blocking serving startup on measurements. See
/// [`ServeSession`] for the builder form and a runnable example.
#[deprecated(
    since = "0.2.0",
    note = "use serve::ServeSession::new(cfg).oracle(oracle).plan(g, a).run(..)"
)]
pub fn serve_plan<F>(
    cfg: &ServeConfig,
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    mut exec_batch: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    ServeSession::new(cfg).oracle(oracle).plan(g, a).run(move |_, batch| exec_batch(batch))
}

/// Serve a Pareto frontier of plans adaptively: a [`FrontierController`]
/// built over `plan_costs` (fastest-first, as returned by
/// [`PlanFrontier::costs`](crate::search::PlanFrontier::costs)) picks the
/// active plan per batch; `exec` executes one batch under the given
/// frontier index. The report records per-request plans, every switch
/// event, and — when every plan has a positive energy estimate — the
/// oracle-estimated energy per request actually spent.
#[deprecated(
    since = "0.2.0",
    note = "use serve::ServeSession::new(cfg).frontier_costs(costs).adaptive(policy).run(..)"
)]
pub fn serve_frontier<F>(
    cfg: &ServeConfig,
    plan_costs: &[GraphCost],
    policy: &AdaptiveConfig,
    exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    ServeSession::new(cfg).frontier_costs(plan_costs).adaptive(policy.clone()).run(exec)
}

/// One (plan, batch) point on a batched frontier: the frontier plan index
/// to execute and the batch size the dispatcher targets while the point
/// is active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperatingPoint {
    /// Plan index into the price grid's outer axis (and the `exec`
    /// closure's first argument).
    pub plan: usize,
    /// Target batch size at this point (>= 1; capped by
    /// [`ServeConfig::batch_max`] at serve time).
    pub batch: usize,
}

/// Serve a batched frontier of (plan, batch) operating points with
/// deadline-aware batch formation and admission control.
///
/// `grid[p][m - 1]` is the oracle's **full-batch** cost of executing plan
/// `p` at batch size `m` (as priced by
/// [`price_plan_at_batch`](crate::search::price_plan_at_batch)); each
/// plan's grid must cover every batch size its operating points can form.
/// A [`FrontierController`] in operating-point mode picks the active
/// point per batch from the live queue depth and EWMA arrival rate.
///
/// Two properties distinguish this loop from [`serve_frontier`]'s greedy
/// batching:
/// - **Admission control**: the batch-fill horizon is anchored at the
///   *oldest pending request's arrival* — a request that already waited
///   `w` seconds gets at most `max_wait_s - w` more, so backlogged
///   batches never stall further just because a big-batch point is
///   active.
/// - **Honest partial-batch pricing**: a formed batch of `m` requests is
///   charged `grid[plan][m - 1]`, not the active point's ideal amortized
///   cost — underfilled batches earn no phantom efficiency.
///
/// [`RequestRecord::plan`] and the switch log index into `ops` (operating
/// points), while `exec` receives the underlying *plan* index.
#[deprecated(
    since = "0.2.0",
    note = "use serve::ServeSession::new(cfg).operating_points(grid, ops).adaptive(policy).run(..)"
)]
pub fn serve_operating_points<F>(
    cfg: &ServeConfig,
    grid: &[Vec<GraphCost>],
    ops: &[OperatingPoint],
    policy: &AdaptiveConfig,
    exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    ServeSession::new(cfg).operating_points(grid, ops).adaptive(policy.clone()).run(exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn fast_exec(inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        // trivial real work: elementwise relu per request
        Ok(inputs.iter().map(crate::tensor::ops::relu).collect())
    }

    fn cfg(requests: usize, batch: usize) -> ServeConfig {
        ServeConfig {
            requests,
            batch_max: batch,
            arrival_rate_hz: 10_000.0,
            max_wait_s: 0.001,
            seed: 1,
            input_shape: vec![1, 3, 8, 8],
            phases: Vec::new(),
            service: ServiceModel::Wallclock,
        }
    }

    /// Plain single-plan serving through the session builder.
    fn run_plain(c: &ServeConfig) -> anyhow::Result<ServeReport> {
        ServeSession::new(c).run(|_, batch| fast_exec(batch))
    }

    #[test]
    fn serves_all_requests_in_order() {
        let report = run_plain(&cfg(50, 4)).unwrap();
        assert_eq!(report.records.len(), 50);
        let ids: Vec<usize> = report.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
        assert!(report.records.iter().all(|r| r.plan == 0 && r.epoch == 0));
        assert!(report.switches.is_empty());
        assert!(report.drift_events.is_empty() && report.swaps.is_empty());
        assert_eq!(report.feedback_rows, 0);
    }

    #[test]
    fn time_accounting_consistent() {
        let report = run_plain(&cfg(40, 4)).unwrap();
        for r in &report.records {
            assert!(r.start_s >= r.arrival_s - 1e-12, "start before arrival");
            assert!(r.done_s > r.start_s, "done before start");
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
        }
        assert!(report.throughput_rps() > 0.0);
        assert!(report.latency_summary().p95 >= report.latency_summary().p50);
        assert!(report.latency_summary().p99 >= report.latency_summary().p95);
    }

    #[test]
    fn batching_kicks_in_under_load() {
        // arrival rate far above service rate + generous window -> batches form
        let report = run_plain(&cfg(64, 8)).unwrap();
        assert!(report.mean_batch_size() > 1.0, "mean batch {}", report.mean_batch_size());
        assert!(report.batches < 64);
    }

    #[test]
    fn batch_max_one_disables_batching() {
        let report = run_plain(&cfg(30, 1)).unwrap();
        assert_eq!(report.batches, 30);
        assert!(report.records.iter().all(|r| r.batch_size == 1));
    }

    #[test]
    fn deterministic_arrivals() {
        let a = run_plain(&cfg(20, 4)).unwrap();
        let b = run_plain(&cfg(20, 4)).unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }

    #[test]
    fn serve_plan_shares_oracle_estimate() {
        use crate::graph::{OpKind, PortRef};
        let oracle = crate::cost::CostOracle::offline_default();
        let mut g = crate::graph::Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let r = g.add1(OpKind::Relu, &[x], "r");
        g.outputs = vec![PortRef::of(r)];
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());
        let c = cfg(10, 2);
        let run = |c: &ServeConfig| {
            ServeSession::new(c).oracle(&oracle).plan(&g, &a).run(|_, b| fast_exec(b))
        };

        // Cold oracle: serving must not trigger any profiling; no estimate.
        let cold = run(&c).unwrap();
        assert_eq!(cold.plan_cost, None);
        assert_eq!(cold.energy_mj_per_request, None);
        assert_eq!(oracle.profiled_total(), 0);

        // Warm the oracle (as `serve --optimize` or a loaded DB would).
        oracle.table_for(&g).unwrap();
        let before = oracle.profiled_total();
        let report = run(&c).unwrap();
        let est = report.plan_cost.expect("estimate attached once warm");
        assert!(est.time_ms > 0.0 && est.energy_j > 0.0);
        assert_eq!(report.energy_mj_per_request, Some(est.energy_j));
        // Pricing the plan measured nothing new.
        assert_eq!(oracle.profiled_total(), before);
    }

    #[test]
    fn exec_errors_propagate() {
        let c = cfg(5, 2);
        let r = ServeSession::new(&c).run(|_, _: &[Tensor]| anyhow::bail!("backend down"));
        assert!(r.is_err());
    }

    #[test]
    fn output_arity_checked() {
        let c = cfg(5, 2);
        let r = ServeSession::new(&c).run(|_, _| Ok(vec![]));
        assert!(r.is_err());
    }

    fn frontier_costs() -> Vec<GraphCost> {
        vec![
            GraphCost { time_ms: 1.0, energy_j: 300.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 2.0, energy_j: 180.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 4.0, energy_j: 100.0, freq: FreqId::NOMINAL },
        ]
    }

    #[test]
    fn adaptive_light_load_serves_energy_plan() {
        // 50 req/s against sub-millisecond service: utilization ~0 — the
        // controller must park on the energy-optimal plan (index 2).
        let cfg = ServeConfig { arrival_rate_hz: 50.0, ..cfg(32, 4) };
        let report = ServeSession::new(&cfg)
            .frontier_costs(&frontier_costs())
            .adaptive(AdaptiveConfig::default())
            .run(|_, batch| fast_exec(batch))
            .unwrap();
        assert!(report.records.iter().all(|r| r.plan == 2), "{:?}", report.plan_histogram());
        assert!(report.switches.is_empty());
        assert_eq!(report.energy_mj_per_request, Some(100.0));
    }

    #[test]
    fn adaptive_overload_switches_toward_latency_plan() {
        // Execution busy-spins 100µs per request per estimated sim-ms, so
        // at 10k req/s every plan but the fastest is overloaded (util ≥ 2):
        // the queue spikes past the panic threshold within a batch or two
        // and the controller must abandon the energy plan.
        let costs = frontier_costs();
        let c = cfg(96, 4);
        let report = ServeSession::new(&c)
            .frontier_costs(&costs)
            .adaptive(AdaptiveConfig::default())
            .run(|plan, batch| {
                let per_req = 100e-6 * costs[plan].time_ms;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < per_req * batch.len() as f64 {}
                Ok(batch.to_vec())
            })
            .unwrap();
        assert!(!report.switches.is_empty(), "overload must trigger switches");
        assert_eq!(report.records.last().unwrap().plan, 0, "{:?}", report.plan_histogram());
        // Energy accounting reflects the mix of plans actually used: the
        // first batch always runs the energy plan (100 mJ), the overloaded
        // tail the latency plan (300 mJ).
        let e = report.energy_mj_per_request.unwrap();
        assert!(e > 100.0 && e < 300.0, "expected a plan mix, got {e}");
        // Switch log is consistent with the per-record plans.
        for w in report.switches.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
            assert_eq!(w[1].from, w[0].to);
        }
    }

    #[test]
    fn single_point_frontier_acts_like_fixed_plan() {
        let costs = vec![GraphCost { time_ms: 1.0, energy_j: 42.0, freq: FreqId::NOMINAL }];
        let c = cfg(20, 4);
        let report = ServeSession::new(&c)
            .frontier_costs(&costs)
            .adaptive(AdaptiveConfig::default())
            .run(|plan, batch| {
                assert_eq!(plan, 0);
                fast_exec(batch)
            })
            .unwrap();
        assert!(report.switches.is_empty());
        assert_eq!(report.energy_mj_per_request, Some(42.0));
        assert_eq!(report.plan_histogram(), vec![20]);
    }

    #[test]
    fn frontier_loop_matches_plain_serve_arrivals() {
        // The generalized loop must not perturb the RNG stream: arrivals
        // (and thus records) line up with plain serving under any plan mix.
        let a = run_plain(&cfg(24, 4)).unwrap();
        let c = cfg(24, 4);
        let b = ServeSession::new(&c)
            .frontier_costs(&frontier_costs())
            .adaptive(AdaptiveConfig::default())
            .run(|_, batch| fast_exec(batch))
            .unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }

    #[test]
    fn bursty_trace_is_deterministic_and_ordered() {
        let cfg = ServeConfig {
            phases: vec![RatePhase::new(200.0, 16), RatePhase::new(5_000.0, 32)],
            ..cfg(1, 4)
        };
        let a = run_plain(&cfg).unwrap();
        let b = run_plain(&cfg).unwrap();
        assert_eq!(a.records.len(), 48, "phases override `requests`");
        assert_eq!(cfg.effective_requests(), 48);
        let bits =
            |r: &ServeReport| r.records.iter().map(|x| x.arrival_s.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "same seed must draw the same bursty trace");
        assert!(a.records.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn invalid_phases_rejected() {
        let zero_rate = ServeConfig { phases: vec![RatePhase::new(0.0, 4)], ..cfg(8, 2) };
        assert!(run_plain(&zero_rate).is_err());
        let zero_reqs = ServeConfig { phases: vec![RatePhase::new(100.0, 0)], ..cfg(8, 2) };
        assert!(run_plain(&zero_reqs).is_err());
    }

    /// Per-plan batch price grids (batch 1..=8): plan 0 fast/hungry,
    /// plan 1 slow/frugal. Batch latency grows sublinearly, so energy per
    /// request amortizes with batch (launch-overhead-dominated regime).
    fn ops_grid() -> Vec<Vec<GraphCost>> {
        let price = |t1: f64, e1: f64| -> Vec<GraphCost> {
            (1..=8)
                .map(|m| {
                    let s = 0.875 + 0.125 * m as f64;
                    GraphCost { time_ms: t1 * s, energy_j: e1 * s, freq: FreqId::NOMINAL }
                })
                .collect()
        };
        vec![price(1.0, 300.0), price(4.0, 100.0)]
    }

    #[test]
    fn ops_light_load_parks_on_cheapest_point() {
        let cfg = ServeConfig { arrival_rate_hz: 50.0, ..cfg(32, 8) };
        let ops = [OperatingPoint { plan: 0, batch: 1 }, OperatingPoint { plan: 1, batch: 8 }];
        let report = ServeSession::new(&cfg)
            .operating_points(&ops_grid(), &ops)
            .adaptive(AdaptiveConfig::default())
            .run(|plan, b| {
                assert!(plan <= 1);
                fast_exec(b)
            })
            .unwrap();
        assert!(report.records.iter().all(|r| r.plan == 1), "{:?}", report.plan_histogram());
        assert!(report.switches.is_empty());
        // Honest partial-batch pricing: at 50 req/s no batch fills, so the
        // batched point earns no amortization — every batch is charged the
        // plan's batch-1 price (100 mJ), not the ideal 23.4 mJ/request.
        assert_eq!(report.energy_mj_per_request, Some(100.0));
        assert!((report.requests_per_joule().unwrap() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn ops_batch_wait_is_bounded_by_max_wait() {
        // Poisson @ 500/s with a 5 ms window and a batch-8 target: batches
        // form, but the oldest request in every batch waits at most
        // max_wait (plus engine wallclock, microscopic for fast_exec).
        let cfg = ServeConfig { arrival_rate_hz: 500.0, max_wait_s: 0.005, ..cfg(64, 8) };
        let ops = [OperatingPoint { plan: 1, batch: 8 }];
        let report = ServeSession::new(&cfg)
            .operating_points(&ops_grid(), &ops)
            .adaptive(AdaptiveConfig::default())
            .run(|_, b| fast_exec(b))
            .unwrap();
        assert!(report.mean_batch_size() > 1.5, "window must batch: {}", report.mean_batch_size());
        let mut seen_start = f64::NEG_INFINITY;
        for r in &report.records {
            if r.start_s != seen_start {
                // First record of each batch = its oldest request.
                seen_start = r.start_s;
                assert!(
                    r.queue_delay_s() <= cfg.max_wait_s + report.busy_s + 1e-9,
                    "oldest request in a batch waited {}s",
                    r.queue_delay_s()
                );
            }
        }
    }

    #[test]
    fn ops_bursty_load_batches_on_capacity_point() {
        // Calm → burst → calm. The batched point is both cheapest per
        // request and highest-capacity here, so the controller starts and
        // stays there; the burst fills its batches.
        let cfg = ServeConfig {
            phases: vec![
                RatePhase::new(100.0, 8),
                RatePhase::new(20_000.0, 80),
                RatePhase::new(100.0, 8),
            ],
            max_wait_s: 0.002,
            ..cfg(1, 8)
        };
        let grid = ops_grid();
        let ops = [OperatingPoint { plan: 0, batch: 1 }, OperatingPoint { plan: 1, batch: 8 }];
        let report = ServeSession::new(&cfg)
            .operating_points(&grid, &ops)
            .adaptive(AdaptiveConfig::default())
            .run(|plan, batch| {
                // Busy-spin 50 µs per estimated sim-ms of the formed batch.
                let per_batch = 50e-6 * grid[plan][batch.len() - 1].time_ms;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < per_batch {}
                Ok(batch.to_vec())
            })
            .unwrap();
        assert_eq!(report.records.len(), 96);
        assert!(report.records.iter().all(|r| r.plan == 1), "{:?}", report.plan_histogram());
        assert!(report.mean_batch_size() > 1.2, "burst must batch: {}", report.mean_batch_size());
    }

    #[test]
    fn ops_single_point_acts_like_fixed_plan() {
        let ops = [OperatingPoint { plan: 0, batch: 1 }];
        let c = cfg(20, 4);
        let report = ServeSession::new(&c)
            .operating_points(&ops_grid(), &ops)
            .adaptive(AdaptiveConfig::default())
            .run(|plan, b| {
                assert_eq!(plan, 0);
                fast_exec(b)
            })
            .unwrap();
        assert!(report.switches.is_empty());
        assert_eq!(report.batches, 20, "batch-1 target disables batching");
        assert_eq!(report.plan_histogram(), vec![20]);
        assert_eq!(report.energy_mj_per_request, Some(300.0));
    }

    #[test]
    fn ops_validation_rejects_bad_points() {
        let grid = ops_grid();
        let c = cfg(8, 4);
        let run = |c: &ServeConfig, ops: &[OperatingPoint]| {
            ServeSession::new(c)
                .operating_points(&grid, ops)
                .adaptive(AdaptiveConfig::default())
                .run(|_, b| fast_exec(b))
        };
        assert!(run(&c, &[]).is_err());
        assert!(run(&c, &[OperatingPoint { plan: 9, batch: 1 }]).is_err());
        assert!(run(&c, &[OperatingPoint { plan: 0, batch: 0 }]).is_err());
        // Effective batch (after the batch_max cap) must be priced.
        let wide = ServeConfig { batch_max: 16, ..c };
        assert!(run(&wide, &[OperatingPoint { plan: 0, batch: 9 }]).is_err());
    }

    /// A deterministic virtual service model over the 3-plan frontier:
    /// service = plan batch time × 1e-4 s/ms, so every run of the same
    /// configuration produces a byte-identical report.
    fn virtual_service() -> ServiceModel {
        ServiceModel::Virtual {
            per_batch_ms: frontier_costs()
                .iter()
                .map(|c| (1..=8).map(|m| c.time_ms * m as f64).collect())
                .collect(),
            scale_s_per_ms: 1e-4,
        }
    }

    #[test]
    fn virtual_service_is_fully_deterministic() {
        let cfg = ServeConfig { service: virtual_service(), ..cfg(40, 4) };
        let run = || {
            ServeSession::new(&cfg)
                .frontier_costs(&frontier_costs())
                .adaptive(AdaptiveConfig::default())
                .run(|_, b| fast_exec(b))
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact(),
            "virtual service must remove all wallclock from the report"
        );
        assert!(a.busy_s > 0.0);
    }

    #[test]
    fn fault_arrays_serialize_only_when_non_empty() {
        let cfg = ServeConfig { service: virtual_service(), ..cfg(16, 4) };
        let mut report = run_plain(&cfg).unwrap();
        let clean = report.to_json().to_string_compact();
        assert!(!clean.contains("\"faults\""), "fault-free reports carry no fault keys");
        assert!(!clean.contains("\"degrades\"") && !clean.contains("\"sheds\""));
        assert_eq!(report.availability(), 1.0);

        report.faults.push(faults::FaultEvent {
            at_s: 0.1,
            kind: faults::FaultKind::DeviceLost { device: crate::energysim::DeviceId::DLA },
        });
        report.sheds.push(faults::ShedEvent { at_s: 0.2, id: 3, retries: 3, waited_s: 0.05 });
        let dirty = report.to_json().to_string_compact();
        assert!(dirty.contains("\"faults\"") && dirty.contains("\"sheds\""));
        assert!(dirty.contains("\"device_lost\""));
        let served = report.records.len() as f64;
        assert!((report.availability() - served / (served + 1.0)).abs() < 1e-12);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_delegate_byte_identically() {
        // Under a virtual service model the legacy entry points and the
        // session builder must produce byte-identical reports (the shims
        // are thin delegates — this pins that contract).
        let cfg = ServeConfig { service: virtual_service(), ..cfg(32, 4) };
        let legacy =
            serve_frontier(&cfg, &frontier_costs(), &AdaptiveConfig::default(), |_, b| {
                fast_exec(b)
            })
            .unwrap();
        let session = ServeSession::new(&cfg)
            .frontier_costs(&frontier_costs())
            .adaptive(AdaptiveConfig::default())
            .run(|_, b| fast_exec(b))
            .unwrap();
        assert_eq!(legacy.to_json().to_string_compact(), session.to_json().to_string_compact());
        let plain_legacy = serve(&cfg, fast_exec).unwrap();
        let plain_session = run_plain(&cfg).unwrap();
        assert_eq!(
            plain_legacy.to_json().to_string_compact(),
            plain_session.to_json().to_string_compact()
        );
    }
}
