//! Serving coordinator: a single-node request loop with Poisson arrivals,
//! FIFO queueing, and dynamic batching — the L3 "thin driver" that puts the
//! optimized `(G, A)` behind a request interface (`eadgo serve`).
//!
//! The loop is a discrete-event simulation driven by *real* service times:
//! request arrivals follow a seeded Poisson process on a virtual clock,
//! while every batch execution is a real engine call whose measured
//! wallclock advances that clock. Latency percentiles therefore reflect
//! genuine compute + queueing behaviour, reproducibly.
//!
//! Three entry points, least to most capable:
//! - [`serve`] — one plan, one `exec_batch` closure.
//! - [`serve_plan`] — one plan, annotated with the shared
//!   [`CostOracle`]'s cost estimate for it.
//! - [`serve_frontier`] — a whole Pareto [`PlanFrontier`] of plans behind
//!   one loop: a [`FrontierController`] watches the live request rate and
//!   queue depth and switches the active plan (energy-optimal under light
//!   load, latency-optimal under pressure, with hysteresis), recording
//!   every switch in [`ServeReport::switches`].
//!
//! [`PlanFrontier`]: crate::search::PlanFrontier

/// Load-adaptive plan selection over a Pareto frontier.
pub mod controller;

pub use controller::{AdaptiveConfig, FrontierController, PlanSwitchEvent};

use crate::algo::Assignment;
use crate::cost::{CostOracle, GraphCost};
use crate::graph::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Total requests to serve.
    pub requests: usize,
    /// Maximum batch size the dispatcher may form.
    pub batch_max: usize,
    /// Mean arrival rate (requests/second) of the Poisson process.
    pub arrival_rate_hz: f64,
    /// How long the dispatcher waits to fill a batch once one request is
    /// pending, seconds (0 = greedy: serve whatever is queued).
    pub max_wait_s: f64,
    /// RNG seed for arrivals and request payloads.
    pub seed: u64,
    /// Input tensor shape per request.
    pub input_shape: Vec<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 64,
            batch_max: 4,
            arrival_rate_hz: 500.0,
            max_wait_s: 0.002,
            seed: 2026,
            input_shape: vec![1, 3, 32, 32],
        }
    }
}

/// Per-request accounting (times on the virtual clock, seconds).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    /// Request index in arrival order.
    pub id: usize,
    /// Arrival time on the virtual clock.
    pub arrival_s: f64,
    /// When the batch containing this request started executing.
    pub start_s: f64,
    /// When the batch completed.
    pub done_s: f64,
    /// Size of the batch that served this request.
    pub batch_size: usize,
    /// Frontier index of the plan that served this request (0 for
    /// single-plan serving).
    pub plan: usize,
}

impl RequestRecord {
    /// End-to-end latency: completion minus arrival.
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }

    /// Time spent queued before execution started.
    pub fn queue_delay_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// Aggregated serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request accounting, in arrival order.
    pub records: Vec<RequestRecord>,
    /// Total virtual time from first arrival to last completion.
    pub span_s: f64,
    /// Real wallclock spent inside the engine.
    pub busy_s: f64,
    /// Number of batches executed.
    pub batches: usize,
    /// The cost oracle's estimate for the served plan (per inference),
    /// when serving went through [`serve_plan`] with a shared oracle.
    pub plan_cost: Option<GraphCost>,
    /// Plan switches taken by the [`FrontierController`] (empty for
    /// fixed-plan serving).
    pub switches: Vec<PlanSwitchEvent>,
    /// Oracle-estimated energy per request in mJ, averaged over the plans
    /// that actually served each request (`None` when no estimate is
    /// available).
    pub energy_mj_per_request: Option<f64>,
}

impl ServeReport {
    /// Latency summary (p50/p95/p99/mean) over all requests.
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.records.iter().map(RequestRecord::latency_s).collect::<Vec<_>>())
    }

    /// Served throughput over the serving span (first arrival to last
    /// completion), requests/second.
    pub fn throughput_rps(&self) -> f64 {
        if self.span_s > 0.0 {
            self.records.len() as f64 / self.span_s
        } else {
            0.0
        }
    }

    /// Average formed batch size.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches > 0 {
            self.records.len() as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    /// Requests served per frontier plan index (length = max plan + 1).
    pub fn plan_histogram(&self) -> Vec<usize> {
        let n = self.records.iter().map(|r| r.plan + 1).max().unwrap_or(0);
        let mut counts = vec![0usize; n];
        for r in &self.records {
            counts[r.plan] += 1;
        }
        counts
    }

    /// Human-readable plan distribution, e.g. `"p0×12 p2×52"` (plans that
    /// served no request are omitted).
    pub fn plan_distribution(&self) -> String {
        self.plan_histogram()
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(i, n)| format!("p{i}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// The shared serving loop behind [`serve`] and [`serve_frontier`]: with
/// no controller every batch runs plan 0 and the behaviour (and RNG
/// stream) is bit-identical to the pre-frontier loop.
fn run_loop<F>(
    cfg: &ServeConfig,
    mut controller: Option<&mut FrontierController>,
    mut exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    anyhow::ensure!(cfg.requests > 0, "requests must be > 0");
    anyhow::ensure!(cfg.batch_max > 0, "batch_max must be > 0");
    anyhow::ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be > 0");

    let mut rng = Rng::seed_from(cfg.seed);
    // Poisson arrivals: exponential inter-arrival times.
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for _ in 0..cfg.requests {
        t += -rng.f64().max(1e-12).ln() / cfg.arrival_rate_hz;
        arrivals.push(t);
    }

    let mut records: Vec<RequestRecord> = Vec::with_capacity(cfg.requests);
    let mut clock = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut batches = 0usize;
    let mut next = 0usize; // next unserved request index

    while next < cfg.requests {
        // Advance to the first pending arrival if idle.
        clock = clock.max(arrivals[next]);
        // The controller decides on the live queue depth at this instant:
        // every request that has arrived but not been served.
        let plan = match controller.as_mut() {
            Some(c) => {
                let mut depth = 1usize;
                while next + depth < cfg.requests && arrivals[next + depth] <= clock {
                    depth += 1;
                }
                c.decide(clock, depth)
            }
            None => 0,
        };
        // Optional batching wait: let the window fill.
        let deadline = clock + cfg.max_wait_s;
        let mut end = next + 1;
        while end < cfg.requests && end - next < cfg.batch_max && arrivals[end] <= deadline {
            end += 1;
        }
        // If we waited for later arrivals, the batch starts at the later of
        // (deadline reached, last included arrival).
        if end - next > 1 {
            clock = clock.max(arrivals[end - 1]);
        }
        let batch_ids: Vec<usize> = (next..end).collect();
        if let Some(c) = controller.as_mut() {
            for &id in &batch_ids {
                c.observe_arrival(arrivals[id]);
            }
        }
        let inputs: Vec<Tensor> = batch_ids
            .iter()
            .map(|_| Tensor::rand(&cfg.input_shape, &mut rng, -1.0, 1.0))
            .collect();

        let t0 = std::time::Instant::now();
        let outputs = exec(plan, &inputs)?;
        let service = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            outputs.len() == inputs.len(),
            "exec_batch returned {} outputs for {} requests",
            outputs.len(),
            inputs.len()
        );
        busy_s += service;
        batches += 1;
        if let Some(c) = controller.as_mut() {
            c.observe_service(plan, service / inputs.len() as f64);
        }
        let start = clock;
        clock += service;
        for &id in &batch_ids {
            records.push(RequestRecord {
                id,
                arrival_s: arrivals[id],
                start_s: start,
                done_s: clock,
                batch_size: batch_ids.len(),
                plan,
            });
        }
        next = end;
    }

    let first = arrivals.first().copied().unwrap_or(0.0);
    Ok(ServeReport {
        span_s: clock - first,
        busy_s,
        batches,
        records,
        plan_cost: None,
        switches: Vec::new(),
        energy_mj_per_request: None,
    })
}

/// Run the serving loop. `exec_batch` performs one real inference batch
/// (one tensor per request) and returns one output per request; its
/// measured wallclock is the service time on the virtual clock.
pub fn serve<F>(cfg: &ServeConfig, mut exec_batch: F) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    run_loop(cfg, None, |_, batch| exec_batch(batch))
}

/// Serve an optimized `(graph, assignment)` plan, annotating the report
/// with the shared [`CostOracle`]'s cost estimate for that plan.
///
/// This is the optimize→serve composition point: the caller hands in the
/// *same* oracle the optimizer searched with (warm profile DB), so the
/// estimate is exactly what the search minimized. Pricing uses only
/// already-available profiles — a cold oracle yields `plan_cost: None`
/// rather than blocking serving startup on measurements.
///
/// ```
/// use eadgo::algo::Assignment;
/// use eadgo::cost::CostOracle;
/// use eadgo::graph::{Graph, OpKind, PortRef};
/// use eadgo::serve::{serve_plan, ServeConfig};
///
/// let oracle = CostOracle::offline_default();
/// let mut g = Graph::new();
/// let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
/// let r = g.add1(OpKind::Relu, &[x], "r");
/// g.outputs = vec![PortRef::of(r)];
/// let a = Assignment::default_for(&g, oracle.reg());
/// oracle.table_for(&g).unwrap(); // warm profiles => estimate attached
///
/// let cfg = ServeConfig { requests: 8, input_shape: vec![1, 3, 8, 8], ..Default::default() };
/// let report = serve_plan(&cfg, &oracle, &g, &a, |batch| {
///     Ok(batch.iter().map(eadgo::tensor::ops::relu).collect())
/// })
/// .unwrap();
/// assert_eq!(report.records.len(), 8);
/// let est = report.plan_cost.expect("oracle is warm");
/// assert_eq!(report.energy_mj_per_request, Some(est.energy_j));
/// ```
pub fn serve_plan<F>(
    cfg: &ServeConfig,
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    exec_batch: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    let plan_cost = oracle.cached_cost(g, a)?;
    let mut report = serve(cfg, exec_batch)?;
    report.plan_cost = plan_cost;
    report.energy_mj_per_request = plan_cost.map(|c| c.energy_j);
    Ok(report)
}

/// Serve a Pareto frontier of plans adaptively: a [`FrontierController`]
/// built over `plan_costs` (fastest-first, as returned by
/// [`PlanFrontier::costs`](crate::search::PlanFrontier::costs)) picks the
/// active plan per batch; `exec` executes one batch under the given
/// frontier index. The report records per-request plans, every switch
/// event, and — when every plan has a positive energy estimate — the
/// oracle-estimated energy per request actually spent.
pub fn serve_frontier<F>(
    cfg: &ServeConfig,
    plan_costs: &[GraphCost],
    policy: &AdaptiveConfig,
    exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    anyhow::ensure!(!plan_costs.is_empty(), "serve_frontier needs at least one plan");
    let mut controller = FrontierController::new(plan_costs.to_vec(), policy.clone());
    let mut report = run_loop(cfg, Some(&mut controller), exec)?;
    report.switches = controller.into_switches();
    if plan_costs.iter().all(|c| c.energy_j > 0.0) && !report.records.is_empty() {
        let total: f64 = report.records.iter().map(|r| plan_costs[r.plan].energy_j).sum();
        report.energy_mj_per_request = Some(total / report.records.len() as f64);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn fast_exec(inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        // trivial real work: elementwise relu per request
        Ok(inputs.iter().map(crate::tensor::ops::relu).collect())
    }

    fn cfg(requests: usize, batch: usize) -> ServeConfig {
        ServeConfig {
            requests,
            batch_max: batch,
            arrival_rate_hz: 10_000.0,
            max_wait_s: 0.001,
            seed: 1,
            input_shape: vec![1, 3, 8, 8],
        }
    }

    #[test]
    fn serves_all_requests_in_order() {
        let report = serve(&cfg(50, 4), fast_exec).unwrap();
        assert_eq!(report.records.len(), 50);
        let ids: Vec<usize> = report.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
        assert!(report.records.iter().all(|r| r.plan == 0));
        assert!(report.switches.is_empty());
    }

    #[test]
    fn time_accounting_consistent() {
        let report = serve(&cfg(40, 4), fast_exec).unwrap();
        for r in &report.records {
            assert!(r.start_s >= r.arrival_s - 1e-12, "start before arrival");
            assert!(r.done_s > r.start_s, "done before start");
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
        }
        assert!(report.throughput_rps() > 0.0);
        assert!(report.latency_summary().p95 >= report.latency_summary().p50);
        assert!(report.latency_summary().p99 >= report.latency_summary().p95);
    }

    #[test]
    fn batching_kicks_in_under_load() {
        // arrival rate far above service rate + generous window -> batches form
        let report = serve(&cfg(64, 8), fast_exec).unwrap();
        assert!(report.mean_batch_size() > 1.0, "mean batch {}", report.mean_batch_size());
        assert!(report.batches < 64);
    }

    #[test]
    fn batch_max_one_disables_batching() {
        let report = serve(&cfg(30, 1), fast_exec).unwrap();
        assert_eq!(report.batches, 30);
        assert!(report.records.iter().all(|r| r.batch_size == 1));
    }

    #[test]
    fn deterministic_arrivals() {
        let a = serve(&cfg(20, 4), fast_exec).unwrap();
        let b = serve(&cfg(20, 4), fast_exec).unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }

    #[test]
    fn serve_plan_shares_oracle_estimate() {
        use crate::graph::{OpKind, PortRef};
        let oracle = crate::cost::CostOracle::offline_default();
        let mut g = crate::graph::Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let r = g.add1(OpKind::Relu, &[x], "r");
        g.outputs = vec![PortRef::of(r)];
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());

        // Cold oracle: serving must not trigger any profiling; no estimate.
        let cold = serve_plan(&cfg(10, 2), &oracle, &g, &a, fast_exec).unwrap();
        assert_eq!(cold.plan_cost, None);
        assert_eq!(cold.energy_mj_per_request, None);
        assert_eq!(oracle.profiled_total(), 0);

        // Warm the oracle (as `serve --optimize` or a loaded DB would).
        oracle.table_for(&g).unwrap();
        let before = oracle.profiled_total();
        let report = serve_plan(&cfg(10, 2), &oracle, &g, &a, fast_exec).unwrap();
        let est = report.plan_cost.expect("estimate attached once warm");
        assert!(est.time_ms > 0.0 && est.energy_j > 0.0);
        assert_eq!(report.energy_mj_per_request, Some(est.energy_j));
        // Pricing the plan measured nothing new.
        assert_eq!(oracle.profiled_total(), before);
    }

    #[test]
    fn exec_errors_propagate() {
        let r = serve(&cfg(5, 2), |_| anyhow::bail!("backend down"));
        assert!(r.is_err());
    }

    #[test]
    fn output_arity_checked() {
        let r = serve(&cfg(5, 2), |_| Ok(vec![]));
        assert!(r.is_err());
    }

    fn frontier_costs() -> Vec<GraphCost> {
        vec![
            GraphCost { time_ms: 1.0, energy_j: 300.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 2.0, energy_j: 180.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 4.0, energy_j: 100.0, freq: FreqId::NOMINAL },
        ]
    }

    #[test]
    fn adaptive_light_load_serves_energy_plan() {
        // 50 req/s against sub-millisecond service: utilization ~0 — the
        // controller must park on the energy-optimal plan (index 2).
        let cfg = ServeConfig { arrival_rate_hz: 50.0, ..cfg(32, 4) };
        let report = serve_frontier(
            &cfg,
            &frontier_costs(),
            &AdaptiveConfig::default(),
            |_, batch| fast_exec(batch),
        )
        .unwrap();
        assert!(report.records.iter().all(|r| r.plan == 2), "{:?}", report.plan_histogram());
        assert!(report.switches.is_empty());
        assert_eq!(report.energy_mj_per_request, Some(100.0));
    }

    #[test]
    fn adaptive_overload_switches_toward_latency_plan() {
        // Execution busy-spins 100µs per request per estimated sim-ms, so
        // at 10k req/s every plan but the fastest is overloaded (util ≥ 2):
        // the queue spikes past the panic threshold within a batch or two
        // and the controller must abandon the energy plan.
        let costs = frontier_costs();
        let report = serve_frontier(
            &cfg(96, 4),
            &costs,
            &AdaptiveConfig::default(),
            |plan, batch| {
                let per_req = 100e-6 * costs[plan].time_ms;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < per_req * batch.len() as f64 {}
                Ok(batch.to_vec())
            },
        )
        .unwrap();
        assert!(!report.switches.is_empty(), "overload must trigger switches");
        assert_eq!(report.records.last().unwrap().plan, 0, "{:?}", report.plan_histogram());
        // Energy accounting reflects the mix of plans actually used: the
        // first batch always runs the energy plan (100 mJ), the overloaded
        // tail the latency plan (300 mJ).
        let e = report.energy_mj_per_request.unwrap();
        assert!(e > 100.0 && e < 300.0, "expected a plan mix, got {e}");
        // Switch log is consistent with the per-record plans.
        for w in report.switches.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
            assert_eq!(w[1].from, w[0].to);
        }
    }

    #[test]
    fn single_point_frontier_acts_like_fixed_plan() {
        let costs = vec![GraphCost { time_ms: 1.0, energy_j: 42.0, freq: FreqId::NOMINAL }];
        let report = serve_frontier(
            &cfg(20, 4),
            &costs,
            &AdaptiveConfig::default(),
            |plan, batch| {
                assert_eq!(plan, 0);
                fast_exec(batch)
            },
        )
        .unwrap();
        assert!(report.switches.is_empty());
        assert_eq!(report.energy_mj_per_request, Some(42.0));
        assert_eq!(report.plan_histogram(), vec![20]);
    }

    #[test]
    fn frontier_loop_matches_plain_serve_arrivals() {
        // The generalized loop must not perturb the RNG stream: arrivals
        // (and thus records) line up with plain `serve` under any plan mix.
        let a = serve(&cfg(24, 4), fast_exec).unwrap();
        let b = serve_frontier(
            &cfg(24, 4),
            &frontier_costs(),
            &AdaptiveConfig::default(),
            |_, batch| fast_exec(batch),
        )
        .unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }
}
