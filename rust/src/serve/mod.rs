//! Serving coordinator: a single-node request loop with Poisson arrivals,
//! FIFO queueing, and dynamic batching — the L3 "thin driver" that puts the
//! optimized `(G, A)` behind a request interface (`eadgo serve`).
//!
//! The loop is a discrete-event simulation driven by *real* service times:
//! request arrivals follow a seeded Poisson process on a virtual clock,
//! while every batch execution is a real engine call whose measured
//! wallclock advances that clock. Latency percentiles therefore reflect
//! genuine compute + queueing behaviour, reproducibly.
//!
//! Four entry points, least to most capable:
//! - [`serve`] — one plan, one `exec_batch` closure.
//! - [`serve_plan`] — one plan, annotated with the shared
//!   [`CostOracle`]'s cost estimate for it.
//! - [`serve_frontier`] — a whole Pareto [`PlanFrontier`] of plans behind
//!   one loop: a [`FrontierController`] watches the live request rate and
//!   queue depth and switches the active plan (energy-optimal under light
//!   load, latency-optimal under pressure, with hysteresis), recording
//!   every switch in [`ServeReport::switches`].
//! - [`serve_operating_points`] — a batched frontier of
//!   ([`OperatingPoint`]) (plan, batch) pairs behind deadline-aware batch
//!   formation: the controller picks an operating point from live queue
//!   depth and EWMA arrival rate, the dispatcher targets that point's
//!   batch size but never holds the oldest pending request past
//!   [`ServeConfig::max_wait_s`] (admission control), and each formed
//!   batch is charged the oracle's price *at its actual size*.
//!
//! Arrival traces are single-rate Poisson by default, or piecewise-rate
//! (bursty) when [`ServeConfig::phases`] is set — see [`trace`].
//!
//! [`PlanFrontier`]: crate::search::PlanFrontier

/// Load-adaptive plan selection over a Pareto frontier.
pub mod controller;
/// Seeded single-rate and piecewise-rate (bursty) Poisson arrival traces.
pub mod trace;

pub use controller::{AdaptiveConfig, FrontierController, PlanSwitchEvent};
pub use trace::RatePhase;

use crate::algo::Assignment;
use crate::cost::{CostOracle, GraphCost};
use crate::graph::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Total requests to serve.
    pub requests: usize,
    /// Maximum batch size the dispatcher may form.
    pub batch_max: usize,
    /// Mean arrival rate (requests/second) of the Poisson process.
    pub arrival_rate_hz: f64,
    /// How long the dispatcher waits to fill a batch once one request is
    /// pending, seconds (0 = greedy: serve whatever is queued).
    pub max_wait_s: f64,
    /// RNG seed for arrivals and request payloads.
    pub seed: u64,
    /// Input tensor shape per request.
    pub input_shape: Vec<usize>,
    /// Piecewise-rate arrival phases for bursty traces. Empty = the
    /// single-rate Poisson process (`arrival_rate_hz` × `requests`,
    /// bit-identical to the pre-trace behavior); non-empty = the phases
    /// define both the rates and the total request count, and
    /// `requests`/`arrival_rate_hz` are ignored.
    pub phases: Vec<RatePhase>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 64,
            batch_max: 4,
            arrival_rate_hz: 500.0,
            max_wait_s: 0.002,
            seed: 2026,
            input_shape: vec![1, 3, 32, 32],
            phases: Vec::new(),
        }
    }
}

impl ServeConfig {
    /// Total requests this config serves: the sum of phase sizes when a
    /// bursty trace is configured, else `requests`.
    pub fn effective_requests(&self) -> usize {
        if self.phases.is_empty() {
            self.requests
        } else {
            self.phases.iter().map(|p| p.requests).sum()
        }
    }

    /// Draw the arrival trace for this config from `rng`. Single-rate
    /// configs reproduce the historical inline draw bit-for-bit.
    fn arrival_trace(&self, rng: &mut Rng) -> anyhow::Result<Vec<f64>> {
        if self.phases.is_empty() {
            anyhow::ensure!(self.requests > 0, "requests must be > 0");
            anyhow::ensure!(self.arrival_rate_hz > 0.0, "arrival rate must be > 0");
            Ok(trace::poisson_arrivals(rng, 0.0, self.arrival_rate_hz, self.requests))
        } else {
            for p in &self.phases {
                anyhow::ensure!(
                    p.rate_hz > 0.0 && p.rate_hz.is_finite(),
                    "phase rate must be a positive finite rate, got {}",
                    p.rate_hz
                );
                anyhow::ensure!(p.requests > 0, "phase request count must be > 0");
            }
            Ok(trace::piecewise_arrivals(rng, &self.phases))
        }
    }
}

/// Per-request accounting (times on the virtual clock, seconds).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    /// Request index in arrival order.
    pub id: usize,
    /// Arrival time on the virtual clock.
    pub arrival_s: f64,
    /// When the batch containing this request started executing.
    pub start_s: f64,
    /// When the batch completed.
    pub done_s: f64,
    /// Size of the batch that served this request.
    pub batch_size: usize,
    /// Frontier index of the plan that served this request (0 for
    /// single-plan serving; the *operating-point* index under
    /// [`serve_operating_points`]).
    pub plan: usize,
}

impl RequestRecord {
    /// End-to-end latency: completion minus arrival.
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }

    /// Time spent queued before execution started.
    pub fn queue_delay_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// Aggregated serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request accounting, in arrival order.
    pub records: Vec<RequestRecord>,
    /// Total virtual time from first arrival to last completion.
    pub span_s: f64,
    /// Real wallclock spent inside the engine.
    pub busy_s: f64,
    /// Number of batches executed.
    pub batches: usize,
    /// The cost oracle's estimate for the served plan (per inference),
    /// when serving went through [`serve_plan`] with a shared oracle.
    pub plan_cost: Option<GraphCost>,
    /// Plan switches taken by the [`FrontierController`] (empty for
    /// fixed-plan serving).
    pub switches: Vec<PlanSwitchEvent>,
    /// Oracle-estimated energy per request in mJ, averaged over the plans
    /// that actually served each request (`None` when no estimate is
    /// available).
    pub energy_mj_per_request: Option<f64>,
}

impl ServeReport {
    /// Latency summary (p50/p95/p99/mean) over all requests.
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.records.iter().map(RequestRecord::latency_s).collect::<Vec<_>>())
    }

    /// Served throughput over the serving span (first arrival to last
    /// completion), requests/second.
    pub fn throughput_rps(&self) -> f64 {
        if self.span_s > 0.0 {
            self.records.len() as f64 / self.span_s
        } else {
            0.0
        }
    }

    /// Oracle-estimated served requests per joule (the ablation's energy
    /// efficiency metric; `None` without an energy estimate).
    pub fn requests_per_joule(&self) -> Option<f64> {
        match self.energy_mj_per_request {
            Some(mj) if mj > 0.0 => Some(1000.0 / mj),
            _ => None,
        }
    }

    /// Average formed batch size.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches > 0 {
            self.records.len() as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    /// Requests served per frontier plan index (length = max plan + 1).
    pub fn plan_histogram(&self) -> Vec<usize> {
        let n = self.records.iter().map(|r| r.plan + 1).max().unwrap_or(0);
        let mut counts = vec![0usize; n];
        for r in &self.records {
            counts[r.plan] += 1;
        }
        counts
    }

    /// Human-readable plan distribution, e.g. `"p0×12 p2×52"` (plans that
    /// served no request are omitted).
    pub fn plan_distribution(&self) -> String {
        self.plan_histogram()
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(i, n)| format!("p{i}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// The shared serving loop behind [`serve`] and [`serve_frontier`]: with
/// no controller every batch runs plan 0 and the behaviour (and RNG
/// stream) is bit-identical to the pre-frontier loop.
fn run_loop<F>(
    cfg: &ServeConfig,
    mut controller: Option<&mut FrontierController>,
    mut exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    anyhow::ensure!(cfg.batch_max > 0, "batch_max must be > 0");

    let mut rng = Rng::seed_from(cfg.seed);
    // Poisson arrivals (single- or piecewise-rate), drawn before any
    // payload so the RNG stream matches the historical inline draw.
    let arrivals = cfg.arrival_trace(&mut rng)?;
    let total = arrivals.len();

    let mut records: Vec<RequestRecord> = Vec::with_capacity(total);
    let mut clock = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut batches = 0usize;
    let mut next = 0usize; // next unserved request index

    while next < total {
        // Advance to the first pending arrival if idle.
        clock = clock.max(arrivals[next]);
        // The controller decides on the live queue depth at this instant:
        // every request that has arrived but not been served.
        let plan = match controller.as_mut() {
            Some(c) => {
                let mut depth = 1usize;
                while next + depth < total && arrivals[next + depth] <= clock {
                    depth += 1;
                }
                c.decide(clock, depth)
            }
            None => 0,
        };
        // Optional batching wait: let the window fill.
        let deadline = clock + cfg.max_wait_s;
        let mut end = next + 1;
        while end < total && end - next < cfg.batch_max && arrivals[end] <= deadline {
            end += 1;
        }
        // If we waited for later arrivals, the batch starts at the later of
        // (deadline reached, last included arrival).
        if end - next > 1 {
            clock = clock.max(arrivals[end - 1]);
        }
        let batch_ids: Vec<usize> = (next..end).collect();
        if let Some(c) = controller.as_mut() {
            for &id in &batch_ids {
                c.observe_arrival(arrivals[id]);
            }
        }
        let inputs: Vec<Tensor> = batch_ids
            .iter()
            .map(|_| Tensor::rand(&cfg.input_shape, &mut rng, -1.0, 1.0))
            .collect();

        let t0 = std::time::Instant::now();
        let outputs = exec(plan, &inputs)?;
        let service = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            outputs.len() == inputs.len(),
            "exec_batch returned {} outputs for {} requests",
            outputs.len(),
            inputs.len()
        );
        busy_s += service;
        batches += 1;
        if let Some(c) = controller.as_mut() {
            c.observe_service(plan, service / inputs.len() as f64);
        }
        let start = clock;
        clock += service;
        for &id in &batch_ids {
            records.push(RequestRecord {
                id,
                arrival_s: arrivals[id],
                start_s: start,
                done_s: clock,
                batch_size: batch_ids.len(),
                plan,
            });
        }
        next = end;
    }

    let first = arrivals.first().copied().unwrap_or(0.0);
    Ok(ServeReport {
        span_s: clock - first,
        busy_s,
        batches,
        records,
        plan_cost: None,
        switches: Vec::new(),
        energy_mj_per_request: None,
    })
}

/// Run the serving loop. `exec_batch` performs one real inference batch
/// (one tensor per request) and returns one output per request; its
/// measured wallclock is the service time on the virtual clock.
pub fn serve<F>(cfg: &ServeConfig, mut exec_batch: F) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    run_loop(cfg, None, |_, batch| exec_batch(batch))
}

/// Serve an optimized `(graph, assignment)` plan, annotating the report
/// with the shared [`CostOracle`]'s cost estimate for that plan.
///
/// This is the optimize→serve composition point: the caller hands in the
/// *same* oracle the optimizer searched with (warm profile DB), so the
/// estimate is exactly what the search minimized. Pricing uses only
/// already-available profiles — a cold oracle yields `plan_cost: None`
/// rather than blocking serving startup on measurements.
///
/// ```
/// use eadgo::algo::Assignment;
/// use eadgo::cost::CostOracle;
/// use eadgo::graph::{Graph, OpKind, PortRef};
/// use eadgo::serve::{serve_plan, ServeConfig};
///
/// let oracle = CostOracle::offline_default();
/// let mut g = Graph::new();
/// let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
/// let r = g.add1(OpKind::Relu, &[x], "r");
/// g.outputs = vec![PortRef::of(r)];
/// let a = Assignment::default_for(&g, oracle.reg());
/// oracle.table_for(&g).unwrap(); // warm profiles => estimate attached
///
/// let cfg = ServeConfig { requests: 8, input_shape: vec![1, 3, 8, 8], ..Default::default() };
/// let report = serve_plan(&cfg, &oracle, &g, &a, |batch| {
///     Ok(batch.iter().map(eadgo::tensor::ops::relu).collect())
/// })
/// .unwrap();
/// assert_eq!(report.records.len(), 8);
/// let est = report.plan_cost.expect("oracle is warm");
/// assert_eq!(report.energy_mj_per_request, Some(est.energy_j));
/// ```
pub fn serve_plan<F>(
    cfg: &ServeConfig,
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    exec_batch: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(&[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    let plan_cost = oracle.cached_cost(g, a)?;
    let mut report = serve(cfg, exec_batch)?;
    report.plan_cost = plan_cost;
    report.energy_mj_per_request = plan_cost.map(|c| c.energy_j);
    Ok(report)
}

/// Serve a Pareto frontier of plans adaptively: a [`FrontierController`]
/// built over `plan_costs` (fastest-first, as returned by
/// [`PlanFrontier::costs`](crate::search::PlanFrontier::costs)) picks the
/// active plan per batch; `exec` executes one batch under the given
/// frontier index. The report records per-request plans, every switch
/// event, and — when every plan has a positive energy estimate — the
/// oracle-estimated energy per request actually spent.
pub fn serve_frontier<F>(
    cfg: &ServeConfig,
    plan_costs: &[GraphCost],
    policy: &AdaptiveConfig,
    exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    anyhow::ensure!(!plan_costs.is_empty(), "serve_frontier needs at least one plan");
    let mut controller = FrontierController::new(plan_costs.to_vec(), policy.clone());
    let mut report = run_loop(cfg, Some(&mut controller), exec)?;
    report.switches = controller.into_switches();
    if plan_costs.iter().all(|c| c.energy_j > 0.0) && !report.records.is_empty() {
        let total: f64 = report.records.iter().map(|r| plan_costs[r.plan].energy_j).sum();
        report.energy_mj_per_request = Some(total / report.records.len() as f64);
    }
    Ok(report)
}

/// One (plan, batch) point on a batched frontier: the frontier plan index
/// to execute and the batch size the dispatcher targets while the point
/// is active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperatingPoint {
    /// Plan index into the price grid's outer axis (and the `exec`
    /// closure's first argument).
    pub plan: usize,
    /// Target batch size at this point (>= 1; capped by
    /// [`ServeConfig::batch_max`] at serve time).
    pub batch: usize,
}

/// Serve a batched frontier of (plan, batch) operating points with
/// deadline-aware batch formation and admission control.
///
/// `grid[p][m - 1]` is the oracle's **full-batch** cost of executing plan
/// `p` at batch size `m` (as priced by
/// [`price_plan_at_batch`](crate::search::price_plan_at_batch)); each
/// plan's grid must cover every batch size its operating points can form.
/// A [`FrontierController`] in operating-point mode picks the active
/// point per batch from the live queue depth and EWMA arrival rate.
///
/// Two properties distinguish this loop from [`serve_frontier`]'s greedy
/// batching:
/// - **Admission control**: the batch-fill horizon is anchored at the
///   *oldest pending request's arrival* — a request that already waited
///   `w` seconds gets at most `max_wait_s - w` more, so backlogged
///   batches never stall further just because a big-batch point is
///   active.
/// - **Honest partial-batch pricing**: a formed batch of `m` requests is
///   charged `grid[plan][m - 1]`, not the active point's ideal amortized
///   cost — underfilled batches earn no phantom efficiency.
///
/// [`RequestRecord::plan`] and the switch log index into `ops` (operating
/// points), while `exec` receives the underlying *plan* index.
pub fn serve_operating_points<F>(
    cfg: &ServeConfig,
    grid: &[Vec<GraphCost>],
    ops: &[OperatingPoint],
    policy: &AdaptiveConfig,
    mut exec: F,
) -> anyhow::Result<ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
{
    anyhow::ensure!(cfg.batch_max > 0, "batch_max must be > 0");
    anyhow::ensure!(!ops.is_empty(), "serve_operating_points needs at least one operating point");
    for op in ops {
        anyhow::ensure!(op.batch >= 1, "operating-point batch must be >= 1");
        anyhow::ensure!(
            op.plan < grid.len(),
            "operating point references plan {} but the grid prices {} plans",
            op.plan,
            grid.len()
        );
        let have = grid[op.plan].len();
        anyhow::ensure!(
            op.batch.min(cfg.batch_max) <= have,
            "plan {} is priced for batches 1..={have}, operating point targets batch {}",
            op.plan,
            op.batch.min(cfg.batch_max)
        );
    }
    // The controller sees each point's *effective* batch (capped by the
    // dispatcher limit) and the full-batch cost at that size, so its
    // per-request estimates match what this loop can actually form.
    let batches: Vec<usize> = ops.iter().map(|o| o.batch.min(cfg.batch_max)).collect();
    let est: Vec<GraphCost> =
        ops.iter().zip(&batches).map(|(o, &b)| grid[o.plan][b - 1]).collect();
    let mut controller =
        FrontierController::for_operating_points(est, batches.clone(), policy.clone());

    let mut rng = Rng::seed_from(cfg.seed);
    let arrivals = cfg.arrival_trace(&mut rng)?;
    let total = arrivals.len();

    let mut records: Vec<RequestRecord> = Vec::with_capacity(total);
    let mut clock = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut n_batches = 0usize;
    let mut energy_mj = 0.0f64;
    let mut next = 0usize;

    while next < total {
        clock = clock.max(arrivals[next]);
        let mut depth = 1usize;
        while next + depth < total && arrivals[next + depth] <= clock {
            depth += 1;
        }
        let op = controller.decide(clock, depth);
        let target = batches[op];
        // Admission control: anchor the fill horizon at the oldest
        // pending request's arrival, never extending a wait already
        // served out (`max(.., clock)` only admits what has *already*
        // arrived by now — it adds no further stalling).
        let horizon = (arrivals[next] + cfg.max_wait_s).max(clock);
        let mut end = next + 1;
        while end < total && end - next < target && arrivals[end] <= horizon {
            end += 1;
        }
        if end - next > 1 {
            clock = clock.max(arrivals[end - 1]);
        }
        let batch_ids: Vec<usize> = (next..end).collect();
        for &id in &batch_ids {
            controller.observe_arrival(arrivals[id]);
        }
        let inputs: Vec<Tensor> = batch_ids
            .iter()
            .map(|_| Tensor::rand(&cfg.input_shape, &mut rng, -1.0, 1.0))
            .collect();

        let t0 = std::time::Instant::now();
        let outputs = exec(ops[op].plan, &inputs)?;
        let service = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            outputs.len() == inputs.len(),
            "exec_batch returned {} outputs for {} requests",
            outputs.len(),
            inputs.len()
        );
        busy_s += service;
        n_batches += 1;
        controller.observe_service(op, service / inputs.len() as f64);
        // Honest partial-batch pricing: charge the plan at the batch size
        // actually formed.
        energy_mj += grid[ops[op].plan][inputs.len() - 1].energy_j;
        let start = clock;
        clock += service;
        for &id in &batch_ids {
            records.push(RequestRecord {
                id,
                arrival_s: arrivals[id],
                start_s: start,
                done_s: clock,
                batch_size: batch_ids.len(),
                plan: op,
            });
        }
        next = end;
    }

    let first = arrivals.first().copied().unwrap_or(0.0);
    Ok(ServeReport {
        span_s: clock - first,
        busy_s,
        batches: n_batches,
        records,
        plan_cost: None,
        switches: controller.into_switches(),
        energy_mj_per_request: if energy_mj > 0.0 && total > 0 {
            Some(energy_mj / total as f64)
        } else {
            None
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn fast_exec(inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        // trivial real work: elementwise relu per request
        Ok(inputs.iter().map(crate::tensor::ops::relu).collect())
    }

    fn cfg(requests: usize, batch: usize) -> ServeConfig {
        ServeConfig {
            requests,
            batch_max: batch,
            arrival_rate_hz: 10_000.0,
            max_wait_s: 0.001,
            seed: 1,
            input_shape: vec![1, 3, 8, 8],
            phases: Vec::new(),
        }
    }

    #[test]
    fn serves_all_requests_in_order() {
        let report = serve(&cfg(50, 4), fast_exec).unwrap();
        assert_eq!(report.records.len(), 50);
        let ids: Vec<usize> = report.records.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
        assert!(report.records.iter().all(|r| r.plan == 0));
        assert!(report.switches.is_empty());
    }

    #[test]
    fn time_accounting_consistent() {
        let report = serve(&cfg(40, 4), fast_exec).unwrap();
        for r in &report.records {
            assert!(r.start_s >= r.arrival_s - 1e-12, "start before arrival");
            assert!(r.done_s > r.start_s, "done before start");
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
        }
        assert!(report.throughput_rps() > 0.0);
        assert!(report.latency_summary().p95 >= report.latency_summary().p50);
        assert!(report.latency_summary().p99 >= report.latency_summary().p95);
    }

    #[test]
    fn batching_kicks_in_under_load() {
        // arrival rate far above service rate + generous window -> batches form
        let report = serve(&cfg(64, 8), fast_exec).unwrap();
        assert!(report.mean_batch_size() > 1.0, "mean batch {}", report.mean_batch_size());
        assert!(report.batches < 64);
    }

    #[test]
    fn batch_max_one_disables_batching() {
        let report = serve(&cfg(30, 1), fast_exec).unwrap();
        assert_eq!(report.batches, 30);
        assert!(report.records.iter().all(|r| r.batch_size == 1));
    }

    #[test]
    fn deterministic_arrivals() {
        let a = serve(&cfg(20, 4), fast_exec).unwrap();
        let b = serve(&cfg(20, 4), fast_exec).unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }

    #[test]
    fn serve_plan_shares_oracle_estimate() {
        use crate::graph::{OpKind, PortRef};
        let oracle = crate::cost::CostOracle::offline_default();
        let mut g = crate::graph::Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let r = g.add1(OpKind::Relu, &[x], "r");
        g.outputs = vec![PortRef::of(r)];
        let a = crate::algo::Assignment::default_for(&g, oracle.reg());

        // Cold oracle: serving must not trigger any profiling; no estimate.
        let cold = serve_plan(&cfg(10, 2), &oracle, &g, &a, fast_exec).unwrap();
        assert_eq!(cold.plan_cost, None);
        assert_eq!(cold.energy_mj_per_request, None);
        assert_eq!(oracle.profiled_total(), 0);

        // Warm the oracle (as `serve --optimize` or a loaded DB would).
        oracle.table_for(&g).unwrap();
        let before = oracle.profiled_total();
        let report = serve_plan(&cfg(10, 2), &oracle, &g, &a, fast_exec).unwrap();
        let est = report.plan_cost.expect("estimate attached once warm");
        assert!(est.time_ms > 0.0 && est.energy_j > 0.0);
        assert_eq!(report.energy_mj_per_request, Some(est.energy_j));
        // Pricing the plan measured nothing new.
        assert_eq!(oracle.profiled_total(), before);
    }

    #[test]
    fn exec_errors_propagate() {
        let r = serve(&cfg(5, 2), |_| anyhow::bail!("backend down"));
        assert!(r.is_err());
    }

    #[test]
    fn output_arity_checked() {
        let r = serve(&cfg(5, 2), |_| Ok(vec![]));
        assert!(r.is_err());
    }

    fn frontier_costs() -> Vec<GraphCost> {
        vec![
            GraphCost { time_ms: 1.0, energy_j: 300.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 2.0, energy_j: 180.0, freq: FreqId::NOMINAL },
            GraphCost { time_ms: 4.0, energy_j: 100.0, freq: FreqId::NOMINAL },
        ]
    }

    #[test]
    fn adaptive_light_load_serves_energy_plan() {
        // 50 req/s against sub-millisecond service: utilization ~0 — the
        // controller must park on the energy-optimal plan (index 2).
        let cfg = ServeConfig { arrival_rate_hz: 50.0, ..cfg(32, 4) };
        let report = serve_frontier(
            &cfg,
            &frontier_costs(),
            &AdaptiveConfig::default(),
            |_, batch| fast_exec(batch),
        )
        .unwrap();
        assert!(report.records.iter().all(|r| r.plan == 2), "{:?}", report.plan_histogram());
        assert!(report.switches.is_empty());
        assert_eq!(report.energy_mj_per_request, Some(100.0));
    }

    #[test]
    fn adaptive_overload_switches_toward_latency_plan() {
        // Execution busy-spins 100µs per request per estimated sim-ms, so
        // at 10k req/s every plan but the fastest is overloaded (util ≥ 2):
        // the queue spikes past the panic threshold within a batch or two
        // and the controller must abandon the energy plan.
        let costs = frontier_costs();
        let report = serve_frontier(
            &cfg(96, 4),
            &costs,
            &AdaptiveConfig::default(),
            |plan, batch| {
                let per_req = 100e-6 * costs[plan].time_ms;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < per_req * batch.len() as f64 {}
                Ok(batch.to_vec())
            },
        )
        .unwrap();
        assert!(!report.switches.is_empty(), "overload must trigger switches");
        assert_eq!(report.records.last().unwrap().plan, 0, "{:?}", report.plan_histogram());
        // Energy accounting reflects the mix of plans actually used: the
        // first batch always runs the energy plan (100 mJ), the overloaded
        // tail the latency plan (300 mJ).
        let e = report.energy_mj_per_request.unwrap();
        assert!(e > 100.0 && e < 300.0, "expected a plan mix, got {e}");
        // Switch log is consistent with the per-record plans.
        for w in report.switches.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
            assert_eq!(w[1].from, w[0].to);
        }
    }

    #[test]
    fn single_point_frontier_acts_like_fixed_plan() {
        let costs = vec![GraphCost { time_ms: 1.0, energy_j: 42.0, freq: FreqId::NOMINAL }];
        let report = serve_frontier(
            &cfg(20, 4),
            &costs,
            &AdaptiveConfig::default(),
            |plan, batch| {
                assert_eq!(plan, 0);
                fast_exec(batch)
            },
        )
        .unwrap();
        assert!(report.switches.is_empty());
        assert_eq!(report.energy_mj_per_request, Some(42.0));
        assert_eq!(report.plan_histogram(), vec![20]);
    }

    #[test]
    fn frontier_loop_matches_plain_serve_arrivals() {
        // The generalized loop must not perturb the RNG stream: arrivals
        // (and thus records) line up with plain `serve` under any plan mix.
        let a = serve(&cfg(24, 4), fast_exec).unwrap();
        let b = serve_frontier(
            &cfg(24, 4),
            &frontier_costs(),
            &AdaptiveConfig::default(),
            |_, batch| fast_exec(batch),
        )
        .unwrap();
        let arr_a: Vec<f64> = a.records.iter().map(|r| r.arrival_s).collect();
        let arr_b: Vec<f64> = b.records.iter().map(|r| r.arrival_s).collect();
        assert_eq!(arr_a, arr_b);
    }

    #[test]
    fn bursty_trace_is_deterministic_and_ordered() {
        let cfg = ServeConfig {
            phases: vec![RatePhase::new(200.0, 16), RatePhase::new(5_000.0, 32)],
            ..cfg(1, 4)
        };
        let a = serve(&cfg, fast_exec).unwrap();
        let b = serve(&cfg, fast_exec).unwrap();
        assert_eq!(a.records.len(), 48, "phases override `requests`");
        assert_eq!(cfg.effective_requests(), 48);
        let bits =
            |r: &ServeReport| r.records.iter().map(|x| x.arrival_s.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "same seed must draw the same bursty trace");
        assert!(a.records.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn invalid_phases_rejected() {
        let zero_rate = ServeConfig { phases: vec![RatePhase::new(0.0, 4)], ..cfg(8, 2) };
        assert!(serve(&zero_rate, fast_exec).is_err());
        let zero_reqs = ServeConfig { phases: vec![RatePhase::new(100.0, 0)], ..cfg(8, 2) };
        assert!(serve(&zero_reqs, fast_exec).is_err());
    }

    /// Per-plan batch price grids (batch 1..=8): plan 0 fast/hungry,
    /// plan 1 slow/frugal. Batch latency grows sublinearly, so energy per
    /// request amortizes with batch (launch-overhead-dominated regime).
    fn ops_grid() -> Vec<Vec<GraphCost>> {
        let price = |t1: f64, e1: f64| -> Vec<GraphCost> {
            (1..=8)
                .map(|m| {
                    let s = 0.875 + 0.125 * m as f64;
                    GraphCost { time_ms: t1 * s, energy_j: e1 * s, freq: FreqId::NOMINAL }
                })
                .collect()
        };
        vec![price(1.0, 300.0), price(4.0, 100.0)]
    }

    #[test]
    fn ops_light_load_parks_on_cheapest_point() {
        let cfg = ServeConfig { arrival_rate_hz: 50.0, ..cfg(32, 8) };
        let ops = [OperatingPoint { plan: 0, batch: 1 }, OperatingPoint { plan: 1, batch: 8 }];
        let report =
            serve_operating_points(&cfg, &ops_grid(), &ops, &AdaptiveConfig::default(), |plan, b| {
                assert!(plan <= 1);
                fast_exec(b)
            })
            .unwrap();
        assert!(report.records.iter().all(|r| r.plan == 1), "{:?}", report.plan_histogram());
        assert!(report.switches.is_empty());
        // Honest partial-batch pricing: at 50 req/s no batch fills, so the
        // batched point earns no amortization — every batch is charged the
        // plan's batch-1 price (100 mJ), not the ideal 23.4 mJ/request.
        assert_eq!(report.energy_mj_per_request, Some(100.0));
        assert!((report.requests_per_joule().unwrap() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn ops_batch_wait_is_bounded_by_max_wait() {
        // Poisson @ 500/s with a 5 ms window and a batch-8 target: batches
        // form, but the oldest request in every batch waits at most
        // max_wait (plus engine wallclock, microscopic for fast_exec).
        let cfg = ServeConfig { arrival_rate_hz: 500.0, max_wait_s: 0.005, ..cfg(64, 8) };
        let ops = [OperatingPoint { plan: 1, batch: 8 }];
        let report =
            serve_operating_points(&cfg, &ops_grid(), &ops, &AdaptiveConfig::default(), |_, b| {
                fast_exec(b)
            })
            .unwrap();
        assert!(report.mean_batch_size() > 1.5, "window must batch: {}", report.mean_batch_size());
        let mut seen_start = f64::NEG_INFINITY;
        for r in &report.records {
            if r.start_s != seen_start {
                // First record of each batch = its oldest request.
                seen_start = r.start_s;
                assert!(
                    r.queue_delay_s() <= cfg.max_wait_s + report.busy_s + 1e-9,
                    "oldest request in a batch waited {}s",
                    r.queue_delay_s()
                );
            }
        }
    }

    #[test]
    fn ops_bursty_load_batches_on_capacity_point() {
        // Calm → burst → calm. The batched point is both cheapest per
        // request and highest-capacity here, so the controller starts and
        // stays there; the burst fills its batches.
        let cfg = ServeConfig {
            phases: vec![
                RatePhase::new(100.0, 8),
                RatePhase::new(20_000.0, 80),
                RatePhase::new(100.0, 8),
            ],
            max_wait_s: 0.002,
            ..cfg(1, 8)
        };
        let grid = ops_grid();
        let ops = [OperatingPoint { plan: 0, batch: 1 }, OperatingPoint { plan: 1, batch: 8 }];
        let report =
            serve_operating_points(&cfg, &grid, &ops, &AdaptiveConfig::default(), |plan, batch| {
                // Busy-spin 50 µs per estimated sim-ms of the formed batch.
                let per_batch = 50e-6 * grid[plan][batch.len() - 1].time_ms;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < per_batch {}
                Ok(batch.to_vec())
            })
            .unwrap();
        assert_eq!(report.records.len(), 96);
        assert!(report.records.iter().all(|r| r.plan == 1), "{:?}", report.plan_histogram());
        assert!(report.mean_batch_size() > 1.2, "burst must batch: {}", report.mean_batch_size());
    }

    #[test]
    fn ops_single_point_acts_like_fixed_plan() {
        let ops = [OperatingPoint { plan: 0, batch: 1 }];
        let report =
            serve_operating_points(&cfg(20, 4), &ops_grid(), &ops, &AdaptiveConfig::default(), |plan, b| {
                assert_eq!(plan, 0);
                fast_exec(b)
            })
            .unwrap();
        assert!(report.switches.is_empty());
        assert_eq!(report.batches, 20, "batch-1 target disables batching");
        assert_eq!(report.plan_histogram(), vec![20]);
        assert_eq!(report.energy_mj_per_request, Some(300.0));
    }

    #[test]
    fn ops_validation_rejects_bad_points() {
        let grid = ops_grid();
        let c = cfg(8, 4);
        let pol = AdaptiveConfig::default();
        assert!(serve_operating_points(&c, &grid, &[], &pol, |_, b| fast_exec(b)).is_err());
        let bad_plan = [OperatingPoint { plan: 9, batch: 1 }];
        assert!(serve_operating_points(&c, &grid, &bad_plan, &pol, |_, b| fast_exec(b)).is_err());
        let bad_batch = [OperatingPoint { plan: 0, batch: 0 }];
        assert!(serve_operating_points(&c, &grid, &bad_batch, &pol, |_, b| fast_exec(b)).is_err());
        // Effective batch (after the batch_max cap) must be priced.
        let too_deep = [OperatingPoint { plan: 0, batch: 9 }];
        let wide = ServeConfig { batch_max: 16, ..c };
        assert!(serve_operating_points(&wide, &grid, &too_deep, &pol, |_, b| fast_exec(b)).is_err());
    }
}
